"""AOT pipeline contract tests: manifest consistency and HLO-text validity.

These validate the artifacts the Rust runtime consumes (skipped if `make
artifacts` has not been run yet).
"""

import json
import os

import numpy as np
import pytest

from compile import aot, configs

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifest(name):
    path = os.path.join(ART, name, "manifest.json")
    if not os.path.exists(path):
        pytest.skip(f"{path} missing (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


@pytest.mark.parametrize("name", ["nano", "small", "e2e"])
def test_manifest_matches_configs(name):
    m = _manifest(name)
    cfg = configs.CONFIGS[name]
    assert m["num_params"] == configs.num_params(cfg)
    assert m["config"]["vocab"] == cfg["vocab"]
    assert m["metric_names"] == configs.METRIC_NAMES
    ts = configs.train_state_layout(cfg)
    assert m["train_state"]["total"] == ts["total"]
    # layout offsets are contiguous and cover num_params
    off = 0
    for entry in m["param_layout"]:
        assert entry["offset"] == off
        off += int(np.prod(entry["shape"]))
    assert off == m["num_params"]


@pytest.mark.parametrize("name", ["nano", "small", "e2e"])
def test_artifact_io_shapes_match_defs(name):
    m = _manifest(name)
    cfg = configs.CONFIGS[name]
    defs = aot.artifact_defs(cfg)
    for art_name, defn in defs.items():
        art = m["artifacts"][art_name]
        want_inputs = [
            {"name": n, "shape": list(s), "dtype": d} for n, s, d in defn["inputs"]
        ]
        assert art["inputs"] == want_inputs, art_name
        assert tuple(art["output"]["shape"]) == defn["output"][0]


@pytest.mark.parametrize("name", ["nano", "small", "e2e"])
def test_hlo_files_exist_and_are_hlo_text(name):
    m = _manifest(name)
    base = os.path.join(ART, name)
    for art_name, art in m["artifacts"].items():
        path = os.path.join(base, art["file"])
        assert os.path.exists(path), art_name
        with open(path) as f:
            head = f.read(200)
        assert head.startswith("HloModule"), f"{art_name} is not HLO text"


@pytest.mark.parametrize("name", ["nano", "small", "e2e"])
def test_init_checkpoint_matches_python_init(name):
    m = _manifest(name)
    cfg = configs.CONFIGS[name]
    path = os.path.join(ART, name, "init_params.bin")
    data = np.fromfile(path, dtype="<f4")
    assert data.shape == (m["num_params"],)
    from compile import model

    want = np.asarray(model.init_params(cfg, seed=0))
    np.testing.assert_array_equal(data, want)


def test_fig5_variants_present_for_small():
    m = _manifest("small")
    for b in m["fig5"]["train_batches"]:
        assert f"fig5_train_b{b}" in m["artifacts"]
    for b in m["fig5"]["gen_batches"]:
        art = m["artifacts"][f"fig5_gen_b{b}"]
        assert art["inputs"][1]["shape"][0] == b
