"""L2 correctness: the model graphs that become the AOT artifacts.

All on the `nano` config (compiles/runs in seconds on CPU).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model

CFG = configs.CONFIGS["nano"]
P = configs.num_params(CFG)


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, seed=0)


def _random_prompts(rng, b, lo=2, hi=8):
    s = CFG["max_seq"]
    tokens = np.zeros((b, s), np.int32)
    lens = rng.integers(lo, hi, b).astype(np.int32)
    for i in range(b):
        tokens[i, : lens[i]] = rng.integers(3, CFG["vocab"], lens[i])
    return tokens, lens


def _gen(params, tokens, lens, frozen, seed=0, temp=1.0, top_k=0):
    return np.asarray(
        jax.jit(lambda *a: model.generate_chunk(CFG, *a))(
            params,
            jnp.asarray(tokens),
            jnp.asarray(lens),
            jnp.asarray(frozen, jnp.int32),
            jnp.asarray([seed], jnp.int32),
            jnp.asarray([temp], jnp.float32),
            jnp.asarray([top_k], jnp.int32),
        )
    )


def test_param_layout_and_count(params):
    assert params.shape == (P,)
    lay = configs.param_layout(CFG)
    total = sum(int(np.prod(s)) for _, s in lay)
    assert total == P
    # unflatten round-trips every element exactly once
    up = model.unflatten_params(CFG, params)
    cat = jnp.concatenate([up[n].reshape(-1) for n, _ in lay])
    np.testing.assert_array_equal(cat, params)


def test_forward_full_shapes(params):
    up = model.unflatten_params(CFG, params)
    b, t = 3, CFG["max_seq"]
    tokens = jnp.zeros((b, t), jnp.int32)
    lens = jnp.asarray([4, 9, t], jnp.int32)
    logits = model.forward_full(CFG, up, tokens, lens)
    assert logits.shape == (b, t, CFG["vocab"])
    logits2, kv_k, kv_v = model.forward_full(CFG, up, tokens, lens, return_kv=True)
    assert kv_k.shape == (CFG["n_layers"], b, CFG["n_heads"], CFG["max_seq"], CFG["d_head"])
    np.testing.assert_allclose(logits, logits2, rtol=1e-6)


def test_causality(params):
    """Changing a future token must not change past logits."""
    up = model.unflatten_params(CFG, params)
    rng = np.random.default_rng(0)
    t = CFG["max_seq"]
    tokens = rng.integers(3, CFG["vocab"], (1, t)).astype(np.int32)
    lens = jnp.asarray([t], jnp.int32)
    l1 = model.forward_full(CFG, up, jnp.asarray(tokens), lens)
    tokens2 = tokens.copy()
    tokens2[0, 10:] = 3
    l2 = model.forward_full(CFG, up, jnp.asarray(tokens2), lens)
    np.testing.assert_allclose(l1[0, :10], l2[0, :10], rtol=1e-5, atol=1e-5)
    assert not np.allclose(l1[0, 10:], l2[0, 10:], rtol=1e-3, atol=1e-3)


def test_generate_chunk_basic(params):
    rng = np.random.default_rng(1)
    b, c, s = CFG["gen_batch"], CFG["gen_chunk"], CFG["max_seq"]
    tokens, lens = _random_prompts(rng, b)
    out = _gen(params, tokens, lens, np.zeros(b, np.int32), seed=5)
    assert out.shape == (b, 2 * c + 2)
    toks, new_len, done = out[:, :c], out[:, 2 * c], out[:, 2 * c + 1]
    assert (new_len >= lens).all() and (new_len <= s).all()
    assert np.all(toks == np.round(toks)), "tokens must be integral f32"
    assert toks.min() >= 0 and toks.max() < CFG["vocab"]


def test_generate_chunk_deterministic_seed(params):
    rng = np.random.default_rng(2)
    tokens, lens = _random_prompts(rng, CFG["gen_batch"])
    z = np.zeros(CFG["gen_batch"], np.int32)
    a = _gen(params, tokens, lens, z, seed=7)
    b = _gen(params, tokens, lens, z, seed=7)
    c = _gen(params, tokens, lens, z, seed=8)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


def test_generate_chunk_frozen_rows(params):
    rng = np.random.default_rng(3)
    b, c = CFG["gen_batch"], CFG["gen_chunk"]
    tokens, lens = _random_prompts(rng, b)
    frozen = np.zeros(b, np.int32)
    frozen[1] = 1
    out = _gen(params, tokens, lens, frozen)
    assert out[1, 2 * c] == lens[1]          # length unchanged
    assert (out[1, :c] == CFG["pad_id"]).all()
    assert (out[1, c:2 * c] == 0.0).all()    # no behaviour logp
    assert out[1, 2 * c + 1] == 1.0          # reported done


def test_generate_chunk_greedy_is_deterministic(params):
    rng = np.random.default_rng(4)
    tokens, lens = _random_prompts(rng, CFG["gen_batch"])
    z = np.zeros(CFG["gen_batch"], np.int32)
    a = _gen(params, tokens, lens, z, seed=1, temp=0.0)
    b = _gen(params, tokens, lens, z, seed=99, temp=0.0)
    np.testing.assert_array_equal(a, b)


def test_generate_partial_rollout_resume_matches_single_shot(params):
    """Greedy decode in two chunks == greedy decode in one longer session.

    This is the partial-rollout invariant (paper §4.2): caching an incomplete
    generation and resuming it next iteration must not change the result.
    """
    rng = np.random.default_rng(5)
    b, c, s = CFG["gen_batch"], CFG["gen_chunk"], CFG["max_seq"]
    tokens, lens = _random_prompts(rng, b)
    z = np.zeros(b, np.int32)

    # one chunk
    out1 = _gen(params, tokens, lens, z, temp=0.0)
    toks1 = out1[:, :c].astype(np.int32)
    len1 = out1[:, 2 * c].astype(np.int32)
    done1 = out1[:, 2 * c + 1]

    # resume: write generated tokens into the buffer, call again
    tokens2 = tokens.copy()
    for i in range(b):
        n = len1[i] - lens[i]
        tokens2[i, lens[i]:len1[i]] = toks1[i, :n]
    out2 = _gen(params, tokens2, len1, done1.astype(np.int32), temp=0.0)
    toks2 = out2[:, :c].astype(np.int32)

    # reference: a config with chunk 2C, same weights (re-trace via scan len)
    import compile.model as m
    cfg2 = dict(CFG, gen_chunk=2 * c)
    outf = np.asarray(
        jax.jit(lambda *a: m.generate_chunk(cfg2, *a))(
            params, jnp.asarray(tokens), jnp.asarray(lens), jnp.asarray(z),
            jnp.asarray([0], jnp.int32), jnp.asarray([0.0], jnp.float32),
            jnp.asarray([0], jnp.int32)))
    toksf = outf[:, : 2 * c].astype(np.int32)
    lenf = outf[:, 4 * c].astype(np.int32)

    for i in range(b):
        got = np.concatenate([toks1[i][: len1[i] - lens[i]],
                              toks2[i][: lenf[i] - len1[i]]])
        want = toksf[i][: lenf[i] - lens[i]]
        np.testing.assert_array_equal(got, want, err_msg=f"row {i}")


def test_behavior_logp_matches_logprobs_eval(params):
    """mu logp recorded at sampling time == pi logp re-evaluated (on-policy)."""
    rng = np.random.default_rng(6)
    b, c, s = CFG["gen_batch"], CFG["gen_chunk"], CFG["max_seq"]
    tokens, lens = _random_prompts(rng, b)
    out = _gen(params, tokens, lens, np.zeros(b, np.int32), seed=3)
    toks = out[:, :c].astype(np.int32)
    logps = out[:, c:2 * c]
    new_len = out[:, 2 * c].astype(np.int32)

    full = tokens.copy()
    for i in range(b):
        full[i, lens[i]:new_len[i]] = toks[i, : new_len[i] - lens[i]]
    tok_in = np.pad(full[:, :-1], ((0, 0), (0, 1)))
    tgt = np.pad(full[:, 1:], ((0, 0), (0, 1)))
    bt = CFG["train_batch"]
    lp = np.asarray(
        jax.jit(lambda *a: model.logprobs_eval(CFG, *a))(
            params, jnp.asarray(tok_in[:bt]), jnp.asarray(tgt[:bt]),
            jnp.asarray(new_len[:bt])))
    for i in range(min(b, bt)):
        for j in range(lens[i], new_len[i]):
            assert abs(logps[i, j - lens[i]] - lp[i, j - 1]) < 5e-4


def test_train_step_decreases_loss_on_policy(params):
    """Repeated AIPO steps on a fixed batch with positive advantage must push
    target_logp up (the optimizer works end-to-end)."""
    rng = np.random.default_rng(8)
    bt, t = CFG["train_batch"], CFG["train_seq"]
    tokens = rng.integers(3, CFG["vocab"], (bt, t)).astype(np.int32)
    targets = rng.integers(3, CFG["vocab"], (bt, t)).astype(np.int32)
    lens = np.full(bt, t, np.int32)
    mask = np.ones((bt, t), np.float32)
    adv = np.ones((bt, t), np.float32)
    state = model.init_train_state(CFG, params)
    step = jax.jit(lambda *a: model.train_step(CFG, *a))
    hyp = jnp.asarray([1e-2, 100.0, 0.0], jnp.float32)

    lp0 = None
    for i in range(5):
        # on-policy: refresh behaviour logp from the current policy
        cur = model.extract_params(CFG, state)
        blogp = model.logprobs_eval(CFG, cur, tokens, targets, lens)
        state = step(state, tokens, targets, blogp, adv, mask, lens, hyp)
        met = np.asarray(model.extract_metrics(CFG, state))
        d = dict(zip(configs.METRIC_NAMES, met[1:]))
        if lp0 is None:
            lp0 = d["target_logp"]
        assert met[0] == i + 1
    assert d["target_logp"] > lp0 + 0.1, (lp0, d["target_logp"])


def test_train_step_grad_clip():
    rng = np.random.default_rng(9)
    params = model.init_params(CFG, seed=1)
    bt, t = CFG["train_batch"], CFG["train_seq"]
    tokens = rng.integers(3, CFG["vocab"], (bt, t)).astype(np.int32)
    targets = rng.integers(3, CFG["vocab"], (bt, t)).astype(np.int32)
    lens = np.full(bt, t, np.int32)
    mask = np.ones((bt, t), np.float32)
    adv = 100.0 * np.ones((bt, t), np.float32)   # enormous gradient
    blogp = -3.0 * np.ones((bt, t), np.float32)
    state = model.init_train_state(CFG, params)
    step = jax.jit(lambda *a: model.train_step(CFG, *a))
    s_clip = step(state, tokens, targets, blogp, adv, mask, lens,
                  jnp.asarray([1e-3, 5.0, 1.0], jnp.float32))
    met = np.asarray(model.extract_metrics(CFG, s_clip))
    d = dict(zip(configs.METRIC_NAMES, met[1:]))
    assert d["grad_norm"] > 1.0  # reported pre-clip norm is large
    # update magnitude is bounded: params moved, but not wildly
    p1 = np.asarray(model.extract_params(CFG, s_clip))
    assert 0 < np.abs(p1 - np.asarray(params)).max() < 0.1


def test_extract_roundtrip(params):
    state = model.init_train_state(CFG, params)
    np.testing.assert_array_equal(
        np.asarray(model.extract_params(CFG, state)), np.asarray(params))
    met = np.asarray(model.extract_metrics(CFG, state))
    assert met.shape == (1 + len(configs.METRIC_NAMES),)
    np.testing.assert_array_equal(met, np.zeros_like(met))
