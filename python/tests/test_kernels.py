"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes and value regimes; these are the core correctness
signal for the kernels that end up inside the AOT artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import aipo, attention, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _mk_aipo_case(rng, n, v, extreme=False):
    scale = 20.0 if extreme else 2.0
    logits = rng.normal(size=(n, v)).astype(np.float32) * scale
    targets = rng.integers(0, v, n).astype(np.int32)
    blogp = (rng.normal(size=n) - 2.0).astype(np.float32)
    adv = rng.normal(size=n).astype(np.float32)
    mask = rng.integers(0, 2, n).astype(np.float32)
    return logits, targets, blogp, adv, mask


@given(
    n=st.integers(1, 40),
    v=st.sampled_from([8, 64, 257, 512]),
    rho=st.floats(1.0, 10.0),
    seed=st.integers(0, 2**31 - 1),
    extreme=st.booleans(),
)
def test_aipo_fwd_matches_ref(n, v, rho, seed, extreme):
    rng = np.random.default_rng(seed)
    logits, targets, blogp, adv, mask = _mk_aipo_case(rng, n, v, extreme)
    rho = jnp.float32(rho)
    outs_k = aipo.aipo_loss_terms(logits, targets, blogp, adv, mask, rho)
    outs_r = ref.aipo_loss_terms_ref(logits, targets, blogp, adv, mask, rho)
    names = ["loss_terms", "logp", "w", "lse", "entropy"]
    for a, b, name in zip(outs_k, outs_r, names):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5, err_msg=name)


@given(
    n=st.integers(1, 24),
    v=st.sampled_from([8, 64, 130]),
    rho=st.floats(1.0, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_aipo_grad_matches_ref(n, v, rho, seed):
    rng = np.random.default_rng(seed)
    logits, targets, blogp, adv, mask = _mk_aipo_case(rng, n, v)
    rho = jnp.float32(rho)

    def total(lg):
        return jnp.sum(aipo.aipo_loss_terms(lg, targets, blogp, adv, mask, rho)[0])

    g_k = jax.grad(total)(jnp.asarray(logits))
    _, _, w, lse, _ = ref.aipo_loss_terms_ref(logits, targets, blogp, adv, mask, rho)
    g_r = ref.aipo_grad_logits_ref(
        jnp.asarray(logits), targets, lse, w, adv, mask, jnp.ones(n, jnp.float32))
    np.testing.assert_allclose(g_k, g_r, rtol=2e-5, atol=2e-5)


def test_aipo_grad_is_paper_estimator():
    """The clipped ratio must NOT be differentiated through (paper §6)."""
    rng = np.random.default_rng(7)
    n, v = 6, 16
    logits, targets, _, adv, _ = _mk_aipo_case(rng, n, v)
    mask = np.ones(n, np.float32)
    # Make everything heavily clipped: behaviour logp very low -> ratio >> rho.
    blogp = np.full(n, -30.0, np.float32)
    rho = jnp.float32(2.0)

    def total(lg):
        return jnp.sum(aipo.aipo_loss_terms(lg, targets, blogp, adv, mask, rho)[0])

    g = np.asarray(jax.grad(total)(jnp.asarray(logits)))
    # expected: -rho * adv * (onehot - softmax): finite and proportional to rho
    lse = np.log(np.exp(logits - logits.max(-1, keepdims=True)).sum(-1)) + logits.max(-1)
    sm = np.exp(logits - lse[:, None])
    onehot = np.eye(v, dtype=np.float32)[targets]
    expected = (-2.0 * adv)[:, None] * (onehot - sm)
    np.testing.assert_allclose(g, expected, rtol=1e-4, atol=1e-4)


def test_aipo_zero_mask_zero_loss_and_grad():
    rng = np.random.default_rng(3)
    n, v = 9, 32
    logits, targets, blogp, adv, _ = _mk_aipo_case(rng, n, v)
    mask = np.zeros(n, np.float32)
    loss_terms = aipo.aipo_loss_terms(logits, targets, blogp, adv, mask, jnp.float32(3.0))[0]
    assert float(jnp.sum(jnp.abs(loss_terms))) == 0.0

    def total(lg):
        return jnp.sum(aipo.aipo_loss_terms(lg, targets, blogp, adv, mask, jnp.float32(3.0))[0])

    g = jax.grad(total)(jnp.asarray(logits))
    assert float(jnp.max(jnp.abs(g))) == 0.0


@given(
    b=st.integers(1, 5),
    h=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([4, 16, 33, 64]),
    dh=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_attention_matches_ref(b, h, s, dh, seed):
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(b, h, dh)).astype(np.float32)
    k = rng.normal(size=(b, h, s, dh)).astype(np.float32)
    v = rng.normal(size=(b, h, s, dh)).astype(np.float32)
    limit = rng.integers(1, s + 1, b).astype(np.int32)
    out_k = attention.decode_attention(q, k, v, limit)
    out_r = ref.decode_attention_ref(q, k, v, limit)
    np.testing.assert_allclose(out_k, out_r, rtol=2e-5, atol=2e-5)


def test_decode_attention_respects_limit():
    """Keys at positions >= limit must have zero influence."""
    rng = np.random.default_rng(11)
    b, h, s, dh = 2, 2, 16, 8
    q = rng.normal(size=(b, h, dh)).astype(np.float32)
    k = rng.normal(size=(b, h, s, dh)).astype(np.float32)
    v = rng.normal(size=(b, h, s, dh)).astype(np.float32)
    limit = np.array([5, 9], np.int32)
    out1 = attention.decode_attention(q, k, v, limit)
    # scribble over the masked region
    k2, v2 = k.copy(), v.copy()
    for row, lim in enumerate(limit):
        k2[row, :, lim:, :] = 1e6
        v2[row, :, lim:, :] = -1e6
    out2 = attention.decode_attention(q, k2, v2, limit)
    np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)


def test_decode_attention_single_key():
    """limit=1 -> output is exactly v[:, :, 0, :]."""
    rng = np.random.default_rng(13)
    b, h, s, dh = 3, 2, 8, 4
    q = rng.normal(size=(b, h, dh)).astype(np.float32)
    k = rng.normal(size=(b, h, s, dh)).astype(np.float32)
    v = rng.normal(size=(b, h, s, dh)).astype(np.float32)
    limit = np.ones(b, np.int32)
    out = attention.decode_attention(q, k, v, limit)
    np.testing.assert_allclose(out, v[:, :, 0, :], rtol=1e-6, atol=1e-6)


def test_aipo_rho_nonpositive_disables_correction():
    """rho <= 0 -> w = 1 everywhere (Fig. 8 no-correction ablation arm)."""
    rng = np.random.default_rng(21)
    n, v = 10, 32
    logits, targets, blogp, adv, mask = _mk_aipo_case(rng, n, v)
    mask = np.ones(n, np.float32)
    loss, logp, w, _, _ = aipo.aipo_loss_terms(
        logits, targets, blogp, adv, mask, jnp.float32(-1.0))
    np.testing.assert_allclose(w, np.ones(n), rtol=1e-6)
    np.testing.assert_allclose(loss, -np.asarray(adv) * np.asarray(logp),
                               rtol=1e-5, atol=1e-6)
