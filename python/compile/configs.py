"""Model/artifact configurations shared between the AOT compile path (python)
and the Rust runtime (via artifacts/<name>/manifest.json).

Three presets:
  nano  — unit/CI tests: compiles in seconds, runs in milliseconds.
  small — integration tests, Figure-5 batch-scaling measurements.
  e2e   — the end-to-end training driver (examples/train_async_math.rs).

Token id conventions (must match rust/src/model/tokenizer.rs):
  0 = PAD, 1 = BOS, 2 = EOS, 3.. = character set.
"""

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2

# Adam hyper-parameters baked into the train_step artifact (lr comes in as a
# runtime input so the Rust side can do schedules).
ADAM_B1 = 0.9
ADAM_B2 = 0.95
ADAM_EPS = 1e-8

# Names of the metric slots written by train_step into the packed train state.
METRIC_NAMES = [
    "loss",          # mean AIPO loss over masked tokens
    "mean_ratio",    # mean unclipped importance ratio pi/mu
    "clip_frac",     # fraction of masked tokens with ratio > rho
    "approx_kl",     # mean (mu_logp - pi_logp) over masked tokens
    "entropy",       # mean per-token policy entropy
    "grad_norm",     # global grad norm (pre-clipping)
    "token_count",   # number of masked (response) tokens in the batch
    "max_ratio",     # max unclipped ratio in the batch
    "adv_mean",      # mean advantage over masked tokens
    "target_logp",   # mean pi log-prob of target tokens
]


def _cfg(
    name,
    vocab,
    d_model,
    n_layers,
    n_heads,
    d_ff,
    max_seq,
    gen_batch,
    gen_chunk,
    train_batch,
):
    assert d_model % n_heads == 0
    return dict(
        name=name,
        vocab=vocab,
        d_model=d_model,
        n_layers=n_layers,
        n_heads=n_heads,
        d_head=d_model // n_heads,
        d_ff=d_ff,
        max_seq=max_seq,
        # generator artifact: per-DP-worker decode batch and tokens per chunk
        gen_batch=gen_batch,
        gen_chunk=gen_chunk,
        # trainer artifact: microbatch x full-sequence
        train_batch=train_batch,
        train_seq=max_seq,
        pad_id=PAD_ID,
        bos_id=BOS_ID,
        eos_id=EOS_ID,
    )


CONFIGS = {
    "nano": _cfg("nano", vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=128,
                 max_seq=64, gen_batch=4, gen_chunk=8, train_batch=4),
    "small": _cfg("small", vocab=256, d_model=128, n_layers=3, n_heads=4,
                  d_ff=512, max_seq=128, gen_batch=4, gen_chunk=16,
                  train_batch=8),
    "e2e": _cfg("e2e", vocab=512, d_model=256, n_layers=4, n_heads=8,
                d_ff=1024, max_seq=256, gen_batch=8, gen_chunk=32,
                train_batch=8),
}

# Figure-5 batch-scaling sweep (real measurement of Assumption 7.1): emit
# train_step variants at these microbatch sizes and generate_chunk variants at
# these decode concurrencies, for the `small` config.
FIG5_TRAIN_BATCHES = [1, 2, 4, 8, 16]
FIG5_GEN_BATCHES = [1, 2, 4, 8, 16]


def param_layout(cfg):
    """Flat f32 parameter vector layout: list of (name, shape) in order.

    The Rust side reads this from the manifest; offsets are cumulative.
    Embedding is tied to the output head (logits = x @ embed.T).
    """
    d, f, v, s = cfg["d_model"], cfg["d_ff"], cfg["vocab"], cfg["max_seq"]
    layout = [("embed", (v, d)), ("pos_embed", (s, d))]
    for i in range(cfg["n_layers"]):
        p = f"layer{i}."
        layout += [
            (p + "ln1_scale", (d,)),
            (p + "ln1_bias", (d,)),
            (p + "wq", (d, d)),
            (p + "wk", (d, d)),
            (p + "wv", (d, d)),
            (p + "wo", (d, d)),
            (p + "ln2_scale", (d,)),
            (p + "ln2_bias", (d,)),
            (p + "w1", (d, f)),
            (p + "b1", (f,)),
            (p + "w2", (f, d)),
            (p + "b2", (d,)),
        ]
    layout += [("lnf_scale", (d,)), ("lnf_bias", (d,))]
    return layout


def num_params(cfg):
    n = 0
    for _, shape in param_layout(cfg):
        size = 1
        for dim in shape:
            size *= dim
        n += size
    return n


def train_state_layout(cfg):
    """Packed train-state vector: [params | m | v | step | metrics]."""
    p = num_params(cfg)
    m = len(METRIC_NAMES)
    return dict(
        params=(0, p),
        adam_m=(p, p),
        adam_v=(2 * p, p),
        step=(3 * p, 1),
        metrics=(3 * p + 1, m),
        total=3 * p + 1 + m,
    )
