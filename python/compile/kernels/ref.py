"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: python/tests/test_kernels.py asserts
the Pallas implementations (interpret=True) match these references under
hypothesis-driven shape/value sweeps.
"""

import jax.numpy as jnp


def aipo_loss_terms_ref(logits, targets, behavior_logp, adv, mask, rho):
    """Reference AIPO per-token loss terms and stats.

    AIPO (paper §6): importance-weighted policy gradient with a one-sided clip,

        loss_t = - min(pi_t / mu_t, rho) * A_t * log pi_t        (masked)

    where the clipped ratio and advantage are treated as constants in the
    gradient (the paper's update is  min(ratio, rho) * A * grad log pi).

    Args:
      logits:        f32[N, V]  learner logits per (flattened) token position
      targets:       i32[N]     sampled token ids
      behavior_logp: f32[N]     log mu(y_t | ...) recorded by the generator
      adv:           f32[N]     per-token advantage estimates
      mask:          f32[N]     1.0 on response tokens, 0.0 elsewhere
      rho:           f32[]      one-sided IS-ratio clip; rho <= 0 DISABLES
                                the correction entirely (w = 1, the plain
                                REINFORCE-on-stale-data ablation of Fig. 8)

    Returns (loss_terms, logp, w, lse, entropy), each f32[N].
    """
    rowmax = jnp.max(logits, axis=-1)
    shifted = logits - rowmax[:, None]
    sumexp = jnp.sum(jnp.exp(shifted), axis=-1)
    lse = jnp.log(sumexp) + rowmax
    tgt_logit = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    logp = tgt_logit - lse
    ratio = jnp.exp(logp - behavior_logp)
    w = jnp.where(rho > 0, jnp.minimum(ratio, rho), 1.0)
    loss_terms = -w * adv * logp * mask
    # entropy = lse - E_p[logit]
    p = jnp.exp(shifted) / sumexp[:, None]
    entropy = lse - jnp.sum(p * logits, axis=-1)
    return loss_terms, logp, w, lse, entropy


def aipo_grad_logits_ref(logits, targets, lse, w, adv, mask, ct):
    """Reference gradient of sum(ct * loss_terms) w.r.t. logits.

    d loss_t / d logits_t = -w_t * A_t * (onehot(target_t) - softmax(logits_t))
    with w treated as a constant (stop-grad), matching the paper's estimator.
    """
    v = logits.shape[-1]
    softmax = jnp.exp(logits - lse[:, None])
    onehot = jnp.eye(v, dtype=logits.dtype)[targets]
    coef = (-w * adv * mask * ct)[:, None]
    return coef * (onehot - softmax)


def decode_attention_ref(q, k_cache, v_cache, limit):
    """Reference single-token decode attention over a KV cache.

    Args:
      q:       f32[B, H, Dh]      query for the current position
      k_cache: f32[B, H, S, Dh]   keys (positions >= limit[b] are invalid)
      v_cache: f32[B, H, S, Dh]
      limit:   i32[B]             row b attends to key positions j < limit[b]

    Returns f32[B, H, Dh].
    """
    dh = q.shape[-1]
    scores = jnp.einsum("bhd,bhsd->bhs", q, k_cache) / jnp.sqrt(
        jnp.asarray(dh, q.dtype)
    )
    s = k_cache.shape[2]
    pos = jnp.arange(s)[None, None, :]
    valid = pos < limit[:, None, None]
    scores = jnp.where(valid, scores, -1e30)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs * valid
    probs = probs / jnp.maximum(jnp.sum(probs, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhs,bhsd->bhd", probs, v_cache)
