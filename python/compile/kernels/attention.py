"""Fused decode-attention kernel (L1, Pallas).

The generator's hot-spot: one query token per sequence attending over the
whole KV cache. The paper's generator uses optimized CUDA decode kernels
(CUDA-graph captured); the TPU re-think (DESIGN.md §Hardware-Adaptation) is a
flash-decoding-style kernel:

  * grid = (B, H): one program instance per (sequence, head), the TPU
    analogue of a CUDA threadblock per head;
  * BlockSpec stages that head's [S, Dh] K/V slices HBM->VMEM; at our sizes
    (S<=256, Dh<=32 -> 32 KiB per operand) the full cache slice is VMEM
    resident, so a single-pass masked softmax suffices. For longer caches the
    same body becomes the inner loop of an online (max, sumexp, acc) scan
    over S-tiles;
  * QK^T and P.V are `dot`s on [S, Dh] tiles — MXU-shaped work, not the
    WMMA-fragment layout a CUDA port would use.

The length mask implements ragged batched decode: row b attends to key
positions j < limit[b] (right-padded batches; see model.generate_chunk).

interpret=True: CPU PJRT cannot run Mosaic custom-calls; interpret mode
lowers to identical-numerics HLO.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _decode_attn_kernel(q_ref, k_ref, v_ref, limit_ref, o_ref):
    q = q_ref[0, 0, :]                   # [Dh]
    k = k_ref[0, 0, :, :]                # [S, Dh]
    v = v_ref[0, 0, :, :]                # [S, Dh]
    limit = limit_ref[0]                 # scalar i32
    dh = q.shape[-1]
    s = k.shape[0]

    scores = jnp.dot(k, q) / jnp.sqrt(jnp.asarray(dh, q.dtype))   # [S]
    valid = jax.lax.iota(jnp.int32, s) < limit
    scores = jnp.where(valid, scores, -1e30)
    m = jnp.max(scores)
    p = jnp.exp(scores - m) * valid.astype(q.dtype)
    denom = jnp.maximum(jnp.sum(p), 1e-30)
    o_ref[0, 0, :] = jnp.dot(p, v) / denom


def decode_attention(q, k_cache, v_cache, limit):
    """Single-token decode attention; see ref.decode_attention_ref.

    Args:
      q:       f32[B, H, Dh]
      k_cache: f32[B, H, S, Dh]
      v_cache: f32[B, H, S, Dh]
      limit:   i32[B]  (row b attends to keys j < limit[b])

    Returns f32[B, H, Dh].
    """
    b, h, s, dh = k_cache.shape
    grid = (b, h)
    q_spec = pl.BlockSpec((1, 1, dh), lambda i, j: (i, j, 0))
    kv_spec = pl.BlockSpec((1, 1, s, dh), lambda i, j: (i, j, 0, 0))
    lim_spec = pl.BlockSpec((1,), lambda i, j: (i,))
    return pl.pallas_call(
        _decode_attn_kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec, lim_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, dh), jnp.float32),
        interpret=INTERPRET,
    )(q, k_cache, v_cache, limit)
