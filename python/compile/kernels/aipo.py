"""Fused AIPO loss kernel (L1, Pallas).

This is the trainer's compute hot-spot on the vocab dimension: for every
token position we need log-softmax over V logits, the target-token gather,
the clipped importance ratio against the recorded behaviour log-prob, and the
advantage weighting. Done naively (jnp log_softmax + gathers) the [N, V]
logits tensor is read several times and a full [N, V] log-prob tensor is
materialized; fused, the logits stream through once and only O(N) outputs are
written.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles rows into
ROW_BLOCK-sized chunks whose [ROW_BLOCK, V] logit tile is staged HBM->VMEM by
the BlockSpec; V for our configs (<= 2048) keeps a tile under 64 KiB, well
inside VMEM, so a single vocab pass per tile suffices (for larger V the same
kernel structure extends to an online multi-tile logsumexp). The backward
kernel *recomputes* the softmax from the saved per-row logsumexp instead of
storing [N, V] probabilities — rematerialization trades one extra VMEM-local
exp for an O(N*V) HBM saving.

The gradient is the paper's estimator (§6):

    grad_logits_t = -min(pi/mu, rho) * A_t * (onehot(y_t) - softmax(logits_t))

i.e. the clipped ratio and advantage multiply grad log pi and are NOT
differentiated through (enforced via jax.custom_vjp below).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO with identical numerics.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Rows per grid step. 8 keeps the fwd tile (ROW_BLOCK x V f32) small enough
# for VMEM at V=2048 while amortizing grid overhead.
ROW_BLOCK = 8

INTERPRET = True


def _fwd_kernel(logits_ref, targets_ref, blogp_ref, adv_ref, mask_ref,
                rho_ref, loss_ref, logp_ref, w_ref, lse_ref, ent_ref):
    logits = logits_ref[...]            # [R, V]
    targets = targets_ref[...]          # [R]
    rho = rho_ref[0]

    rowmax = jnp.max(logits, axis=-1)
    shifted = logits - rowmax[:, None]
    expd = jnp.exp(shifted)
    sumexp = jnp.sum(expd, axis=-1)
    lse = jnp.log(sumexp) + rowmax

    tgt_logit = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    logp = tgt_logit - lse
    ratio = jnp.exp(logp - blogp_ref[...])
    # rho <= 0 disables the off-policy correction (w = 1): the Figure-8
    # "without importance sampling" ablation arm.
    w = jnp.where(rho > 0, jnp.minimum(ratio, rho), 1.0)

    loss_ref[...] = -w * adv_ref[...] * logp * mask_ref[...]
    logp_ref[...] = logp
    w_ref[...] = w
    lse_ref[...] = lse
    # entropy = lse - E_p[logit]; reuse the staged exp tile.
    p = expd / sumexp[:, None]
    ent_ref[...] = lse - jnp.sum(p * logits, axis=-1)


def _bwd_kernel(logits_ref, targets_ref, lse_ref, w_ref, adv_ref, mask_ref,
                ct_ref, grad_ref):
    logits = logits_ref[...]            # [R, V]
    targets = targets_ref[...]          # [R]
    # Rematerialize softmax from the saved logsumexp (no [N,V] residual).
    softmax = jnp.exp(logits - lse_ref[...][:, None])
    v = logits.shape[-1]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (logits.shape[0], v), 1)
              == targets[:, None]).astype(logits.dtype)
    coef = (-w_ref[...] * adv_ref[...] * mask_ref[...] * ct_ref[...])[:, None]
    grad_ref[...] = coef * (onehot - softmax)


def _pad_rows(n):
    return (n + ROW_BLOCK - 1) // ROW_BLOCK * ROW_BLOCK


def _fwd_call(logits, targets, blogp, adv, mask, rho):
    n, v = logits.shape
    np_ = _pad_rows(n)
    if np_ != n:
        pad = np_ - n
        logits = jnp.pad(logits, ((0, pad), (0, 0)))
        targets = jnp.pad(targets, (0, pad))
        blogp = jnp.pad(blogp, (0, pad))
        adv = jnp.pad(adv, (0, pad))
        mask = jnp.pad(mask, (0, pad))
    grid = (np_ // ROW_BLOCK,)
    rho_arr = jnp.asarray(rho, jnp.float32).reshape((1,))
    row = pl.BlockSpec((ROW_BLOCK,), lambda i: (i,))
    mat = pl.BlockSpec((ROW_BLOCK, v), lambda i: (i, 0))
    full = pl.BlockSpec((1,), lambda i: (0,))
    out_shape = [jax.ShapeDtypeStruct((np_,), jnp.float32)] * 5
    loss, logp, w, lse, ent = pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[mat, row, row, row, row, full],
        out_specs=[row] * 5,
        out_shape=out_shape,
        interpret=INTERPRET,
    )(logits, targets, blogp, adv, mask, rho_arr)
    return loss[:n], logp[:n], w[:n], lse[:n], ent[:n]


def _bwd_call(logits, targets, lse, w, adv, mask, ct):
    n, v = logits.shape
    np_ = _pad_rows(n)
    if np_ != n:
        pad = np_ - n
        logits = jnp.pad(logits, ((0, pad), (0, 0)))
        targets = jnp.pad(targets, (0, pad))
        lse = jnp.pad(lse, (0, pad))
        w = jnp.pad(w, (0, pad))
        adv = jnp.pad(adv, (0, pad))
        mask = jnp.pad(mask, (0, pad))
        ct = jnp.pad(ct, (0, pad))
    grid = (np_ // ROW_BLOCK,)
    row = pl.BlockSpec((ROW_BLOCK,), lambda i: (i,))
    mat = pl.BlockSpec((ROW_BLOCK, v), lambda i: (i, 0))
    grad = pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[mat, row, row, row, row, row, row],
        out_specs=mat,
        out_shape=jax.ShapeDtypeStruct((np_, v), jnp.float32),
        interpret=INTERPRET,
    )(logits, targets, lse, w, adv, mask, ct)
    return grad[:n]


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def aipo_loss_terms(logits, targets, blogp, adv, mask, rho):
    """Fused AIPO per-token loss terms; see ref.aipo_loss_terms_ref.

    Returns (loss_terms, logp, w, lse, entropy); differentiable in `logits`
    only, with the paper's stop-grad-on-(w * adv) gradient.
    """
    return _fwd_call(logits, targets, blogp, adv, mask, rho)


def _vjp_fwd(logits, targets, blogp, adv, mask, rho):
    outs = _fwd_call(logits, targets, blogp, adv, mask, rho)
    _, _, w, lse, _ = outs
    return outs, (logits, targets, lse, w, adv, mask, blogp, rho)


def _vjp_bwd(res, cts):
    logits, targets, lse, w, adv, mask, blogp, rho = res
    ct_loss = cts[0]  # only loss_terms' cotangent feeds the policy gradient
    grad_logits = _bwd_call(logits, targets, lse, w, adv, mask, ct_loss)
    f0 = lambda x: np.zeros(x.shape, dtype=jax.dtypes.float0)
    return (grad_logits, f0(targets), jnp.zeros_like(blogp),
            jnp.zeros_like(adv), jnp.zeros_like(mask), jnp.zeros_like(rho))


aipo_loss_terms.defvjp(_vjp_fwd, _vjp_bwd)
