"""L2: the policy model as JAX compute graphs, AOT-lowered to HLO artifacts.

A decoder-only transformer (pre-LN, learned positional embeddings, tied
input/output embedding) with four entry points, each lowered by aot.py into a
single-output HLO executable the Rust runtime drives:

  generate_chunk  — the generator executor's whole decode chunk in ONE call:
                    in-graph prefill over the current token buffer, then a
                    lax.scan of C decode steps (Pallas decode-attention
                    kernel, KV-cache scatter, temperature/top-k sampling with
                    in-graph threefry RNG, EOS handling). Returns a packed
                    f32[B, 2C+2] = [tokens | behaviour_logp | new_len | done].
  train_step      — one AIPO update: full-sequence forward (standard jnp
                    attention — the trainer is the "FSDP bf16" path in the
                    paper; the fused kernels live on the generator/loss),
                    Pallas fused AIPO loss (custom VJP), global-norm clip,
                    Adam. State is ONE packed f32 vector
                    [params | m | v | step | metrics] so the executable has a
                    single array output and stays device-resident between
                    calls (see DESIGN.md: tuple outputs crash the PJRT shim).
  extract_params / extract_metrics — O(1)-cost slices of the packed train
                    state, so the Rust side fetches 13 MB of weights for a
                    DDMA publication or 11 floats of metrics without pulling
                    the whole 40 MB state to host.
  logprobs_eval   — log pi(target | prefix) for lag/KL diagnostics.

Everything is f32; step counters and token ids travel as f32 inside packed
buffers (exact below 2^24). Python never runs at serve time: these graphs are
lowered once by aot.py and executed from Rust via PJRT.
"""

import jax
import jax.numpy as jnp

from . import configs
from .kernels import aipo
from .kernels import attention as attn_kernel

# ---------------------------------------------------------------------------
# Parameter handling


def unflatten_params(cfg, flat):
    """Split the flat f32[P] vector into a dict of named arrays."""
    params = {}
    off = 0
    for name, shape in configs.param_layout(cfg):
        size = 1
        for d in shape:
            size *= d
        params[name] = jax.lax.slice(flat, (off,), (off + size,)).reshape(shape)
        off += size
    return params


def init_params(cfg, seed):
    """Initialization used for the artifacts' init checkpoint (aot.py)."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape in configs.param_layout(cfg):
        key, sub = jax.random.split(key)
        size = 1
        for d in shape:
            size *= d
        if name.endswith(("_scale",)):
            chunks.append(jnp.ones(size, jnp.float32))
        elif name.endswith(("_bias", "b1", "b2")):
            chunks.append(jnp.zeros(size, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else size
            scale = 0.02 if name in ("embed", "pos_embed") else 1.0 / jnp.sqrt(fan_in)
            chunks.append(jax.random.normal(sub, (size,), jnp.float32) * scale)
    return jnp.concatenate(chunks)


# ---------------------------------------------------------------------------
# Transformer blocks


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def _split_heads(x, n_heads):
    b, t, d = x.shape
    return x.reshape(b, t, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, t, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * dh)


def _full_attention(q, k, v, lens):
    """Causal + length-masked attention over full sequences (trainer path).

    q,k,v: [B, H, T, Dh]; lens: i32[B] — key positions >= lens[b] are PAD.
    """
    dh = q.shape[-1]
    t = q.shape[2]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(
        jnp.asarray(dh, q.dtype)
    )
    qpos = jnp.arange(t)[None, None, :, None]
    kpos = jnp.arange(t)[None, None, None, :]
    causal = kpos <= qpos
    valid = kpos < lens[:, None, None, None]
    mask = jnp.logical_and(causal, valid)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = probs * mask.astype(probs.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def forward_full(cfg, params, tokens, lens, return_kv=False):
    """Full-sequence forward.

    tokens: i32[B, T] right-padded; lens: i32[B] valid lengths.
    Returns logits f32[B, T, V] (and optionally per-layer KV caches shaped
    [L, B, H, S, Dh] with T <= S positions filled, for in-graph prefill).
    """
    h_dim = cfg["n_heads"]
    b, t = tokens.shape
    x = params["embed"][tokens] + params["pos_embed"][None, :t, :]
    kv_ks, kv_vs = [], []
    for i in range(cfg["n_layers"]):
        p = f"layer{i}."
        y = _layer_norm(x, params[p + "ln1_scale"], params[p + "ln1_bias"])
        q = _split_heads(y @ params[p + "wq"], h_dim)
        k = _split_heads(y @ params[p + "wk"], h_dim)
        v = _split_heads(y @ params[p + "wv"], h_dim)
        o = _full_attention(q, k, v, lens)
        x = x + _merge_heads(o) @ params[p + "wo"]
        y = _layer_norm(x, params[p + "ln2_scale"], params[p + "ln2_bias"])
        x = x + (jax.nn.gelu(y @ params[p + "w1"] + params[p + "b1"])
                 @ params[p + "w2"] + params[p + "b2"])
        if return_kv:
            s = cfg["max_seq"]
            pad = s - t
            kv_ks.append(jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))))
            kv_vs.append(jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))))
    x = _layer_norm(x, params["lnf_scale"], params["lnf_bias"])
    logits = x @ params["embed"].T
    if return_kv:
        return logits, jnp.stack(kv_ks), jnp.stack(kv_vs)
    return logits


# ---------------------------------------------------------------------------
# Generation (generator executor artifact)


def _decode_one(cfg, params, kv_k, kv_v, tok, pos, done):
    """One decode step for the whole batch; positions are per-row (ragged).

    kv_k/kv_v: [L, B, H, S, Dh]; tok: i32[B]; pos: i32[B]; done: bool[B].
    Returns (logits [B, V], kv_k', kv_v').
    """
    n_heads, s = cfg["n_heads"], cfg["max_seq"]
    safe_pos = jnp.minimum(pos, s - 1)
    x = params["embed"][tok] + params["pos_embed"][safe_pos]       # [B, D]
    x = x[:, None, :]                                              # [B,1,D]
    onehot = (jnp.arange(s)[None, :] == safe_pos[:, None]).astype(jnp.float32)
    # rows that are done must not overwrite cache entries
    onehot = onehot * (1.0 - done.astype(jnp.float32))[:, None]    # [B, S]
    new_k, new_v = [], []
    for i in range(cfg["n_layers"]):
        p = f"layer{i}."
        y = _layer_norm(x, params[p + "ln1_scale"], params[p + "ln1_bias"])
        q = _split_heads(y @ params[p + "wq"], n_heads)[:, :, 0, :]  # [B,H,Dh]
        k = _split_heads(y @ params[p + "wk"], n_heads)[:, :, 0, :]
        v = _split_heads(y @ params[p + "wv"], n_heads)[:, :, 0, :]
        # scatter k,v into the cache at per-row positions via one-hot blend
        oh = onehot[:, None, :, None]                               # [B,1,S,1]
        kc = kv_k[i] * (1.0 - oh) + oh * k[:, :, None, :]
        vc = kv_v[i] * (1.0 - oh) + oh * v[:, :, None, :]
        new_k.append(kc)
        new_v.append(vc)
        # attend to keys j <= pos  (the current token was just written)
        o = attn_kernel.decode_attention(q, kc, vc, safe_pos + 1)   # [B,H,Dh]
        x = x + (o.reshape(o.shape[0], -1) @ params[p + "wo"])[:, None, :]
        y = _layer_norm(x, params[p + "ln2_scale"], params[p + "ln2_bias"])
        x = x + (jax.nn.gelu(y @ params[p + "w1"] + params[p + "b1"])
                 @ params[p + "w2"] + params[p + "b2"])
    xf = _layer_norm(x[:, 0, :], params["lnf_scale"], params["lnf_bias"])
    logits = xf @ params["embed"].T
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def _sample(key, logits, temperature, top_k):
    """Temperature/top-k sampling; returns (token, behaviour_logp).

    The behaviour log-prob is of the ACTUAL sampling distribution (post
    temperature and top-k) — this is mu in AIPO's pi/mu ratio; when the
    generator runs quantized or lagged this genuinely differs from pi.
    temperature <= 0 selects greedy argmax (logp of the greedy dist = 0).
    """
    v = logits.shape[-1]
    # top-k mask (top_k <= 0 disables)
    sorted_desc = -jnp.sort(-logits, axis=-1)                     # [B, V]
    k_idx = jnp.clip(top_k - 1, 0, v - 1)
    kth = sorted_desc[:, k_idx]                                    # [B]
    topk_mask = jnp.logical_or(top_k <= 0, logits >= kth[:, None])
    masked = jnp.where(topk_mask, logits, -1e30)

    temp = jnp.maximum(temperature, 1e-4)
    scaled = masked / temp
    sampled = jax.random.categorical(key, scaled, axis=-1)
    greedy = jnp.argmax(logits, axis=-1)
    use_greedy = temperature <= 0.0
    tok = jnp.where(use_greedy, greedy, sampled)

    logz = jax.nn.log_softmax(scaled, axis=-1)
    logp = jnp.take_along_axis(logz, tok[:, None], axis=-1)[:, 0]
    logp = jnp.where(use_greedy, 0.0, logp)
    return tok, logp


def generate_chunk(cfg, params_flat, tokens, lens, frozen, seed, temperature,
                   top_k):
    """Generate up to C tokens for each row of a right-padded batch.

    Args:
      params_flat: f32[P]
      tokens:      i32[B, S]  prompt + previously generated tokens, right-pad
      lens:        i32[B]     current valid length per row
      frozen:      i32[B]     1 -> row is finished/idle, do not decode it
      seed:        i32[1]     RNG seed for this chunk
      temperature: f32[1]     <= 0 -> greedy
      top_k:       i32[1]     <= 0 -> disabled

    Returns packed f32[B, 2C + 2]:
      [:, 0:C]        new tokens (as f32; PAD for rows already done)
      [:, C:2C]       behaviour log-probs
      [:, 2C]         new length
      [:, 2C+1]       done flag (1.0 if EOS emitted or length hit max_seq)

    Partial rollouts (paper §4.2): the Rust side calls this repeatedly with
    the updated buffer/lengths; an unfinished row simply resumes next call.
    The in-graph prefill recomputes the KV cache for the buffered prefix each
    chunk — recompute trades O(prefill) FLOPs for not persisting a tuple of
    device-side caches between calls.
    """
    c = cfg["gen_chunk"]
    s = cfg["max_seq"]
    eos, pad = cfg["eos_id"], cfg["pad_id"]
    params = unflatten_params(cfg, params_flat)

    # In-graph prefill over the whole buffer (padding rows masked out).
    _, kv_k, kv_v = forward_full(cfg, params, tokens, lens, return_kv=True)
    # The token to feed the first decode step: last valid token per row.
    last_tok = jnp.take_along_axis(
        tokens, jnp.maximum(lens - 1, 0)[:, None], axis=-1)[:, 0]
    already_done = jnp.logical_or(lens >= s, frozen > 0)
    key0 = jax.random.PRNGKey(seed[0])
    temp = temperature[0]
    tk = top_k[0]

    def step(carry, _):
        kv_k, kv_v, tok, pos, done, key = carry
        key, sub = jax.random.split(key)
        logits, kv_k, kv_v = _decode_one(cfg, params, kv_k, kv_v, tok, pos, done)
        new_tok, logp = _sample(sub, logits, temp, tk)
        new_tok = jnp.where(done, pad, new_tok)
        logp = jnp.where(done, 0.0, logp)
        new_done = jnp.logical_or(done, new_tok == eos)
        new_pos = jnp.where(done, pos, pos + 1)
        # hitting the end of the buffer also terminates the row
        new_done = jnp.logical_or(new_done, new_pos >= s)
        carry = (kv_k, kv_v, new_tok, new_pos, new_done, key)
        return carry, (new_tok, logp)

    # NOTE on positions: the prefix occupies [0, len); the first generated
    # token is *written* at position len (cache write in _decode_one uses the
    # query position pos, which for the first step must be len-1's successor).
    # _decode_one writes the INPUT token's kv at `pos` then attends j <= pos;
    # the input token of step 0 is tokens[len-1] whose kv already exists from
    # prefill — overwriting it with identical values is benign, and the newly
    # sampled token becomes the next step's input at pos+1.
    carry0 = (kv_k, kv_v, last_tok, jnp.maximum(lens - 1, 0), already_done, key0)
    (kv_k, kv_v, _, pos, done, _), (toks, logps) = jax.lax.scan(
        step, carry0, None, length=c)

    toks = toks.T.astype(jnp.float32)      # [B, C]
    logps = logps.T                        # [B, C]
    # pos is the position of the last *input* token; +1 counts the sampled
    # token appended after it. A row that ends exactly at the buffer edge
    # samples one token that no longer fits — clamp so new_len <= S (the
    # caller drops the overflow sample).
    new_len = jnp.minimum(pos + 1, s).astype(jnp.float32)
    # rows that were already full keep their length
    new_len = jnp.where(already_done, lens.astype(jnp.float32), new_len)
    out = jnp.concatenate(
        [toks, logps, new_len[:, None], done.astype(jnp.float32)[:, None]],
        axis=1,
    )
    return out


# ---------------------------------------------------------------------------
# Training (trainer executor artifact)


def _adam_update(flat, m, v, step, grads, lr):
    b1, b2, eps = configs.ADAM_B1, configs.ADAM_B2, configs.ADAM_EPS
    m = b1 * m + (1.0 - b1) * grads
    v = b2 * v + (1.0 - b2) * grads * grads
    t = step + 1.0
    mhat = m / (1.0 - b1**t)
    vhat = v / (1.0 - b2**t)
    return flat - lr * mhat / (jnp.sqrt(vhat) + eps), m, v


def train_step(cfg, state, tokens, targets, blogp, adv, mask, lens, hyp):
    """One AIPO update over a packed train state.

    Args:
      state:   f32[TS] = [params | m | v | step | metrics]
      tokens:  i32[B, T] input tokens (right-padded full sequences)
      targets: i32[B, T] tokens[t+1] (next-token targets)
      blogp:   f32[B, T] behaviour log-probs (0 where mask==0)
      adv:     f32[B, T] per-token advantages
      mask:    f32[B, T] 1.0 on response-token positions
      lens:    i32[B]    valid lengths (for the attention mask)
      hyp:     f32[3]    [lr, rho, grad_clip (<=0 disables)]

    Returns the updated packed state f32[TS].
    """
    lay = configs.train_state_layout(cfg)
    p_sz = lay["params"][1]
    flat = jax.lax.slice(state, (0,), (p_sz,))
    m = jax.lax.slice(state, (p_sz,), (2 * p_sz,))
    v = jax.lax.slice(state, (2 * p_sz,), (3 * p_sz,))
    step = state[3 * p_sz]
    lr, rho, grad_clip = hyp[0], hyp[1], hyp[2]

    b, t = tokens.shape
    n = b * t

    def loss_fn(flat_params):
        params = unflatten_params(cfg, flat_params)
        logits = forward_full(cfg, params, tokens, lens)           # [B,T,V]
        loss_terms, logp, w, _, ent = aipo.aipo_loss_terms(
            logits.reshape(n, -1),
            targets.reshape(n),
            blogp.reshape(n),
            adv.reshape(n),
            mask.reshape(n),
            rho,
        )
        mflat = mask.reshape(n)
        denom = jnp.maximum(jnp.sum(mflat), 1.0)
        loss = jnp.sum(loss_terms) / denom
        # diagnostics (all masked means)
        ratio = jnp.exp(logp - blogp.reshape(n))
        stats = dict(
            mean_ratio=jnp.sum(ratio * mflat) / denom,
            clip_frac=jnp.sum((ratio > rho) * mflat) / denom,
            approx_kl=jnp.sum((blogp.reshape(n) - logp) * mflat) / denom,
            entropy=jnp.sum(ent * mflat) / denom,
            token_count=jnp.sum(mflat),
            max_ratio=jnp.max(ratio * mflat),
            adv_mean=jnp.sum(adv.reshape(n) * mflat) / denom,
            target_logp=jnp.sum(logp * mflat) / denom,
        )
        return loss, stats

    (loss, stats), grads = jax.value_and_grad(loss_fn, has_aux=True)(flat)
    gnorm = jnp.sqrt(jnp.sum(grads * grads))
    scale = jnp.where(
        jnp.logical_and(grad_clip > 0.0, gnorm > grad_clip),
        grad_clip / jnp.maximum(gnorm, 1e-12),
        1.0,
    )
    grads = grads * scale
    flat, m, v = _adam_update(flat, m, v, step, grads, lr)

    metrics = jnp.stack([
        loss,
        stats["mean_ratio"],
        stats["clip_frac"],
        stats["approx_kl"],
        stats["entropy"],
        gnorm,
        stats["token_count"],
        stats["max_ratio"],
        stats["adv_mean"],
        stats["target_logp"],
    ])
    return jnp.concatenate([flat, m, v, (step + 1.0)[None], metrics])


def extract_params(cfg, state):
    p_sz = configs.train_state_layout(cfg)["params"][1]
    return jax.lax.slice(state, (0,), (p_sz,))


def extract_metrics(cfg, state):
    """Returns f32[1 + n_metrics] = [step | metrics]."""
    lay = configs.train_state_layout(cfg)
    start = lay["step"][0]
    return jax.lax.slice(state, (start,), (lay["total"],))


def init_train_state(cfg, params_flat):
    lay = configs.train_state_layout(cfg)
    p_sz = lay["params"][1]
    zeros = jnp.zeros(p_sz, jnp.float32)
    tail = jnp.zeros(1 + len(configs.METRIC_NAMES), jnp.float32)
    return jnp.concatenate([params_flat, zeros, zeros, tail])


# ---------------------------------------------------------------------------
# Evaluation / diagnostics artifact


def logprobs_eval(cfg, params_flat, tokens, targets, lens):
    """log pi(target_t | tokens_{<=t}) — f32[B, T].

    Used by the Rust side for off-policy lag diagnostics (compare against the
    recorded behaviour log-probs) and optional KL-to-reference penalties.
    """
    params = unflatten_params(cfg, params_flat)
    logits = forward_full(cfg, params, tokens, lens)
    logz = jax.nn.log_softmax(logits, axis=-1)
    return jnp.take_along_axis(logz, targets[:, :, None], axis=-1)[:, :, 0]
