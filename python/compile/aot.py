"""AOT compile path: lower the L2 graphs to HLO text + manifest + init ckpt.

Python runs ONCE here (`make artifacts`); the Rust coordinator then loads
`artifacts/<config>/*.hlo.txt` via the PJRT C API and never calls back into
Python.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every artifact has exactly ONE array output: multi-output executables come
back from the PJRT shim as a single tuple buffer whose ToLiteralSync
CHECK-fails, so the graphs pack their state into flat vectors instead
(model.py docstring).

Usage:
  python -m compile.aot --out ../artifacts [--configs nano small e2e] [--fig5]
"""

import argparse
import json
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs as cfgs
from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _io(name, shape, dtype):
    return {"name": name, "shape": list(shape), "dtype": dtype}


F32 = "f32"
I32 = "i32"
_NP = {"f32": np.float32, "i32": np.int32}


def artifact_defs(cfg):
    """Entry points to lower for `cfg`: name -> (fn, input specs, out shape).

    Input specs are (name, shape, dtype) in call order — the Rust runtime
    reads these from the manifest and validates literals against them.
    """
    p = cfgs.num_params(cfg)
    ts = cfgs.train_state_layout(cfg)["total"]
    b, s, c = cfg["gen_batch"], cfg["max_seq"], cfg["gen_chunk"]
    bt, t = cfg["train_batch"], cfg["train_seq"]
    n_metrics = len(cfgs.METRIC_NAMES)

    return {
        "generate_chunk": dict(
            fn=lambda params, tokens, lens, frozen, seed, temp, top_k:
                model.generate_chunk(cfg, params, tokens, lens, frozen, seed,
                                     temp, top_k),
            inputs=[("params", (p,), F32), ("tokens", (b, s), I32),
                    ("lens", (b,), I32), ("frozen", (b,), I32),
                    ("seed", (1,), I32), ("temperature", (1,), F32),
                    ("top_k", (1,), I32)],
            output=((b, 2 * c + 2), F32),
        ),
        "train_step": dict(
            fn=lambda state, tokens, targets, blogp, adv, mask, lens, hyp:
                model.train_step(cfg, state, tokens, targets, blogp, adv,
                                 mask, lens, hyp),
            inputs=[("state", (ts,), F32), ("tokens", (bt, t), I32),
                    ("targets", (bt, t), I32), ("blogp", (bt, t), F32),
                    ("adv", (bt, t), F32), ("mask", (bt, t), F32),
                    ("lens", (bt,), I32), ("hyp", (3,), F32)],
            output=((ts,), F32),
        ),
        "extract_params": dict(
            fn=lambda state: model.extract_params(cfg, state),
            inputs=[("state", (ts,), F32)],
            output=((p,), F32),
        ),
        "extract_metrics": dict(
            fn=lambda state: model.extract_metrics(cfg, state),
            inputs=[("state", (ts,), F32)],
            output=((1 + n_metrics,), F32),
        ),
        "logprobs_eval": dict(
            fn=lambda params, tokens, targets, lens:
                model.logprobs_eval(cfg, params, tokens, targets, lens),
            inputs=[("params", (p,), F32), ("tokens", (bt, t), I32),
                    ("targets", (bt, t), I32), ("lens", (bt,), I32)],
            output=((bt, t), F32),
        ),
    }


def lower_one(defn):
    specs = [_spec(shape, _NP[dt]) for _, shape, dt in defn["inputs"]]
    lowered = jax.jit(defn["fn"]).lower(*specs)
    return to_hlo_text(lowered)


def emit_config(cfg, out_dir, fig5=False):
    cdir = os.path.join(out_dir, cfg["name"])
    os.makedirs(cdir, exist_ok=True)
    defs = artifact_defs(cfg)

    manifest_arts = {}
    for name, defn in defs.items():
        path = f"{name}.hlo.txt"
        print(f"  lowering {cfg['name']}/{name} ...", flush=True)
        text = lower_one(defn)
        with open(os.path.join(cdir, path), "w") as f:
            f.write(text)
        manifest_arts[name] = {
            "file": path,
            "inputs": [_io(n, s, d) for n, s, d in defn["inputs"]],
            "output": _io("out", defn["output"][0], defn["output"][1]),
        }

    # Figure-5 sweep variants: train_step at several microbatch sizes and
    # generate_chunk at several decode concurrencies (real Assumption-7.1
    # measurement harness; see benches/fig5_batch_scaling.rs).
    fig5_arts = {}
    if fig5:
        for b in cfgs.FIG5_TRAIN_BATCHES:
            vcfg = dict(cfg, train_batch=b)
            defn = artifact_defs(vcfg)["train_step"]
            name = f"fig5_train_b{b}"
            print(f"  lowering {cfg['name']}/{name} ...", flush=True)
            with open(os.path.join(cdir, f"{name}.hlo.txt"), "w") as f:
                f.write(lower_one(defn))
            fig5_arts[name] = {
                "file": f"{name}.hlo.txt",
                "inputs": [_io(n, s, d) for n, s, d in defn["inputs"]],
                "output": _io("out", defn["output"][0], defn["output"][1]),
            }
        for b in cfgs.FIG5_GEN_BATCHES:
            vcfg = dict(cfg, gen_batch=b)
            defn = artifact_defs(vcfg)["generate_chunk"]
            name = f"fig5_gen_b{b}"
            print(f"  lowering {cfg['name']}/{name} ...", flush=True)
            with open(os.path.join(cdir, f"{name}.hlo.txt"), "w") as f:
                f.write(lower_one(defn))
            fig5_arts[name] = {
                "file": f"{name}.hlo.txt",
                "inputs": [_io(n, s, d) for n, s, d in defn["inputs"]],
                "output": _io("out", defn["output"][0], defn["output"][1]),
            }
    manifest_arts.update(fig5_arts)

    # Initial checkpoint (raw little-endian f32) so Rust and Python agree on
    # initialization without Rust re-implementing jax.random.
    params = np.asarray(model.init_params(cfg, seed=0), dtype="<f4")
    params.tofile(os.path.join(cdir, "init_params.bin"))

    layout = []
    off = 0
    for name, shape in cfgs.param_layout(cfg):
        size = int(np.prod(shape))
        layout.append({"name": name, "shape": list(shape), "offset": off})
        off += size

    ts_lay = cfgs.train_state_layout(cfg)
    manifest = {
        "config": cfg,
        "num_params": cfgs.num_params(cfg),
        "param_layout": layout,
        "train_state": {k: list(v) if isinstance(v, tuple) else v
                        for k, v in ts_lay.items()},
        "metric_names": cfgs.METRIC_NAMES,
        "adam": {"b1": cfgs.ADAM_B1, "b2": cfgs.ADAM_B2, "eps": cfgs.ADAM_EPS},
        "artifacts": manifest_arts,
        "fig5": {
            "train_batches": cfgs.FIG5_TRAIN_BATCHES if fig5 else [],
            "gen_batches": cfgs.FIG5_GEN_BATCHES if fig5 else [],
        },
    }
    with open(os.path.join(cdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  wrote {cdir}/manifest.json ({cfgs.num_params(cfg)} params)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", nargs="*", default=["nano", "small", "e2e"])
    ap.add_argument("--fig5-config", default="small",
                    help="config that also gets Figure-5 sweep variants")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    for name in args.configs:
        cfg = cfgs.CONFIGS[name]
        print(f"config {name}:", flush=True)
        emit_config(cfg, args.out, fig5=(name == args.fig5_config))
    with open(os.path.join(args.out, "index.json"), "w") as f:
        json.dump({"configs": args.configs}, f)
    print("AOT done.")


if __name__ == "__main__":
    main()
