#!/usr/bin/env bash
# Bench-regression gate for the weight-sync plane, the offloading memory
# plane, and the elastic-fleet recovery path.
#
# Compares the freshly-measured target/BENCH_weightsync.json (written by
# `cargo bench --bench weightsync_overlap`) against the committed baseline
# BENCH_weightsync.json at the repo root:
#
#   * shape checks (booleans) must hold outright: sharded+overlapped stall
#     strictly below monolithic, quantized round-trip within bound, delta
#     streams bit-exact (incl. the zero-run-encoded XOR wire format, which
#     must also undercut the full-f32 payload on clustered updates), top-k
#     within its cumulative bound, and the acceptance floor that background
#     publish blocked time is >= 5x below the inline fan-out;
#   * the two headline ratios — overlap_stall_speedup (monolithic stall /
#     sharded+overlapped stall) and publish_blocked_speedup (inline publish
#     blocked / background publish blocked) — must not regress more than
#     BENCH_GATE_TOL (default 20%) below the baseline. Ratios are gated
#     rather than raw seconds so the gate is stable across machines; the
#     raw numbers ride along in the JSON artifact for inspection.
#
# When the committed BENCH_offload.json baseline exists, the memplane bench
# summary (target/BENCH_offload.json, written by `cargo bench --bench
# offload_overlap`) is gated the same way: shape checks (overlapped
# prefetch hides >= 70% of the eager transfer time, oversized colocations
# raise capacity errors, shard integrity holds, colocated arms move the
# full offload volume) plus the prefetch_hidden_frac ratio with an
# absolute 0.7 floor.
#
# When the committed BENCH_elastic.json baseline exists, the elastic
# recovery summary (target/BENCH_elastic.json, written by `cargo bench
# --bench elastic_recovery`) is gated too: shape checks (the supervisor
# absorbs the whole seeded kill schedule without a global stop, every
# parked partial is resumed, both arms reach the row quota) plus the
# throughput-retained and recovery-speed ratios.
#
# Usage: tools/bench_gate.sh [current.json] [baseline.json]
# Env:   BENCH_GATE_TOL=0.20   fractional allowed regression on ratios
#
# Wired into CI (.github/workflows/ci.yml bench-smoke job) and
# `./verify.sh --bench`. Refresh a baseline by copying a trusted run's
# target/BENCH_*.json over the matching repo-root file.

set -uo pipefail
cd "$(dirname "$0")/.."

CUR="${1:-target/BENCH_weightsync.json}"
BASE="${2:-BENCH_weightsync.json}"
TOL="${BENCH_GATE_TOL:-0.20}"

fail=0

if [ ! -f "$CUR" ]; then
    echo "bench_gate: FAIL — current summary $CUR missing (run \
cargo bench --bench weightsync_overlap first)"
    exit 1
fi
if [ ! -f "$BASE" ]; then
    echo "bench_gate: FAIL — committed baseline $BASE missing"
    exit 1
fi

# Extract "key":<scalar> from a flat one-line JSON object.
field() {
    grep -oE "\"$2\":(-?[0-9][0-9.eE+-]*|true|false)" "$1" | head -1 | sed 's/^[^:]*://'
}

require_true() {
    local key="$1"
    local val
    val=$(field "$CUR" "$key")
    if [ "$val" != "true" ]; then
        echo "bench_gate: FAIL — $key is '${val:-missing}', expected true"
        fail=1
    else
        echo "bench_gate: OK   — $key"
    fi
}

# current >= baseline * (1 - TOL), plus an optional absolute floor
require_ratio() {
    local key="$1" floor="${2:-0}"
    local cur base
    cur=$(field "$CUR" "$key")
    base=$(field "$BASE" "$key")
    if [ -z "$cur" ]; then
        echo "bench_gate: FAIL — $key missing from $CUR"
        fail=1
        return
    fi
    if [ -z "$base" ]; then
        echo "bench_gate: FAIL — $key missing from baseline $BASE"
        fail=1
        return
    fi
    if awk -v c="$cur" -v b="$base" -v t="$TOL" -v f="$floor" \
        'BEGIN { min = b * (1 - t); if (f + 0 > min) min = f + 0; exit !(c + 0 >= min) }'
    then
        echo "bench_gate: OK   — $key = $cur (baseline $base, tol $TOL)"
    else
        echo "bench_gate: FAIL — $key = $cur regressed below baseline $base (tol $TOL)"
        fail=1
    fi
}

echo "== bench_gate: $CUR vs $BASE (tol ${TOL}) =="
require_true stall_strictly_lower
require_true quant_within_bound
require_true publish_blocked_5x
require_true delta_exact
require_true rle_below_full
require_true topk_within_bound
require_true auto_adaptive
require_ratio overlap_stall_speedup
require_ratio publish_blocked_speedup 5

# --- memplane offload bench (gated once its baseline is committed) ---
OFF_CUR="${BENCH_OFFLOAD_CUR:-target/BENCH_offload.json}"
OFF_BASE="${BENCH_OFFLOAD_BASE:-BENCH_offload.json}"
if [ -f "$OFF_BASE" ]; then
    if [ ! -f "$OFF_CUR" ]; then
        echo "bench_gate: FAIL — offload summary $OFF_CUR missing (run \
cargo bench --bench offload_overlap first)"
        fail=1
    else
        echo "== bench_gate: $OFF_CUR vs $OFF_BASE (tol ${TOL}) =="
        CUR="$OFF_CUR"
        BASE="$OFF_BASE"
        require_true prefetch_hides_70pct
        require_true capacity_error_raised
        require_true integrity_ok
        require_true moved_full_volume
        require_ratio prefetch_hidden_frac 0.7
    fi
else
    echo "bench_gate: note — $OFF_BASE baseline not committed yet; offload \
gate skipped"
fi

# --- elastic recovery bench (gated once its baseline is committed) ---
ELA_CUR="${BENCH_ELASTIC_CUR:-target/BENCH_elastic.json}"
ELA_BASE="${BENCH_ELASTIC_BASE:-BENCH_elastic.json}"
if [ -f "$ELA_BASE" ]; then
    if [ ! -f "$ELA_CUR" ]; then
        echo "bench_gate: FAIL — elastic summary $ELA_CUR missing (run \
cargo bench --bench elastic_recovery first)"
        fail=1
    else
        echo "== bench_gate: $ELA_CUR vs $ELA_BASE (tol ${TOL}) =="
        CUR="$ELA_CUR"
        BASE="$ELA_BASE"
        # shape: the supervisor absorbs the whole kill schedule (no
        # escalation to a global stop), every scheduled kill restarts,
        # every parked partial is resumed, and both arms hit their quota
        require_true no_global_stop
        require_true restarts_complete
        require_true partials_migrated_ok
        require_true rows_complete
        # ratios (greater is better, conservative committed baselines):
        # fraction of clean throughput retained under churn, and inverse
        # mean kill->first-row recovery time
        require_ratio throughput_retained_frac 0.1
        require_ratio recovery_speed
    fi
else
    echo "bench_gate: note — $ELA_BASE baseline not committed yet; elastic \
gate skipped"
fi

# --- multi-trainer scaling bench (gated once its baseline is committed) ---
MT_CUR="${BENCH_MULTITRAINER_CUR:-target/BENCH_multitrainer.json}"
MT_BASE="${BENCH_MULTITRAINER_BASE:-BENCH_multitrainer.json}"
if [ -f "$MT_BASE" ]; then
    if [ ! -f "$MT_CUR" ]; then
        echo "bench_gate: FAIL — multitrainer summary $MT_CUR missing (run \
cargo bench --bench multitrainer_scaling first)"
        fail=1
    else
        echo "== bench_gate: $MT_CUR vs $MT_BASE (tol ${TOL}) =="
        CUR="$MT_CUR"
        BASE="$MT_BASE"
        # shape: both arms drain the full row quota, the 2-replica
        # partition is exactly disjoint, every step published, and the
        # DES periodic point lands between sync and async wall clocks
        require_true rows_complete
        require_true partition_disjoint
        require_true publishes_complete
        require_true periodic_between
        # headline: trained-rows/sec at 2 trainer replicas vs 1 — the
        # ISSUE's acceptance floor is an absolute 1.6x
        require_ratio trainer_scaling_2x 1.6
    fi
else
    echo "bench_gate: note — $MT_BASE baseline not committed yet; \
multitrainer gate skipped"
fi

if [ "$fail" = 0 ]; then
    echo "bench_gate: PASS"
else
    echo "bench_gate: FAILED"
fi
exit "$fail"
