#!/usr/bin/env bash
# Tier-1 verification entry point (see ROADMAP.md).
#
#   ./verify.sh            build + test (+ advisory fmt check)
#   ./verify.sh --strict   also fail on rustfmt drift
#
# The fmt check is advisory by default because the offline image may lack
# a rustfmt component; build + test are the hard gate.

set -uo pipefail
cd "$(dirname "$0")"

strict_fmt=0
[ "${1:-}" = "--strict" ] && strict_fmt=1

fail=0

echo "== cargo build --release =="
cargo build --release || fail=1

echo "== cargo test -q =="
cargo test -q || fail=1

echo "== cargo fmt --check (advisory) =="
if cargo fmt --version >/dev/null 2>&1; then
    if ! cargo fmt --all -- --check; then
        echo "warning: rustfmt drift detected"
        [ "$strict_fmt" = 1 ] && fail=1
    fi
else
    echo "rustfmt not installed; skipping"
fi

if [ "$fail" = 0 ]; then
    echo "verify: OK"
else
    echo "verify: FAILED"
fi
exit "$fail"
