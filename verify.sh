#!/usr/bin/env bash
# Tier-1 verification entry point (see ROADMAP.md).
#
#   ./verify.sh            build + test (+ advisory fmt & clippy checks)
#   ./verify.sh --strict   also fail on rustfmt drift / clippy findings
#
# The fmt and clippy checks are advisory by default because the offline
# image may lack those components; build + test are the hard gate.

set -uo pipefail
cd "$(dirname "$0")"

strict=0
[ "${1:-}" = "--strict" ] && strict=1

fail=0

echo "== cargo build --release =="
cargo build --release || fail=1

echo "== cargo test -q =="
cargo test -q || fail=1

echo "== cargo fmt --check (advisory) =="
if cargo fmt --version >/dev/null 2>&1; then
    if ! cargo fmt --all -- --check; then
        echo "warning: rustfmt drift detected"
        [ "$strict" = 1 ] && fail=1
    fi
else
    echo "rustfmt not installed; skipping"
fi

echo "== cargo clippy -q --all-targets (advisory) =="
if cargo clippy --version >/dev/null 2>&1; then
    # clippy exits 0 on plain warnings; strict mode must deny them for the
    # gate to exist
    clippy_flags=""
    [ "$strict" = 1 ] && clippy_flags="-D warnings"
    if ! cargo clippy -q --all-targets -- $clippy_flags; then
        echo "warning: clippy findings detected"
        [ "$strict" = 1 ] && fail=1
    fi
else
    echo "clippy not installed; skipping"
fi

if [ "$fail" = 0 ]; then
    echo "verify: OK"
else
    echo "verify: FAILED"
fi
exit "$fail"
