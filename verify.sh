#!/usr/bin/env bash
# Tier-1 verification entry point (see ROADMAP.md).
#
#   ./verify.sh            build + test (+ advisory fmt & clippy checks)
#   ./verify.sh --strict   also fail on rustfmt drift / clippy findings
#   ./verify.sh --bench    also run the weight-sync + offload benches and
#                          gate them against the committed repo-root
#                          BENCH_weightsync.json / BENCH_offload.json
#                          baselines (tools/bench_gate.sh)
#
# The fmt and clippy checks are advisory by default because the offline
# image may lack those components; build + test are the hard gate. CI
# (.github/workflows/ci.yml) runs plain verify as the required job, strict
# as allowed-to-fail, and the bench gate in its own smoke job.

set -uo pipefail
cd "$(dirname "$0")"

strict=0
run_bench=0
for arg in "$@"; do
    case "$arg" in
        --strict) strict=1 ;;
        --bench) run_bench=1 ;;
        *) echo "verify.sh: unknown flag '$arg' (use --strict / --bench)"; exit 2 ;;
    esac
done

fail=0

echo "== cargo build --release =="
cargo build --release || fail=1

echo "== cargo test -q =="
cargo test -q || fail=1

echo "== cargo fmt --check (advisory) =="
if cargo fmt --version >/dev/null 2>&1; then
    if ! cargo fmt --all -- --check; then
        echo "warning: rustfmt drift detected"
        [ "$strict" = 1 ] && fail=1
    fi
else
    echo "rustfmt not installed; skipping"
fi

echo "== cargo clippy -q --all-targets (advisory) =="
if cargo clippy --version >/dev/null 2>&1; then
    # clippy exits 0 on plain warnings; strict mode must deny them for the
    # gate to exist
    clippy_flags=""
    [ "$strict" = 1 ] && clippy_flags="-D warnings"
    if ! cargo clippy -q --all-targets -- $clippy_flags; then
        echo "warning: clippy findings detected"
        [ "$strict" = 1 ] && fail=1
    fi
else
    echo "clippy not installed; skipping"
fi

if [ "$run_bench" = 1 ]; then
    echo "== cargo bench --bench weightsync_overlap/offload_overlap + bench gate =="
    bench_ok=1
    cargo bench --bench weightsync_overlap || { echo "error: weightsync_overlap bench failed"; bench_ok=0; }
    cargo bench --bench offload_overlap || { echo "error: offload_overlap bench failed"; bench_ok=0; }
    if [ "$bench_ok" = 1 ]; then
        ./tools/bench_gate.sh || fail=1
    else
        fail=1
    fi
fi

if [ "$fail" = 0 ]; then
    echo "verify: OK"
else
    echo "verify: FAILED"
fi
exit "$fail"
