//! Cluster-scale what-if explorer: interactively sweep the calibrated cost
//! model that reproduces the paper's Tables/Figures, for configurations the
//! paper never ran.
//!
//!     cargo run --release --example simulate_cluster -- \
//!         [--model 8B|70B|405B] [--gpus N] [--batch B] [--no-fp8]
//!
//! Prints: the optimizer's best sync and async configurations, the
//! theta sweep (how sensitive the step time is to the GPU split), and the
//! DDMA/PS weight-sync comparison at this scale.

use llamarl::ddma::ps_baseline::PsModel;
use llamarl::ddma::topology::DdmaModel;
use llamarl::simulator::problem::{eval_async_config, solve_async, solve_sync};
use llamarl::simulator::{HardwareModel, LLAMA_MODELS};
use llamarl::util::bench::Table;
use llamarl::util::cli::Args;

fn main() -> llamarl::Result<()> {
    let args = Args::from_env(&["no-fp8"])?;
    let name = args.str_or("model", "70B");
    let model = LLAMA_MODELS
        .iter()
        .find(|m| m.name == name)
        .copied()
        .ok_or_else(|| llamarl::Error::msg("model must be 8B|70B|405B"))?;
    let mut hw = HardwareModel::paper_scale(model);
    hw.g0 = args.usize_or("gpus", hw.g0 as usize)? as f64;
    hw.b0 = args.usize_or("batch", hw.b0 as usize)? as f64;
    hw.fp8_generator = !args.flag("no-fp8");

    println!(
        "\n=== cluster what-if: {} on {} GPUs, global batch {} (fp8 gen: {}) ===\n",
        model.name, hw.g0, hw.b0, hw.fp8_generator
    );

    let p = hw.problem();
    let sync = solve_sync(&p);
    let asn = solve_async(&p);
    println!("baseline replay (paper cfg): {:.1} s/step", hw.baseline_replay_secs());
    println!(
        "best sync   : {:.1} s/step  (bt={} bg={} m={})",
        sync.step_secs, sync.bt, sync.bg, sync.m
    );
    println!(
        "best async  : {:.1} s/step  (bt={} bg={} mt={} mg={} theta={:.2} -> {}t/{}g GPUs)",
        asn.step_secs,
        asn.bt,
        asn.bg,
        asn.mt,
        asn.mg,
        asn.theta,
        asn.trainer_gpus.round(),
        asn.generator_gpus.round()
    );
    println!(
        "speedup     : {:.2}x vs paper-config baseline, {:.2}x vs best sync\n",
        hw.baseline_replay_secs() / asn.step_secs,
        sync.step_secs / asn.step_secs
    );

    println!("--- theta sensitivity (GPU split trainer/generator) ---\n");
    let mut t = Table::new(&["theta", "trainer GPUs", "step secs", ""]);
    for i in 1..10 {
        let theta = i as f64 / 10.0;
        let secs = eval_async_config(&p, asn.bt, asn.bg, asn.mt, asn.mg, theta);
        let bar = "#".repeat((40.0 * asn.step_secs / secs) as usize);
        t.row(vec![
            format!("{theta:.1}"),
            format!("{}", (theta * hw.g0).round()),
            format!("{secs:.1}"),
            bar,
        ]);
    }
    t.print();

    println!("\n--- weight sync at this scale ---\n");
    let ddma = DdmaModel::calibrated();
    let ps = PsModel::calibrated();
    println!(
        "DDMA: {:.2} s   (theoretical link floor {:.4} s)",
        ddma.sync_secs(model.params, asn.trainer_gpus.round() as usize),
        ddma.floor_secs(model.params, asn.trainer_gpus.round() as usize)
    );
    println!("parameter-server baseline: {:.1} s", ps.sync_secs(model.params));
    Ok(())
}
