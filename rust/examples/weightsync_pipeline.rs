//! Sharded weight-sync walkthrough (paper §5.2): resharding planner,
//! quantized shard transfer, and generation-overlapped double buffering.
//!
//! Self-contained (no artifacts needed): builds a synthetic tensor map,
//! reshards a trainer-side FSDP layout into a generator-side TP layout,
//! streams a quantized publish into a double-buffered generator slot while
//! a "decode" thread keeps reading the old version, and finishes with the
//! cluster-scale cost of the same schedule.
//!
//!     cargo run --release --example weightsync_pipeline

use std::sync::Arc;

use llamarl::ddma::topology::DdmaModel;
use llamarl::ddma::WeightsBus;
use llamarl::util::bench::fmt_secs;
use llamarl::weightsync::{
    contiguous_entries, even_entries, plan_reshard, run_transfer, Layout, ShardEncoding,
};

fn main() -> llamarl::Result<()> {
    // 1. two disagreeing tilings of the same flat vector
    let sizes = [4096usize, 4096, 2048, 2048, 1024];
    let es = contiguous_entries(&sizes);
    let p: usize = sizes.iter().sum();
    let src = Layout::fsdp(p, 4);
    let dst = Layout::tp(p, 2, &es)?;
    println!(
        "flat vector: {p} params; trainer FSDP over {} ranks ({} intervals), \
         generator TP over {} ranks ({} intervals)",
        src.n_ranks,
        src.shards.len(),
        dst.n_ranks,
        dst.shards.len()
    );

    // 2. the minimal per-link schedule between them
    let plan = plan_reshard(&src, &dst)?;
    println!(
        "\nreshard plan: {} ops over {} links; busiest link {} elems \
         (total {}):",
        plan.ops.len(),
        plan.n_links(),
        plan.max_link_elems(),
        plan.total_elems()
    );
    for (link, elems) in plan.link_elems() {
        println!("  trainer r{} -> generator r{}: {elems} elems", link.0, link.1);
    }

    // 3. quantized shard transfer: 4x fewer bytes, bounded error
    let params: Vec<f32> = (0..p).map(|i| ((i as f32) * 0.031).sin()).collect();
    let mut out = vec![0.0f32; p];
    let f32_t = run_transfer(&params, &mut out, &plan, 1, ShardEncoding::F32);
    assert_eq!(out, params);
    let int8_t = run_transfer(&params, &mut out, &plan, 2, ShardEncoding::Int8);
    println!(
        "\ntransfer: f32 {} bytes exact; int8 {} bytes, max |err| {:.2e} \
         (bound {:.2e})",
        f32_t.bytes, int8_t.bytes, int8_t.max_abs_err, int8_t.err_bound
    );
    assert!(int8_t.max_abs_err <= int8_t.err_bound);

    // 4. generation-overlapped double buffering with version fencing
    let bus = Arc::new(WeightsBus::with_layouts(
        vec![0.0; p],
        src,
        dst,
        ShardEncoding::Int8,
    )?);
    let slot = bus.register_generator();
    let publisher = {
        let bus = bus.clone();
        std::thread::spawn(move || {
            for v in 1..=3u64 {
                bus.publish(vec![v as f32; p]);
            }
        })
    };
    let mut attaches = 0u64;
    let mut seen = Vec::new();
    loop {
        // decode keeps reading a complete front version the whole time
        let front = slot.attach();
        assert!(front.data.iter().all(|x| (*x - front.version as f32).abs() < 0.05));
        attaches += 1;
        if let Some(snap) = slot.swap_at_boundary() {
            seen.push(snap.version);
        }
        if bus.version() >= 3 {
            while let Some(snap) = slot.swap_at_boundary() {
                seen.push(snap.version);
            }
            break;
        }
    }
    publisher.join().unwrap();
    println!(
        "\ndouble buffering: decode attached {attaches} times while 3 versions \
         streamed; fenced swaps promoted versions {seen:?} \
         (mean swap stall {})",
        fmt_secs(slot.mean_stall_secs())
    );
    println!(
        "ddma facade: {} publishes, mean {} each; slowest-shard (parallel) {}",
        bus.publish_count(),
        fmt_secs(bus.mean_publish_secs()),
        fmt_secs(bus.mean_shard_max_secs())
    );

    // 5. the same schedule at cluster scale (70B, Table 4)
    let model = DdmaModel::calibrated();
    let p70: usize = 70_000_000_000;
    let plan70 = plan_reshard(
        &Layout::fsdp(p70, 128),
        &Layout::tp(p70, 8, &even_entries(p70, 80))?,
    )?;
    println!(
        "\ncluster (70B): monolithic broadcast {}, planned bf16 {}, \
         planned int8 {} — time follows the busiest link, not model size.",
        fmt_secs(p70 as f64 * 2.0 / model.link.ib_bps),
        fmt_secs(model.plan_secs(&plan70, 2.0)),
        fmt_secs(model.plan_secs(&plan70, 1.0)),
    );
    Ok(())
}
