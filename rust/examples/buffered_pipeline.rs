//! The streaming trajectory data plane, end to end: direct-channel async
//! (Mode::Async) vs buffered async over the RolloutStore
//! (Mode::AsyncBuffered), compared on throughput and realized off-policy
//! lag.
//!
//! With compiled artifacts present (`make artifacts`) this drives the REAL
//! pipeline twice — same executors, same DDMA bus, only the reward→trainer
//! data plane differs. Without artifacts it falls back to the synthetic
//! threaded driver (real threads, real store, modeled compute) plus the
//! discrete-event timeline, so the example always runs end to end.
//!
//!     cargo run --release --example buffered_pipeline -- [--steps 6]
//!     cargo run --release --example buffered_pipeline -- --max-staleness 2

use llamarl::coordinator::{run_training, Mode, PipelineConfig};
use llamarl::dataplane::{
    run_driver, AdmissionPolicy, DriverConfig, SamplingStrategy, StoreConfig, Transport,
};
use llamarl::metrics::print_report;
use llamarl::simulator::{simulate_async_buffered, BufferedDesConfig, DesConfig};
use llamarl::simulator::des::simulate_async;
use llamarl::util::bench::Table;
use llamarl::util::cli::Args;

fn main() -> llamarl::Result<()> {
    let args = Args::from_env(&[])?;
    let artifact_dir = args.str_or("artifacts", "artifacts/nano");
    let bound = args.u64_or("max-staleness", 4)?;
    let staleness = if bound == 0 { None } else { Some(bound) };

    if std::path::Path::new(&artifact_dir).join("manifest.json").exists() {
        real_pipeline(&args, &artifact_dir, staleness)?;
    } else {
        eprintln!(
            "{artifact_dir} missing (run `make artifacts`) — using the synthetic driver\n"
        );
        synthetic_pipeline(&args, staleness)?;
    }
    Ok(())
}

/// Both real pipelines over the compiled artifacts.
fn real_pipeline(args: &Args, artifact_dir: &str, staleness: Option<u64>) -> llamarl::Result<()> {
    let base = PipelineConfig {
        artifact_dir: artifact_dir.into(),
        max_steps: args.u64_or("steps", 6)?,
        max_response: 10,
        n_generations: 4,
        n_generator_workers: 2,
        queue_capacity: 2,
        store: StoreConfig {
            capacity: 64,
            max_staleness: staleness,
            ..StoreConfig::default()
        },
        ..PipelineConfig::default()
    };

    println!("--- direct-channel async (Mode::Async) ---");
    let direct = run_training(&PipelineConfig {
        mode: Mode::Async,
        out_dir: std::env::temp_dir().join("llamarl_bufex_async"),
        ..base.clone()
    })?;
    print_report(&direct);

    println!("\n--- buffered async over the RolloutStore (Mode::AsyncBuffered) ---");
    let buffered = run_training(&PipelineConfig {
        mode: Mode::AsyncBuffered,
        out_dir: std::env::temp_dir().join("llamarl_bufex_buffered"),
        ..base
    })?;
    print_report(&buffered);

    let lag = |r: &llamarl::coordinator::RunReport| {
        let n = r.records.len().max(1) as f64;
        r.records.iter().map(|x| x.mean_lag).sum::<f64>() / n
    };
    println!(
        "\ncomparison: direct {:.2}s/step lag {:.2} | buffered {:.2}s/step lag {:.2}{}",
        direct.mean_step_secs(),
        lag(&direct),
        buffered.mean_step_secs(),
        lag(&buffered),
        staleness.map_or(String::new(), |b| format!(" (bound {b})")),
    );
    Ok(())
}

/// No artifacts: the synthetic threaded driver + the DES timeline.
fn synthetic_pipeline(args: &Args, staleness: Option<u64>) -> llamarl::Result<()> {
    let steps = args.u64_or("steps", 40)?;
    let base = DriverConfig {
        train_steps: steps,
        ..DriverConfig::default()
    };
    let store = |sampling: SamplingStrategy| {
        Transport::Store(StoreConfig {
            capacity: 64,
            shards: 4,
            max_staleness: staleness,
            admission: AdmissionPolicy::EvictOldest,
            sampling,
            seed: 0,
        })
    };

    println!("synthetic driver: {steps} train steps, 2 producers, real threads\n");
    let mut t = Table::new(&["transport", "rows/s", "mean lag", "max lag", "dropped"]);
    for transport in [
        Transport::Channel { capacity: 4 },
        store(SamplingStrategy::Fifo),
        store(SamplingStrategy::FreshestFirst),
        store(SamplingStrategy::StalenessWeighted),
    ] {
        let r = run_driver(&DriverConfig {
            transport,
            ..base.clone()
        });
        let dropped = r
            .dataplane
            .as_ref()
            .map(|d| d.dropped_stale + d.dropped_capacity + d.evicted)
            .unwrap_or(0);
        t.row(vec![
            r.transport.clone(),
            format!("{:.0}", r.rows_per_sec),
            format!("{:.2}", r.mean_lag),
            r.max_lag.to_string(),
            dropped.to_string(),
        ]);
    }
    t.print();

    println!("\nDES timeline (train-bound regime, staleness pressure visible):\n");
    let cfg = DesConfig {
        steps: 200,
        train_secs: 48.0,
        ..DesConfig::default()
    };
    let direct = simulate_async(&cfg);
    let buffered = simulate_async_buffered(
        &cfg,
        &BufferedDesConfig {
            store_capacity: 8,
            max_staleness: staleness.unwrap_or(u64::MAX),
            freshest_first: false,
        },
    );
    let mut d = Table::new(&["arch", "s/step", "mean lag", "max lag", "dropped batches"]);
    d.row(vec![
        "async (channel)".into(),
        format!("{:.2}", direct.step_secs_mean),
        format!("{:.2}", direct.mean_lag_steps),
        format!("{:.0}", direct.max_lag_steps),
        "0".into(),
    ]);
    d.row(vec![
        "async_buffered (store)".into(),
        format!("{:.2}", buffered.step_secs_mean),
        format!("{:.2}", buffered.mean_lag_steps),
        format!("{:.0}", buffered.max_lag_steps),
        buffered.dropped_batches.to_string(),
    ]);
    d.print();
    println!(
        "\nShape check: the store holds realized lag at or below the bound by\n\
         dropping aged batches, while the free-running generator keeps the\n\
         trainer fed — the channel can only bound lag by throttling."
    );
    Ok(())
}
