//! Paper **Figure 6**: training-quality comparison — synchronous on-policy
//! RL vs asynchronous AIPO, evaluated on the three held-out suites
//! (math_test / math_500 / gsm_style, the MATH / MATH-500 / GSM8K analogs).
//!
//! Both arms share the same pretrained base checkpoint, hyper-parameters,
//! seeds and step budget; the only difference is the execution architecture
//! (paper §8.3). Expected shape: the async curves track the sync curves —
//! off-policyness with AIPO correction does not cost quality.
//!
//!     cargo run --release --example quality_comparison -- \
//!         [--artifacts artifacts/small] [--steps 60] [--pretrain-steps 1500]

use llamarl::coordinator::{
    run_pretraining, run_training, Mode, PipelineConfig, PretrainConfig, RunReport,
};
use llamarl::util::bench::Table;
use llamarl::util::cli::Args;

fn last_eval(r: &RunReport, suite: &str) -> Option<f64> {
    r.evals
        .iter()
        .filter(|e| e.suite == suite)
        .next_back()
        .map(|e| e.accuracy)
}

fn first_eval(r: &RunReport, suite: &str) -> Option<f64> {
    r.evals.iter().find(|e| e.suite == suite).map(|e| e.accuracy)
}

fn main() -> llamarl::Result<()> {
    let args = Args::from_env(&[])?;
    let artifact_dir = args.str_or("artifacts", "artifacts/small");
    let steps = args.u64_or("steps", 60)?;
    let out_root = std::path::PathBuf::from(args.str_or("out", "runs/quality"));
    let ckpt = out_root.join("pretrained");

    println!("pretraining shared base model ...");
    let rep = run_pretraining(
        &PretrainConfig {
            artifact_dir: artifact_dir.clone().into(),
            steps: args.u64_or("pretrain-steps", 1500)?,
            lr: 1e-3,
            grad_clip: 1.0,
            seed: 7,
            log_every: 0,
        },
        &ckpt,
    )?;
    println!("base model target_logp {:.3}", rep.final_target_logp);

    let base = PipelineConfig {
        artifact_dir: artifact_dir.into(),
        max_steps: steps,
        n_generations: 4,
        temperature: 0.8,
        max_response: 10,
        eval_every: (steps / 4).max(1),
        eval_max_per_suite: args.usize_or("eval-problems", 64)?,
        init_checkpoint: Some(ckpt),
        seed: 11,
        ..PipelineConfig::default()
    };

    println!("\n=== arm 1/2: synchronous on-policy baseline ===");
    let sync = run_training(&PipelineConfig {
        mode: Mode::Sync,
        out_dir: out_root.join("sync"),
        ..base.clone()
    })?;
    println!("{}", sync.summary());

    println!("\n=== arm 2/2: asynchronous AIPO (LlamaRL) ===");
    let asy = run_training(&PipelineConfig {
        mode: Mode::Async,
        n_generator_workers: 2,
        queue_capacity: 3,
        out_dir: out_root.join("async"),
        ..base
    })?;
    println!("{}", asy.summary());

    println!("\n=== Figure 6: final accuracy by suite ===\n");
    let mut t = Table::new(&["suite", "base (v0)", "sync final", "async final", "delta"]);
    for suite in ["math_test", "math_500", "gsm_style"] {
        let base_acc = first_eval(&sync, suite).unwrap_or(f64::NAN);
        let s = last_eval(&sync, suite).unwrap_or(f64::NAN);
        let a = last_eval(&asy, suite).unwrap_or(f64::NAN);
        t.row(vec![
            suite.into(),
            format!("{:.1}%", base_acc * 100.0),
            format!("{:.1}%", s * 100.0),
            format!("{:.1}%", a * 100.0),
            format!("{:+.1}pp", (a - s) * 100.0),
        ]);
    }
    t.print();

    println!(
        "\ntraining rewards: sync final {:.3}, async final {:.3}",
        sync.final_reward(),
        asy.final_reward()
    );
    println!(
        "wall-clock: sync {:.0}s vs async {:.0}s for the same {} steps",
        sync.wall_secs, asy.wall_secs, steps
    );
    println!(
        "\nShape check (paper Fig. 6): async deltas within noise of sync —\n\
         asynchronous training does not compromise model quality."
    );
    Ok(())
}
