//! DDMA weight-synchronization walkthrough (paper §5.2, Figure 4).
//!
//! Demonstrates the in-process DDMA path end to end with REAL weights from
//! the nano artifacts: the trainer publishes sharded snapshots to the bus,
//! concurrent generator "workers" attach zero-copy, versions stay
//! monotonic, and a late subscriber blocks until the version it needs.
//! Finishes with the calibrated cluster-scale Table-4 numbers.
//!
//!     cargo run --release --example ddma_demo

use std::sync::Arc;
use std::time::Instant;

use llamarl::ddma::ps_baseline::PsModel;
use llamarl::ddma::topology::DdmaModel;
use llamarl::ddma::{sharded_copy, WeightsBus};
use llamarl::model::load_init_params;
use llamarl::runtime::Manifest;
use llamarl::util::bench::fmt_secs;

fn main() -> llamarl::Result<()> {
    let manifest = Manifest::load("artifacts/nano")?;
    let params = load_init_params(&manifest)?;
    let p = params.len();
    println!("model: {} params ({:.1} MB f32)\n", p, p as f64 * 4.0 / 1e6);

    // 1. sharded snapshot (each "rank" copies only its shard)
    let t0 = Instant::now();
    let copy = sharded_copy(&params, 8);
    let copy_t = t0.elapsed().as_secs_f64();
    let max_shard = copy.shard_secs.iter().cloned().fold(0.0, f64::max);
    println!(
        "sharded copy: total {} over 8 shards; slowest shard {} \
         (cluster DDMA time = max shard, shards move in parallel)",
        fmt_secs(copy_t),
        fmt_secs(max_shard),
    );

    // 2. bus publish / zero-copy attach with concurrent subscribers
    let bus = Arc::new(WeightsBus::new(copy.data));
    let mut readers = Vec::new();
    for w in 0..3 {
        let bus = bus.clone();
        readers.push(std::thread::spawn(move || {
            // wait for version 5, then attach
            let snap = bus.wait_for(5);
            (w, snap.version, snap.data.len())
        }));
    }
    let t1 = Instant::now();
    for step in 1..=5u64 {
        let mut new = (*bus.latest().data).clone();
        new[0] = step as f32; // "optimizer update"
        let v = bus.publish(new);
        assert_eq!(v, step);
    }
    println!(
        "published 5 versions in {} ({}/publish mean incl. snapshot copy)",
        fmt_secs(t1.elapsed().as_secs_f64()),
        fmt_secs(bus.mean_publish_secs()),
    );
    for r in readers {
        let (w, version, len) = r.join().unwrap();
        println!("worker {w}: attached to version {version} ({len} params, zero-copy Arc)");
    }

    // 3. cluster-scale model (Table 4)
    println!("\n--- calibrated cluster-scale comparison (paper Table 4) ---\n");
    let ddma = DdmaModel::calibrated();
    let ps = PsModel::calibrated();
    for (name, params) in [("7B", 7e9), ("70B", 70e9), ("405B", 405e9)] {
        let gpus = if params > 100e9 { 512 } else { 128 };
        println!(
            "{name:>5}: DDMA {:>6.2} s   vs   parameter-server {:>8.2} s   ({:.0}x)",
            ddma.sync_secs(params, gpus),
            ps.sync_secs(params),
            ps.sync_secs(params) / ddma.sync_secs(params, gpus)
        );
    }
    println!(
        "\nterabyte-scale weights sync in ~2 s because every GPU only moves\n\
         its own shard — time is a function of shard size, not model size."
    );
    Ok(())
}
