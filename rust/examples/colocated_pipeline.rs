//! Colocated offloading walkthrough: the memory plane end to end.
//!
//! Self-contained (no artifacts needed): plans a colocated placement for a
//! testbed-scale rank, drives the generate -> train phase-lease cycle with
//! the background offload executor (optimizer state swaps to host behind
//! decode, prefetches back behind the hint), and shows the two loud
//! failure modes: an infeasible colocation rejected at plan time, and a
//! double-free caught by the pool accountant.
//!
//!     cargo run --release --example colocated_pipeline

use llamarl::ddma::topology::DdmaModel;
use llamarl::memplane::plan::{plan_colocation, Phase, Residency};
use llamarl::memplane::pool::{AllocClass, MemPool, MemSpec, Placement};
use llamarl::memplane::{MemPlane, MemPlaneConfig};
use llamarl::simulator::hardware::{HardwareModel, LLAMA_MODELS};
use llamarl::util::bench::fmt_secs;

const MB: u64 = 1_000_000;

fn main() -> llamarl::Result<()> {
    // 1. a rank whose phases fit but whose union does not: the colocated
    //    regime (train needs 120 MB, generate-with-optimizer 160, cap 136)
    let spec = MemSpec::new(24 * MB, 24 * MB, 48 * MB, 64 * MB, 24 * MB);
    let cap = 136 * MB;
    let offload = [AllocClass::Grads, AllocClass::OptimState];
    let plan = plan_colocation(spec, cap, 512 * MB, true, false, &offload)?;
    println!("colocation plan ({} MB rank, {} MB total state):", cap / MB, spec.total() / MB);
    for p in Phase::ALL {
        let placed: Vec<String> = AllocClass::ALL
            .iter()
            .map(|c| {
                format!(
                    "{}:{}",
                    c.name(),
                    match plan.residency(p, *c) {
                        Residency::Device => "dev",
                        Residency::Host => "HOST",
                        Residency::Dropped => "-",
                    }
                )
            })
            .collect();
        println!(
            "  {:<9} {} ({} MB on device)",
            p.name(),
            placed.join(" "),
            plan.device_bytes(p) / MB
        );
    }

    // 2. the same plan, infeasible: rejected before anything runs
    match plan_colocation(spec, 100 * MB, 512 * MB, true, false, &offload) {
        Err(e) => println!("\n100 MB rank rejected loudly:\n  {e}"),
        Ok(_) => unreachable!("train phase cannot fit 100 MB"),
    }

    // 3. the live plane: lease cycle with background offload + prefetch
    let plane = MemPlane::new(
        spec,
        &MemPlaneConfig {
            colocate: true,
            device_bytes: cap,
            host_bytes: 512 * MB,
            ..MemPlaneConfig::default()
        },
    )?;
    for round in 0..3 {
        {
            let g = plane.lease(Phase::Generate)?;
            plane.hint_next(Phase::Train); // stream the optimizer back early
            g.wait_class(AllocClass::KvCache)?; // KV grows as the drain frees HBM
        }
        {
            let t = plane.lease(Phase::Train)?;
            t.wait_class(AllocClass::OptimState)?;
            t.wait_class(AllocClass::Grads)?;
        }
        println!(
            "round {round}: device {} / {} MB, host {} MB",
            plane.usage().device_used / MB,
            plane.device_cap() / MB,
            plane.usage().host_used / MB
        );
    }
    plane.flush()?;
    plane.verify_integrity()?;
    let m = plane.metrics();
    use std::sync::atomic::Ordering::Relaxed;
    println!(
        "3 rounds: {:.0} MB offloaded, {:.0} MB prefetched, leases blocked \
         {}, {} prefetch hits",
        m.d2h_bytes.load(Relaxed) as f64 / 1e6,
        m.h2d_bytes.load(Relaxed) as f64 / 1e6,
        fmt_secs(m.wait_secs()),
        m.prefetch_hits.load(Relaxed),
    );

    // 4. the accountant catches protocol violations
    let pool = MemPool::new(10 * MB, 10 * MB);
    let a = pool.acquire(AllocClass::Params, 4 * MB, Placement::Device)?;
    pool.release(a)?;
    match pool.release(a) {
        Err(e) => println!("\ndouble free caught: {e}"),
        Ok(()) => unreachable!("double free must error"),
    }

    // 5. paper scale: the 70B colocated rank's flip costs on the PCIe link
    let hw = HardwareModel::paper_scale(LLAMA_MODELS[1]);
    let s70 = MemSpec::paper_rank(&hw, 8.0, 6.0, 128.0);
    let plan70 = plan_colocation(
        s70,
        hw.gpu.mem_bytes as u64,
        u64::MAX,
        true,
        false,
        &offload,
    )?;
    let (d2h, h2d) = plan70.des_offload_costs(&DdmaModel::calibrated(), 64);
    println!(
        "\n70B colocated H100 rank (mp=8): offload {} + prefetch {} per \
         step, hidden behind a multi-second generation window",
        fmt_secs(d2h),
        fmt_secs(h2d)
    );
    Ok(())
}
