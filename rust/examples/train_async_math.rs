//! End-to-end driver (DESIGN.md §Experiment index): full-system training of
//! the `e2e` transformer (~3.4M params) on the synthetic arithmetic corpus.
//!
//! Pipeline, mirroring the paper's production flow:
//!   1. supervised pretraining (the "base model" — the paper starts from
//!      pretrained Llama-3.1),
//!   2. asynchronous AIPO RL: DP generator workers + reward executor +
//!      trainer, DDMA weight sync, partial rollouts, group-mean baseline,
//!   3. periodic greedy evaluation on the three held-out suites.
//!
//! Results land in EXPERIMENTS.md §E2E. Flags:
//!   --pretrain-steps N   (default 3000; 0 reuses an existing checkpoint)
//!   --steps N            RL steps (default 300)
//!   --mode sync|async    (default async)
//!   --workers N          generator workers (default 2)
//!   --rho X              AIPO clip (default 4; <=0 disables correction)
//!   --out DIR            run directory (default runs/e2e_async)

use llamarl::coordinator::{
    run_pretraining, run_training, Mode, PipelineConfig, PretrainConfig,
};
use llamarl::metrics::{print_report, report_json};
use llamarl::util::cli::Args;

fn main() -> llamarl::Result<()> {
    let args = Args::from_env(&["quantize-generator"])?;
    let artifact_dir = args.str_or("artifacts", "artifacts/e2e");
    let out_dir = std::path::PathBuf::from(args.str_or("out", "runs/e2e_async"));
    let ckpt_dir = out_dir.join("pretrained");
    let pretrain_steps = args.u64_or("pretrain-steps", 3000)?;
    std::fs::create_dir_all(&out_dir)?;

    // Phase 1: supervised pretraining -> base checkpoint
    if pretrain_steps > 0 || !ckpt_dir.join("meta.json").exists() {
        let steps = if pretrain_steps == 0 { 3000 } else { pretrain_steps };
        println!("[1/2] pretraining base model: {steps} supervised steps ...");
        let rep = run_pretraining(
            &PretrainConfig {
                artifact_dir: artifact_dir.clone().into(),
                steps,
                lr: args.f64_or("pretrain-lr", 1e-3)? as f32,
                grad_clip: 1.0,
                seed: 7,
                log_every: 200,
            },
            &ckpt_dir,
        )?;
        println!(
            "      done in {:.0}s, final target_logp {:.3}",
            rep.wall_secs, rep.final_target_logp
        );
    } else {
        println!("[1/2] reusing pretrained checkpoint at {}", ckpt_dir.display());
    }

    // Phase 2: asynchronous AIPO RL
    let mode = match args.str_or("mode", "async").as_str() {
        "sync" => Mode::Sync,
        _ => Mode::Async,
    };
    let cfg = PipelineConfig {
        artifact_dir: artifact_dir.into(),
        mode,
        n_generator_workers: args.usize_or("workers", 2)?,
        queue_capacity: args.usize_or("queue-capacity", 2)?,
        scored_capacity: args.usize_or("scored-capacity", 2)?,
        n_generations: args.usize_or("n-generations", 4)?,
        max_steps: args.u64_or("steps", 300)?,
        temperature: args.f64_or("temperature", 0.8)? as f32,
        quantize_generator: args.flag("quantize-generator"),
        max_response: args.usize_or("max-response", 12)?,
        eval_every: args.u64_or("eval-every", 25)?,
        eval_max_per_suite: args.usize_or("eval-problems", 100)?,
        seed: args.u64_or("seed", 0)?,
        out_dir: out_dir.clone(),
        init_checkpoint: Some(ckpt_dir),
        ..PipelineConfig::default()
    };
    let mut cfg = cfg;
    cfg.aipo.lr = args.f64_or("lr", 2e-4)? as f32;
    cfg.aipo.rho = args.f64_or("rho", 4.0)? as f32;

    println!(
        "[2/2] RL: mode={:?} steps={} workers={} rho={} lr={}",
        cfg.mode, cfg.max_steps, cfg.n_generator_workers, cfg.aipo.rho, cfg.aipo.lr
    );
    let report = run_training(&cfg)?;
    print_report(&report);

    // persist a machine-readable summary next to the metrics log
    let summary_path = out_dir.join("report.json");
    std::fs::write(&summary_path, report_json(&report).to_string())?;
    println!("\nwrote {} and {}", summary_path.display(),
             report.metrics_path.as_ref().unwrap().display());

    // reward curve sparkline for the terminal
    let rewards: Vec<f64> = report.records.iter().map(|r| r.reward_mean).collect();
    if rewards.len() >= 10 {
        let bins = 20.min(rewards.len());
        let chunk = rewards.len() / bins;
        print!("reward curve: ");
        for c in rewards.chunks(chunk).take(bins) {
            let m = c.iter().sum::<f64>() / c.len() as f64;
            let glyph = match (m * 8.0) as usize {
                0 => '_',
                1 => '.',
                2 => ':',
                3 => '-',
                4 => '=',
                5 => '+',
                6 => '*',
                7 => '#',
                _ => '@',
            };
            print!("{glyph}");
        }
        println!();
    }
    Ok(())
}
