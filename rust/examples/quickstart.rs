//! Quickstart: the smallest possible LlamaRL job.
//!
//! Loads the `nano` artifacts, runs 3 synchronous RL steps (generate ->
//! score -> AIPO train -> in-place weight update) and 3 asynchronous steps
//! (executor threads + DDMA bus), then prints both reports.
//!
//!     make artifacts && cargo run --release --example quickstart

use llamarl::coordinator::{run_training, Mode, PipelineConfig};
use llamarl::metrics::print_report;

fn main() -> llamarl::Result<()> {
    let base = PipelineConfig {
        artifact_dir: "artifacts/nano".into(),
        max_steps: 3,
        max_response: 10,
        n_generations: 4,
        eval_every: 3,
        eval_max_per_suite: 16,
        ..PipelineConfig::default()
    };

    println!("--- synchronous on-policy baseline (DeepSpeed-Chat-like) ---");
    let sync = run_training(&PipelineConfig {
        mode: Mode::Sync,
        out_dir: std::env::temp_dir().join("llamarl_quickstart_sync"),
        ..base.clone()
    })?;
    print_report(&sync);

    println!("\n--- asynchronous off-policy LlamaRL pipeline ---");
    let asy = run_training(&PipelineConfig {
        mode: Mode::Async,
        n_generator_workers: 2,
        out_dir: std::env::temp_dir().join("llamarl_quickstart_async"),
        ..base
    })?;
    print_report(&asy);

    println!(
        "\nNote the async report's off-policy lag: trajectories were sampled\n\
         1-4 weight versions behind the trainer — exactly what AIPO's clipped\n\
         importance ratio corrects (paper §6)."
    );
    Ok(())
}
