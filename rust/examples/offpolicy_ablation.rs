//! Paper **Figure 8**: do off-policy corrections matter?
//!
//! Three async arms from the same pretrained base, with the off-policy
//! pressure deliberately amplified (deep queue => steps of lag, int8
//! generator => quantized behaviour policy, elevated LR):
//!
//!   rho=4      — AIPO's one-sided clipped importance correction (paper)
//!   rho=1e6    — unclipped importance sampling (high variance)
//!   rho<=0     — NO correction: plain REINFORCE on stale samples
//!
//! Expected shape (paper Fig. 8): the uncorrected arm destabilizes —
//! entropy collapse / reward drop / exploding ratios — while clipped AIPO
//! stays healthy.
//!
//!     cargo run --release --example offpolicy_ablation -- [--steps 40]

use llamarl::coordinator::{
    run_pretraining, run_training, Mode, PipelineConfig, PretrainConfig, RunReport,
};
use llamarl::util::bench::Table;
use llamarl::util::cli::Args;

fn stability_stats(r: &RunReport) -> (f64, f64, f64, f64) {
    let n = r.records.len().max(1);
    let tail = &r.records[r.records.len().saturating_sub(n / 3)..];
    let tail_reward =
        tail.iter().map(|x| x.reward_mean).sum::<f64>() / tail.len().max(1) as f64;
    let final_entropy = r.records.last().map(|x| x.entropy).unwrap_or(f64::NAN);
    let max_ratio = r
        .records
        .iter()
        .map(|x| x.mean_ratio)
        .fold(f64::NAN, f64::max);
    let max_grad = r
        .records
        .iter()
        .map(|x| x.grad_norm)
        .fold(f64::NAN, f64::max);
    (tail_reward, final_entropy, max_ratio, max_grad)
}

fn main() -> llamarl::Result<()> {
    let args = Args::from_env(&[])?;
    let artifact_dir = args.str_or("artifacts", "artifacts/small");
    let steps = args.u64_or("steps", 40)?;
    let out_root = std::path::PathBuf::from(args.str_or("out", "runs/ablation"));
    let ckpt = out_root.join("pretrained");

    println!("pretraining shared base model ...");
    run_pretraining(
        &PretrainConfig {
            artifact_dir: artifact_dir.clone().into(),
            steps: args.u64_or("pretrain-steps", 1500)?,
            lr: 1e-3,
            grad_clip: 1.0,
            seed: 7,
            log_every: 0,
        },
        &ckpt,
    )?;

    let mut base = PipelineConfig {
        artifact_dir: artifact_dir.into(),
        mode: Mode::Async,
        n_generator_workers: 2,
        // deep pipeline -> several steps of off-policy lag
        queue_capacity: 6,
        scored_capacity: 12,
        n_generations: 4,
        max_steps: steps,
        temperature: 1.0,
        // quantized behaviour policy: mu != pi even at zero lag (§4.3)
        quantize_generator: true,
        max_response: 10,
        eval_every: 0,
        init_checkpoint: Some(ckpt),
        seed: 13,
        ..PipelineConfig::default()
    };
    // aggressive LR amplifies the divergence between versions
    base.aipo.lr = args.f64_or("lr", 1e-3)? as f32;
    base.aipo.grad_clip = 0.0; // no safety net: let instability show

    let arms: Vec<(&str, f32)> = vec![
        ("AIPO rho=4 (paper)", 4.0),
        ("unclipped IS", 1e6),
        ("no correction", -1.0),
    ];
    let mut results = Vec::new();
    for (name, rho) in &arms {
        println!("\n=== arm: {name} ===");
        let mut cfg = base.clone();
        cfg.aipo.rho = *rho;
        cfg.out_dir = out_root.join(name.replace(' ', "_").replace('=', ""));
        let r = run_training(&cfg)?;
        println!("{}", r.summary());
        results.push((name.to_string(), r));
    }

    println!("\n=== Figure 8: stability under amplified off-policyness ===\n");
    let mut t = Table::new(&[
        "arm",
        "tail reward",
        "final entropy",
        "max mean-ratio",
        "max grad norm",
        "mean lag",
    ]);
    for (name, r) in &results {
        let (tail_reward, entropy, max_ratio, max_grad) = stability_stats(r);
        let mean_lag = r.records.iter().map(|x| x.mean_lag).sum::<f64>()
            / r.records.len().max(1) as f64;
        t.row(vec![
            name.clone(),
            format!("{tail_reward:.3}"),
            format!("{entropy:.3}"),
            format!("{max_ratio:.2}"),
            format!("{max_grad:.2}"),
            format!("{mean_lag:.2}"),
        ]);
    }
    t.print();
    println!(
        "\nShape check (paper Fig. 8): the corrected arm keeps bounded ratios\n\
         and healthy entropy; removing the correction (or the clip) lets\n\
         stale-gradient noise through — larger ratio/grad excursions and a\n\
         less stable reward tail."
    );
    Ok(())
}
