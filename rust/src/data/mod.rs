//! Synthetic verifiable-reward tasks (the MATH/GSM8K substitute).
//!
//! The paper trains on the MATH dataset with a sympy exact-match scorer and
//! evaluates on MATH test / MATH-500 / GSM8K. This environment has no
//! datasets, so we build the closest synthetic equivalent that exercises the
//! same code paths: prompts with short verifiable answers, a rule-based
//! exact-match scorer, and three held-out eval suites with distinct
//! distributions (see [`task::EvalSuite`]).

pub mod task;

pub use task::{
    eval_suites, Difficulty, EvalSuite, Problem, PromptScheduler, PromptTask, TaskGen,
};
