//! Arithmetic-reasoning task generator, prompt scheduling and eval suites.

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::model::Tokenizer;
use crate::util::error::Result;
use crate::util::rng::Rng;

/// Task difficulty tiers. A curriculum-free mixture of these is the training
/// distribution; eval suites draw from related but distinct distributions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Difficulty {
    /// single-digit a+b
    Add1,
    /// two-digit a+b / a-b
    AddSub2,
    /// a*b with a,b <= 12
    Mul,
    /// three-term a+b-c
    ThreeTerm,
}

impl Difficulty {
    pub const ALL: [Difficulty; 4] = [
        Difficulty::Add1,
        Difficulty::AddSub2,
        Difficulty::Mul,
        Difficulty::ThreeTerm,
    ];
}

/// One generated problem: prompt text and its unique correct answer.
#[derive(Debug, Clone)]
pub struct Problem {
    pub prompt: String,
    pub answer: String,
    pub difficulty: Difficulty,
}

pub fn make_problem(rng: &mut Rng, d: Difficulty) -> Problem {
    let (prompt, answer) = match d {
        Difficulty::Add1 => {
            let a = rng.range(0, 10);
            let b = rng.range(0, 10);
            (format!("{a}+{b}="), format!("{}", a + b))
        }
        Difficulty::AddSub2 => {
            let a = rng.range(10, 100);
            let b = rng.range(10, 100);
            if rng.bool(0.5) {
                (format!("{a}+{b}="), format!("{}", a + b))
            } else {
                let (hi, lo) = if a >= b { (a, b) } else { (b, a) };
                (format!("{hi}-{lo}="), format!("{}", hi - lo))
            }
        }
        Difficulty::Mul => {
            let a = rng.range(2, 13);
            let b = rng.range(2, 13);
            (format!("{a}*{b}="), format!("{}", a * b))
        }
        Difficulty::ThreeTerm => {
            let a = rng.range(1, 50);
            let b = rng.range(1, 50);
            let c = rng.range(1, a + b + 1);
            (format!("{a}+{b}-{c}="), format!("{}", a + b - c))
        }
    };
    Problem {
        prompt,
        answer,
        difficulty: d,
    }
}

/// Exact-match scorer (the rule-based reward; paper Fig. 1). The response is
/// everything the policy generated before EOS; trailing whitespace ignored.
pub fn score(problem: &Problem, response: &str) -> f32 {
    if response.trim_end() == problem.answer {
        1.0
    } else {
        0.0
    }
}

/// Task generator: a seeded stream over a difficulty mixture.
#[derive(Debug)]
pub struct TaskGen {
    rng: Rng,
    mixture: Vec<Difficulty>,
}

impl TaskGen {
    pub fn new(seed: u64, mixture: Vec<Difficulty>) -> TaskGen {
        assert!(!mixture.is_empty());
        TaskGen {
            rng: Rng::new(seed),
            mixture,
        }
    }

    pub fn training_mixture(seed: u64) -> TaskGen {
        TaskGen::new(seed, Difficulty::ALL.to_vec())
    }

    pub fn next(&mut self) -> Problem {
        let d = *self.rng.choice(&self.mixture);
        make_problem(&mut self.rng, d)
    }
}

/// A prompt replicated n_generations times (the paper's group for the
/// group-mean baseline). All replicas share `group_id`.
#[derive(Debug, Clone)]
pub struct PromptTask {
    pub group_id: u64,
    pub replica: usize,
    pub n_replicas: usize,
    pub problem: Problem,
    pub prompt_tokens: Vec<i32>,
}

/// Thread-safe prompt source shared by generator workers. Emits each
/// problem's n replicas consecutively so groups complete quickly.
pub struct PromptScheduler {
    inner: Mutex<SchedulerInner>,
    n_generations: usize,
}

struct SchedulerInner {
    gen: TaskGen,
    tok: Tokenizer,
    queue: VecDeque<PromptTask>,
    next_group: u64,
    issued: u64,
}

impl PromptScheduler {
    pub fn new(seed: u64, vocab: usize, n_generations: usize) -> Result<PromptScheduler> {
        Ok(PromptScheduler {
            inner: Mutex::new(SchedulerInner {
                gen: TaskGen::training_mixture(seed),
                tok: Tokenizer::new(vocab)?,
                queue: VecDeque::new(),
                next_group: 0,
                issued: 0,
            }),
            n_generations,
        })
    }

    /// Pop the next prompt task, synthesizing a new group when empty.
    pub fn next(&self) -> PromptTask {
        let mut s = self.inner.lock().unwrap();
        if s.queue.is_empty() {
            let problem = s.gen.next();
            let prompt_tokens = s
                .tok
                .encode_prompt(&problem.prompt)
                .expect("task grammar must be tokenizable");
            let group_id = s.next_group;
            s.next_group += 1;
            for replica in 0..self.n_generations {
                s.queue.push_back(PromptTask {
                    group_id,
                    replica,
                    n_replicas: self.n_generations,
                    problem: problem.clone(),
                    prompt_tokens: prompt_tokens.clone(),
                });
            }
        }
        s.issued += 1;
        s.queue.pop_front().unwrap()
    }

    pub fn issued(&self) -> u64 {
        self.inner.lock().unwrap().issued
    }

    /// Crash-resume: advance the fixed-seed prompt stream past the `n`
    /// tasks a recorded run already consumed, so a resumed run continues
    /// the same sequence instead of regenerating it from the start.
    pub fn fast_forward(&self, n: u64) {
        for _ in 0..n {
            self.next();
        }
    }
}

/// Held-out evaluation suites, mirroring the paper's three benchmarks:
///
/// * `math_test`  — same mixture as training, disjoint seed (MATH test)
/// * `math_500`   — fixed 500-problem subset of that distribution (MATH-500)
/// * `gsm_style`  — shifted distribution: heavier 3-term/mul mix (GSM8K)
#[derive(Debug, Clone)]
pub struct EvalSuite {
    pub name: &'static str,
    pub problems: Vec<Problem>,
}

pub fn eval_suites(n_per_suite: usize) -> Vec<EvalSuite> {
    let mut math_gen = TaskGen::new(0xEBA1_0001, Difficulty::ALL.to_vec());
    let math_test = (0..n_per_suite).map(|_| math_gen.next()).collect();

    let mut m500_gen = TaskGen::new(0xEBA1_0500, Difficulty::ALL.to_vec());
    let math_500 = (0..n_per_suite.min(500)).map(|_| m500_gen.next()).collect();

    let mut gsm_gen = TaskGen::new(
        0xEBA1_8000,
        vec![
            Difficulty::ThreeTerm,
            Difficulty::ThreeTerm,
            Difficulty::Mul,
            Difficulty::AddSub2,
        ],
    );
    let gsm = (0..n_per_suite).map(|_| gsm_gen.next()).collect();

    vec![
        EvalSuite {
            name: "math_test",
            problems: math_test,
        },
        EvalSuite {
            name: "math_500",
            problems: math_500,
        },
        EvalSuite {
            name: "gsm_style",
            problems: gsm,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn problems_are_correct() {
        let mut rng = Rng::new(1);
        for d in Difficulty::ALL {
            for _ in 0..200 {
                let p = make_problem(&mut rng, d);
                // evaluate the prompt expression and compare to answer
                let expr = p.prompt.trim_end_matches('=');
                let val = eval_expr(expr);
                assert_eq!(val.to_string(), p.answer, "{}", p.prompt);
                assert_eq!(score(&p, &p.answer), 1.0);
                assert_eq!(score(&p, "nope"), 0.0);
            }
        }
    }

    fn eval_expr(e: &str) -> i64 {
        // tiny evaluator for the task grammar: left-assoc + - *single
        if let Some(i) = e.rfind('+') {
            if i > 0 {
                return eval_expr(&e[..i]) + eval_expr(&e[i + 1..]);
            }
        }
        if let Some(i) = e.rfind('-') {
            if i > 0 {
                return eval_expr(&e[..i]) - eval_expr(&e[i + 1..]);
            }
        }
        if let Some(i) = e.find('*') {
            return eval_expr(&e[..i]) * eval_expr(&e[i + 1..]);
        }
        e.parse().unwrap()
    }

    #[test]
    fn prompts_tokenizable() {
        let tok = Tokenizer::new(64).unwrap();
        let mut gen = TaskGen::training_mixture(3);
        for _ in 0..500 {
            let p = gen.next();
            assert!(tok.encode(&p.prompt).is_ok());
            assert!(tok.encode(&p.answer).is_ok());
            assert!(p.prompt.len() <= 20, "prompt too long: {}", p.prompt);
        }
    }

    #[test]
    fn scheduler_groups_replicas() {
        let s = PromptScheduler::new(5, 64, 4).unwrap();
        let tasks: Vec<_> = (0..8).map(|_| s.next()).collect();
        assert!(tasks[..4].iter().all(|t| t.group_id == tasks[0].group_id));
        assert!(tasks[4..].iter().all(|t| t.group_id == tasks[4].group_id));
        assert_ne!(tasks[0].group_id, tasks[4].group_id);
        let replicas: Vec<_> = tasks[..4].iter().map(|t| t.replica).collect();
        assert_eq!(replicas, vec![0, 1, 2, 3]);
        assert_eq!(tasks[0].problem.prompt, tasks[3].problem.prompt);
    }

    #[test]
    fn eval_suites_are_deterministic_and_distinct() {
        let a = eval_suites(50);
        let b = eval_suites(50);
        assert_eq!(a.len(), 3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.problems.len(), y.problems.len());
            for (p, q) in x.problems.iter().zip(&y.problems) {
                assert_eq!(p.prompt, q.prompt);
            }
        }
        // training stream (seed 0) and math_test must differ
        let mut train = TaskGen::training_mixture(0);
        let overlap = a[0]
            .problems
            .iter()
            .filter(|p| (0..50).any(|_| train.next().prompt == p.prompt))
            .count();
        assert!(overlap < 50);
    }
}
