//! Blocked-time attribution: classify every track's wall clock into
//! compute / channel-blocked / sync-blocked / offload-wait / idle.
//!
//! The recorder's RAII guards mean spans on one track nest properly, so
//! the innermost-wins rule is exact: each span's *self time* (duration
//! minus its children's durations) is charged to the class of its own
//! name. `send_blocked` inside `gen_chunk` charges the blocked window to
//! the channel and only the remainder to compute — no interval store, no
//! double counting, one O(n log n) sweep per track. Idle is the part of
//! the run window outside any top-level span. Fractions are of the
//! run-wide window `[t_min, t_max]`, so per-track busy fractions sum to
//! at most 1 by construction (top-level spans on a track are disjoint).

use std::collections::BTreeMap;

use crate::analysis::ingest::ClosedSpan;
use crate::trace;
use crate::util::json::Value;

/// Where a span's self time goes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimeClass {
    /// useful work (generation, scoring, training, streaming transfers)
    Compute,
    /// blocked on channel/store backpressure or starvation
    Channel,
    /// blocked on the weight-sync plane (inline publish, fenced reload)
    Sync,
    /// blocked on memplane residency (lease holder waiting on a transfer)
    Offload,
}

/// Classification by span name: the blocked vocabulary is closed (each
/// name documents the one resource being waited on — see the schema
/// table in [`crate::trace`]); everything else is work.
pub fn classify(name: &str) -> TimeClass {
    match name {
        trace::SEND_BLOCKED | trace::RECV_BLOCKED | trace::STORE_SAMPLE => TimeClass::Channel,
        trace::PUBLISH_BLOCK | trace::WEIGHT_SYNC => TimeClass::Sync,
        trace::OFFLOAD_WAIT => TimeClass::Offload,
        _ => TimeClass::Compute,
    }
}

/// One track's wall-clock breakdown over the run window.
#[derive(Debug, Clone, Default)]
pub struct TrackAttribution {
    pub track: String,
    pub window_secs: f64,
    pub compute_secs: f64,
    pub channel_secs: f64,
    pub sync_secs: f64,
    pub offload_secs: f64,
    /// union of top-level spans (== sum of the four classes up to float
    /// rounding)
    pub busy_secs: f64,
    pub idle_secs: f64,
}

impl TrackAttribution {
    pub fn busy_frac(&self) -> f64 {
        frac(self.busy_secs, self.window_secs)
    }

    pub fn to_json(&self) -> Value {
        Value::object(vec![
            ("track", Value::str(self.track.clone())),
            ("window_secs", Value::num(self.window_secs)),
            ("busy_frac", Value::num(self.busy_frac())),
            ("compute_frac", Value::num(frac(self.compute_secs, self.window_secs))),
            (
                "channel_blocked_frac",
                Value::num(frac(self.channel_secs, self.window_secs)),
            ),
            (
                "sync_blocked_frac",
                Value::num(frac(self.sync_secs, self.window_secs)),
            ),
            (
                "offload_wait_frac",
                Value::num(frac(self.offload_secs, self.window_secs)),
            ),
            ("idle_frac", Value::num(frac(self.idle_secs, self.window_secs))),
        ])
    }
}

fn frac(x: f64, window: f64) -> f64 {
    if window > 0.0 {
        (x / window).clamp(0.0, 1.0)
    } else {
        0.0
    }
}

/// Attribute every track's time over the shared window `[t_min, t_max]`
/// (microseconds). Spans are assumed balanced (ingest enforces it).
pub fn attribute(spans: &[ClosedSpan], t_min_us: f64, t_max_us: f64) -> Vec<TrackAttribution> {
    let window_secs = ((t_max_us - t_min_us) / 1e6).max(0.0);
    let mut by_track: BTreeMap<&str, Vec<&ClosedSpan>> = BTreeMap::new();
    for s in spans {
        by_track.entry(&s.track).or_default().push(s);
    }
    let mut out = Vec::with_capacity(by_track.len());
    for (track, mut spans) in by_track {
        // parents sort before their children: by start ascending, then
        // end descending (a parent shares its child's start only when it
        // also ends no earlier)
        spans.sort_by(|a, b| {
            a.start_us
                .partial_cmp(&b.start_us)
                .unwrap()
                .then(b.end_us.partial_cmp(&a.end_us).unwrap())
        });
        let mut attr = TrackAttribution {
            track: track.to_string(),
            window_secs,
            ..TrackAttribution::default()
        };
        // sweep stack: (name, end_us, dur, children_dur)
        let mut stack: Vec<(&str, f64, f64, f64)> = Vec::new();
        let mut top_level_end = f64::NEG_INFINITY;
        // pop every open span ending at or before `up_to`, charging its
        // self time to its class and its full duration to its parent
        fn settle<'a>(
            attr: &mut TrackAttribution,
            stack: &mut Vec<(&'a str, f64, f64, f64)>,
            up_to: f64,
        ) {
            while let Some(&(name, end, dur, children)) = stack.last() {
                if end > up_to {
                    break;
                }
                stack.pop();
                let self_secs = (dur - children).max(0.0);
                match classify(name) {
                    TimeClass::Compute => attr.compute_secs += self_secs,
                    TimeClass::Channel => attr.channel_secs += self_secs,
                    TimeClass::Sync => attr.sync_secs += self_secs,
                    TimeClass::Offload => attr.offload_secs += self_secs,
                }
                if let Some(parent) = stack.last_mut() {
                    parent.3 += dur;
                }
            }
        }
        for s in &spans {
            settle(&mut attr, &mut stack, s.start_us);
            if stack.is_empty() {
                // top-level: busy time is the union (overlap-safe even if
                // a dropped E let two "top-level" spans overlap)
                let start = s.start_us.max(top_level_end);
                attr.busy_secs += ((s.end_us - start) / 1e6).max(0.0);
                top_level_end = top_level_end.max(s.end_us);
            }
            stack.push((&s.name, s.end_us, s.dur_secs(), 0.0));
        }
        settle(&mut attr, &mut stack, f64::INFINITY);
        attr.idle_secs = (window_secs - attr.busy_secs).max(0.0);
        out.push(attr);
    }
    out
}
