//! Span-duration histograms: one streaming [`LogHistogram`] per
//! `(track, name)` key, plus the cross-track merge per span name.
//!
//! The core property the bucket layout buys (fixed log-linear buckets,
//! see [`LogHistogram`]): merging shard histograms bucket-wise is exactly
//! equivalent to histogramming the concatenated stream, so the per-track
//! shards and the per-name merged view are two readouts of the same
//! counts — no re-pass over the spans, no approximation introduced by
//! the merge itself. Quantiles carry the documented
//! [`LogHistogram::RELATIVE_ERROR`] bound either way.

use std::collections::BTreeMap;

use crate::analysis::ingest::ClosedSpan;
use crate::util::json::Value;
use crate::util::stats::LogHistogram;

/// Duration histograms keyed `(track, name)`.
#[derive(Debug, Default)]
pub struct SpanHistograms {
    map: BTreeMap<(String, String), LogHistogram>,
}

impl SpanHistograms {
    pub fn new() -> SpanHistograms {
        SpanHistograms::default()
    }

    pub fn record(&mut self, span: &ClosedSpan) {
        self.map
            .entry((span.track.clone(), span.name.clone()))
            .or_insert_with(LogHistogram::new)
            .record(span.dur_secs());
    }

    pub fn from_spans(spans: &[ClosedSpan]) -> SpanHistograms {
        let mut h = SpanHistograms::new();
        for s in spans {
            h.record(s);
        }
        h
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&(String, String), &LogHistogram)> + '_ {
        self.map.iter()
    }

    /// Cross-track view: all shards of one span name merged bucket-wise
    /// (`weightsync-link0` + `weightsync-link1` + ... -> `sync_overlap`).
    pub fn merged_by_name(&self) -> BTreeMap<String, LogHistogram> {
        let mut out: BTreeMap<String, LogHistogram> = BTreeMap::new();
        for ((_, name), hist) in &self.map {
            out.entry(name.clone()).or_insert_with(LogHistogram::new).merge(hist);
        }
        out
    }

    /// Total recorded seconds per span name (exact sums, not bucketed).
    pub fn totals_by_name(&self) -> BTreeMap<String, f64> {
        let mut out: BTreeMap<String, f64> = BTreeMap::new();
        for ((_, name), hist) in &self.map {
            *out.entry(name.clone()).or_insert(0.0) += hist.sum();
        }
        out
    }

    /// Per-(track, name) stat rows for `analysis.json`.
    pub fn to_json(&self) -> Value {
        Value::Array(
            self.map
                .iter()
                .map(|((track, name), h)| hist_row(Some(track), name, h))
                .collect(),
        )
    }

    /// Per-name merged stat rows for `analysis.json`.
    pub fn merged_json(&self) -> Value {
        Value::Array(
            self.merged_by_name()
                .iter()
                .map(|(name, h)| hist_row(None, name, h))
                .collect(),
        )
    }
}

fn hist_row(track: Option<&str>, name: &str, h: &LogHistogram) -> Value {
    let mut pairs = Vec::new();
    if let Some(t) = track {
        pairs.push(("track", Value::str(t)));
    }
    pairs.extend([
        ("name", Value::str(name)),
        ("count", Value::num(h.count() as f64)),
        ("total_secs", Value::num(h.sum())),
        ("mean_secs", Value::num(h.mean())),
        ("p50_secs", Value::num(h.quantile_or(0.50, 0.0))),
        ("p90_secs", Value::num(h.quantile_or(0.90, 0.0))),
        ("p99_secs", Value::num(h.quantile_or(0.99, 0.0))),
        ("min_secs", Value::num(if h.count() > 0 { h.min() } else { 0.0 })),
        ("max_secs", Value::num(if h.count() > 0 { h.max() } else { 0.0 })),
    ]);
    Value::object(pairs)
}
