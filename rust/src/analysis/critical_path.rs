//! Per-step critical-path extraction: which plane bounded each optimizer
//! step, and which bounded the run.
//!
//! Steps anchor the timeline: the stepped graph's `train` phase spans
//! when present, otherwise the async modes' `train_step` spans. Step k's
//! window runs from the previous anchor's end (or the run start) to its
//! own end — everything the step had to wait for happened in that
//! window. Within it, each plane's *presence* is the merged union of its
//! spans' intervals across all tracks (union, not sum: eight generator
//! replicas decoding concurrently are one plane being busy, not 8x), and
//! the bounding plane is the one present longest. The run-level verdict
//! sums the per-window presences — the measured analogue of the DES
//! reports' idle-fraction story, localized to steps.

use std::collections::BTreeMap;

use crate::analysis::ingest::ClosedSpan;
use crate::trace;
use crate::util::json::Value;

/// The planes a step can wait on, in report order.
pub const PLANES: &[&str] = &[
    "generate",
    "score",
    "train",
    "weightsync",
    "memplane",
    "dataplane",
];

/// Span name -> plane index in [`PLANES`] (None for lifecycle instants
/// and names outside the plane vocabulary).
pub fn plane_of(name: &str) -> Option<usize> {
    match name {
        trace::GENERATE | trace::GEN_CHUNK => Some(0),
        trace::SCORE | trace::REWARD_SCORE => Some(1),
        trace::TRAIN | trace::TRAIN_STEP => Some(2),
        trace::WEIGHT_SYNC | trace::SYNC_OVERLAP | trace::PUBLISH_BLOCK => Some(3),
        trace::OFFLOAD_D2H | trace::OFFLOAD_H2D | trace::OFFLOAD_WAIT => Some(4),
        trace::SEND_BLOCKED | trace::RECV_BLOCKED | trace::STORE_SAMPLE => Some(5),
        _ => None,
    }
}

/// One step's window and plane presence.
#[derive(Debug, Clone)]
pub struct StepPath {
    /// the anchor span's value (the optimizer step number)
    pub step: u64,
    pub start_us: f64,
    pub end_us: f64,
    /// union-overlap seconds per plane, indexed like [`PLANES`]
    pub plane_secs: Vec<f64>,
    /// plane with the largest presence in this window
    pub bounding: &'static str,
}

/// The extracted critical path over all steps.
#[derive(Debug, Clone, Default)]
pub struct CriticalPath {
    pub steps: Vec<StepPath>,
    /// summed per-window presence, indexed like [`PLANES`]
    pub totals: Vec<f64>,
    /// plane with the largest summed presence ("none" when no spans)
    pub bounding: &'static str,
}

impl CriticalPath {
    pub fn to_json(&self) -> Value {
        let planes = |secs: &[f64]| {
            Value::object(
                PLANES
                    .iter()
                    .zip(secs)
                    .map(|(p, s)| (*p, Value::num(*s)))
                    .collect(),
            )
        };
        Value::object(vec![
            ("overall_bounding_plane", Value::str(self.bounding)),
            ("plane_totals_secs", planes(&self.totals)),
            (
                "steps",
                Value::Array(
                    self.steps
                        .iter()
                        .map(|s| {
                            Value::object(vec![
                                ("step", Value::num(s.step as f64)),
                                (
                                    "window_secs",
                                    Value::num(((s.end_us - s.start_us) / 1e6).max(0.0)),
                                ),
                                ("bounding_plane", Value::str(s.bounding)),
                                ("plane_secs", planes(&s.plane_secs)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Union length of `intervals` clipped to `[lo, hi]`, in seconds.
fn union_secs(intervals: &[(f64, f64)], lo: f64, hi: f64) -> f64 {
    let mut clipped: Vec<(f64, f64)> = intervals
        .iter()
        .map(|&(a, b)| (a.max(lo), b.min(hi)))
        .filter(|&(a, b)| b > a)
        .collect();
    clipped.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut total = 0.0;
    let mut cur: Option<(f64, f64)> = None;
    for (a, b) in clipped {
        match &mut cur {
            Some((_, ce)) if a <= *ce => *ce = ce.max(b),
            _ => {
                if let Some((cs, ce)) = cur {
                    total += ce - cs;
                }
                cur = Some((a, b));
            }
        }
    }
    if let Some((cs, ce)) = cur {
        total += ce - cs;
    }
    total / 1e6
}

fn argmax(secs: &[f64]) -> &'static str {
    let mut best = 0;
    for (i, s) in secs.iter().enumerate() {
        if *s > secs[best] {
            best = i;
        }
    }
    if secs.is_empty() || secs[best] <= 0.0 {
        "none"
    } else {
        PLANES[best]
    }
}

/// Extract the per-step critical path from a run's closed spans.
pub fn extract(spans: &[ClosedSpan], t_min_us: f64, t_max_us: f64) -> CriticalPath {
    // anchors: stepped `train` phases when present, else `train_step`
    let phase_anchors: Vec<&ClosedSpan> =
        spans.iter().filter(|s| s.name == trace::TRAIN).collect();
    let mut anchors: Vec<&ClosedSpan> = if phase_anchors.is_empty() {
        spans.iter().filter(|s| s.name == trace::TRAIN_STEP).collect()
    } else {
        phase_anchors
    };
    anchors.sort_by(|a, b| a.end_us.partial_cmp(&b.end_us).unwrap());

    // per-plane interval pools, gathered once
    let mut pools: Vec<Vec<(f64, f64)>> = vec![Vec::new(); PLANES.len()];
    for s in spans {
        if let Some(p) = plane_of(&s.name) {
            pools[p].push((s.start_us, s.end_us));
        }
    }

    let mut path = CriticalPath {
        totals: vec![0.0; PLANES.len()],
        bounding: "none",
        ..CriticalPath::default()
    };
    let mut prev_end = t_min_us;
    for a in anchors {
        let (lo, hi) = (prev_end, a.end_us.max(prev_end));
        let plane_secs: Vec<f64> = pools
            .iter()
            .map(|pool| union_secs(pool, lo, hi))
            .collect();
        for (t, s) in path.totals.iter_mut().zip(&plane_secs) {
            *t += s;
        }
        path.steps.push(StepPath {
            step: a.value as u64,
            start_us: lo,
            end_us: hi,
            bounding: argmax(&plane_secs),
            plane_secs,
        });
        prev_end = hi;
    }
    if path.steps.is_empty() {
        // no anchors (e.g. a log from a run killed before step 1): fall
        // back to whole-window presence so the verdict is still useful
        path.totals = pools
            .iter()
            .map(|pool| union_secs(pool, t_min_us, t_max_us))
            .collect();
    }
    path.bounding = argmax(&path.totals);
    path
}
