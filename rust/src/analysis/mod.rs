//! Trace-analysis plane: turn a run's streaming event log into answers.
//!
//! The trace plane (PR 6) records *what happened*; this module computes
//! *what it means*, in one streaming pass over `OUT/trace_events.jsonl`
//! or a journal's mirrored `event` lines:
//!
//! * [`ingest`] — the pass itself: [`JournalReader`]-based decode (torn
//!   tails tolerated, interior corruption fatal), span pairing through
//!   the shared [`SpanStacks`] B/E balance checker (also used by
//!   `tracecheck --file`), instant/counter capture including the
//!   collector's final `dropped_events` tally, and the journal's `meta`
//!   config for the DES bridge.
//! * [`histogram`] — streaming log-bucketed duration histograms per
//!   `(track, span name)` ([`LogHistogram`]: fixed bucket layout, so
//!   shard-merge is exactly concatenation and quantiles carry a
//!   documented relative-error bound), plus the cross-track merged view.
//! * [`attribution`] — blocked-time attribution: each track's wall clock
//!   classified compute / channel-blocked / sync-blocked / offload-wait
//!   / idle by innermost-wins self time over the properly nested spans.
//! * [`critical_path`] — per-step windows anchored on `train` /
//!   `train_step` spans, each charged to the plane whose merged span
//!   union dominates it; names the bounding plane per step and overall.
//! * [`divergence`] — `analyze --des`: re-cost the recorded config
//!   through the matching `simulate_*` path and report measured-vs-
//!   predicted ratios per shared segment name.
//!
//! `llamarl analyze` drives all of it and emits `analysis.json` (via
//! [`crate::util::json`]) plus the human report below.
//!
//! [`JournalReader`]: crate::journal::JournalReader
//! [`SpanStacks`]: ingest::SpanStacks
//! [`LogHistogram`]: crate::util::stats::LogHistogram

pub mod attribution;
pub mod critical_path;
pub mod divergence;
pub mod histogram;
pub mod ingest;

use std::path::Path;

pub use attribution::{attribute, classify, TimeClass, TrackAttribution};
pub use critical_path::{extract, plane_of, CriticalPath, PLANES};
pub use divergence::{diverge, Divergence, SegmentDivergence};
pub use histogram::SpanHistograms;
pub use ingest::{load, ClosedSpan, RunData, SpanStacks};

use crate::util::error::Result;
use crate::util::json::Value;

/// Everything `llamarl analyze` computes for one run.
pub struct Analysis {
    pub source: String,
    pub run: RunData,
    pub hists: SpanHistograms,
    pub tracks: Vec<TrackAttribution>,
    pub path: CriticalPath,
    /// present only under `--des` (needs the journal's meta config)
    pub divergence: Option<Divergence>,
}

/// One-pass analysis of `path` (a journal or a raw trace event log).
/// Balance violations and dropped events are *reported*, not fatal here —
/// the CLI decides exit status after `analysis.json` is on disk.
pub fn analyze_file(path: impl AsRef<Path>, des: bool) -> Result<Analysis> {
    let path = path.as_ref();
    let run = load(path)?;
    let hists = SpanHistograms::from_spans(&run.spans);
    let tracks = attribute(&run.spans, run.t_min_us, run.t_max_us);
    let cp = extract(&run.spans, run.t_min_us, run.t_max_us);
    let divergence = if des { Some(diverge(&run)?) } else { None };
    Ok(Analysis {
        source: path.display().to_string(),
        run,
        hists,
        tracks,
        path: cp,
        divergence,
    })
}

impl Analysis {
    /// The `analysis.json` document.
    pub fn to_json(&self) -> Value {
        Value::object(vec![
            ("source", Value::str(self.source.clone())),
            ("events", Value::num(self.run.events as f64)),
            ("spans", Value::num(self.run.spans.len() as f64)),
            ("wall_secs", Value::num(self.run.wall_secs())),
            (
                "dropped_events",
                Value::num(self.run.dropped_events as f64),
            ),
            ("truncated_tail", Value::Bool(self.run.truncated_tail)),
            (
                "balance_violations",
                Value::Array(
                    self.run
                        .violations
                        .iter()
                        .map(|v| Value::str(v.clone()))
                        .collect(),
                ),
            ),
            (
                "instants",
                Value::object(
                    self.run
                        .instants
                        .iter()
                        .map(|(k, n)| (k.as_str(), Value::num(*n as f64)))
                        .collect(),
                ),
            ),
            ("span_stats", self.hists.to_json()),
            ("span_stats_by_name", self.hists.merged_json()),
            (
                "tracks",
                Value::Array(self.tracks.iter().map(|t| t.to_json()).collect()),
            ),
            ("critical_path", self.path.to_json()),
            (
                "divergence",
                self.divergence
                    .as_ref()
                    .map(|d| d.to_json())
                    .unwrap_or(Value::Null),
            ),
        ])
    }

    /// The human report `llamarl analyze` prints.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "== analyze {} ==\n{} events, {} spans, {:.3}s wall{}{}",
            self.source,
            self.run.events,
            self.run.spans.len(),
            self.run.wall_secs(),
            if self.run.dropped_events > 0 {
                format!(", {} DROPPED", self.run.dropped_events)
            } else {
                String::new()
            },
            if self.run.truncated_tail {
                ", torn tail"
            } else {
                ""
            },
        );
        let _ = writeln!(s, "\nspan latencies (merged across tracks):");
        let _ = writeln!(
            s,
            "  {:<16} {:>6} {:>10} {:>10} {:>10} {:>10}",
            "name", "count", "total s", "p50 s", "p90 s", "p99 s"
        );
        for (name, h) in self.hists.merged_by_name() {
            let _ = writeln!(
                s,
                "  {:<16} {:>6} {:>10.4} {:>10.5} {:>10.5} {:>10.5}",
                name,
                h.count(),
                h.sum(),
                h.quantile_or(0.50, 0.0),
                h.quantile_or(0.90, 0.0),
                h.quantile_or(0.99, 0.0),
            );
        }
        let _ = writeln!(s, "\nblocked-time attribution (fraction of run window):");
        let _ = writeln!(
            s,
            "  {:<20} {:>8} {:>8} {:>8} {:>8} {:>8}",
            "track", "compute", "channel", "sync", "offload", "idle"
        );
        for t in &self.tracks {
            let w = t.window_secs.max(1e-12);
            let _ = writeln!(
                s,
                "  {:<20} {:>7.1}% {:>7.1}% {:>7.1}% {:>7.1}% {:>7.1}%",
                t.track,
                100.0 * t.compute_secs / w,
                100.0 * t.channel_secs / w,
                100.0 * t.sync_secs / w,
                100.0 * t.offload_secs / w,
                100.0 * t.idle_secs / w,
            );
        }
        let _ = writeln!(
            s,
            "\ncritical path: {} steps, run bounded by '{}'",
            self.path.steps.len(),
            self.path.bounding
        );
        for st in &self.path.steps {
            let _ = writeln!(
                s,
                "  step {:>3}: {:>8.4}s window, bounded by '{}'",
                st.step,
                (st.end_us - st.start_us) / 1e6,
                st.bounding
            );
        }
        if let Some(d) = &self.divergence {
            let _ = writeln!(
                s,
                "\nDES divergence ({} mode, {} steps): wall {:.3}s measured \
                 vs {:.3}s predicted (ratio {:.2})",
                d.mode, d.steps, d.measured_wall_secs, d.predicted_wall_secs, d.wall_ratio
            );
            for seg in &d.segments {
                let _ = writeln!(
                    s,
                    "  {:<14} measured {:>9.4}s  predicted {:>9.4}s  ratio {}",
                    seg.name,
                    seg.measured_secs,
                    seg.predicted_secs,
                    seg.ratio
                        .map(|r| format!("{r:.2}"))
                        .unwrap_or_else(|| "-".into()),
                );
            }
        }
        if !self.run.violations.is_empty() {
            let _ = writeln!(
                s,
                "\nBALANCE VIOLATIONS ({}):",
                self.run.violations.len()
            );
            for v in self.run.violations.iter().take(10) {
                let _ = writeln!(s, "  {v}");
            }
            if self.run.violations.len() > 10 {
                let _ = writeln!(s, "  ... and {} more", self.run.violations.len() - 10);
            }
        }
        s
    }
}
