//! Streaming ingest: one pass over a run's event stream into the shapes
//! the analyses consume.
//!
//! The input is whatever the trace plane wrote — the collector's raw
//! `trace_events.jsonl` or a journal whose `event` lines mirror it — read
//! through the same [`JournalReader`] the resume path uses, so analyze
//! inherits its torn-tail tolerance and interior-corruption detection for
//! free. Span pairing rides [`SpanStacks`], the per-track B/E balance
//! checker shared with `tracecheck --file`: spans on one track close in
//! LIFO order (the recorder's RAII guards guarantee it at the source), so
//! a name mismatch or an E without a B is evidence of log corruption or
//! ring overflow, not a scheduling artifact.

use std::collections::BTreeMap;
use std::path::Path;

use crate::journal::{JournalReader, JournalRecord};
use crate::util::error::Result;
use crate::util::json::Value;

/// A matched B/E pair on one track. Durations are given by the trace
/// plane's microsecond clock; `value` is the B event's payload (step,
/// chunk seq, rows — see the schema table in [`crate::trace`]).
#[derive(Debug, Clone)]
pub struct ClosedSpan {
    pub track: String,
    pub name: String,
    pub start_us: f64,
    pub end_us: f64,
    pub value: f64,
}

impl ClosedSpan {
    pub fn dur_secs(&self) -> f64 {
        ((self.end_us - self.start_us) / 1e6).max(0.0)
    }
}

/// Per-track span stacks enforcing the B/E discipline. Shared by
/// `llamarl analyze` (JSONL events) and `tracecheck --file` (Chrome
/// export): both inputs describe completed runs, where every begin must
/// have a matching end on the same track in LIFO order.
#[derive(Debug, Default)]
pub struct SpanStacks {
    stacks: BTreeMap<String, Vec<(String, f64, f64)>>,
    violations: Vec<String>,
}

impl SpanStacks {
    pub fn new() -> SpanStacks {
        SpanStacks::default()
    }

    pub fn begin(&mut self, track: &str, name: &str, t_us: f64, value: f64) {
        self.stacks
            .entry(track.to_string())
            .or_default()
            .push((name.to_string(), t_us, value));
    }

    /// Close the innermost open span on `track`. Returns the matched pair,
    /// or `None` with a recorded violation when the end has no begin or
    /// names a different span than the innermost open one.
    pub fn end(&mut self, track: &str, name: &str, t_us: f64) -> Option<ClosedSpan> {
        let stack = self.stacks.entry(track.to_string()).or_default();
        match stack.pop() {
            None => {
                self.violations
                    .push(format!("track '{track}': E '{name}' without a matching B"));
                None
            }
            Some((open, start_us, value)) => {
                if open != name {
                    self.violations.push(format!(
                        "track '{track}': E '{name}' closes open span '{open}' \
                         (improper nesting)"
                    ));
                    return None;
                }
                Some(ClosedSpan {
                    track: track.to_string(),
                    name: open,
                    start_us,
                    end_us: t_us,
                    value,
                })
            }
        }
    }

    /// Mismatches seen so far (E-without-B, name mismatch on close).
    pub fn violations(&self) -> &[String] {
        &self.violations
    }

    /// Spans still open — a completed run's log must leave none.
    pub fn unclosed(&self) -> Vec<String> {
        let mut out = Vec::new();
        for (track, stack) in &self.stacks {
            for (name, _, _) in stack {
                out.push(format!("track '{track}': span '{name}' never closed"));
            }
        }
        out
    }
}

/// Everything one streaming pass extracts from the event stream.
#[derive(Debug, Default)]
pub struct RunData {
    pub spans: Vec<ClosedSpan>,
    /// earliest / latest span or instant timestamp (the run window;
    /// bookkeeping counter lines are excluded)
    pub t_min_us: f64,
    pub t_max_us: f64,
    /// trace-plane events seen (spans count twice: B and E)
    pub events: u64,
    /// instant-event counts by name (`node_restart`, `store_admit`, ...)
    pub instants: BTreeMap<String, u64>,
    /// the collector's final ring-overflow tally (0 = complete log)
    pub dropped_events: u64,
    /// the journal's `meta` record: the resolved run config, when the
    /// input is a journal (`analyze --des` requires it)
    pub config: Option<Value>,
    pub truncated_tail: bool,
    /// B/E discipline violations: mismatches first, then unclosed spans
    pub violations: Vec<String>,
    /// spans left open at end-of-stream (subset of `violations`; expected
    /// for a SIGKILLed journal, an error for a completed run)
    pub unclosed: usize,
}

impl RunData {
    pub fn wall_secs(&self) -> f64 {
        ((self.t_max_us - self.t_min_us) / 1e6).max(0.0)
    }
}

/// One streaming pass over `path` (journal or raw event log). O(line)
/// memory for the stream itself; retained state is the closed spans plus
/// the per-track open stacks.
pub fn load(path: impl AsRef<Path>) -> Result<RunData> {
    let path = path.as_ref();
    let mut reader = JournalReader::open(path)?;
    let mut stacks = SpanStacks::new();
    let mut data = RunData {
        t_min_us: f64::INFINITY,
        t_max_us: f64::NEG_INFINITY,
        ..RunData::default()
    };
    while let Some(item) = reader.next_record() {
        let (_seq, rec) = item?;
        match rec {
            JournalRecord::Event {
                t_us,
                track,
                ph,
                name,
                value,
            } => {
                data.events += 1;
                match ph.as_str() {
                    "B" => {
                        data.t_min_us = data.t_min_us.min(t_us);
                        data.t_max_us = data.t_max_us.max(t_us);
                        stacks.begin(&track, &name, t_us, value);
                    }
                    "E" => {
                        data.t_min_us = data.t_min_us.min(t_us);
                        data.t_max_us = data.t_max_us.max(t_us);
                        if let Some(span) = stacks.end(&track, &name, t_us) {
                            data.spans.push(span);
                        }
                    }
                    "i" => {
                        data.t_min_us = data.t_min_us.min(t_us);
                        data.t_max_us = data.t_max_us.max(t_us);
                        *data.instants.entry(name).or_insert(0) += 1;
                    }
                    // counters are bookkeeping, not timeline: exclude from
                    // the run window (the collector's final dropped_events
                    // line lands after every node has stopped)
                    "C" => {
                        if name == crate::trace::DROPPED_EVENTS {
                            data.dropped_events = value as u64;
                        }
                    }
                    _ => {}
                }
            }
            JournalRecord::Meta { config } => data.config = Some(config),
            _ => {}
        }
    }
    data.truncated_tail = reader.truncated_tail();
    let unclosed = stacks.unclosed();
    data.unclosed = unclosed.len();
    data.violations = stacks.violations().to_vec();
    data.violations.extend(unclosed);
    if !data.t_min_us.is_finite() {
        data.t_min_us = 0.0;
        data.t_max_us = 0.0;
    }
    Ok(data)
}
