//! Measured-vs-DES divergence: re-cost a traced run's recorded config
//! through the matching discrete-event simulator and compare timelines
//! segment by segment.
//!
//! This is the first plank of the ROADMAP's measured-vs-simulated
//! bridge. The calibration is deliberately *from the run itself*: the
//! DES gets the measured per-step means (generation, scoring, training,
//! sync stalls) as its deterministic segment costs (`gen_sigma = 0`,
//! batch = concurrency = 1, the run's own seed and data-plane knobs),
//! so the comparison isolates the *structural* model — how the simulator
//! composes those segments into a timeline — from the cost model. A
//! wall-clock ratio near 1 means the DES's overlap/bubble structure
//! matches the real pipeline; a per-segment ratio far from 1 names the
//! segment whose accounting diverges.

use crate::analysis::ingest::RunData;
use crate::simulator::des::{
    simulate_async, simulate_async_buffered, simulate_periodic, simulate_sync,
    BufferedDesConfig, DesConfig,
};
use crate::trace;
use crate::util::error::{Error, Result};
use crate::util::json::Value;

/// One shared timeline segment, measured against predicted.
#[derive(Debug, Clone)]
pub struct SegmentDivergence {
    pub name: &'static str,
    pub measured_secs: f64,
    pub predicted_secs: f64,
    /// measured / predicted; `None` when the prediction is ~0 (a segment
    /// the config disables — a nonzero measurement then IS the finding)
    pub ratio: Option<f64>,
}

/// The full divergence report for one traced run.
#[derive(Debug, Clone)]
pub struct Divergence {
    pub mode: String,
    /// optimizer steps the calibration normalized by
    pub steps: u64,
    pub measured_wall_secs: f64,
    pub predicted_wall_secs: f64,
    pub wall_ratio: f64,
    pub segments: Vec<SegmentDivergence>,
}

impl Divergence {
    pub fn to_json(&self) -> Value {
        Value::object(vec![
            ("mode", Value::str(self.mode.clone())),
            ("steps", Value::num(self.steps as f64)),
            ("measured_wall_secs", Value::num(self.measured_wall_secs)),
            ("predicted_wall_secs", Value::num(self.predicted_wall_secs)),
            ("wall_ratio", Value::num(self.wall_ratio)),
            (
                "segments",
                Value::Array(
                    self.segments
                        .iter()
                        .map(|s| {
                            Value::object(vec![
                                ("name", Value::str(s.name)),
                                ("measured_secs", Value::num(s.measured_secs)),
                                ("predicted_secs", Value::num(s.predicted_secs)),
                                (
                                    "ratio",
                                    s.ratio.map(Value::num).unwrap_or(Value::Null),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Total seconds and span count for `name` across all tracks.
fn tot(data: &RunData, name: &str) -> (f64, u64) {
    let mut secs = 0.0;
    let mut n = 0;
    for s in &data.spans {
        if s.name == name {
            secs += s.dur_secs();
            n += 1;
        }
    }
    (secs, n)
}

/// Prefer the stepped phase span when present, else the async analogue
/// (the two never both carry a mode's primary timeline).
fn tot_either(data: &RunData, phase: &str, fallback: &str) -> (f64, u64) {
    let (secs, n) = tot(data, phase);
    if n > 0 {
        (secs, n)
    } else {
        tot(data, fallback)
    }
}

fn per_step(total: f64, steps: u64) -> f64 {
    total / steps.max(1) as f64
}

/// Re-cost `data`'s recorded config through the matching simulator and
/// report measured-vs-predicted ratios per shared segment.
pub fn diverge(data: &RunData) -> Result<Divergence> {
    let cfg = data.config.as_ref().ok_or_else(|| {
        Error::Cli(
            "--des needs the run's recorded config: point analyze at the \
             journal (out dir or journal.jsonl), not the bare event log"
                .into(),
        )
    })?;
    let mode = cfg
        .get("mode")
        .and_then(Value::as_str)
        .unwrap_or("async_buffered")
        .to_string();

    let (gen_secs, _) = tot_either(data, trace::GENERATE, trace::GEN_CHUNK);
    let (score_secs, _) = tot_either(data, trace::SCORE, trace::REWARD_SCORE);
    let (train_secs, train_n) = tot_either(data, trace::TRAIN, trace::TRAIN_STEP);
    let (sync_secs, sync_n) = tot_either(data, trace::WEIGHT_SYNC, trace::SYNC_OVERLAP);
    let (publish_secs, _) = tot(data, trace::PUBLISH_BLOCK);
    let (d2h, _) = tot(data, trace::OFFLOAD_D2H);
    let (h2d, _) = tot(data, trace::OFFLOAD_H2D);
    let (owait, _) = tot(data, trace::OFFLOAD_WAIT);
    if train_n == 0 {
        return Err(Error::Cli(
            "--des found no train/train_step spans to calibrate against \
             (was the run traced?)"
                .into(),
        ));
    }
    let steps = train_n;
    let sync_background = cfg
        .get("sync_background")
        .and_then(Value::as_bool)
        .unwrap_or(true);
    let des = DesConfig {
        steps: steps as usize,
        // one sequence per batch at measured per-step cost: the timeline
        // structure is under test, not the packing model
        batch: 1,
        concurrency: 1,
        gen_mean_secs: per_step(gen_secs, steps),
        gen_sigma: 0.0,
        train_secs: per_step(train_secs, steps),
        score_secs: per_step(score_secs, steps),
        queue_capacity: cfg
            .get("queue_capacity")
            .and_then(Value::as_usize)
            .unwrap_or(4),
        partial_rollout_cap: f64::INFINITY,
        weight_sync_secs: if sync_n > 0 { sync_secs / sync_n as f64 } else { 0.0 },
        sync_overlap: sync_background,
        publish_block_secs: per_step(publish_secs, steps),
        background_publish: sync_background,
        offload_d2h_secs: per_step(d2h, steps),
        offload_h2d_secs: per_step(h2d, steps),
        offload_overlap: cfg
            .get("offload_background")
            .and_then(Value::as_bool)
            .unwrap_or(true),
        seed: cfg.get("seed").and_then(Value::as_f64).unwrap_or(0.0) as u64,
    };
    let report = match mode.as_str() {
        "sync" => simulate_sync(&des),
        "async" => simulate_async(&des),
        "periodic" => simulate_periodic(
            &des,
            cfg.get("period_steps")
                .and_then(Value::as_usize)
                .unwrap_or(1)
                .max(1),
        ),
        _ => {
            let max_staleness = cfg
                .get("max_staleness")
                .and_then(Value::as_f64)
                .unwrap_or(0.0) as u64;
            simulate_async_buffered(
                &des,
                &BufferedDesConfig {
                    store_capacity: cfg
                        .get("store_capacity")
                        .and_then(Value::as_usize)
                        .unwrap_or(4),
                    max_staleness: if max_staleness == 0 { u64::MAX } else { max_staleness },
                    freshest_first: cfg
                        .get("sampling")
                        .and_then(Value::as_str)
                        .map(|s| s.starts_with("freshest"))
                        .unwrap_or(false),
                },
            )
        }
    };

    let measured = [
        ("generate", gen_secs),
        ("score", score_secs),
        ("train", train_secs),
        ("weight_sync", sync_secs),
        ("publish_block", publish_secs),
        ("offload", d2h + h2d + owait),
    ];
    let segments = measured
        .iter()
        .map(|&(name, m)| {
            let p = report
                .segments
                .iter()
                .find(|(n, _)| *n == name)
                .map(|(_, s)| *s)
                .unwrap_or(0.0);
            SegmentDivergence {
                name,
                measured_secs: m,
                predicted_secs: p,
                ratio: if p > 1e-9 { Some(m / p) } else { None },
            }
        })
        .collect();
    let measured_wall = data.wall_secs();
    Ok(Divergence {
        mode,
        steps,
        measured_wall_secs: measured_wall,
        predicted_wall_secs: report.total_secs,
        wall_ratio: if report.total_secs > 1e-9 {
            measured_wall / report.total_secs
        } else {
            0.0
        },
        segments,
    })
}
