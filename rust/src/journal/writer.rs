//! The durable journal writer: one append-only JSONL file, one mutex.
//!
//! Every record is stamped with the next monotonic `seq`, serialized
//! through `util::json`, written and flushed while the writer lock is
//! held — so journal order *is* seq order, and a snapshot built inside
//! [`JournalWriter::write_snapshot`]'s closure is a consistent cut: no
//! admit/consume/mint record can interleave with it. Hook-path writes
//! (store observer callbacks, bus mint hook) must not propagate errors
//! into the data path, so they count failures instead; the run surfaces
//! `write_errors` at finish.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::dataplane::{ConsumeReason, StoreObserver};
use crate::journal::record::{JournalRecord, SnapshotRecord};
use crate::rl::Trajectory;
use crate::util::error::Result;

struct Inner {
    w: BufWriter<File>,
    next_seq: u64,
}

pub struct JournalWriter {
    inner: Mutex<Inner>,
    bytes_written: AtomicU64,
    records_flushed: AtomicU64,
    write_errors: AtomicU64,
    /// wall-clock origin for the snapshot-lag metric
    epoch: Instant,
    /// nanos-since-epoch of the last snapshot record (0 = none yet)
    last_snapshot_nanos: AtomicU64,
    /// graph node lifecycle mirror, folded into every snapshot
    nodes: Mutex<BTreeMap<String, String>>,
}

impl JournalWriter {
    /// Start a fresh journal at `path` (truncating), seq starting at 0.
    pub fn create(path: impl AsRef<Path>) -> Result<JournalWriter> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let f = File::create(path)?;
        Ok(Self::with_file(f, 0))
    }

    /// Reopen an existing journal for a resumed run, appending records
    /// from `next_seq` (one past the last fully-written record). A
    /// SIGKILL can leave a torn final line with no trailing newline;
    /// appending onto it would fuse the new record into the partial
    /// line and turn a tolerated torn *tail* into hard *interior*
    /// corruption, so the file is first truncated back to its last
    /// newline (to empty when there is none).
    pub fn append(path: impl AsRef<Path>, next_seq: u64) -> Result<JournalWriter> {
        let mut f = OpenOptions::new().read(true).write(true).open(path)?;
        truncate_torn_tail(&mut f)?;
        f.seek(SeekFrom::End(0))?;
        Ok(Self::with_file(f, next_seq))
    }

    fn with_file(f: File, next_seq: u64) -> JournalWriter {
        JournalWriter {
            inner: Mutex::new(Inner {
                w: BufWriter::new(f),
                next_seq,
            }),
            bytes_written: AtomicU64::new(0),
            records_flushed: AtomicU64::new(0),
            write_errors: AtomicU64::new(0),
            epoch: Instant::now(),
            last_snapshot_nanos: AtomicU64::new(0),
            nodes: Mutex::new(BTreeMap::new()),
        }
    }

    fn write_locked(&self, inner: &mut Inner, rec: &JournalRecord) -> Result<()> {
        let seq = inner.next_seq;
        let line = rec.to_value(seq).to_string();
        writeln!(inner.w, "{line}")?;
        inner.w.flush()?;
        inner.next_seq = seq + 1;
        self.bytes_written
            .fetch_add(line.len() as u64 + 1, Ordering::Relaxed);
        self.records_flushed.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Append one record; each record is flushed before the lock drops,
    /// so a SIGKILL can lose at most the line being written.
    pub fn write(&self, rec: &JournalRecord) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        self.write_locked(&mut inner, rec)
    }

    /// Hook-path append: never propagates the error into the caller's
    /// data path, only counts it.
    pub fn write_infallible(&self, rec: &JournalRecord) {
        if self.write(rec).is_err() {
            self.write_errors.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Build and append a snapshot record *while holding the writer lock*.
    /// Because every other record also serializes through that lock, the
    /// state gathered by `build` (store dump, bus front, node states) is
    /// exactly the state as of this journal position — the consistent cut
    /// crash-resume reconstructs from. `build` must not write journal
    /// records itself (it would self-deadlock) and must not be called
    /// from a thread holding store shard locks (lock order is journal →
    /// shards, never the reverse).
    pub fn write_snapshot(&self, build: impl FnOnce() -> SnapshotRecord) {
        let mut inner = self.inner.lock().unwrap();
        let mut snap = build();
        snap.nodes = self.nodes.lock().unwrap().clone();
        if self
            .write_locked(&mut inner, &JournalRecord::Snapshot(snap))
            .is_err()
        {
            self.write_errors.fetch_add(1, Ordering::Relaxed);
        } else {
            let nanos = u64::try_from(self.epoch.elapsed().as_nanos()).unwrap_or(u64::MAX);
            self.last_snapshot_nanos.store(nanos, Ordering::Relaxed);
        }
    }

    /// Record a graph-node lifecycle transition (also mirrored into every
    /// later snapshot's `nodes` map).
    pub fn note_node(&self, name: &str, state: &str) {
        self.nodes
            .lock()
            .unwrap()
            .insert(name.to_string(), state.to_string());
        self.write_infallible(&JournalRecord::Node {
            name: name.to_string(),
            state: state.to_string(),
        });
    }

    // -- lag metrics (the --metrics-interval snapshot series) ---------------

    pub fn bytes_written(&self) -> u64 {
        self.bytes_written.load(Ordering::Relaxed)
    }

    pub fn records_flushed(&self) -> u64 {
        self.records_flushed.load(Ordering::Relaxed)
    }

    pub fn write_errors(&self) -> u64 {
        self.write_errors.load(Ordering::Relaxed)
    }

    /// Seconds since the last snapshot record (time since writer creation
    /// when none has been written yet).
    pub fn secs_since_snapshot(&self) -> f64 {
        let now = u64::try_from(self.epoch.elapsed().as_nanos()).unwrap_or(u64::MAX);
        let last = self.last_snapshot_nanos.load(Ordering::Relaxed);
        now.saturating_sub(last) as f64 / 1e9
    }
}

/// Truncate `f` back to one past its last `'\n'` (to empty when it has
/// none), scanning backwards in chunks so a long torn record costs one
/// tail read, not a full-file pass. A file already ending in a newline
/// is left untouched.
fn truncate_torn_tail(f: &mut File) -> Result<()> {
    let end = f.seek(SeekFrom::End(0))?;
    let mut buf = [0u8; 4096];
    let mut pos = end;
    let mut keep = 0u64;
    while pos > 0 {
        let chunk = buf.len().min(pos as usize);
        pos -= chunk as u64;
        f.seek(SeekFrom::Start(pos))?;
        f.read_exact(&mut buf[..chunk])?;
        if let Some(i) = buf[..chunk].iter().rposition(|&b| b == b'\n') {
            keep = pos + i as u64 + 1;
            break;
        }
    }
    if keep != end {
        f.set_len(keep)?;
    }
    Ok(())
}

/// The journal is the rollout store's durable replica: admissions carry
/// the full row payload, consumptions reference admission seqs — together
/// with periodic snapshots, replaying a suffix of these reconstructs the
/// resident set exactly.
impl StoreObserver for JournalWriter {
    fn on_admit(&self, rows: &[(u64, Trajectory)]) {
        self.write_infallible(&JournalRecord::Admit {
            rows: rows.to_vec(),
        });
    }

    fn on_consume(&self, seqs: &[u64], reason: ConsumeReason) {
        self.write_infallible(&JournalRecord::Consume {
            store_seqs: seqs.to_vec(),
            reason,
        });
    }
}
