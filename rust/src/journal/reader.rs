//! Pull-based streaming journal reader.
//!
//! The reader is an iterator of typed records over a JSONL journal — one
//! line parsed (`util::json`) and decoded at a time, never materializing
//! the document (the `kaleidawave__json-iterator-reader` /
//! `thomcc__smoljson` idiom: resume on a multi-hour journal reads O(line)
//! memory, not O(file)). A killed run may leave a half-written final
//! line; the reader tolerates exactly that — a parse/decode failure on
//! the *last* line of the file ends the stream and sets
//! [`JournalReader::truncated_tail`], while the same failure with more
//! content after it is a hard corruption error.

use std::fs::File;
use std::io::{BufRead, BufReader};
use std::path::Path;

use crate::journal::record::JournalRecord;
use crate::util::error::{Error, Result};

pub struct JournalReader {
    r: BufReader<File>,
    line_no: usize,
    /// physical lines read from the file, blank or not — the 1-based
    /// line number a text editor would show for the corruption site
    phys_line: usize,
    truncated: bool,
    done: bool,
}

impl JournalReader {
    pub fn open(path: impl AsRef<Path>) -> Result<JournalReader> {
        Ok(JournalReader {
            r: BufReader::new(File::open(path)?),
            line_no: 0,
            phys_line: 0,
            truncated: false,
            done: false,
        })
    }

    /// True once the stream ended on a half-written final line (the
    /// signature of a killed run). Only meaningful after the iterator
    /// returns `None`.
    pub fn truncated_tail(&self) -> bool {
        self.truncated
    }

    /// Complete lines consumed so far.
    pub fn lines_read(&self) -> usize {
        self.line_no
    }

    fn at_eof(&mut self) -> bool {
        matches!(self.r.fill_buf(), Ok(buf) if buf.is_empty())
    }

    /// Pull the next `(journal_seq, record)`. `None` is end-of-stream
    /// (clean, or tolerated truncated tail — check `truncated_tail`).
    #[allow(clippy::should_implement_trait)] // also exposed via Iterator
    pub fn next_record(&mut self) -> Option<Result<(u64, JournalRecord)>> {
        if self.done {
            return None;
        }
        let mut line = String::new();
        loop {
            line.clear();
            match self.r.read_line(&mut line) {
                Err(e) => {
                    self.done = true;
                    return Some(Err(Error::Io(e)));
                }
                Ok(0) => {
                    self.done = true;
                    return None;
                }
                Ok(_) => self.phys_line += 1,
            }
            let text = line.trim_end_matches(['\n', '\r']);
            if text.trim().is_empty() {
                continue; // blank line (never written, but harmless)
            }
            let decoded = crate::util::json::Value::parse(text)
                .and_then(|v| JournalRecord::from_value(&v));
            match decoded {
                Ok(rec) => {
                    self.line_no += 1;
                    return Some(Ok(rec));
                }
                Err(e) => {
                    self.done = true;
                    // a bad *final* line is the torn tail of a killed run:
                    // end the stream; bad lines mid-file are corruption
                    if self.at_eof() {
                        self.truncated = true;
                        return None;
                    }
                    return Some(Err(Error::Manifest(format!(
                        "journal corrupt at line {}: {e}",
                        self.phys_line
                    ))));
                }
            }
        }
    }
}

impl Iterator for JournalReader {
    type Item = Result<(u64, JournalRecord)>;

    fn next(&mut self) -> Option<Self::Item> {
        self.next_record()
    }
}
