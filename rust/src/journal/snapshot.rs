//! Background snapshot daemon: periodically folds a consistent cut of the
//! run's durable state (rollout store, bus front + slot fences, memplane
//! residency, node lifecycle) into the journal stream.
//!
//! The daemon owns no state of its own — the runtime hands it a `build`
//! closure that gathers from the live planes, and
//! [`JournalWriter::write_snapshot`] runs it under the writer lock so the
//! cut is atomic with respect to journal order. One snapshot is written
//! immediately at start (so even a run killed in its first interval has a
//! resume point) and one at stop (so a *clean* journal always ends with a
//! fresh cut ahead of the finish record).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::journal::record::SnapshotRecord;
use crate::journal::writer::JournalWriter;

pub struct SnapshotDaemon {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl SnapshotDaemon {
    pub fn start(
        journal: Arc<JournalWriter>,
        interval_secs: f64,
        build: impl Fn() -> SnapshotRecord + Send + 'static,
    ) -> SnapshotDaemon {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let interval = Duration::from_secs_f64(interval_secs.max(0.01));
        let handle = std::thread::Builder::new()
            .name("journal-snapshot".into())
            .spawn(move || {
                journal.write_snapshot(&build);
                let mut last = Instant::now();
                while !stop2.load(Ordering::Acquire) {
                    std::thread::sleep(Duration::from_millis(5).min(interval));
                    if last.elapsed() >= interval {
                        journal.write_snapshot(&build);
                        last = Instant::now();
                    }
                }
                journal.write_snapshot(&build);
            })
            .expect("spawn journal-snapshot");
        SnapshotDaemon {
            stop,
            handle: Some(handle),
        }
    }

    /// Write the final cut and join the daemon.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for SnapshotDaemon {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
