//! The durable run-journal: the authoritative, replayable record of a run.
//!
//! PR 6's trace collector streams an append-only JSONL *event log*; this
//! module promotes that idiom into a durable-state subsystem. A training
//! run (when `journal = true`, the default) writes `out_dir/journal.jsonl`
//! alongside the event log: every line carries a monotonic `seq` and a
//! typed `kind`, and four record families make the journal self-contained:
//!
//! * **state deltas** — store admissions (with full row payloads),
//!   consumptions (by admission seq), weight-sync version mints, trainer
//!   step records, stepped-mode progress ticks, node lifecycle;
//! * **snapshot records** — periodic consistent cuts (store shard
//!   contents + staleness watermark, bus front version + registered-slot
//!   fences, memplane residency, node states) taken *under the journal
//!   writer lock*, so a snapshot plus the suffix after it reconstructs
//!   the run exactly;
//! * **meta** — the fully-resolved run config as record 0, making the
//!   journal replayable with no side channel;
//! * **finish** — the clean-shutdown marker whose absence identifies a
//!   killed run.
//!
//! Consumers pull through [`JournalReader`] — an iterator of typed
//! records over `util::json`, one line at a time, never materializing the
//! document, tolerant of the half-written final line a SIGKILL leaves
//! (the `kaleidawave__json-iterator-reader` / `thomcc__smoljson` reading
//! idiom). On top of it sit [`plan_resume`] (`llamarl resume`: latest
//! snapshot + suffix replay → continue the run), deterministic replay
//! (`llamarl replay`: re-drive the recorded config and compare training
//! trajectories field-for-field), and the `llamarl journal`
//! tail/filter/stats query command.

pub mod reader;
pub mod record;
pub mod resume;
pub mod snapshot;
pub mod writer;

pub use reader::JournalReader;
pub use record::{JournalRecord, SnapshotRecord, StoreSnapshot};
pub use resume::{
    compare_steps, find_checkpoint_state, plan_resume, PriorTotals, ResumePlan, ResumeState,
    StepMismatch,
};
pub use snapshot::SnapshotDaemon;
pub use writer::JournalWriter;
