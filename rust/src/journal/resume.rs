//! Crash-resume planning and replay comparison over a recorded journal.
//!
//! [`plan_resume`] makes one streaming pass (constant memory in the
//! journal length, modulo the resident-row working set): it keeps the
//! latest snapshot as the base cut and folds the *suffix* of
//! admissions/consumptions/version-mints on top of it, exactly the way
//! the ROADMAP's durable-journal item specifies — resume never loads the
//! whole journal, and everything before the last snapshot is skipped as
//! soon as a newer snapshot supersedes it.

use std::collections::HashSet;
use std::path::Path;

use crate::coordinator::TrainStepRecord;
use crate::journal::reader::JournalReader;
use crate::journal::record::{JournalRecord, StoreSnapshot};
use crate::model::load_checkpoint;
use crate::util::error::{Error, Result};
use crate::util::json::Value;

/// What a resumed run inherits from the journaled prefix (report merging
/// and scheduler fast-forward).
#[derive(Debug, Clone, Default)]
pub struct PriorTotals {
    pub tokens: u64,
    pub trajectories: u64,
    pub chunks: u64,
    /// every completed step record, in order (prepended to the resumed
    /// run's report so curves stay continuous)
    pub records: Vec<TrainStepRecord>,
}

/// Reconstructed run state: the controller threads this through
/// `PipelineConfig.resume` to re-seed the store, bus front, trainer clock
/// and prompt scheduler before the graph launches.
#[derive(Debug, Clone, Default)]
pub struct ResumeState {
    /// optimizer step to continue from (last journaled step record)
    pub start_step: u64,
    /// bus front: new version mints continue above this
    pub bus_version: u64,
    /// journal seq the resumed run appends from
    pub next_seq: u64,
    /// rollout-store durable state (None in channel-scored modes)
    pub store: Option<StoreSnapshot>,
    pub prior: PriorTotals,
    /// packed trainer state from the newest on-disk checkpoint at or
    /// below `start_step` (None: trainer re-inits from scratch — counts
    /// still line up, weights restart)
    pub init_state: Option<Vec<f32>>,
}

/// A planned resume: the recorded config plus the reconstructed state.
pub struct ResumePlan {
    /// the `config::to_json` object from the journal's meta record
    pub config: Value,
    /// true when the journal ends with a finish record (nothing to resume)
    pub finished: bool,
    /// true when the journal ended on a torn final line (killed run)
    pub truncated_tail: bool,
    pub state: ResumeState,
}

/// Stream the journal once and reconstruct the latest consistent state.
pub fn plan_resume(journal_path: impl AsRef<Path>) -> Result<ResumePlan> {
    let mut reader = JournalReader::open(&journal_path)?;
    let mut config: Option<Value> = None;
    let mut base: Option<StoreSnapshot> = None;
    let mut base_bus_version = 0u64;
    let mut suffix_admits: Vec<(u64, crate::rl::Trajectory)> = Vec::new();
    let mut consumed: HashSet<u64> = HashSet::new();
    let mut max_admit_next = 0u64;
    let mut max_mint = 0u64;
    let mut records: Vec<TrainStepRecord> = Vec::new();
    let mut last_tick: Option<(u64, u64, u64, u64)> = None;
    let mut admitted_total = 0u64;
    let mut finished = false;
    let mut last_seq = 0u64;
    let mut any = false;

    while let Some(item) = reader.next_record() {
        let (seq, rec) = item?;
        last_seq = last_seq.max(seq);
        any = true;
        match rec {
            JournalRecord::Meta { config: c } => config = Some(c),
            JournalRecord::Snapshot(s) => {
                base_bus_version = base_bus_version.max(s.bus_version);
                if let Some(st) = s.store {
                    base = Some(st);
                    // the snapshot already excludes earlier consumptions;
                    // start the suffix fresh from this cut
                    suffix_admits.clear();
                    consumed.clear();
                }
            }
            JournalRecord::Admit { rows } => {
                admitted_total += rows.len() as u64;
                // tracked across the whole stream (admission seqs are
                // monotonic), BEFORE consumptions are retained out: even
                // when the newest admissions were all consumed, the
                // resumed store must not re-mint their seqs — duplicate
                // store_seqs in the journal would poison the next
                // resume's dedup-by-seq and shared consumed set
                for (s, _) in &rows {
                    max_admit_next = max_admit_next.max(s + 1);
                }
                suffix_admits.extend(rows);
            }
            JournalRecord::Consume { store_seqs, .. } => {
                consumed.extend(store_seqs);
            }
            JournalRecord::Mint { version, .. } => max_mint = max_mint.max(version),
            JournalRecord::Step { record } => records.push(record),
            JournalRecord::Tick {
                step,
                tokens,
                trajectories,
                chunks,
            } => last_tick = Some((step, tokens, trajectories, chunks)),
            JournalRecord::Finish { .. } => finished = true,
            // elastic-fleet churn records and forward-compat unknowns carry
            // no durable state — the resumed cut is the same with or
            // without the restarts that happened along the way
            JournalRecord::Event { .. }
            | JournalRecord::Node { .. }
            | JournalRecord::NodeRestart { .. }
            | JournalRecord::FleetResize { .. }
            | JournalRecord::Unknown { .. } => {}
        }
    }
    let config = config.ok_or_else(|| {
        Error::Manifest("journal has no meta record (not a run journal?)".into())
    })?;

    let start_step = records.last().map(|r| r.step).unwrap_or(0);
    // rebuild the resident set: base cut + suffix admissions (deduped by
    // admission seq — an admit can be journaled just after the cut that
    // already contains its rows) minus everything consumed since
    let mut store = base;
    let had_admits = !suffix_admits.is_empty() || store.is_some();
    if had_admits {
        let st = store.get_or_insert_with(StoreSnapshot::default);
        let mut present: HashSet<u64> = st.rows.iter().map(|(s, _)| *s).collect();
        for (seq, traj) in suffix_admits {
            if present.insert(seq) {
                st.rows.push((seq, traj));
            }
        }
        st.rows.retain(|(s, _)| !consumed.contains(s));
        st.rows.sort_by_key(|(s, _)| *s);
        st.next_seq = st
            .next_seq
            .max(max_admit_next)
            .max(st.rows.last().map(|(s, _)| s + 1).unwrap_or(0));
        st.watermark = st.watermark.max(start_step);
    }

    let prior = PriorTotals {
        tokens: last_tick.map(|t| t.1).unwrap_or(0),
        trajectories: last_tick.map(|t| t.2).unwrap_or(admitted_total),
        chunks: last_tick.map(|t| t.3).unwrap_or(0),
        records,
    };

    Ok(ResumePlan {
        config,
        finished,
        truncated_tail: reader.truncated_tail(),
        state: ResumeState {
            start_step,
            bus_version: base_bus_version.max(max_mint),
            next_seq: if any { last_seq + 1 } else { 0 },
            store,
            prior,
            init_state: None,
        },
    })
}

/// Find the newest `ckpt_step{N}` directory with `N <= start_step` under
/// the run's out_dir and load its packed state. Best-effort: a missing or
/// unreadable checkpoint resumes with fresh trainer state.
pub fn find_checkpoint_state(out_dir: &Path, start_step: u64) -> Option<(u64, Vec<f32>)> {
    let mut best: Option<(u64, std::path::PathBuf)> = None;
    for entry in std::fs::read_dir(out_dir).ok()?.flatten() {
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(n) = name.strip_prefix("ckpt_step") {
            if let Ok(step) = n.parse::<u64>() {
                if step <= start_step && best.as_ref().map(|(b, _)| step > *b).unwrap_or(true)
                {
                    best = Some((step, entry.path()));
                }
            }
        }
    }
    let (step, dir) = best?;
    load_checkpoint(&dir).ok().map(|c| (step, c.state))
}

/// One replay mismatch, rendered for the CLI.
pub struct StepMismatch {
    pub step: u64,
    pub field: &'static str,
    pub recorded: f64,
    pub live: f64,
}

/// Compare a recorded training trajectory against a re-driven one,
/// field by field. `wall_secs` is excluded (timing is not replayable);
/// everything else must match exactly — values round-trip the journal via
/// the shortest-roundtrip f64 format, so equality here is bit-equality up
/// to JSON's `-0.0`/NaN collapse (NaN == NaN counts as a match).
pub fn compare_steps(recorded: &[TrainStepRecord], live: &[TrainStepRecord]) -> Vec<StepMismatch> {
    let mut out = Vec::new();
    let same = |a: f64, b: f64| a == b || (a.is_nan() && b.is_nan());
    if recorded.len() != live.len() {
        out.push(StepMismatch {
            step: 0,
            field: "step_count",
            recorded: recorded.len() as f64,
            live: live.len() as f64,
        });
    }
    for (r, l) in recorded.iter().zip(live.iter()) {
        let fields: [(&'static str, f64, f64); 11] = [
            ("step", r.step as f64, l.step as f64),
            ("loss", r.loss, l.loss),
            ("reward_mean", r.reward_mean, l.reward_mean),
            ("mean_ratio", r.mean_ratio, l.mean_ratio),
            ("clip_frac", r.clip_frac, l.clip_frac),
            ("approx_kl", r.approx_kl, l.approx_kl),
            ("entropy", r.entropy, l.entropy),
            ("grad_norm", r.grad_norm, l.grad_norm),
            ("mean_lag", r.mean_lag, l.mean_lag),
            ("max_lag", r.max_lag as f64, l.max_lag as f64),
            ("rows", r.rows as f64, l.rows as f64),
        ];
        for (field, rv, lv) in fields {
            if !same(rv, lv) {
                out.push(StepMismatch {
                    step: r.step,
                    field,
                    recorded: rv,
                    live: lv,
                });
            }
        }
    }
    out
}
