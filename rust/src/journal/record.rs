//! Typed journal records and their `util::json` wire forms.
//!
//! Every journal line is one JSON object with two universal keys — `seq`
//! (the monotonic journal sequence number) and `kind` (the record tag) —
//! plus the kind-specific payload, flattened where the field names cannot
//! collide. Serialization goes through [`crate::util::json::Value`] like
//! every other writer in the tree, so the shortest-roundtrip f64 format
//! makes numeric payloads (train metrics, behaviour log-probs) exact
//! across a write → stream-read → replay cycle.

use std::collections::BTreeMap;

use crate::coordinator::TrainStepRecord;
use crate::data::{Difficulty, Problem, PromptTask};
use crate::dataplane::{ConsumeReason, PartialRollout};
use crate::rl::{FinishReason, Trajectory};
use crate::util::error::{Error, Result};
use crate::util::json::Value;

/// One record in the run-journal stream.
#[derive(Debug, Clone)]
pub enum JournalRecord {
    /// First record of a fresh journal: the fully-resolved run config
    /// (the `config::to_json` form), so `resume`/`replay` can rebuild the
    /// exact `PipelineConfig` without any side channel.
    Meta { config: Value },
    /// One trace-plane event mirrored into the journal (same line schema
    /// as the collector's event log: B/E spans, i instants, C counters).
    Event {
        t_us: f64,
        track: String,
        ph: String,
        name: String,
        value: f64,
    },
    /// Rows admitted into the rollout store, with their admission seqs.
    Admit { rows: Vec<(u64, Trajectory)> },
    /// Rows that left the store (sampled / evicted / aged out), by seq.
    Consume {
        store_seqs: Vec<u64>,
        reason: ConsumeReason,
    },
    /// A weight-sync version mint on the DDMA bus.
    Mint { version: u64, publisher: usize },
    /// One completed optimizer step with its full metric record.
    Step { record: TrainStepRecord },
    /// Stepped-mode progress fence: cumulative generation totals after
    /// `step` ticks (what scheduler fast-forward and count-parity use).
    Tick {
        step: u64,
        tokens: u64,
        trajectories: u64,
        chunks: u64,
    },
    /// Graph node lifecycle ("start" / "stop").
    Node { name: String, state: String },
    /// A supervised fleet replica restarted under its node's
    /// `RestartPolicy` instead of stopping the world.
    NodeRestart {
        node: String,
        /// 1-based restart ordinal for this replica
        attempt: u64,
        backoff_ms: u64,
        /// partial rollouts the dying attempt parked for survivors
        migrated: u64,
        error: String,
    },
    /// The elastic fleet controller resized a node's replica set.
    FleetResize {
        node: String,
        from: u64,
        to: u64,
        reason: String,
    },
    /// Periodic consistent snapshot of the durable run state.
    Snapshot(SnapshotRecord),
    /// Clean end of run. A journal without one was killed mid-flight.
    Finish { steps: u64, trajectories: u64 },
    /// A record kind this build does not recognize (a journal written by a
    /// newer build). Decode keeps the tag and drops the payload: readers
    /// pass it through, `journal --stats` counts it, resume skips it —
    /// forward tolerance instead of a hard decode error.
    Unknown { kind: String },
}

/// The payload of a [`JournalRecord::Snapshot`]: everything `resume` needs
/// to reconstruct the run without reading the prefix before it.
#[derive(Debug, Clone, Default)]
pub struct SnapshotRecord {
    /// trainer optimizer clock at the cut
    pub trainer_step: u64,
    /// weight-sync bus front (latest minted version)
    pub bus_version: u64,
    pub bus_publishes: u64,
    /// per-registered-generator fence positions (front versions)
    pub slot_fronts: Vec<u64>,
    /// rollout-store durable state (None in channel-scored modes)
    pub store: Option<StoreSnapshot>,
    /// memplane residency at the cut (bytes in each pool)
    pub mem_device_used: u64,
    pub mem_host_used: u64,
    /// graph node lifecycle states at the cut (name -> start|stop)
    pub nodes: BTreeMap<String, String>,
}

/// Rollout-store contents inside a snapshot record.
#[derive(Debug, Clone, Default)]
pub struct StoreSnapshot {
    pub next_seq: u64,
    pub watermark: u64,
    pub rows: Vec<(u64, Trajectory)>,
    pub partials: Vec<PartialRollout>,
}

impl JournalRecord {
    pub fn kind(&self) -> &'static str {
        match self {
            JournalRecord::Meta { .. } => "meta",
            JournalRecord::Event { .. } => "event",
            JournalRecord::Admit { .. } => "admit",
            JournalRecord::Consume { .. } => "consume",
            JournalRecord::Mint { .. } => "mint",
            JournalRecord::Step { .. } => "step",
            JournalRecord::Tick { .. } => "tick",
            JournalRecord::Node { .. } => "node",
            JournalRecord::NodeRestart { .. } => "node_restart",
            JournalRecord::FleetResize { .. } => "fleet_resize",
            JournalRecord::Snapshot(_) => "snapshot",
            JournalRecord::Finish { .. } => "finish",
            JournalRecord::Unknown { .. } => "unknown",
        }
    }

    /// Wire form for journal seq `seq`.
    pub fn to_value(&self, seq: u64) -> Value {
        // an Unknown record re-serializes under its ORIGINAL tag (payload
        // already dropped at decode), so copying a journal keeps the kind
        let kind = match self {
            JournalRecord::Unknown { kind } => Value::str(kind.clone()),
            _ => Value::str(self.kind()),
        };
        let mut pairs: Vec<(&str, Value)> = vec![("seq", Value::num(seq as f64)), ("kind", kind)];
        match self {
            JournalRecord::Meta { config } => pairs.push(("config", config.clone())),
            JournalRecord::Event {
                t_us,
                track,
                ph,
                name,
                value,
            } => {
                pairs.push(("t_us", Value::num(*t_us)));
                pairs.push(("track", Value::str(track.clone())));
                pairs.push(("ph", Value::str(ph.clone())));
                pairs.push(("name", Value::str(name.clone())));
                pairs.push(("value", Value::num(*value)));
            }
            JournalRecord::Admit { rows } => {
                pairs.push(("rows", admitted_rows_to_value(rows)));
            }
            JournalRecord::Consume { store_seqs, reason } => {
                pairs.push(("store_seqs", u64_array(store_seqs)));
                pairs.push(("reason", Value::str(reason.name())));
            }
            JournalRecord::Mint { version, publisher } => {
                pairs.push(("version", Value::num(*version as f64)));
                pairs.push(("publisher", Value::num(*publisher as f64)));
            }
            JournalRecord::Step { record } => {
                pairs.push(("record", step_record_to_value(record)));
            }
            JournalRecord::Tick {
                step,
                tokens,
                trajectories,
                chunks,
            } => {
                pairs.push(("step", Value::num(*step as f64)));
                pairs.push(("tokens", Value::num(*tokens as f64)));
                pairs.push(("trajectories", Value::num(*trajectories as f64)));
                pairs.push(("chunks", Value::num(*chunks as f64)));
            }
            JournalRecord::Node { name, state } => {
                pairs.push(("name", Value::str(name.clone())));
                pairs.push(("state", Value::str(state.clone())));
            }
            JournalRecord::NodeRestart {
                node,
                attempt,
                backoff_ms,
                migrated,
                error,
            } => {
                pairs.push(("node", Value::str(node.clone())));
                pairs.push(("attempt", Value::num(*attempt as f64)));
                pairs.push(("backoff_ms", Value::num(*backoff_ms as f64)));
                pairs.push(("migrated", Value::num(*migrated as f64)));
                pairs.push(("error", Value::str(error.clone())));
            }
            JournalRecord::FleetResize {
                node,
                from,
                to,
                reason,
            } => {
                pairs.push(("node", Value::str(node.clone())));
                pairs.push(("from", Value::num(*from as f64)));
                pairs.push(("to", Value::num(*to as f64)));
                pairs.push(("reason", Value::str(reason.clone())));
            }
            JournalRecord::Unknown { .. } => {}
            JournalRecord::Snapshot(s) => {
                pairs.push(("trainer_step", Value::num(s.trainer_step as f64)));
                pairs.push(("bus_version", Value::num(s.bus_version as f64)));
                pairs.push(("bus_publishes", Value::num(s.bus_publishes as f64)));
                pairs.push(("slot_fronts", u64_array(&s.slot_fronts)));
                pairs.push((
                    "store",
                    match &s.store {
                        None => Value::Null,
                        Some(st) => Value::object(vec![
                            ("next_seq", Value::num(st.next_seq as f64)),
                            ("watermark", Value::num(st.watermark as f64)),
                            ("rows", admitted_rows_to_value(&st.rows)),
                            (
                                "partials",
                                Value::Array(
                                    st.partials.iter().map(partial_to_value).collect(),
                                ),
                            ),
                        ]),
                    },
                ));
                pairs.push(("mem_device_used", Value::num(s.mem_device_used as f64)));
                pairs.push(("mem_host_used", Value::num(s.mem_host_used as f64)));
                pairs.push((
                    "nodes",
                    Value::Object(
                        s.nodes
                            .iter()
                            .map(|(k, v)| (k.clone(), Value::str(v.clone())))
                            .collect(),
                    ),
                ));
            }
            JournalRecord::Finish {
                steps,
                trajectories,
            } => {
                pairs.push(("steps", Value::num(*steps as f64)));
                pairs.push(("trajectories", Value::num(*trajectories as f64)));
            }
        }
        Value::object(pairs)
    }

    /// Decode one journal line. Lines without a `kind` key but with a `ph`
    /// key are accepted as bare trace events (seq 0), so the streaming
    /// reader also validates the collector's raw `trace_events.jsonl`.
    pub fn from_value(v: &Value) -> Result<(u64, JournalRecord)> {
        let kind = match v.get("kind").and_then(|k| k.as_str()) {
            Some(k) => k.to_string(),
            None if v.get("ph").is_some() => "event".to_string(),
            None => return Err(bad("record has no 'kind'")),
        };
        let seq = v.get("seq").and_then(|s| s.as_f64()).unwrap_or(0.0) as u64;
        let rec = match kind.as_str() {
            "meta" => JournalRecord::Meta {
                config: v.req("config")?.clone(),
            },
            "event" => JournalRecord::Event {
                t_us: v.req_f64("t_us")?,
                track: v.req_str("track")?.to_string(),
                ph: v.req_str("ph")?.to_string(),
                name: v.req_str("name")?.to_string(),
                value: v.req_f64("value")?,
            },
            "admit" => JournalRecord::Admit {
                rows: admitted_rows_from_value(v.req("rows")?)?,
            },
            "consume" => JournalRecord::Consume {
                store_seqs: u64_array_from(v.req("store_seqs")?)?,
                reason: ConsumeReason::parse(v.req_str("reason")?)
                    .ok_or_else(|| bad("unknown consume reason"))?,
            },
            "mint" => JournalRecord::Mint {
                version: v.req_f64("version")? as u64,
                publisher: v.req_usize("publisher")?,
            },
            "step" => JournalRecord::Step {
                record: step_record_from_value(v.req("record")?)?,
            },
            "tick" => JournalRecord::Tick {
                step: v.req_f64("step")? as u64,
                tokens: v.req_f64("tokens")? as u64,
                trajectories: v.req_f64("trajectories")? as u64,
                chunks: v.req_f64("chunks")? as u64,
            },
            "node" => JournalRecord::Node {
                name: v.req_str("name")?.to_string(),
                state: v.req_str("state")?.to_string(),
            },
            "node_restart" => JournalRecord::NodeRestart {
                node: v.req_str("node")?.to_string(),
                attempt: v.req_f64("attempt")? as u64,
                backoff_ms: v.req_f64("backoff_ms")? as u64,
                migrated: v.req_f64("migrated")? as u64,
                error: v.req_str("error")?.to_string(),
            },
            "fleet_resize" => JournalRecord::FleetResize {
                node: v.req_str("node")?.to_string(),
                from: v.req_f64("from")? as u64,
                to: v.req_f64("to")? as u64,
                reason: v.req_str("reason")?.to_string(),
            },
            "snapshot" => {
                let store = match v.req("store")? {
                    Value::Null => None,
                    st => Some(StoreSnapshot {
                        next_seq: st.req_f64("next_seq")? as u64,
                        watermark: st.req_f64("watermark")? as u64,
                        rows: admitted_rows_from_value(st.req("rows")?)?,
                        partials: st
                            .req_array("partials")?
                            .iter()
                            .map(partial_from_value)
                            .collect::<Result<Vec<_>>>()?,
                    }),
                };
                let nodes = v
                    .req("nodes")?
                    .as_object()
                    .ok_or_else(|| bad("'nodes' is not an object"))?
                    .iter()
                    .map(|(k, val)| {
                        val.as_str()
                            .map(|s| (k.clone(), s.to_string()))
                            .ok_or_else(|| bad("node state is not a string"))
                    })
                    .collect::<Result<BTreeMap<_, _>>>()?;
                JournalRecord::Snapshot(SnapshotRecord {
                    trainer_step: v.req_f64("trainer_step")? as u64,
                    bus_version: v.req_f64("bus_version")? as u64,
                    bus_publishes: v.req_f64("bus_publishes")? as u64,
                    slot_fronts: u64_array_from(v.req("slot_fronts")?)?,
                    store,
                    mem_device_used: v.req_f64("mem_device_used")? as u64,
                    mem_host_used: v.req_f64("mem_host_used")? as u64,
                    nodes,
                })
            }
            "finish" => JournalRecord::Finish {
                steps: v.req_f64("steps")? as u64,
                trajectories: v.req_f64("trajectories")? as u64,
            },
            // forward tolerance: a kind from a newer build decodes as a
            // skippable marker instead of poisoning the whole read (the
            // reader still treats MALFORMED lines as corruption — only a
            // well-formed object with an unrecognized tag lands here)
            other => JournalRecord::Unknown {
                kind: other.to_string(),
            },
        };
        Ok((seq, rec))
    }
}

fn bad(msg: &str) -> Error {
    Error::Manifest(format!("journal record: {msg}"))
}

// -- scalar array helpers ---------------------------------------------------

fn u64_array(xs: &[u64]) -> Value {
    Value::Array(xs.iter().map(|x| Value::num(*x as f64)).collect())
}

fn u64_array_from(v: &Value) -> Result<Vec<u64>> {
    v.as_array()
        .ok_or_else(|| bad("expected a number array"))?
        .iter()
        .map(|x| {
            x.as_f64()
                .map(|f| f as u64)
                .ok_or_else(|| bad("non-number in array"))
        })
        .collect()
}

fn i32_array(xs: &[i32]) -> Value {
    Value::Array(xs.iter().map(|x| Value::num(*x as f64)).collect())
}

fn i32_array_from(v: &Value) -> Result<Vec<i32>> {
    v.as_array()
        .ok_or_else(|| bad("expected a number array"))?
        .iter()
        .map(|x| {
            x.as_f64()
                .map(|f| f as i32)
                .ok_or_else(|| bad("non-number in array"))
        })
        .collect()
}

/// f32 → f64 widening is exact, and the f64 JSON format is shortest
/// roundtrip, so behaviour log-probs survive the journal bit-for-bit.
fn f32_array(xs: &[f32]) -> Value {
    Value::Array(xs.iter().map(|x| Value::num(*x as f64)).collect())
}

fn f32_array_from(v: &Value) -> Result<Vec<f32>> {
    v.as_array()
        .ok_or_else(|| bad("expected a number array"))?
        .iter()
        .map(|x| {
            x.as_f64()
                .map(|f| f as f32)
                .ok_or_else(|| bad("non-number in array"))
        })
        .collect()
}

// -- domain payloads --------------------------------------------------------

fn difficulty_name(d: Difficulty) -> &'static str {
    match d {
        Difficulty::Add1 => "add1",
        Difficulty::AddSub2 => "addsub2",
        Difficulty::Mul => "mul",
        Difficulty::ThreeTerm => "three_term",
    }
}

fn difficulty_from(s: &str) -> Result<Difficulty> {
    match s {
        "add1" => Ok(Difficulty::Add1),
        "addsub2" => Ok(Difficulty::AddSub2),
        "mul" => Ok(Difficulty::Mul),
        "three_term" => Ok(Difficulty::ThreeTerm),
        _ => Err(bad("unknown difficulty")),
    }
}

fn problem_to_value(p: &Problem) -> Value {
    Value::object(vec![
        ("prompt", Value::str(p.prompt.clone())),
        ("answer", Value::str(p.answer.clone())),
        ("difficulty", Value::str(difficulty_name(p.difficulty))),
    ])
}

fn problem_from_value(v: &Value) -> Result<Problem> {
    Ok(Problem {
        prompt: v.req_str("prompt")?.to_string(),
        answer: v.req_str("answer")?.to_string(),
        difficulty: difficulty_from(v.req_str("difficulty")?)?,
    })
}

pub fn trajectory_to_value(t: &Trajectory) -> Value {
    Value::object(vec![
        ("group_id", Value::num(t.group_id as f64)),
        ("replica", Value::num(t.replica as f64)),
        ("n_replicas", Value::num(t.n_replicas as f64)),
        ("problem", problem_to_value(&t.problem)),
        ("prompt_tokens", i32_array(&t.prompt_tokens)),
        ("response_tokens", i32_array(&t.response_tokens)),
        ("behavior_logp", f32_array(&t.behavior_logp)),
        ("gen_version", Value::num(t.gen_version as f64)),
        ("chunks", Value::num(t.chunks as f64)),
        (
            "finish",
            Value::str(match t.finish {
                FinishReason::Eos => "eos",
                FinishReason::Length => "length",
            }),
        ),
        ("reward", Value::num(t.reward as f64)),
        ("advantage", Value::num(t.advantage as f64)),
    ])
}

pub fn trajectory_from_value(v: &Value) -> Result<Trajectory> {
    Ok(Trajectory {
        group_id: v.req_f64("group_id")? as u64,
        replica: v.req_usize("replica")?,
        n_replicas: v.req_usize("n_replicas")?,
        problem: problem_from_value(v.req("problem")?)?,
        prompt_tokens: i32_array_from(v.req("prompt_tokens")?)?,
        response_tokens: i32_array_from(v.req("response_tokens")?)?,
        behavior_logp: f32_array_from(v.req("behavior_logp")?)?,
        gen_version: v.req_f64("gen_version")? as u64,
        chunks: v.req_f64("chunks")? as u32,
        finish: match v.req_str("finish")? {
            "eos" => FinishReason::Eos,
            "length" => FinishReason::Length,
            _ => return Err(bad("unknown finish reason")),
        },
        reward: v.req_f64("reward")? as f32,
        advantage: v.req_f64("advantage")? as f32,
    })
}

fn admitted_rows_to_value(rows: &[(u64, Trajectory)]) -> Value {
    Value::Array(
        rows.iter()
            .map(|(seq, t)| {
                Value::object(vec![
                    ("store_seq", Value::num(*seq as f64)),
                    ("traj", trajectory_to_value(t)),
                ])
            })
            .collect(),
    )
}

fn admitted_rows_from_value(v: &Value) -> Result<Vec<(u64, Trajectory)>> {
    v.as_array()
        .ok_or_else(|| bad("'rows' is not an array"))?
        .iter()
        .map(|r| {
            Ok((
                r.req_f64("store_seq")? as u64,
                trajectory_from_value(r.req("traj")?)?,
            ))
        })
        .collect()
}

fn partial_to_value(p: &PartialRollout) -> Value {
    Value::object(vec![
        (
            "task",
            Value::object(vec![
                ("group_id", Value::num(p.task.group_id as f64)),
                ("replica", Value::num(p.task.replica as f64)),
                ("n_replicas", Value::num(p.task.n_replicas as f64)),
                ("problem", problem_to_value(&p.task.problem)),
                ("prompt_tokens", i32_array(&p.task.prompt_tokens)),
            ]),
        ),
        ("tokens", i32_array(&p.tokens)),
        ("prompt_len", Value::num(p.prompt_len as f64)),
        ("logps", f32_array(&p.logps)),
        ("chunks", Value::num(p.chunks as f64)),
        ("gen_version", Value::num(p.gen_version as f64)),
    ])
}

fn partial_from_value(v: &Value) -> Result<PartialRollout> {
    let task = v.req("task")?;
    Ok(PartialRollout {
        task: PromptTask {
            group_id: task.req_f64("group_id")? as u64,
            replica: task.req_usize("replica")?,
            n_replicas: task.req_usize("n_replicas")?,
            problem: problem_from_value(task.req("problem")?)?,
            prompt_tokens: i32_array_from(task.req("prompt_tokens")?)?,
        },
        tokens: i32_array_from(v.req("tokens")?)?,
        prompt_len: v.req_usize("prompt_len")?,
        logps: f32_array_from(v.req("logps")?)?,
        chunks: v.req_f64("chunks")? as u32,
        gen_version: v.req_f64("gen_version")? as u64,
    })
}

fn step_record_to_value(r: &TrainStepRecord) -> Value {
    Value::object(vec![
        ("step", Value::num(r.step as f64)),
        ("trainer_replica", Value::num(r.replica as f64)),
        ("wall_secs", Value::num(r.wall_secs)),
        ("loss", Value::num(r.loss)),
        ("reward_mean", Value::num(r.reward_mean)),
        ("mean_ratio", Value::num(r.mean_ratio)),
        ("clip_frac", Value::num(r.clip_frac)),
        ("approx_kl", Value::num(r.approx_kl)),
        ("entropy", Value::num(r.entropy)),
        ("grad_norm", Value::num(r.grad_norm)),
        ("mean_lag", Value::num(r.mean_lag)),
        ("max_lag", Value::num(r.max_lag as f64)),
        ("rows", Value::num(r.rows as f64)),
    ])
}

/// NaN metric fields (a kernel not exporting a metric) serialize as JSON
/// null; read them back as NaN so replay comparison treats NaN == NaN.
fn opt_f64(v: &Value, key: &str) -> Result<f64> {
    match v.req(key)? {
        Value::Null => Ok(f64::NAN),
        x => x
            .as_f64()
            .ok_or_else(|| bad(&format!("'{key}' is not a number"))),
    }
}

fn step_record_from_value(v: &Value) -> Result<TrainStepRecord> {
    Ok(TrainStepRecord {
        step: v.req_f64("step")? as u64,
        // absent in journals written before trainer fleets existed: those
        // runs had exactly one trainer, replica 0
        replica: v.req_f64("trainer_replica").unwrap_or(0.0) as usize,
        wall_secs: opt_f64(v, "wall_secs")?,
        loss: opt_f64(v, "loss")?,
        reward_mean: opt_f64(v, "reward_mean")?,
        mean_ratio: opt_f64(v, "mean_ratio")?,
        clip_frac: opt_f64(v, "clip_frac")?,
        approx_kl: opt_f64(v, "approx_kl")?,
        entropy: opt_f64(v, "entropy")?,
        grad_norm: opt_f64(v, "grad_norm")?,
        mean_lag: opt_f64(v, "mean_lag")?,
        max_lag: v.req_f64("max_lag")? as u64,
        rows: v.req_usize("rows")?,
    })
}
