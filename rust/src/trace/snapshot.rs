//! Live telemetry snapshots (`--metrics-interval <secs>`).
//!
//! End-of-run tallies ([`crate::coordinator::graph::TelemetryHub`]) say
//! *how much* time went where, never *when*. The [`Sampler`] closes that
//! gap: a background thread calls a caller-supplied sampling closure at a
//! fixed cadence and appends each snapshot as one JSONL line, producing a
//! time series of the same counters the final report aggregates —
//! publishes, blocked seconds, store occupancy, offload bytes — while the
//! run is still going.
//!
//! The closure samples atomics and lock-free snapshots only; taking a
//! sample never blocks a plane. A final sample is always written at
//! [`Sampler::stop`] so the series covers the whole run.

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::util::error::{Error, Result};
use crate::util::json::Value;
use crate::util::logging::JsonlWriter;

/// Periodic JSONL telemetry sampler. Construct with [`Sampler::start`],
/// stop with [`Sampler::stop`] (dropping it also stops the thread).
pub struct Sampler {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl Sampler {
    /// Spawn the sampling thread: every `interval_secs` (floored at 10 ms)
    /// it appends `sample()` — an object; an `elapsed_secs` field is
    /// injected — to the JSONL file at `path`.
    pub fn start(
        path: impl AsRef<Path>,
        interval_secs: f64,
        sample: impl Fn() -> Value + Send + 'static,
    ) -> Result<Sampler> {
        let writer = JsonlWriter::create(path)?;
        let interval = Duration::from_secs_f64(interval_secs.max(0.01));
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("telemetry-snapshot".into())
            .spawn(move || {
                let t0 = Instant::now();
                loop {
                    // sleep in small increments so stop() returns promptly
                    let mut waited = Duration::ZERO;
                    while waited < interval && !stop2.load(Ordering::Acquire) {
                        let step = (interval - waited).min(Duration::from_millis(10));
                        std::thread::sleep(step);
                        waited += step;
                    }
                    let stopping = stop2.load(Ordering::Acquire);
                    let mut v = sample();
                    if let Value::Object(m) = &mut v {
                        m.insert(
                            "elapsed_secs".into(),
                            Value::num(t0.elapsed().as_secs_f64()),
                        );
                    }
                    let _ = writer.write(&v);
                    if stopping {
                        return;
                    }
                }
            })
            .map_err(|e| Error::Msg(format!("spawn telemetry sampler: {e}")))?;
        Ok(Sampler {
            stop,
            handle: Some(handle),
        })
    }

    /// Signal the thread, let it write one final snapshot, and join.
    pub fn stop(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        if let Some(h) = self.handle.take() {
            self.stop.store(true, Ordering::Release);
            let _ = h.join();
        }
    }
}

impl Drop for Sampler {
    fn drop(&mut self) {
        self.halt();
    }
}
