//! Background drain thread: merges the per-thread recorder rings into an
//! append-only streaming JSONL event log.
//!
//! The collector is the single consumer of every recorder ring. At a
//! ~10 ms cadence it drains all rings, appends each event as one JSONL
//! line (via [`crate::util::logging::JsonlWriter`], the same writer the
//! metrics log uses), and retains the merged stream in memory so
//! [`Collector::finish`] can hand the whole run to the Chrome exporter.
//!
//! The JSONL log is written *incrementally* — each line is flushed as it
//! is drained — so a crashed or killed run still leaves a readable event
//! log up to its last collector pass. This streaming, append-only shape
//! is the deliberate seed of the ROADMAP's durable run-journal item.
//!
//! Line schema (see the [`crate::trace`] module docs for the event
//! taxonomy):
//!
//! ```json
//! {"t_us":1234.5,"track":"generator-0","ph":"B","name":"generate","value":0}
//! ```

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::journal::{JournalRecord, JournalWriter};
use crate::trace::recorder::{self, EventKind, TraceEvent};
use crate::util::error::{Error, Result};
use crate::util::json::Value;
use crate::util::logging::JsonlWriter;

/// Drain cadence: small enough that a 4096-slot ring absorbs bursts,
/// large enough that the collector thread is invisible in profiles.
const DRAIN_INTERVAL: Duration = Duration::from_millis(10);

/// The merged result of one trace session.
pub struct TraceLog {
    /// every drained event, in per-ring order (per-track timestamps are
    /// monotone; cross-track order is whatever the drain interleaved)
    pub events: Vec<TraceEvent>,
    /// events lost to full rings (0 in a healthy run)
    pub dropped: u64,
}

fn ph(kind: EventKind) -> &'static str {
    match kind {
        EventKind::Begin => "B",
        EventKind::End => "E",
        EventKind::Instant => "i",
        EventKind::Counter => "C",
    }
}

fn event_line(ev: &TraceEvent) -> Value {
    Value::object(vec![
        ("t_us", Value::num(ev.t_nanos as f64 / 1e3)),
        ("track", Value::str(ev.track.clone())),
        ("ph", Value::str(ph(ev.kind))),
        ("name", Value::str(ev.name)),
        ("value", Value::num(ev.value)),
    ])
}

/// The background collector. Construct with [`Collector::start`], stop
/// with [`Collector::finish`]; exactly one may run at a time (the
/// recorder rings have a single consumer).
pub struct Collector {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<(Vec<TraceEvent>, Option<Error>)>>,
}

impl Collector {
    /// Arm the recorder, clear any stale ring contents, open the event
    /// log at `path` (parent dirs created) and spawn the drain thread.
    pub fn start(path: impl AsRef<Path>) -> Result<Collector> {
        Collector::start_with_journal(path, None)
    }

    /// [`Collector::start`], additionally mirroring every drained event
    /// into the run-journal as a [`JournalRecord::Event`] (best-effort:
    /// journal write failures are counted on the writer, never fatal to
    /// the drain loop).
    pub fn start_with_journal(
        path: impl AsRef<Path>,
        journal: Option<Arc<JournalWriter>>,
    ) -> Result<Collector> {
        let writer = JsonlWriter::create(path)?;
        recorder::reset();
        recorder::enable();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("trace-collector".into())
            .spawn(move || {
                let mut retained: Vec<TraceEvent> = Vec::new();
                let mut first_err: Option<Error> = None;
                loop {
                    let stopping = stop2.load(Ordering::Acquire);
                    let from = retained.len();
                    recorder::drain_all(&mut retained);
                    if first_err.is_none() {
                        for ev in &retained[from..] {
                            if let Err(e) = writer.write(&event_line(ev)) {
                                first_err = Some(e);
                                break;
                            }
                        }
                    }
                    if let Some(j) = &journal {
                        for ev in &retained[from..] {
                            j.write_infallible(&JournalRecord::Event {
                                t_us: ev.t_nanos as f64 / 1e3,
                                track: ev.track.clone(),
                                ph: ph(ev.kind).to_string(),
                                name: ev.name.to_string(),
                                value: ev.value,
                            });
                        }
                    }
                    if stopping {
                        // the stop flag was observed *before* this final
                        // drain, so every event recorded before finish()
                        // was captured. Close the stream with the ring-drop
                        // counter so `llamarl analyze` can gate on overflow
                        // without the Chrome export's otherData side channel.
                        let t_us = recorder::now_nanos() as f64 / 1e3;
                        let dropped = recorder::dropped_total() as f64;
                        let line = Value::object(vec![
                            ("t_us", Value::num(t_us)),
                            ("track", Value::str("trace-collector")),
                            ("ph", Value::str("C")),
                            ("name", Value::str(crate::trace::DROPPED_EVENTS)),
                            ("value", Value::num(dropped)),
                        ]);
                        if first_err.is_none() {
                            if let Err(e) = writer.write(&line) {
                                first_err = Some(e);
                            }
                        }
                        if let Some(j) = &journal {
                            j.write_infallible(&JournalRecord::Event {
                                t_us,
                                track: "trace-collector".into(),
                                ph: "C".into(),
                                name: crate::trace::DROPPED_EVENTS.into(),
                                value: dropped,
                            });
                        }
                        return (retained, first_err);
                    }
                    std::thread::sleep(DRAIN_INTERVAL);
                }
            })
            .map_err(|e| Error::Msg(format!("spawn trace collector: {e}")))?;
        Ok(Collector {
            stop,
            handle: Some(handle),
        })
    }

    /// Disarm the recorder, run one final drain, and return the merged
    /// log. Surfaces the first event-log write error, if any.
    pub fn finish(mut self) -> Result<TraceLog> {
        recorder::disable();
        self.stop.store(true, Ordering::Release);
        let handle = self.handle.take().expect("collector joined once");
        let (events, err) = handle
            .join()
            .map_err(|_| Error::Msg("trace collector thread panicked".into()))?;
        if let Some(e) = err {
            return Err(e);
        }
        Ok(TraceLog {
            events,
            dropped: recorder::dropped_total(),
        })
    }
}

impl Drop for Collector {
    fn drop(&mut self) {
        // finish() not called (error path): stop the thread, drop the log
        if let Some(h) = self.handle.take() {
            recorder::disable();
            self.stop.store(true, Ordering::Release);
            let _ = h.join();
        }
    }
}
