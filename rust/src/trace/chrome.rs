//! Chrome Trace Event Format exporter (`--trace <path>`).
//!
//! Converts a [`TraceLog`] into the `{"traceEvents":[...]}` JSON that
//! `chrome://tracing` and Perfetto load: one track (tid) per node
//! replica / worker thread, named via `thread_name` metadata events, with
//! `B`/`E` duration pairs, scoped `i` instants and `C` counter samples.
//! Timestamps are microseconds since the shared trace epoch.
//!
//! Span names match the DES timeline segment vocabulary (`sync_overlap`,
//! `offload_d2h`, ...) so a simulated timeline and a measured one are
//! directly comparable side by side.
//!
//! The file is built and serialized entirely through [`crate::util::json`]
//! — the CI traced arm re-parses it with the same module (`llamarl
//! tracecheck`), closing the round-trip.

use std::collections::btree_map::Entry;
use std::collections::BTreeMap;
use std::path::Path;

use crate::trace::collector::TraceLog;
use crate::trace::recorder::EventKind;
use crate::util::error::Result;
use crate::util::json::Value;

/// All tracks share one process in the exported trace.
const PID: f64 = 1.0;

fn args_value(v: f64) -> Value {
    Value::object(vec![("value", Value::num(v))])
}

/// Write `log` to `path` in Chrome Trace Event Format.
pub fn export(log: &TraceLog, path: impl AsRef<Path>) -> Result<()> {
    // stable tid per track, in order of first appearance
    let mut tids: BTreeMap<&str, usize> = BTreeMap::new();
    let mut order: Vec<&str> = Vec::new();
    for ev in &log.events {
        if let Entry::Vacant(slot) = tids.entry(ev.track.as_str()) {
            slot.insert(order.len() + 1);
            order.push(ev.track.as_str());
        }
    }

    let mut events: Vec<Value> = Vec::with_capacity(log.events.len() + order.len());
    for track in &order {
        let tid = tids[track] as f64;
        events.push(Value::object(vec![
            ("ph", Value::str("M")),
            ("name", Value::str("thread_name")),
            ("pid", Value::num(PID)),
            ("tid", Value::num(tid)),
            ("args", Value::object(vec![("name", Value::str(*track))])),
        ]));
    }
    for ev in &log.events {
        let tid = tids[ev.track.as_str()] as f64;
        let ts = ev.t_nanos as f64 / 1e3;
        let ph = match ev.kind {
            EventKind::Begin => "B",
            EventKind::End => "E",
            EventKind::Instant => "i",
            EventKind::Counter => "C",
        };
        let mut pairs = vec![
            ("ph", Value::str(ph)),
            ("name", Value::str(ev.name)),
            ("pid", Value::num(PID)),
            ("tid", Value::num(tid)),
            ("ts", Value::num(ts)),
        ];
        match ev.kind {
            EventKind::Begin | EventKind::Counter => pairs.push(("args", args_value(ev.value))),
            EventKind::Instant => {
                // thread-scoped instant
                pairs.push(("s", Value::str("t")));
                pairs.push(("args", args_value(ev.value)));
            }
            EventKind::End => {}
        }
        events.push(Value::object(pairs));
    }

    let top = Value::object(vec![
        ("traceEvents", Value::Array(events)),
        ("displayTimeUnit", Value::str("ms")),
        (
            "otherData",
            Value::object(vec![("dropped_events", Value::num(log.dropped as f64))]),
        ),
    ]);
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, top.to_string())?;
    Ok(())
}
