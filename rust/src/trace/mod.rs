//! Run-wide tracing plane: spans + streaming event log, Chrome-trace
//! export, and live telemetry snapshots.
//!
//! LlamaRL's headline claims are about *where time goes* — overlapped
//! weight-sync, hidden offload transfers, asynchronous generation — but
//! tallies assembled at run end cannot localize a mid-run stall to a
//! node, phase, or plane. This module turns every claim the benches gate
//! into an inspectable timeline:
//!
//! * [`recorder`] — per-thread lock-free ring buffers behind a cheap
//!   [`span`]/[`instant`]/[`counter`] API ([`TraceSpan`] RAII guards, one
//!   shared monotonic epoch, track identity = node thread name). One
//!   relaxed atomic load when disabled.
//! * [`collector`] — a background drain thread merging the rings into an
//!   append-only **streaming JSONL event log** (the deliberate seed of
//!   the ROADMAP's durable run-journal item).
//! * [`chrome`] — `--trace <path>` export in Chrome Trace Event Format,
//!   loadable in Perfetto, one track per node replica, span names shared
//!   with the DES timeline segments so simulated and measured timelines
//!   are directly comparable.
//! * [`snapshot`] — `--metrics-interval <secs>` periodic JSONL snapshots
//!   of the live telemetry counters instead of end-of-run-only tallies.
//!
//! All four planes are instrumented: the dataplane store, the weightsync
//! executor's link-group workers, the memplane offload executor, and the
//! graph runtime's node lifecycle + channel blocked sections.
//!
//! # Event schema
//!
//! Every event carries `(t_us, track, ph, name, value)` in the JSONL log;
//! `ph` follows Chrome phase letters (`B`/`E` span, `i` instant, `C`
//! counter). The vocabulary (spans share names with the DES timeline
//! segment/config stems):
//!
//! | name | ph | plane / track | value |
//! |---|---|---|---|
//! | `generate` / `score` / `train` | B/E | stepped-graph phases (controller) | step |
//! | `gen_chunk` | B/E | one `generate_chunk` artifact call (`generator-{i}`) | chunk seq |
//! | `train_step` | B/E | one optimizer step (trainer / controller) | step |
//! | `reward_score` | B/E | reward-fleet scoring pass (`reward-{i}`) | rows |
//! | `weight_sync` | B/E | ddma inline publish fan-out (trainer) | version |
//! | `sync_overlap` | B/E | weightsync link-group stream (`weightsync-link{g}`) | version |
//! | `publish_block` | B/E | trainer blocked inside `publish` | version |
//! | `offload_d2h` / `offload_h2d` | B/E | memplane shard move (`memplane-offload`) | shard idx |
//! | `offload_wait` | B/E | lease holder blocked on residency | shard idx |
//! | `send_blocked` / `recv_blocked` | B/E | channel back-pressure (producing node) | 0 |
//! | `store_sample` | B/E | rollout-store batch assembly (trainer) | rows |
//! | `version_mint` | i | ddma version counter bump (trainer) | version |
//! | `store_admit` | i | rollout-store group admission | rows |
//! | `store_evict` | i | EvictOldest made room | rows |
//! | `store_drop_stale` / `store_drop_capacity` | i | admission drops | rows |
//! | `lease_acquire` / `lease_release` | i | memplane phase lease | phase idx |
//! | `node_start` / `node_stop` | i | graph node lifecycle | 0 |
//! | `node_restart` | i | supervised replica respawn (restarting node) | attempt |
//! | `fleet_resize` | i | elastic fleet grew/shrank (`fleet-controller`) | new size |
//! | `dropped_events` | C | collector final drain (`trace-collector`) | ring drops |
//!
//! The `dropped_events` counter is the last line of every event log: the
//! collector appends it at `finish()` so downstream consumers
//! (`llamarl analyze`) can gate on recorder-ring overflow without the
//! Chrome export's `otherData` side channel.
//!
//! # Journal records
//!
//! The durable run-journal (`out_dir/journal.jsonl`, [`crate::journal`])
//! is a second JSONL stream layered over the same `util::json` plumbing.
//! Every line carries a monotonic `seq` plus a `kind` tag; trace events
//! are mirrored into it as `kind: "event"` lines so one file replays the
//! whole run. Record kinds:
//!
//! | kind | payload | written by |
//! |---|---|---|
//! | `meta` | resolved `PipelineConfig` JSON | controller, line 0 of a fresh run |
//! | `event` | `(t_us, track, ph, name, value)` trace event | trace collector drain |
//! | `admit` | `[{store_seq, traj}]` rows admitted | store observer hook |
//! | `consume` | `store_seqs` + reason (`sample`/`evict`/`stale`) | store observer hook |
//! | `mint` | weights `version` + publisher | ddma mint hook |
//! | `step` | full `TrainStepRecord` | trainer, after each step |
//! | `tick` | cumulative step/tokens/trajectories/chunks | stepped scheduler |
//! | `node` | node name + `start`/`stop` | graph runtime |
//! | `snapshot` | store dump, bus fronts, memplane residency, node states | snapshot daemon |
//! | `finish` | final steps + trajectories | controller, last line |
//!
//! `llamarl resume --journal` rebuilds store+bus from the latest
//! `snapshot` and replays the suffix; `llamarl replay` re-drives the
//! recorded config and diffs live step records against `step` lines.
//!
//! # Lifecycle
//!
//! The controller owns the session: [`Collector::start`] arms the
//! recorder and opens `out_dir/trace_events.jsonl`; after the graph
//! joins, [`Collector::finish`] returns the merged [`TraceLog`] and
//! [`chrome::export`] writes the `--trace` file. The [`Sampler`] runs
//! independently (snapshots need no recorder) and is active whenever
//! `--metrics-interval` is positive.

pub mod chrome;
pub mod collector;
pub mod recorder;
pub mod snapshot;

pub use collector::{Collector, TraceLog};
pub use recorder::{
    counter, disable, enable, enabled, instant, set_track, span, span_with, Event, EventKind,
    TraceEvent, TraceSpan, RING_CAP,
};
pub use snapshot::Sampler;

/// Events lost so far to full recorder rings (0 when tracing is off or
/// healthy). Surfaced in the [`RunReport`] and the live snapshot series.
///
/// [`RunReport`]: crate::coordinator::RunReport
pub fn dropped_events() -> u64 {
    recorder::dropped_total()
}

// ---------------------------------------------------------------------------
// Span vocabulary (shared with the DES timeline segment names)

pub const GENERATE: &str = "generate";
pub const SCORE: &str = "score";
pub const TRAIN: &str = "train";
pub const WEIGHT_SYNC: &str = "weight_sync";
pub const SYNC_OVERLAP: &str = "sync_overlap";
pub const PUBLISH_BLOCK: &str = "publish_block";
pub const OFFLOAD_D2H: &str = "offload_d2h";
pub const OFFLOAD_H2D: &str = "offload_h2d";
pub const OFFLOAD_WAIT: &str = "offload_wait";
pub const SEND_BLOCKED: &str = "send_blocked";
pub const RECV_BLOCKED: &str = "recv_blocked";
pub const STORE_SAMPLE: &str = "store_sample";
/// one `generate_chunk` artifact call on a generator replica (the async
/// modes' per-chunk analogue of the stepped `generate` phase)
pub const GEN_CHUNK: &str = "gen_chunk";
/// one optimizer step on the trainer (the async modes' per-step analogue
/// of the stepped `train` phase; nests inside it in stepped mode)
pub const TRAIN_STEP: &str = "train_step";
/// a reward worker scoring a trajectory batch (async modes have no
/// stepped `score` phase — this is the fleet's own timeline)
pub const REWARD_SCORE: &str = "reward_score";

// instants
pub const VERSION_MINT: &str = "version_mint";
pub const STORE_ADMIT: &str = "store_admit";
pub const STORE_EVICT: &str = "store_evict";
pub const STORE_DROP_STALE: &str = "store_drop_stale";
pub const STORE_DROP_CAPACITY: &str = "store_drop_capacity";
pub const LEASE_ACQUIRE: &str = "lease_acquire";
pub const LEASE_RELEASE: &str = "lease_release";
pub const NODE_START: &str = "node_start";
pub const NODE_STOP: &str = "node_stop";
/// a supervised replica is being respawned (value = attempt number)
pub const NODE_RESTART: &str = "node_restart";
/// the elastic fleet controller grew or shrank the generator fleet
/// (value = new replica count)
pub const FLEET_RESIZE: &str = "fleet_resize";

// counters
/// final-drain counter: events lost to full recorder rings over the run
pub const DROPPED_EVENTS: &str = "dropped_events";
