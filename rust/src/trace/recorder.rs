//! Per-thread lock-free event recorder: the hot-path half of the tracing
//! plane.
//!
//! Every instrumented thread owns a fixed-capacity single-producer /
//! single-consumer ring ([`RING_CAP`] slots, power of two). The producer
//! (the instrumented code) appends with one relaxed head load, one
//! acquire tail load, a plain slot write and a release head store — no
//! locks, no allocation, no syscalls. The single consumer (the
//! [`crate::trace::collector`] drain thread) reads `[tail, head)` under
//! an acquire head load and publishes the new tail with a release store.
//! A full ring drops the event and bumps a counter instead of blocking:
//! tracing must never introduce the stall it is measuring.
//!
//! The disabled path is one relaxed atomic load per call site
//! ([`enabled`]); no ring is touched and no thread state is created, so
//! an untraced run pays effectively nothing (the overhead smoke test in
//! `tests/trace_plane.rs` bounds it).
//!
//! Track identity: each ring is registered under the owning thread's name
//! (the graph runtime names every node thread `generator-{i}`,
//! `reward-{i}`, `evaluator`, `weightsync-link{g}`, `memplane-offload`),
//! which becomes the Chrome-trace track. Threads that predate naming —
//! the controller thread running the trainer — call [`set_track`].

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Slots per thread ring. Power of two; at the collector's drain cadence
/// (~10 ms) this absorbs hundreds of thousands of events per second per
/// thread before dropping.
pub const RING_CAP: usize = 4096;

/// What a ring slot records. `Begin`/`End` bracket a [`TraceSpan`];
/// `Instant` marks a point event; `Counter` samples a monotonically
/// interesting value (both carry it in `value`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    Begin,
    End,
    Instant,
    Counter,
}

/// One recorded event: plain-old-data so the ring slot write is a single
/// memcpy with no drop glue.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// nanoseconds since the shared trace epoch (monotonic clock)
    pub t_nanos: u64,
    pub kind: EventKind,
    /// static span/instant name from the [`crate::trace`] vocabulary
    pub name: &'static str,
    /// span payload / instant argument / counter sample
    pub value: f64,
}

const EMPTY_EVENT: Event = Event {
    t_nanos: 0,
    kind: EventKind::Instant,
    name: "",
    value: 0.0,
};

/// A drained event stamped with the producing thread's track name.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub track: String,
    pub t_nanos: u64,
    pub kind: EventKind,
    pub name: &'static str,
    pub value: f64,
}

// ---------------------------------------------------------------------------
// SPSC ring

struct Ring {
    slots: Box<[UnsafeCell<Event>]>,
    /// next slot the producer writes (monotonic, wraps via masking)
    head: AtomicUsize,
    /// next slot the consumer reads
    tail: AtomicUsize,
    /// events discarded because the ring was full
    dropped: AtomicU64,
}

// SAFETY: the producer is the owning thread and the consumer is the single
// collector thread; `head`/`tail` release/acquire pairs order every slot
// write before the matching read, and `[tail, head)` never aliases a slot
// the producer may touch (it refuses to write when the ring is full).
unsafe impl Sync for Ring {}

impl Ring {
    fn new() -> Ring {
        Ring {
            slots: (0..RING_CAP)
                .map(|_| UnsafeCell::new(EMPTY_EVENT))
                .collect::<Vec<_>>()
                .into_boxed_slice(),
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Producer side; owning thread only.
    fn push(&self, ev: Event) {
        let head = self.head.load(Ordering::Relaxed);
        let tail = self.tail.load(Ordering::Acquire);
        if head.wrapping_sub(tail) >= RING_CAP {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        // SAFETY: slot `head` is outside the consumer's [tail, head) window
        // until the release store below publishes it.
        unsafe {
            *self.slots[head & (RING_CAP - 1)].get() = ev;
        }
        self.head.store(head.wrapping_add(1), Ordering::Release);
    }

    /// Consumer side; collector thread only.
    fn drain_into(&self, out: &mut Vec<Event>) {
        let head = self.head.load(Ordering::Acquire);
        let mut tail = self.tail.load(Ordering::Relaxed);
        while tail != head {
            // SAFETY: the acquire head load ordered the producer's slot
            // write before this read; the producer will not reuse the slot
            // until the release tail store below.
            out.push(unsafe { *self.slots[tail & (RING_CAP - 1)].get() });
            tail = tail.wrapping_add(1);
        }
        self.tail.store(tail, Ordering::Release);
    }
}

struct RingEntry {
    ring: Ring,
    /// Chrome-trace track name; defaults to the thread name at lazy
    /// registration, overridable via [`set_track`].
    track: Mutex<String>,
}

static REGISTRY: Mutex<Vec<Arc<RingEntry>>> = Mutex::new(Vec::new());

thread_local! {
    static LOCAL: Arc<RingEntry> = register_current_thread();
}

fn register_current_thread() -> Arc<RingEntry> {
    let cur = std::thread::current();
    let track = match cur.name() {
        Some(n) => n.to_string(),
        None => format!("thread-{:?}", cur.id()),
    };
    let entry = Arc::new(RingEntry {
        ring: Ring::new(),
        track: Mutex::new(track),
    });
    REGISTRY.lock().unwrap().push(entry.clone());
    entry
}

// ---------------------------------------------------------------------------
// Clock + enablement

static ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();

fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the shared monotonic trace epoch (pinned at the first
/// [`enable`]).
pub fn now_nanos() -> u64 {
    u64::try_from(epoch().elapsed().as_nanos()).unwrap_or(u64::MAX)
}

/// Arm the recorder. Pins the shared epoch on first use so every thread's
/// timestamps share one origin.
pub fn enable() {
    let _ = epoch();
    ENABLED.store(true, Ordering::SeqCst);
}

/// Disarm the recorder: subsequent `span`/`instant`/`counter` calls return
/// to the one-relaxed-load fast path.
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// The per-call-site gate: one relaxed atomic load.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

fn record(kind: EventKind, name: &'static str, value: f64) {
    let ev = Event {
        t_nanos: now_nanos(),
        kind,
        name,
        value,
    };
    // try_with: a Drop running during thread-local teardown must not panic
    let _ = LOCAL.try_with(|e| e.ring.push(ev));
}

// ---------------------------------------------------------------------------
// Recording API

/// RAII span guard: records `Begin` on creation (when tracing is enabled)
/// and the matching `End` on drop. Cheap to construct on the disabled
/// path — a bool, no ring touch.
#[must_use = "a span measures the scope it is alive for"]
pub struct TraceSpan {
    name: &'static str,
    armed: bool,
}

impl Drop for TraceSpan {
    fn drop(&mut self) {
        if self.armed {
            record(EventKind::End, self.name, 0.0);
        }
    }
}

/// Open a span named from the [`crate::trace`] vocabulary.
#[inline]
pub fn span(name: &'static str) -> TraceSpan {
    span_with(name, 0.0)
}

/// Open a span carrying a payload value (e.g. the streamed version).
#[inline]
pub fn span_with(name: &'static str, value: f64) -> TraceSpan {
    if !enabled() {
        return TraceSpan { name, armed: false };
    }
    record(EventKind::Begin, name, value);
    TraceSpan { name, armed: true }
}

/// Record a point event (e.g. a version mint or store admission).
#[inline]
pub fn instant(name: &'static str, value: f64) {
    if enabled() {
        record(EventKind::Instant, name, value);
    }
}

/// Sample a counter value onto the current thread's track.
#[inline]
pub fn counter(name: &'static str, value: f64) {
    if enabled() {
        record(EventKind::Counter, name, value);
    }
}

/// Rename the current thread's track (for threads whose OS name is not the
/// node identity — the controller thread hosting the trainer executor).
pub fn set_track(name: &str) {
    let _ = LOCAL.try_with(|e| *e.track.lock().unwrap() = name.to_string());
}

// ---------------------------------------------------------------------------
// Consumer API (collector only)

/// Drain every registered ring into `out`, stamping each event with its
/// ring's track name. Single consumer: only the collector thread calls
/// this.
pub(crate) fn drain_all(out: &mut Vec<TraceEvent>) {
    let entries: Vec<Arc<RingEntry>> = REGISTRY.lock().unwrap().clone();
    let mut scratch = Vec::new();
    for e in entries {
        scratch.clear();
        e.ring.drain_into(&mut scratch);
        if scratch.is_empty() {
            continue;
        }
        let track = e.track.lock().unwrap().clone();
        out.extend(scratch.iter().map(|ev| TraceEvent {
            track: track.clone(),
            t_nanos: ev.t_nanos,
            kind: ev.kind,
            name: ev.name,
            value: ev.value,
        }));
    }
}

/// Total events dropped to full rings since the last [`reset`].
pub(crate) fn dropped_total() -> u64 {
    REGISTRY
        .lock()
        .unwrap()
        .iter()
        .map(|e| e.ring.dropped.load(Ordering::Relaxed))
        .sum()
}

/// Discard any events left in the rings from a previous trace session and
/// zero the drop counters. Called by the collector at start so a new
/// session begins clean.
pub(crate) fn reset() {
    let entries: Vec<Arc<RingEntry>> = REGISTRY.lock().unwrap().clone();
    let mut scratch = Vec::new();
    for e in entries {
        scratch.clear();
        e.ring.drain_into(&mut scratch);
        e.ring.dropped.store(0, Ordering::Relaxed);
    }
}
