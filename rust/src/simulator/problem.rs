//! The paper's constrained-optimization formulation (§7).
//!
//! Quantities (Definition 7.2): G0 GPUs, global batch B0, per-GPU memory M0,
//! model bytes W0; microbatch b_t, decode concurrency b_g; model-parallel
//! degrees m_t, m_g; trainer GPU fraction theta.
//!
//! Memory (Table 2):  trainer (4*W0 + At*b_t)/m_t <= M0,
//!                    generator (Wg + Kg*b_g)/m_g <= M0.
//!
//! Step time:  T_sync  = B0/G0 * m * (eta_t(b_t) + eta_g(b_g))     (Eq. 2)
//!             T_async = B0/G0 * max(eta_t*m_t/theta,
//!                                   eta_g*m_g/(1-theta))          (Eq. 3)
//!
//! The solver grid-searches b (eta is an arbitrary function pointer, so no
//! closed form), sets m to its memory-constraint minimum (Lemmas B.1/B.2
//! prove optima sit on the constraint), and for the async case balances
//! theta so both sides of the max are equal (Lemma B.3).

/// Per-sample processing time eta(b), seconds. Must be monotone
/// non-increasing in b (Assumption 7.1).
pub type Eta = Box<dyn Fn(f64) -> f64>;

pub struct ProblemSpec {
    /// total GPUs
    pub g0: f64,
    /// global batch size
    pub b0: f64,
    /// per-GPU memory, bytes
    pub m0: f64,
    /// trainer model bytes (weights only; optimizer/grads derived as 4x)
    pub w0: f64,
    /// generator model bytes (< w0 when quantized)
    pub wg: f64,
    /// activation bytes per sample (trainer)
    pub a_t: f64,
    /// KV-cache bytes per concurrent sequence (generator)
    pub k_g: f64,
    pub eta_t: Eta,
    pub eta_g: Eta,
    /// candidate microbatch sizes to search
    pub bt_grid: Vec<f64>,
    /// candidate decode concurrencies to search
    pub bg_grid: Vec<f64>,
    /// per-phase comm penalty multipliers applied as eta*m*penalty(m)
    /// (paper §4.3: large mp inflates inter-node communication; decode is
    /// latency-bound so its penalty is much steeper than training's). The
    /// pure paper form uses `|_| 1.0` for both.
    pub pen_t: Box<dyn Fn(f64) -> f64>,
    pub pen_g: Box<dyn Fn(f64) -> f64>,
    /// straggler/bubble multiplier on the SYNC generation phase only: the
    /// all-rows-finish barrier (paper Fig. 2a) costs the tail of the
    /// generation-length distribution, growing with model scale (paper
    /// §1.1). Async absorbs it via continuous batching + partial rollouts.
    pub sync_straggler: f64,
    /// Tensor-parallel scaling exponent alpha and reference degree m_ref:
    /// per-instance time tau(b, m) = tau_ref(b) * (m_ref/m)^alpha, so the
    /// step-time m-factor becomes m^(1-alpha) * m_ref^alpha * penalty(m).
    ///
    /// alpha = 0 recovers the paper's Definition 7.3 exactly (tau
    /// m-independent) — that is what the Theorem-7.5 property tests use.
    /// alpha ~ 0.85 models real sub-linear TP scaling for the Table-3
    /// replay (adding GPUs to an instance speeds it, but not linearly).
    pub tp_alpha: f64,
    pub m_ref: f64,
    /// LlamaRL's trainer parallelism is FSDP (paper Table 1: "FSDP/3D"):
    /// weights/optimizer/grad memory shards over the WHOLE trainer group
    /// (theta*G0 GPUs), decoupling the compute degree m_t from the Table-2
    /// memory bound — per-GPU memory becomes
    ///     4*W0/(theta*G0) + At*b_t/m_t  <=  M0.
    /// false = the paper's pure Table-2 form (used by the theorem tests).
    pub trainer_fsdp: bool,
}

impl ProblemSpec {
    /// The m-dependent multiplier of eta in the step-time formulas.
    pub fn m_factor_t(&self, m: f64) -> f64 {
        m.powf(1.0 - self.tp_alpha) * self.m_ref.powf(self.tp_alpha) * (self.pen_t)(m)
    }

    pub fn m_factor_g(&self, m: f64) -> f64 {
        m.powf(1.0 - self.tp_alpha) * self.m_ref.powf(self.tp_alpha) * (self.pen_g)(m)
    }
    /// Minimal trainer sharding degree for microbatch b (Table 2 row set 1).
    pub fn min_mt(&self, bt: f64) -> f64 {
        ((4.0 * self.w0 + self.a_t * bt) / self.m0).ceil().max(1.0)
    }

    /// Minimal generator sharding degree for concurrency b (Table 2 row 2).
    pub fn min_mg(&self, bg: f64) -> f64 {
        ((self.wg + self.k_g * bg) / self.m0).ceil().max(1.0)
    }
}

#[derive(Debug, Clone)]
pub struct SyncSolution {
    pub step_secs: f64,
    pub bt: f64,
    pub bg: f64,
    pub m: f64,
    pub eta_t: f64,
    pub eta_g: f64,
}

#[derive(Debug, Clone)]
pub struct AsyncSolution {
    pub step_secs: f64,
    pub bt: f64,
    pub bg: f64,
    pub mt: f64,
    pub mg: f64,
    pub theta: f64,
    pub trainer_gpus: f64,
    pub generator_gpus: f64,
    pub eta_t: f64,
    pub eta_g: f64,
}

/// Solve problem (6): the synchronous co-located baseline. One shared
/// sharding degree m; step time is the SUM of phases (sequential execution).
pub fn solve_sync(p: &ProblemSpec) -> SyncSolution {
    let mut best: Option<SyncSolution> = None;
    for &bt in &p.bt_grid {
        for &bg in &p.bg_grid {
            // shared constraint (Lemma B.1: optimum sits on equality)
            let m = ((4.0 * p.w0 + p.a_t * bt + p.wg + p.k_g * bg) / p.m0)
                .ceil()
                .max(1.0);
            if m > p.g0 {
                continue;
            }
            let et = (p.eta_t)(bt);
            let eg = (p.eta_g)(bg);
            let t = p.b0 / p.g0
                * (et * p.m_factor_t(m) + p.sync_straggler * eg * p.m_factor_g(m));
            if best.as_ref().map(|b| t < b.step_secs).unwrap_or(true) {
                best = Some(SyncSolution {
                    step_secs: t,
                    bt,
                    bg,
                    m,
                    eta_t: et,
                    eta_g: eg,
                });
            }
        }
    }
    best.expect("no feasible sync configuration (increase g0 or grids)")
}

/// Solve problem (7): LlamaRL's decoupled async form. Independent memory
/// constraints; theta balances the two sides of the max (Lemma B.3).
pub fn solve_async(p: &ProblemSpec) -> AsyncSolution {
    let mut best: Option<AsyncSolution> = None;
    for &bt in &p.bt_grid {
        // With trainer_fsdp the compute degree m_t is free (weights shard
        // over the whole group) and only activations bind it; otherwise
        // m_t is pinned to the Table-2 minimum (Lemma B.2).
        let mt_candidates: Vec<f64> = if p.trainer_fsdp {
            p.bt_grid.clone()
        } else {
            vec![p.min_mt(bt)]
        };
        for &mt in &mt_candidates {
            if mt > p.g0 {
                continue;
            }
            if p.trainer_fsdp && p.a_t * bt / mt >= p.m0 {
                continue;
            }
            let tt = (p.eta_t)(bt) * p.m_factor_t(mt); // T_t** (Eq. 10, scaled)
            for &bg in &p.bg_grid {
                let mg = p.min_mg(bg);
                if mt + mg > p.g0 {
                    continue;
                }
                let tg = (p.eta_g)(bg) * p.m_factor_g(mg);
                // optimal theta equalizes both sides: theta = tt / (tt + tg)
                let mut theta = tt / (tt + tg);
                if p.trainer_fsdp {
                    // FSDP memory bound: 4*W0/(theta*G0) + At*bt/mt <= M0
                    let theta_mem = 4.0 * p.w0 / ((p.m0 - p.a_t * bt / mt) * p.g0);
                    if theta_mem >= 1.0 {
                        continue;
                    }
                    theta = theta.max(theta_mem).max(mt / p.g0);
                }
                if theta >= 1.0 || (1.0 - theta) * p.g0 < mg {
                    continue;
                }
                let t = p.b0 / p.g0 * (tt / theta).max(tg / (1.0 - theta));
                if best.as_ref().map(|b| t < b.step_secs).unwrap_or(true) {
                    best = Some(AsyncSolution {
                        step_secs: t,
                        bt,
                        bg,
                        mt,
                        mg,
                        theta,
                        trainer_gpus: theta * p.g0,
                        generator_gpus: (1.0 - theta) * p.g0,
                        eta_t: (p.eta_t)(bt),
                        eta_g: (p.eta_g)(bg),
                    });
                }
            }
        }
    }
    best.expect("no feasible async configuration (increase g0 or grids)")
}

/// Evaluate a FIXED async configuration (for replaying the paper's Table-3
/// rows rather than optimizing).
pub fn eval_async_config(
    p: &ProblemSpec,
    bt: f64,
    bg: f64,
    mt: f64,
    mg: f64,
    theta: f64,
) -> f64 {
    let tt = (p.eta_t)(bt) * p.m_factor_t(mt);
    let tg = (p.eta_g)(bg) * p.m_factor_g(mg);
    p.b0 / p.g0 * (tt / theta).max(tg / (1.0 - theta))
}

/// Evaluate a FIXED sync configuration.
pub fn eval_sync_config(p: &ProblemSpec, bt: f64, bg: f64, m: f64) -> f64 {
    p.b0 / p.g0
        * ((p.eta_t)(bt) * p.m_factor_t(m)
            + p.sync_straggler * (p.eta_g)(bg) * p.m_factor_g(m))
}

pub fn default_grid() -> Vec<f64> {
    vec![
        1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0, 2048.0,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_problem() -> ProblemSpec {
        ProblemSpec {
            g0: 1024.0,
            b0: 2048.0,
            m0: 80e9,
            w0: 100e9,
            wg: 100e9,
            a_t: 2e9,
            k_g: 1e9,
            eta_t: Box::new(|b| 4.0 / b + 0.5),
            eta_g: Box::new(|b| 8.0 / b + 1.0),
            bt_grid: default_grid(),
            bg_grid: default_grid(),
            pen_t: Box::new(|_| 1.0),
            pen_g: Box::new(|_| 1.0),
            sync_straggler: 1.0,
            tp_alpha: 0.0,
            m_ref: 1.0,
            trainer_fsdp: false,
        }
    }

    #[test]
    fn async_strictly_beats_sync() {
        let p = toy_problem();
        let s = solve_sync(&p);
        let a = solve_async(&p);
        assert!(
            a.step_secs < s.step_secs,
            "Theorem 7.5 violated: async {} >= sync {}",
            a.step_secs,
            s.step_secs
        );
    }

    #[test]
    fn solutions_satisfy_memory_constraints() {
        let p = toy_problem();
        let s = solve_sync(&p);
        assert!((4.0 * p.w0 + p.a_t * s.bt + p.wg + p.k_g * s.bg) / s.m <= p.m0 * 1.0001);
        let a = solve_async(&p);
        assert!((4.0 * p.w0 + p.a_t * a.bt) / a.mt <= p.m0 * 1.0001);
        assert!((p.wg + p.k_g * a.bg) / a.mg <= p.m0 * 1.0001);
        assert!(a.theta > 0.0 && a.theta < 1.0);
    }

    #[test]
    fn theta_balances_sides() {
        let p = toy_problem();
        let a = solve_async(&p);
        let tt = a.eta_t * a.mt / a.theta;
        let tg = a.eta_g * a.mg / (1.0 - a.theta);
        assert!((tt - tg).abs() / tt < 1e-9, "Lemma B.3: {tt} vs {tg}");
    }

    #[test]
    fn fixed_config_eval_matches_solver_at_optimum() {
        let p = toy_problem();
        let a = solve_async(&p);
        let t = eval_async_config(&p, a.bt, a.bg, a.mt, a.mg, a.theta);
        assert!((t - a.step_secs).abs() / t < 1e-9);
    }
}
