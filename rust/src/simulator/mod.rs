//! Cluster simulator: re-derives the paper's H100-scale evaluation from its
//! own cost model (Definitions 7.2–7.4, Table 2), since this testbed has no
//! GPUs.
//!
//! Two layers:
//!
//! * [`problem`] — the paper's abstract constrained-optimization form:
//!   arbitrary monotone-decreasing per-sample-time functions eta_t/eta_g, the
//!   Table-2 memory constraints, and a solver for problems (6) (synchronous)
//!   and (7) (LlamaRL). Theorem 7.5 (async strictly faster) is verified
//!   numerically over random instances in `rust/tests/prop_simulator.rs`.
//! * [`hardware`] — a physical cost model (FLOPs/HBM roofline + batch
//!   efficiency saturation + model-parallel communication penalty) that
//!   instantiates eta for Llama-3.1 8B/70B/405B on H100s, calibrated against
//!   the paper's Table-3 baseline rows; the async predictions are then
//!   genuine model outputs compared against the paper's LlamaRL rows.
//! * [`des`] — a discrete-event timeline of the architectures with
//!   straggler (generation-length) variance: reproduces the Figure-2 bubble
//!   structure, the partial-rollout ablation, and the buffered-pipeline
//!   (rollout-store) timeline with capacity eviction and an enforced
//!   staleness bound.

pub mod des;
pub mod hardware;
pub mod problem;

pub use des::{
    simulate_async, simulate_async_buffered, simulate_periodic, simulate_sync,
    simulate_timeline, BufferedDesConfig, DesConfig, DesReport,
};
pub use hardware::{
    calibrated_eta, GpuSpec, HardwareModel, ModelSpec, PaperRow, LLAMA_MODELS, PAPER_TABLE3,
};
pub use problem::{
    solve_async, solve_sync, AsyncSolution, Eta, ProblemSpec, SyncSolution,
};
