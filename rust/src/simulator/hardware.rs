//! Physical instantiation of the paper's cost model for Llama-3.1 models on
//! H100 clusters, calibrated against Table 3's baseline rows.
//!
//! Calibration contract (documented in DESIGN.md): the paper's *baseline*
//! rows pin the absolute scale of eta_t + eta_g per model size (via Eq. 2);
//! two shape constants split and curve them:
//!
//! * `GEN_FRACTION` — share of a synchronous step spent generating (the
//!   paper: generation is "memory-bound with major execution time
//!   contribution");
//! * `FIXED_FRACTION` — share of per-sample time that amortizes away with
//!   batch (Figure 5's curvature): eta(b) = c0/b + c1.
//!
//! Everything else — memory-forced minimum sharding degrees, the
//! theta split, fp8's halved generator footprint, the large-mp
//! communication penalty — comes from the model, so the simulated *LlamaRL*
//! rows and the Figure-7 speedup curve are genuine predictions, compared
//! against the paper's published numbers by the benches.

use crate::simulator::problem::{default_grid, ProblemSpec};

/// Architecture constants of the evaluated models.
#[derive(Debug, Clone, Copy)]
pub struct ModelSpec {
    pub name: &'static str,
    pub params: f64,
    pub layers: f64,
    pub d_model: f64,
    /// grouped-query attention KV width (d_kv = d_model / gqa_ratio)
    pub gqa_ratio: f64,
}

pub const LLAMA_MODELS: [ModelSpec; 3] = [
    ModelSpec {
        name: "8B",
        params: 8e9,
        layers: 32.0,
        d_model: 4096.0,
        gqa_ratio: 4.0,
    },
    ModelSpec {
        name: "70B",
        params: 70e9,
        layers: 80.0,
        d_model: 8192.0,
        gqa_ratio: 8.0,
    },
    ModelSpec {
        name: "405B",
        params: 405e9,
        layers: 126.0,
        d_model: 16384.0,
        gqa_ratio: 8.0,
    },
];

#[derive(Debug, Clone, Copy)]
pub struct GpuSpec {
    pub mem_bytes: f64,
    pub bf16_flops: f64,
    pub hbm_bps: f64,
}

pub const H100: GpuSpec = GpuSpec {
    mem_bytes: 80e9,
    bf16_flops: 989e12,
    hbm_bps: 3.35e12,
};

/// Sequence-length assumptions for the RL workload (MATH-style prompts).
pub const SEQ_TOTAL: f64 = 2048.0;

/// Paper Table 3 rows (the ground truth the benches print alongside).
#[derive(Debug, Clone, Copy)]
pub struct PaperRow {
    pub model: &'static str,
    pub system: &'static str,
    pub step_secs: f64,
    pub total_gpus: f64,
    pub trainer_mp: f64,
    pub generator_mp: f64,
    pub fp8_generator: bool,
}

pub const PAPER_TABLE3: [PaperRow; 10] = [
    PaperRow { model: "8B", system: "baseline", step_secs: 22.45, total_gpus: 256.0, trainer_mp: 8.0, generator_mp: 8.0, fp8_generator: false },
    PaperRow { model: "70B", system: "baseline", step_secs: 82.32, total_gpus: 256.0, trainer_mp: 8.0, generator_mp: 8.0, fp8_generator: false },
    PaperRow { model: "405B", system: "baseline", step_secs: 635.8, total_gpus: 1024.0, trainer_mp: 64.0, generator_mp: 64.0, fp8_generator: false },
    PaperRow { model: "8B", system: "llamarl", step_secs: 12.22, total_gpus: 256.0, trainer_mp: 8.0, generator_mp: 8.0, fp8_generator: false },
    PaperRow { model: "8B", system: "llamarl", step_secs: 8.90, total_gpus: 256.0, trainer_mp: 8.0, generator_mp: 1.0, fp8_generator: false },
    PaperRow { model: "70B", system: "llamarl", step_secs: 26.19, total_gpus: 256.0, trainer_mp: 8.0, generator_mp: 8.0, fp8_generator: false },
    PaperRow { model: "70B", system: "llamarl", step_secs: 20.67, total_gpus: 256.0, trainer_mp: 8.0, generator_mp: 4.0, fp8_generator: true },
    PaperRow { model: "405B", system: "llamarl", step_secs: 240.8, total_gpus: 1024.0, trainer_mp: 32.0, generator_mp: 32.0, fp8_generator: false },
    PaperRow { model: "405B", system: "llamarl", step_secs: 100.5, total_gpus: 1024.0, trainer_mp: 16.0, generator_mp: 16.0, fp8_generator: false },
    PaperRow { model: "405B", system: "llamarl", step_secs: 59.5, total_gpus: 1024.0, trainer_mp: 16.0, generator_mp: 8.0, fp8_generator: true },
];

/// The paper's headline speedups per size (baseline / best LlamaRL row).
pub fn paper_speedup(model: &str) -> f64 {
    let base = PAPER_TABLE3
        .iter()
        .find(|r| r.model == model && r.system == "baseline")
        .unwrap()
        .step_secs;
    let best = PAPER_TABLE3
        .iter()
        .filter(|r| r.model == model && r.system == "llamarl")
        .map(|r| r.step_secs)
        .fold(f64::INFINITY, f64::min);
    base / best
}

/// Calibration shape constants (see module docs).
pub const GEN_FRACTION: f64 = 0.7;
pub const FIXED_FRACTION: f64 = 0.35;

/// Sub-linear tensor-parallel scaling exponent: tau(b, m) = tau_ref *
/// (m_ref/m)^alpha. 0.85 means doubling an instance's GPUs buys ~1.8x.
pub const TP_ALPHA: f64 = 0.85;

/// fp8 generator kernels run ~1.4x faster than bf16 on H100 (in addition
/// to halving the weight footprint).
pub const FP8_GEN_SPEEDUP: f64 = 1.4;

/// Inter-node communication penalties once an instance spans > 1 node of 8
/// GPUs (paper §4.3: "smaller mp size ... significantly reduce the
/// inter-node communications"). Training is throughput-bound (overlappable
/// all-reduces, mild penalty); single-token decode is latency-bound (a
/// blocking all-reduce per layer per token, steep penalty).
pub fn comm_penalty_train(m: f64) -> f64 {
    1.0 + 0.10 * (m / 8.0).max(1.0).log2()
}

pub fn comm_penalty_gen(m: f64) -> f64 {
    1.0 + 0.60 * (m / 8.0).max(1.0).log2()
}

/// Straggler/bubble multiplier on the synchronous generation phase: the
/// all-rows-finish barrier (Fig. 2a) costs the generation-length tail, and
/// the paper observes the effect grows with model scale ("larger models
/// introduce larger generation time differences causing larger bubbles",
/// §1.1). Calibrated shape: +12% per doubling beyond 8B.
pub fn sync_straggler_factor(params: f64) -> f64 {
    1.0 + 0.12 * (params / 8e9).max(1.0).log2()
}

/// The paper baseline's model-parallel degree for a model size (the forced
/// co-located TP degree; also the calibration reference m_ref).
pub fn baseline_mp(model: &str) -> f64 {
    PAPER_TABLE3
        .iter()
        .find(|r| r.model == model && r.system == "baseline")
        .map(|r| r.trainer_mp)
        .unwrap_or(8.0)
}

/// Baseline batch sizes assumed for the calibration anchor (per-instance
/// microbatch / decode concurrency of the paper's baseline configs).
pub const BASE_BT: f64 = 8.0;
pub const BASE_BG: f64 = 16.0;

#[derive(Debug, Clone, Copy)]
pub struct HardwareModel {
    pub model: ModelSpec,
    pub gpu: GpuSpec,
    pub g0: f64,
    pub b0: f64,
    /// fp8 generator weights (halved footprint, same eta shape)
    pub fp8_generator: bool,
    /// enable the large-mp communication penalty (paper §4.3)
    pub mp_penalty: bool,
}

impl HardwareModel {
    pub fn paper_scale(model: ModelSpec) -> HardwareModel {
        let g0 = if model.params > 100e9 { 1024.0 } else { 256.0 };
        HardwareModel {
            model,
            gpu: H100,
            g0,
            b0: 2048.0,
            fp8_generator: false,
            mp_penalty: true,
        }
    }

    /// Trainer activation bytes per sample (selective recomputation, bf16).
    pub fn act_bytes_per_sample(&self) -> f64 {
        4.0 * self.model.layers * self.model.d_model * SEQ_TOTAL * 2.0
    }

    /// Generator KV-cache bytes per concurrent sequence (GQA, bf16).
    pub fn kv_bytes_per_seq(&self) -> f64 {
        2.0 * self.model.layers * SEQ_TOTAL * (self.model.d_model / self.model.gqa_ratio) * 2.0
    }

    pub fn w0_bytes(&self) -> f64 {
        2.0 * self.model.params
    }

    pub fn wg_bytes(&self) -> f64 {
        if self.fp8_generator {
            self.model.params
        } else {
            2.0 * self.model.params
        }
    }

    /// Eq. 2 inverted on the paper's baseline row, accounting for the
    /// m-factor at the baseline's own configuration: the calibration anchor
    /// eta_t(BASE_BT) + eta_g(BASE_BG) for this model size.
    fn eta_sum_anchor(&self) -> f64 {
        let row = PAPER_TABLE3
            .iter()
            .find(|r| r.model == self.model.name && r.system == "baseline")
            .expect("model has a baseline row");
        // Invert Eq. 2 with the per-phase m-factors and straggler term:
        //   T = B0/G0 * A * [(1-gamma) * m * pen_t(m)
        //                    + straggler * gamma * m * pen_g(m)]
        // (m_ref = m_base makes the alpha terms collapse to m_base).
        let m = row.trainer_mp;
        let (pt, pg, st) = if self.mp_penalty {
            (
                comm_penalty_train(m),
                comm_penalty_gen(m),
                sync_straggler_factor(self.model.params),
            )
        } else {
            (1.0, 1.0, 1.0)
        };
        let weight = (1.0 - GEN_FRACTION) * m * pt + st * GEN_FRACTION * m * pg;
        row.step_secs * row.total_gpus / (2048.0 * weight)
    }

    /// Build the optimization problem for this hardware point (physical
    /// form: sub-linear TP scaling + inter-node penalty; the pure paper
    /// form is exercised by the property tests with tp_alpha = 0).
    pub fn problem(&self) -> ProblemSpec {
        let anchor = self.eta_sum_anchor();
        let (eta_t_fn, eta_g) = calibrated_eta(anchor);
        let eta_g_fn: crate::simulator::problem::Eta = if self.fp8_generator {
            Box::new(move |b| eta_g(b) / FP8_GEN_SPEEDUP)
        } else {
            eta_g
        };
        let (pen_t, pen_g): (Box<dyn Fn(f64) -> f64>, Box<dyn Fn(f64) -> f64>) =
            if self.mp_penalty {
                (Box::new(comm_penalty_train), Box::new(comm_penalty_gen))
            } else {
                (Box::new(|_| 1.0), Box::new(|_| 1.0))
            };
        let straggler = if self.mp_penalty {
            sync_straggler_factor(self.model.params)
        } else {
            1.0
        };
        ProblemSpec {
            g0: self.g0,
            b0: self.b0,
            m0: self.gpu.mem_bytes,
            w0: self.w0_bytes(),
            wg: self.wg_bytes(),
            a_t: self.act_bytes_per_sample(),
            k_g: self.kv_bytes_per_seq(),
            eta_t: eta_t_fn,
            eta_g: eta_g_fn,
            bt_grid: default_grid(),
            bg_grid: default_grid(),
            pen_t,
            pen_g,
            sync_straggler: straggler,
            tp_alpha: TP_ALPHA,
            m_ref: baseline_mp(self.model.name),
            trainer_fsdp: true,
        }
    }

    /// The paper baseline replay: step time at the paper's own co-located
    /// configuration (m = published mp, calibration batches). By
    /// construction this reproduces the paper's baseline column.
    pub fn baseline_replay_secs(&self) -> f64 {
        let p = self.problem();
        crate::simulator::problem::eval_sync_config(
            &p,
            BASE_BT,
            BASE_BG,
            baseline_mp(self.model.name),
        )
    }
}

/// Split + curve the anchored per-sample time into eta_t(b), eta_g(b)
/// (eta(b) = c0/b + c1, Assumption 7.1 satisfied by construction).
pub fn calibrated_eta(anchor_sum: f64) -> (crate::simulator::problem::Eta, crate::simulator::problem::Eta) {
    let eta_t_base = (1.0 - GEN_FRACTION) * anchor_sum;
    let eta_g_base = GEN_FRACTION * anchor_sum;
    let c0_t = FIXED_FRACTION * eta_t_base * BASE_BT;
    let c1_t = (1.0 - FIXED_FRACTION) * eta_t_base;
    let c0_g = FIXED_FRACTION * eta_g_base * BASE_BG;
    let c1_g = (1.0 - FIXED_FRACTION) * eta_g_base;
    (
        Box::new(move |b: f64| c0_t / b + c1_t),
        Box::new(move |b: f64| c0_g / b + c1_g),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::problem::{solve_async, solve_sync};

    #[test]
    fn eta_is_monotone_decreasing() {
        let (et, eg) = calibrated_eta(5.0);
        let grid = default_grid();
        for w in grid.windows(2) {
            assert!(et(w[1]) <= et(w[0]));
            assert!(eg(w[1]) <= eg(w[0]));
        }
    }

    #[test]
    fn anchor_reproduces_baseline_step_time() {
        for m in LLAMA_MODELS {
            let hw = HardwareModel::paper_scale(m);
            let row = PAPER_TABLE3
                .iter()
                .find(|r| r.model == m.name && r.system == "baseline")
                .unwrap();
            let t = hw.baseline_replay_secs();
            assert!(
                (t - row.step_secs).abs() / row.step_secs < 1e-9,
                "{}: {t} vs {}",
                m.name,
                row.step_secs
            );
        }
    }

    #[test]
    fn async_speedup_grows_with_model_size() {
        let mut speedups = Vec::new();
        for m in LLAMA_MODELS {
            let hw = HardwareModel::paper_scale(m);
            let base = hw.baseline_replay_secs();
            let hw8 = HardwareModel {
                fp8_generator: true,
                ..hw
            };
            let asn = solve_async(&hw8.problem());
            speedups.push(base / asn.step_secs);
        }
        assert!(
            speedups[0] < speedups[1] && speedups[1] < speedups[2],
            "speedup must grow with scale: {speedups:?}"
        );
        assert!(speedups[0] > 1.0);
    }

    #[test]
    fn optimized_sync_never_beats_async() {
        for m in LLAMA_MODELS {
            let hw = HardwareModel::paper_scale(m);
            let p = hw.problem();
            let sync = solve_sync(&p);
            let asn = solve_async(&hw.problem());
            assert!(asn.step_secs <= sync.step_secs * 1.0001, "{}", m.name);
        }
    }

    #[test]
    fn paper_speedups() {
        assert!((paper_speedup("8B") - 22.45 / 8.90).abs() < 1e-9);
        assert!((paper_speedup("405B") - 635.8 / 59.5).abs() < 1e-9);
    }
}
