//! Discrete-event simulation of the two execution architectures
//! (paper Figure 2): per-GPU-group timelines, idle-bubble accounting,
//! straggler effects from generation-length variance, and the
//! partial-rollout mitigation (paper §4.2).
//!
//! The DES models one "processing group" per executor. Generation length is
//! lognormal (heavy right tail = stragglers). In the synchronous
//! architecture the trainer waits for the LAST sequence of every batch
//! (Fig. 2a bubbles); asynchronously, groups free-run with a bounded queue
//! (Fig. 2b) and partial rollouts cap per-iteration generation so stragglers
//! span iterations instead of blocking peers.

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct DesConfig {
    /// RL steps to simulate
    pub steps: usize,
    /// sequences per training batch
    pub batch: usize,
    /// decode slots per generator group (concurrency)
    pub concurrency: usize,
    /// mean generation time per sequence, seconds
    pub gen_mean_secs: f64,
    /// lognormal sigma of generation time (straggler heaviness)
    pub gen_sigma: f64,
    /// trainer time per batch, seconds
    pub train_secs: f64,
    /// reward scoring time per batch, seconds
    pub score_secs: f64,
    /// async queue capacity, in batches
    pub queue_capacity: usize,
    /// cap generation work per iteration at this multiple of the mean
    /// (partial rollouts); f64::INFINITY disables
    pub partial_rollout_cap: f64,
    pub seed: u64,
}

impl Default for DesConfig {
    fn default() -> Self {
        DesConfig {
            steps: 50,
            batch: 64,
            concurrency: 16,
            gen_mean_secs: 8.0,
            gen_sigma: 0.6,
            // a balanced regime (generation ~32 s per 64-batch at 16 slots,
            // training 24 s): both bubbles visible, as in paper Fig. 2
            train_secs: 24.0,
            score_secs: 0.2,
            queue_capacity: 2,
            partial_rollout_cap: f64::INFINITY,
            seed: 0,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct DesReport {
    pub total_secs: f64,
    pub step_secs_mean: f64,
    /// fraction of the run the generator group sat idle
    pub gen_idle_frac: f64,
    /// fraction of the run the trainer group sat idle
    pub train_idle_frac: f64,
    /// mean number of trainer steps of lag for consumed batches (async)
    pub mean_lag_steps: f64,
    /// per-step completion times
    pub step_ends: Vec<f64>,
}

/// Draw per-sequence generation times; lognormal, mean-normalized.
fn gen_times(rng: &mut Rng, cfg: &DesConfig, n: usize) -> Vec<f64> {
    let mu = -0.5 * cfg.gen_sigma * cfg.gen_sigma; // E[lognormal]=1
    (0..n)
        .map(|_| cfg.gen_mean_secs * rng.lognormal(mu, cfg.gen_sigma))
        .collect()
}

/// Time for one generator group to finish `batch` sequences with
/// `concurrency` slots (greedy multi-slot packing). With a partial-rollout
/// cap, work beyond `cap` carries into the NEXT batch (head-start credit),
/// so the batch completes at the cap while the tail overlaps.
fn batch_generation_time(
    rng: &mut Rng,
    cfg: &DesConfig,
    carry: &mut Vec<f64>,
) -> f64 {
    let mut times = gen_times(rng, cfg, cfg.batch);
    // resume carried partial sequences first (they replace fresh draws)
    for (t, c) in times.iter_mut().zip(carry.iter()) {
        *t = *c;
    }
    carry.clear();
    let cap = cfg.partial_rollout_cap * cfg.gen_mean_secs;
    let mut slots = vec![0.0f64; cfg.concurrency.max(1)];
    for &t in &times {
        // assign to the earliest-free slot
        let (idx, _) = slots
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        if t > cap {
            // generate up to the cap now, carry the remainder
            slots[idx] += cap;
            carry.push(t - cap);
        } else {
            slots[idx] += t;
        }
    }
    slots.iter().cloned().fold(0.0, f64::max)
}

/// Synchronous architecture (Fig. 2a): each step is gen -> score -> train on
/// the same clock; generator idles during training and vice versa.
pub fn simulate_sync(cfg: &DesConfig) -> DesReport {
    let mut rng = Rng::new(cfg.seed);
    let mut t = 0.0f64;
    let mut gen_busy = 0.0;
    let mut train_busy = 0.0;
    let mut step_ends = Vec::with_capacity(cfg.steps);
    let mut carry = Vec::new();
    for _ in 0..cfg.steps {
        let g = batch_generation_time(&mut rng, cfg, &mut carry);
        t += g;
        gen_busy += g;
        t += cfg.score_secs;
        t += cfg.train_secs;
        train_busy += cfg.train_secs;
        step_ends.push(t);
    }
    DesReport {
        total_secs: t,
        step_secs_mean: t / cfg.steps as f64,
        gen_idle_frac: 1.0 - gen_busy / t,
        train_idle_frac: 1.0 - train_busy / t,
        mean_lag_steps: 0.0,
        step_ends,
    }
}

/// Asynchronous architecture (Fig. 2b): generator and trainer free-run;
/// a bounded queue of generated batches provides backpressure. Weight
/// versions advance with trainer steps; each batch records the version gap
/// between its generation and its consumption (off-policy lag).
pub fn simulate_async(cfg: &DesConfig) -> DesReport {
    let mut rng = Rng::new(cfg.seed);
    let mut gen_clock = 0.0f64;
    let mut train_clock = 0.0f64;
    let mut gen_busy = 0.0f64;
    let mut train_busy = 0.0f64;
    // queue entries: (ready_time, trainer_step_when_generated)
    let mut queue: std::collections::VecDeque<(f64, usize)> = Default::default();
    let mut lags = Vec::with_capacity(cfg.steps);
    let mut step_ends = Vec::with_capacity(cfg.steps);
    let mut done_steps = 0usize;
    let mut carry = Vec::new();

    while done_steps < cfg.steps {
        // generator produces whenever the queue has room
        while queue.len() < cfg.queue_capacity && gen_clock <= train_clock + 1e-9 {
            let g = batch_generation_time(&mut rng, cfg, &mut carry);
            gen_clock += g;
            gen_busy += g;
            queue.push_back((gen_clock, done_steps));
        }
        // trainer consumes the next ready batch
        match queue.pop_front() {
            Some((ready, gen_at_step)) => {
                let start = train_clock.max(ready) + cfg.score_secs;
                train_clock = start + cfg.train_secs;
                train_busy += cfg.train_secs;
                lags.push((done_steps - gen_at_step) as f64);
                done_steps += 1;
                step_ends.push(train_clock);
            }
            None => {
                // queue empty: generator must get ahead of the train clock
                let g = batch_generation_time(&mut rng, cfg, &mut carry);
                gen_clock = gen_clock.max(train_clock) + g;
                gen_busy += g;
                queue.push_back((gen_clock, done_steps));
            }
        }
    }
    let total = train_clock.max(gen_clock);
    DesReport {
        total_secs: total,
        step_secs_mean: total / cfg.steps as f64,
        gen_idle_frac: 1.0 - gen_busy / total,
        train_idle_frac: 1.0 - train_busy / total,
        mean_lag_steps: lags.iter().sum::<f64>() / lags.len().max(1) as f64,
        step_ends,
    }
}

/// Convenience: run both architectures on the same config.
pub fn simulate_timeline(cfg: &DesConfig) -> (DesReport, DesReport) {
    (simulate_sync(cfg), simulate_async(cfg))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn async_faster_than_sync() {
        let cfg = DesConfig::default();
        let (s, a) = simulate_timeline(&cfg);
        assert!(
            a.total_secs < s.total_secs,
            "async {} !< sync {}",
            a.total_secs,
            s.total_secs
        );
    }

    #[test]
    fn sync_trainer_idles_during_generation() {
        let cfg = DesConfig::default();
        let s = simulate_sync(&cfg);
        assert!(s.train_idle_frac > 0.5, "train_idle={}", s.train_idle_frac);
    }

    #[test]
    fn async_lag_bounded_by_queue() {
        let cfg = DesConfig {
            queue_capacity: 3,
            ..DesConfig::default()
        };
        let a = simulate_async(&cfg);
        assert!(a.mean_lag_steps <= 3.0 + 1e-9);
        assert!(a.mean_lag_steps >= 0.0);
    }

    #[test]
    fn partial_rollouts_reduce_straggler_cost() {
        let heavy = DesConfig {
            gen_sigma: 1.0,
            steps: 100,
            ..DesConfig::default()
        };
        let without = simulate_sync(&heavy);
        let with = simulate_sync(&DesConfig {
            partial_rollout_cap: 2.0,
            ..heavy
        });
        assert!(
            with.total_secs < without.total_secs,
            "partial rollouts should shorten the straggler tail: {} vs {}",
            with.total_secs,
            without.total_secs
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = DesConfig::default();
        let a1 = simulate_async(&cfg);
        let a2 = simulate_async(&cfg);
        assert_eq!(a1.total_secs, a2.total_secs);
    }
}
