//! Discrete-event simulation of the two execution architectures
//! (paper Figure 2): per-GPU-group timelines, idle-bubble accounting,
//! straggler effects from generation-length variance, and the
//! partial-rollout mitigation (paper §4.2).
//!
//! The DES models one "processing group" per executor. Generation length is
//! lognormal (heavy right tail = stragglers). In the synchronous
//! architecture the trainer waits for the LAST sequence of every batch
//! (Fig. 2a bubbles); asynchronously, groups free-run with a bounded queue
//! (Fig. 2b) and partial rollouts cap per-iteration generation so stragglers
//! span iterations instead of blocking peers.

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct DesConfig {
    /// RL steps to simulate
    pub steps: usize,
    /// sequences per training batch
    pub batch: usize,
    /// decode slots per generator group (concurrency)
    pub concurrency: usize,
    /// mean generation time per sequence, seconds
    pub gen_mean_secs: f64,
    /// lognormal sigma of generation time (straggler heaviness)
    pub gen_sigma: f64,
    /// trainer time per batch, seconds
    pub train_secs: f64,
    /// reward scoring time per batch, seconds
    pub score_secs: f64,
    /// async queue capacity, in batches
    pub queue_capacity: usize,
    /// cap generation work per iteration at this multiple of the mean
    /// (partial rollouts); f64::INFINITY disables
    pub partial_rollout_cap: f64,
    /// weight-sync stall per refresh, seconds (e.g. a planner schedule
    /// costed by `ddma::topology::DdmaModel::plan_secs`); 0 disables
    pub weight_sync_secs: f64,
    /// generation-overlapped sync: shards stream into the double-buffered
    /// slot while decode runs, so the generator pays only the O(1) fenced
    /// swap instead of `weight_sync_secs` (valid when sync time is well
    /// under a batch's decode time, as in paper Table 4). Sync mode cannot
    /// overlap — the next batch needs the new weights before it starts.
    pub sync_overlap: bool,
    /// trainer-side stall per publish (encode + fan-out on the trainer
    /// thread when the weight-sync plane runs inline); 0 disables
    pub publish_block_secs: f64,
    /// background streaming executor: publish is enqueue-and-return, so the
    /// trainer never pays `publish_block_secs` (the stream rides the
    /// link-group workers instead). The sync architecture cannot benefit —
    /// its next generation batch needs the new weights before it starts.
    pub background_publish: bool,
    /// colocated offloading (sync/colocated architecture only): D2H
    /// seconds to swap trainer state to host when generation begins — cost
    /// a [`crate::memplane::plan::ColocationPlan::des_offload_costs`]
    /// derivation on the calibrated PCIe link; 0 disables
    pub offload_d2h_secs: f64,
    /// H2D seconds to prefetch the state back before training resumes
    pub offload_h2d_secs: f64,
    /// background offload executor: both transfers overlap the generation
    /// window they bracket, so the step pays only the part generation is
    /// too short to hide (the memplane's hint-prefetch protocol). Without
    /// it every phase flip serializes the full transfer.
    pub offload_overlap: bool,
    pub seed: u64,
}

impl Default for DesConfig {
    fn default() -> Self {
        DesConfig {
            steps: 50,
            batch: 64,
            concurrency: 16,
            gen_mean_secs: 8.0,
            gen_sigma: 0.6,
            // a balanced regime (generation ~32 s per 64-batch at 16 slots,
            // training 24 s): both bubbles visible, as in paper Fig. 2
            train_secs: 24.0,
            score_secs: 0.2,
            queue_capacity: 2,
            partial_rollout_cap: f64::INFINITY,
            weight_sync_secs: 0.0,
            sync_overlap: false,
            publish_block_secs: 0.0,
            background_publish: false,
            offload_d2h_secs: 0.0,
            offload_h2d_secs: 0.0,
            offload_overlap: false,
            seed: 0,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct DesReport {
    pub total_secs: f64,
    pub step_secs_mean: f64,
    /// fraction of the run the generator group sat idle
    pub gen_idle_frac: f64,
    /// fraction of the run the trainer group sat idle
    pub train_idle_frac: f64,
    /// mean number of trainer steps of lag for consumed batches (async)
    pub mean_lag_steps: f64,
    /// max consumed lag, in trainer steps
    pub max_lag_steps: f64,
    /// batches discarded by the buffered data plane (eviction + staleness)
    pub dropped_batches: usize,
    /// per-step completion times
    pub step_ends: Vec<f64>,
    /// total predicted seconds per timeline segment, under the canonical
    /// names shared with the trace plane's span vocabulary (`generate`,
    /// `score`, `train`, `weight_sync`, `publish_block`, `offload`).
    /// `llamarl analyze --des` pairs these against the measured span
    /// totals of a traced run — the first plank of the ROADMAP's
    /// measured-vs-DES bridge. A segment the config disables reports 0.
    pub segments: Vec<(&'static str, f64)>,
}

/// Data-plane knobs for [`simulate_async_buffered`]: the DES analogue of
/// [`crate::dataplane::StoreConfig`] at batch granularity.
#[derive(Debug, Clone)]
pub struct BufferedDesConfig {
    /// store capacity, in batches; overflow evicts the oldest (generation
    /// never blocks)
    pub store_capacity: usize,
    /// consume nothing older than this many trainer steps (u64::MAX
    /// disables); aged batches are dropped, not trained on
    pub max_staleness: u64,
    /// sample the freshest batch instead of FIFO
    pub freshest_first: bool,
}

impl Default for BufferedDesConfig {
    fn default() -> Self {
        BufferedDesConfig {
            store_capacity: 4,
            max_staleness: u64::MAX,
            freshest_first: false,
        }
    }
}

/// Draw per-sequence generation times; lognormal, mean-normalized.
fn gen_times(rng: &mut Rng, cfg: &DesConfig, n: usize) -> Vec<f64> {
    let mu = -0.5 * cfg.gen_sigma * cfg.gen_sigma; // E[lognormal]=1
    (0..n)
        .map(|_| cfg.gen_mean_secs * rng.lognormal(mu, cfg.gen_sigma))
        .collect()
}

/// Time for one generator group to finish `batch` sequences with
/// `concurrency` slots (greedy multi-slot packing). With a partial-rollout
/// cap, work beyond `cap` carries into the NEXT batch (head-start credit),
/// so the batch completes at the cap while the tail overlaps.
fn batch_generation_time(
    rng: &mut Rng,
    cfg: &DesConfig,
    carry: &mut Vec<f64>,
) -> f64 {
    let mut times = gen_times(rng, cfg, cfg.batch);
    // resume carried partial sequences first (they replace fresh draws)
    for (t, c) in times.iter_mut().zip(carry.iter()) {
        *t = *c;
    }
    carry.clear();
    let cap = cfg.partial_rollout_cap * cfg.gen_mean_secs;
    let mut slots = vec![0.0f64; cfg.concurrency.max(1)];
    for &t in &times {
        // assign to the earliest-free slot
        let (idx, _) = slots
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        if t > cap {
            // generate up to the cap now, carry the remainder
            slots[idx] += cap;
            carry.push(t - cap);
        } else {
            slots[idx] += t;
        }
    }
    slots.iter().cloned().fold(0.0, f64::max)
}

/// Generator-side stall per weight refresh in the free-running
/// architectures: overlapped sync hides the stream behind decode and pays
/// only the fenced swap (modelled as 0 here — it is one pointer exchange).
fn gen_sync_stall(cfg: &DesConfig) -> f64 {
    if cfg.sync_overlap {
        0.0
    } else {
        cfg.weight_sync_secs
    }
}

/// Trainer-side stall per publish: the background streaming executor turns
/// the fan-out into enqueue-and-return, otherwise the trainer's clock pays
/// the inline encode + stream.
fn trainer_publish_stall(cfg: &DesConfig) -> f64 {
    if cfg.background_publish {
        0.0
    } else {
        cfg.publish_block_secs
    }
}

/// Colocated-offload stall per step in the sequential architecture: the
/// D2H swap-out brackets the head of the generation window and the H2D
/// prefetch its tail. Overlapped (background executor + hint prefetch),
/// the step pays only what generation is too short to hide; eager, every
/// flip serializes its full transfer.
fn colocated_offload_stall(cfg: &DesConfig, gen_secs: f64) -> f64 {
    let total = cfg.offload_d2h_secs + cfg.offload_h2d_secs;
    if cfg.offload_overlap {
        (total - gen_secs).max(0.0)
    } else {
        total
    }
}

/// Synchronous architecture (Fig. 2a): each step is gen -> score -> train on
/// the same clock; generator idles during training and vice versa. The
/// weight reload (`weight_sync_secs`) cannot overlap anything — the next
/// batch needs the new weights before it starts. Colocated offloading adds
/// its flip transfers around the generation window (timeline segments:
/// offload at its head, prefetch at its tail), hidden behind decode when
/// `offload_overlap` is set.
pub fn simulate_sync(cfg: &DesConfig) -> DesReport {
    let mut rng = Rng::new(cfg.seed);
    let mut t = 0.0f64;
    let mut gen_busy = 0.0;
    let mut train_busy = 0.0;
    let mut step_ends = Vec::with_capacity(cfg.steps);
    let mut carry = Vec::new();
    let mut offload_total = 0.0;
    for _ in 0..cfg.steps {
        let g = batch_generation_time(&mut rng, cfg, &mut carry);
        let o = colocated_offload_stall(cfg, g);
        t += g + o;
        gen_busy += g;
        offload_total += o;
        t += cfg.score_secs;
        t += cfg.train_secs;
        train_busy += cfg.train_secs;
        // weight reload AND the inline publish fan-out both serialize here;
        // backgrounding cannot help — the next batch needs the new weights
        t += cfg.weight_sync_secs + cfg.publish_block_secs;
        step_ends.push(t);
    }
    let n = cfg.steps as f64;
    DesReport {
        total_secs: t,
        step_secs_mean: t / cfg.steps as f64,
        gen_idle_frac: 1.0 - gen_busy / t,
        train_idle_frac: 1.0 - train_busy / t,
        mean_lag_steps: 0.0,
        max_lag_steps: 0.0,
        dropped_batches: 0,
        step_ends,
        segments: vec![
            ("generate", gen_busy),
            ("score", cfg.score_secs * n),
            ("train", train_busy),
            ("weight_sync", cfg.weight_sync_secs * n),
            ("publish_block", cfg.publish_block_secs * n),
            ("offload", offload_total),
        ],
    }
}

/// Asynchronous architecture (Fig. 2b): generator and trainer free-run;
/// a bounded queue of generated batches provides backpressure. Weight
/// versions advance with trainer steps; each batch records the version gap
/// between its generation and its consumption (off-policy lag).
pub fn simulate_async(cfg: &DesConfig) -> DesReport {
    let mut rng = Rng::new(cfg.seed);
    let mut gen_clock = 0.0f64;
    let mut train_clock = 0.0f64;
    let mut gen_busy = 0.0f64;
    let mut train_busy = 0.0f64;
    // queue entries: (ready_time, trainer_step_when_generated)
    let mut queue: std::collections::VecDeque<(f64, usize)> = Default::default();
    let mut lags = Vec::with_capacity(cfg.steps);
    let mut step_ends = Vec::with_capacity(cfg.steps);
    let mut done_steps = 0usize;
    let mut carry = Vec::new();
    let mut batches_generated = 0usize;

    let stall = gen_sync_stall(cfg);
    while done_steps < cfg.steps {
        // generator produces whenever the queue has room; each batch starts
        // with a weight refresh (stall unless sync is overlapped). The
        // stall advances the clock but is NOT busy time — it is exactly the
        // idle bubble overlapped sync removes (and sync mode accounts the
        // same reload as idle).
        while queue.len() < cfg.queue_capacity && gen_clock <= train_clock + 1e-9 {
            let g = batch_generation_time(&mut rng, cfg, &mut carry);
            gen_clock += g + stall;
            gen_busy += g;
            batches_generated += 1;
            queue.push_back((gen_clock, done_steps));
        }
        // trainer consumes the next ready batch; each optimizer step ends
        // with a publish (enqueue-only when backgrounded)
        match queue.pop_front() {
            Some((ready, gen_at_step)) => {
                let start = train_clock.max(ready) + cfg.score_secs;
                train_clock = start + cfg.train_secs + trainer_publish_stall(cfg);
                train_busy += cfg.train_secs;
                lags.push((done_steps - gen_at_step) as f64);
                done_steps += 1;
                step_ends.push(train_clock);
            }
            None => {
                // queue empty: generator must get ahead of the train clock
                let g = batch_generation_time(&mut rng, cfg, &mut carry);
                gen_clock = gen_clock.max(train_clock) + g + stall;
                gen_busy += g;
                batches_generated += 1;
                queue.push_back((gen_clock, done_steps));
            }
        }
    }
    let total = train_clock.max(gen_clock);
    let n = cfg.steps as f64;
    DesReport {
        total_secs: total,
        step_secs_mean: total / cfg.steps as f64,
        gen_idle_frac: 1.0 - gen_busy / total,
        train_idle_frac: 1.0 - train_busy / total,
        mean_lag_steps: lags.iter().sum::<f64>() / lags.len().max(1) as f64,
        max_lag_steps: lags.iter().cloned().fold(0.0, f64::max),
        dropped_batches: 0,
        step_ends,
        segments: vec![
            ("generate", gen_busy),
            ("score", cfg.score_secs * n),
            ("train", train_busy),
            ("weight_sync", stall * batches_generated as f64),
            ("publish_block", trainer_publish_stall(cfg) * n),
            ("offload", 0.0),
        ],
    }
}

/// Buffered-pipeline architecture (the streaming data plane): the
/// generator free-runs into a capacity-bounded store with evict-oldest
/// admission — it NEVER blocks on the trainer — while the trainer samples
/// per strategy and refuses batches older than `max_staleness` trainer
/// steps. Compared to [`simulate_async`], staleness is an enforced bound
/// (stale batches are dropped, costing generation throughput) instead of a
/// side effect of queue depth (which bounds lag only by throttling the
/// generator).
pub fn simulate_async_buffered(cfg: &DesConfig, dp: &BufferedDesConfig) -> DesReport {
    let mut rng = Rng::new(cfg.seed);
    let mut gen_clock = 0.0f64;
    let mut train_clock = 0.0f64;
    let mut gen_busy = 0.0f64;
    let mut train_busy = 0.0f64;
    // store entries: (ready_time, trainer_step_when_generated)
    let mut store: std::collections::VecDeque<(f64, usize)> = Default::default();
    let mut lags = Vec::with_capacity(cfg.steps);
    let mut step_ends = Vec::with_capacity(cfg.steps);
    let mut done_steps = 0usize;
    let mut dropped = 0usize;
    let mut carry = Vec::new();
    let mut batches_generated = 0usize;
    let cap = dp.store_capacity.max(1);
    let stall = gen_sync_stall(cfg);

    while done_steps < cfg.steps {
        // Generator free-runs: produce while it is behind the train clock,
        // and always at least until one batch is in the store. Overflow
        // evicts the oldest resident batch (capacity pressure) — the
        // generator itself never waits.
        while store.is_empty() || gen_clock <= train_clock + 1e-9 {
            let g = batch_generation_time(&mut rng, cfg, &mut carry);
            gen_clock += g + stall;
            gen_busy += g;
            batches_generated += 1;
            store.push_back((gen_clock, done_steps));
            if store.len() > cap {
                store.pop_front();
                dropped += 1;
            }
        }
        // Staleness purge: consuming a batch older than the bound is
        // forbidden, so it is dropped on the floor instead.
        let before = store.len();
        store.retain(|(_, gs)| done_steps - gs <= dp.max_staleness as usize);
        dropped += before - store.len();
        if store.is_empty() {
            continue; // everything aged out; generate afresh
        }
        // Sample per strategy.
        let (ready, gen_at_step) = if dp.freshest_first {
            store.pop_back().unwrap()
        } else {
            store.pop_front().unwrap()
        };
        let start = train_clock.max(ready) + cfg.score_secs;
        train_clock = start + cfg.train_secs + trainer_publish_stall(cfg);
        train_busy += cfg.train_secs;
        lags.push((done_steps - gen_at_step) as f64);
        done_steps += 1;
        step_ends.push(train_clock);
    }
    // wall clock ends when the trainer finishes; generation beyond that
    // point is speculative work for a run that already ended
    let total = train_clock;
    let n = cfg.steps as f64;
    DesReport {
        total_secs: total,
        step_secs_mean: total / cfg.steps as f64,
        gen_idle_frac: (1.0 - gen_busy / total).max(0.0),
        train_idle_frac: 1.0 - train_busy / total,
        mean_lag_steps: lags.iter().sum::<f64>() / lags.len().max(1) as f64,
        max_lag_steps: lags.iter().cloned().fold(0.0, f64::max),
        dropped_batches: dropped,
        step_ends,
        segments: vec![
            ("generate", gen_busy),
            ("score", cfg.score_secs * n),
            ("train", train_busy),
            ("weight_sync", stall * batches_generated as f64),
            ("publish_block", trainer_publish_stall(cfg) * n),
            ("offload", 0.0),
        ],
    }
}

/// Periodic asynchrony (PAPERS.md: arXiv 2511.18871): generators free-run
/// for `period_steps` batches against frozen weights while the trainer
/// fleet trains the PREVIOUS period's batches; the two sides re-join at
/// the period fence, where exactly ONE coalesced publish lands. This is a
/// two-stage pipeline at period granularity — each period costs
/// `max(generate, train)` instead of their sum (sync) — but unlike
/// free-running async the fence bounds off-policy lag at one period, and
/// the barrier realizes `E[max(G, T)] >= max(E[G], E[T])` every period,
/// so the wall clock lands between the two architectures.
pub fn simulate_periodic(cfg: &DesConfig, period_steps: usize) -> DesReport {
    let p = period_steps.max(1);
    let mut rng = Rng::new(cfg.seed);
    let mut carry = Vec::new();
    let stall = gen_sync_stall(cfg);
    let publish_once = trainer_publish_stall(cfg);
    let mut t = 0.0f64;
    let mut gen_busy = 0.0f64;
    let mut train_busy = 0.0f64;
    let mut sync_paid = 0.0f64;
    let mut publish_paid = 0.0f64;
    let mut step_ends = Vec::with_capacity(cfg.steps);
    let mut lags = Vec::with_capacity(cfg.steps);
    // pipeline fill: the first period's data must exist before any
    // training starts (the one-period offset every later period hides)
    let mut pending = p.min(cfg.steps);
    let mut fill = stall;
    for _ in 0..pending {
        fill += batch_generation_time(&mut rng, cfg, &mut carry);
    }
    gen_busy += fill - stall;
    sync_paid += stall;
    t += fill;
    let mut done = 0usize;
    while done < cfg.steps {
        // trainer side: consume the period banked by the generators, then
        // pay the boundary's single coalesced publish
        let k = pending;
        let mut train_t = 0.0f64;
        for _ in 0..k {
            train_t += cfg.score_secs + cfg.train_secs;
            train_busy += cfg.train_secs;
            step_ends.push(t + train_t);
            // one-period pipeline offset: this batch was generated while
            // the previous period's k steps trained
            lags.push(k as f64);
        }
        train_t += publish_once;
        publish_paid += publish_once;
        done += k;
        // generator side, concurrent: bank the NEXT period's batches with
        // one weight refresh at the boundary it launched from
        let next = p.min(cfg.steps - done);
        let mut gen_t = 0.0f64;
        if next > 0 {
            gen_t += stall;
            sync_paid += stall;
            for _ in 0..next {
                let g = batch_generation_time(&mut rng, cfg, &mut carry);
                gen_t += g;
                gen_busy += g;
            }
        }
        pending = next;
        // the period fence: both sides re-join before the next period
        t += train_t.max(gen_t);
    }
    let n = cfg.steps as f64;
    DesReport {
        total_secs: t,
        step_secs_mean: t / n,
        gen_idle_frac: (1.0 - gen_busy / t).max(0.0),
        train_idle_frac: 1.0 - train_busy / t,
        mean_lag_steps: lags.iter().sum::<f64>() / lags.len().max(1) as f64,
        max_lag_steps: lags.iter().cloned().fold(0.0, f64::max),
        dropped_batches: 0,
        step_ends,
        segments: vec![
            ("generate", gen_busy),
            ("score", cfg.score_secs * n),
            ("train", train_busy),
            ("weight_sync", sync_paid),
            ("publish_block", publish_paid),
            ("offload", 0.0),
        ],
    }
}

/// Convenience: run both architectures on the same config.
pub fn simulate_timeline(cfg: &DesConfig) -> (DesReport, DesReport) {
    (simulate_sync(cfg), simulate_async(cfg))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn async_faster_than_sync() {
        let cfg = DesConfig::default();
        let (s, a) = simulate_timeline(&cfg);
        assert!(
            a.total_secs < s.total_secs,
            "async {} !< sync {}",
            a.total_secs,
            s.total_secs
        );
    }

    #[test]
    fn sync_trainer_idles_during_generation() {
        let cfg = DesConfig::default();
        let s = simulate_sync(&cfg);
        assert!(s.train_idle_frac > 0.5, "train_idle={}", s.train_idle_frac);
    }

    #[test]
    fn async_lag_bounded_by_queue() {
        let cfg = DesConfig {
            queue_capacity: 3,
            ..DesConfig::default()
        };
        let a = simulate_async(&cfg);
        assert!(a.mean_lag_steps <= 3.0 + 1e-9);
        assert!(a.mean_lag_steps >= 0.0);
    }

    #[test]
    fn partial_rollouts_reduce_straggler_cost() {
        let heavy = DesConfig {
            gen_sigma: 1.0,
            steps: 100,
            ..DesConfig::default()
        };
        let without = simulate_sync(&heavy);
        let with = simulate_sync(&DesConfig {
            partial_rollout_cap: 2.0,
            ..heavy
        });
        assert!(
            with.total_secs < without.total_secs,
            "partial rollouts should shorten the straggler tail: {} vs {}",
            with.total_secs,
            without.total_secs
        );
    }

    #[test]
    fn overlapped_sync_removes_generator_stall() {
        let base = DesConfig {
            weight_sync_secs: 4.0,
            ..DesConfig::default()
        };
        let blocking = simulate_async(&base);
        let overlapped = simulate_async(&DesConfig {
            sync_overlap: true,
            ..base.clone()
        });
        assert!(
            overlapped.total_secs < blocking.total_secs,
            "overlap {} !< blocking {}",
            overlapped.total_secs,
            blocking.total_secs
        );
        // zero sync cost == overlapped sync: the stall is the whole gap
        let free = simulate_async(&DesConfig {
            weight_sync_secs: 0.0,
            ..base
        });
        assert_eq!(overlapped.total_secs, free.total_secs);
    }

    #[test]
    fn sync_mode_always_pays_weight_reload() {
        let cfg = DesConfig {
            weight_sync_secs: 4.0,
            sync_overlap: true, // ignored by the sync architecture
            ..DesConfig::default()
        };
        let with = simulate_sync(&cfg);
        let without = simulate_sync(&DesConfig {
            weight_sync_secs: 0.0,
            ..cfg.clone()
        });
        let gap = with.total_secs - without.total_secs;
        assert!(
            (gap - 4.0 * cfg.steps as f64).abs() < 1e-6,
            "reload cost should be steps * sync_secs, got {gap}"
        );
    }

    #[test]
    fn background_publish_removes_trainer_stall() {
        let base = DesConfig {
            publish_block_secs: 3.0,
            ..DesConfig::default()
        };
        let inline = simulate_async(&base);
        let background = simulate_async(&DesConfig {
            background_publish: true,
            ..base.clone()
        });
        assert!(
            background.total_secs < inline.total_secs,
            "background {} !< inline {}",
            background.total_secs,
            inline.total_secs
        );
        // enqueue-and-return == never paying the block at all
        let free = simulate_async(&DesConfig {
            publish_block_secs: 0.0,
            ..base.clone()
        });
        assert_eq!(background.total_secs, free.total_secs);
        // the buffered plane benefits identically
        let dp = BufferedDesConfig::default();
        let b_inline = simulate_async_buffered(&base, &dp);
        let b_bg = simulate_async_buffered(
            &DesConfig {
                background_publish: true,
                ..base.clone()
            },
            &dp,
        );
        assert!(b_bg.total_secs < b_inline.total_secs);
    }

    #[test]
    fn sync_architecture_cannot_background_publish() {
        let cfg = DesConfig {
            publish_block_secs: 2.0,
            background_publish: true, // ignored: next batch needs weights
            ..DesConfig::default()
        };
        let with = simulate_sync(&cfg);
        let without = simulate_sync(&DesConfig {
            publish_block_secs: 0.0,
            ..cfg.clone()
        });
        let gap = with.total_secs - without.total_secs;
        assert!(
            (gap - 2.0 * cfg.steps as f64).abs() < 1e-6,
            "publish block should cost steps * block_secs in sync, got {gap}"
        );
    }

    #[test]
    fn overlapped_offload_hides_behind_generation() {
        let base = DesConfig {
            offload_d2h_secs: 3.0,
            offload_h2d_secs: 3.0,
            ..DesConfig::default()
        };
        let eager = simulate_sync(&base);
        let overlapped = simulate_sync(&DesConfig {
            offload_overlap: true,
            ..base.clone()
        });
        let free = simulate_sync(&DesConfig {
            offload_d2h_secs: 0.0,
            offload_h2d_secs: 0.0,
            ..base.clone()
        });
        // eager pays steps * (d2h + h2d) in full
        let gap = eager.total_secs - free.total_secs;
        assert!((gap - 6.0 * base.steps as f64).abs() < 1e-6, "{gap}");
        // generation (~32 s/step) dwarfs the 6 s transfer: fully hidden
        assert_eq!(overlapped.total_secs, free.total_secs);
        // transfers larger than the generation window pay only the excess
        let huge = DesConfig {
            offload_d2h_secs: 200.0,
            offload_h2d_secs: 200.0,
            offload_overlap: true,
            ..base
        };
        let partially = simulate_sync(&huge);
        assert!(partially.total_secs > free.total_secs);
        assert!(
            partially.total_secs
                < simulate_sync(&DesConfig {
                    offload_overlap: false,
                    ..huge
                })
                .total_secs
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = DesConfig::default();
        let a1 = simulate_async(&cfg);
        let a2 = simulate_async(&cfg);
        assert_eq!(a1.total_secs, a2.total_secs);
    }

    #[test]
    fn buffered_lag_never_exceeds_staleness_bound() {
        for bound in [0u64, 1, 3] {
            let cfg = DesConfig {
                steps: 150,
                train_secs: 48.0, // train-bound: the store actually fills
                ..DesConfig::default()
            };
            let dp = BufferedDesConfig {
                store_capacity: 8,
                max_staleness: bound,
                freshest_first: false,
            };
            let r = simulate_async_buffered(&cfg, &dp);
            assert!(
                r.max_lag_steps <= bound as f64 + 1e-9,
                "bound {bound}: max lag {}",
                r.max_lag_steps
            );
        }
    }

    #[test]
    fn buffered_matches_or_beats_lag_matched_channel_async() {
        // Apples-to-apples: both arms hold realized lag <= 1 step. The
        // channel can only do that with queue_capacity 1, which throttles
        // the generator and exposes every straggler; the buffered plane
        // keeps a deep free-running store and drops stale batches instead.
        // Averaged over seeds so one lucky straggler draw cannot flip it.
        let mut channel_total = 0.0;
        let mut buffered_total = 0.0;
        for seed in 0..5u64 {
            let cfg = DesConfig {
                steps: 200,
                gen_sigma: 1.0,
                seed,
                ..DesConfig::default()
            };
            let channel = simulate_async(&DesConfig {
                queue_capacity: 1,
                ..cfg.clone()
            });
            let buffered = simulate_async_buffered(
                &cfg,
                &BufferedDesConfig {
                    store_capacity: 8,
                    max_staleness: 1,
                    freshest_first: false,
                },
            );
            assert!(channel.mean_lag_steps <= 1.0 + 1e-9);
            assert!(buffered.max_lag_steps <= 1.0 + 1e-9);
            channel_total += channel.total_secs;
            buffered_total += buffered.total_secs;
        }
        assert!(
            buffered_total <= channel_total * 1.05,
            "buffered {buffered_total} !<= channel {channel_total}"
        );
    }

    #[test]
    fn buffered_freshest_first_trades_drops_for_lag() {
        let cfg = DesConfig {
            steps: 150,
            train_secs: 48.0, // train-bound: staleness pressure exists
            ..DesConfig::default()
        };
        let fifo = simulate_async_buffered(
            &cfg,
            &BufferedDesConfig {
                store_capacity: 6,
                max_staleness: u64::MAX,
                freshest_first: false,
            },
        );
        let fresh = simulate_async_buffered(
            &cfg,
            &BufferedDesConfig {
                store_capacity: 6,
                max_staleness: u64::MAX,
                freshest_first: true,
            },
        );
        assert!(
            fresh.mean_lag_steps <= fifo.mean_lag_steps + 1e-9,
            "freshest-first lag {} !<= fifo lag {}",
            fresh.mean_lag_steps,
            fifo.mean_lag_steps
        );
    }

    #[test]
    fn buffered_deterministic_given_seed() {
        let cfg = DesConfig::default();
        let dp = BufferedDesConfig::default();
        let a = simulate_async_buffered(&cfg, &dp);
        let b = simulate_async_buffered(&cfg, &dp);
        assert_eq!(a.total_secs, b.total_secs);
        assert_eq!(a.dropped_batches, b.dropped_batches);
    }

    #[test]
    fn periodic_lands_between_sync_and_async() {
        // the ISSUE's bench curve in miniature: the period fence realizes
        // E[max(G, T)] per period (slower than free-running async) but
        // still pipelines the two sides (faster than sync's G + T)
        let cfg = DesConfig {
            steps: 200,
            ..DesConfig::default()
        };
        let s = simulate_sync(&cfg);
        let a = simulate_async(&cfg);
        let p = simulate_periodic(&cfg, 4);
        assert!(
            p.total_secs < s.total_secs,
            "periodic {} !< sync {}",
            p.total_secs,
            s.total_secs
        );
        assert!(
            p.total_secs >= a.total_secs,
            "periodic {} !>= async {}",
            p.total_secs,
            a.total_secs
        );
    }

    #[test]
    fn periodic_lag_bounded_by_period() {
        let cfg = DesConfig::default();
        for period in [1usize, 4, 8] {
            let p = simulate_periodic(&cfg, period);
            assert!(
                p.max_lag_steps <= period as f64 + 1e-9,
                "period {}: max lag {}",
                period,
                p.max_lag_steps
            );
        }
    }

    #[test]
    fn periodic_coalesces_publishes() {
        // one blocking publish per period, not per step: the periodic
        // trainer's publish_block segment shrinks with the period length
        let cfg = DesConfig {
            publish_block_secs: 3.0,
            background_publish: false,
            ..DesConfig::default()
        };
        let per_step = simulate_periodic(&cfg, 1);
        let coalesced = simulate_periodic(&cfg, 5);
        let paid = |r: &DesReport| {
            r.segments
                .iter()
                .find(|(n, _)| *n == "publish_block")
                .map(|(_, s)| *s)
                .unwrap()
        };
        assert!(
            paid(&coalesced) < paid(&per_step) / 2.0,
            "coalesced publish {} !< per-step {} / 2",
            paid(&coalesced),
            paid(&per_step)
        );
    }

    #[test]
    fn periodic_deterministic_given_seed() {
        let cfg = DesConfig::default();
        let a = simulate_periodic(&cfg, 4);
        let b = simulate_periodic(&cfg, 4);
        assert_eq!(a.total_secs, b.total_secs);
        assert_eq!(a.step_ends, b.step_ends);
    }
}
