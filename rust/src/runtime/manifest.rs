//! Artifact manifest: the contract between the AOT compile path
//! (python/compile/aot.py) and the Rust runtime. Parsed from
//! `artifacts/<config>/manifest.json`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::error::{Error, Result};
use crate::util::json::Value;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => Err(Error::Manifest(format!("unknown dtype '{other}'"))),
        }
    }
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(v: &Value) -> Result<TensorSpec> {
        let shape = v
            .req_array("shape")?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| Error::Manifest("bad shape".into())))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec {
            name: v.req_str("name")?.to_string(),
            shape,
            dtype: Dtype::parse(v.req_str("dtype")?)?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactDef {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub output: TensorSpec,
}

/// Model hyper-parameters, mirroring python/compile/configs.py.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub gen_batch: usize,
    pub gen_chunk: usize,
    pub train_batch: usize,
    pub train_seq: usize,
    pub pad_id: i32,
    pub bos_id: i32,
    pub eos_id: i32,
}

impl ModelConfig {
    fn parse(v: &Value) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: v.req_str("name")?.to_string(),
            vocab: v.req_usize("vocab")?,
            d_model: v.req_usize("d_model")?,
            n_layers: v.req_usize("n_layers")?,
            n_heads: v.req_usize("n_heads")?,
            d_head: v.req_usize("d_head")?,
            d_ff: v.req_usize("d_ff")?,
            max_seq: v.req_usize("max_seq")?,
            gen_batch: v.req_usize("gen_batch")?,
            gen_chunk: v.req_usize("gen_chunk")?,
            train_batch: v.req_usize("train_batch")?,
            train_seq: v.req_usize("train_seq")?,
            pad_id: v.req_f64("pad_id")? as i32,
            bos_id: v.req_f64("bos_id")? as i32,
            eos_id: v.req_f64("eos_id")? as i32,
        })
    }

    /// Approximate parameter count formula (embed tied); used by the
    /// simulator to extrapolate W0 for paper-scale models.
    pub fn approx_params(&self) -> usize {
        let d = self.d_model;
        let per_layer = 4 * d * d + 2 * d * self.d_ff + self.d_ff + 5 * d;
        self.vocab * d + self.max_seq * d + self.n_layers * per_layer + 2 * d
    }
}

#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

/// Packed train-state layout: [params | m | v | step | metrics].
#[derive(Debug, Clone)]
pub struct TrainStateLayout {
    pub params: (usize, usize),
    pub adam_m: (usize, usize),
    pub adam_v: (usize, usize),
    pub step: (usize, usize),
    pub metrics: (usize, usize),
    pub total: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ModelConfig,
    pub num_params: usize,
    pub param_layout: Vec<ParamEntry>,
    pub train_state: TrainStateLayout,
    pub metric_names: Vec<String>,
    pub artifacts: BTreeMap<String, ArtifactDef>,
    pub fig5_train_batches: Vec<usize>,
    pub fig5_gen_batches: Vec<usize>,
}

fn parse_span(v: &Value, key: &str) -> Result<(usize, usize)> {
    let arr = v.req_array(key)?;
    if arr.len() != 2 {
        return Err(Error::Manifest(format!("span '{key}' must have 2 items")));
    }
    Ok((
        arr[0].as_usize().ok_or_else(|| Error::Manifest("bad span".into()))?,
        arr[1].as_usize().ok_or_else(|| Error::Manifest("bad span".into()))?,
    ))
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Manifest(format!(
                "cannot read {} (run `make artifacts`?): {e}",
                path.display()
            ))
        })?;
        let v = Value::parse(&text)?;

        let config = ModelConfig::parse(v.req("config")?)?;
        let num_params = v.req_usize("num_params")?;

        let mut param_layout = Vec::new();
        for e in v.req_array("param_layout")? {
            param_layout.push(ParamEntry {
                name: e.req_str("name")?.to_string(),
                shape: e
                    .req_array("shape")?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect(),
                offset: e.req_usize("offset")?,
            });
        }

        let ts = v.req("train_state")?;
        let train_state = TrainStateLayout {
            params: parse_span(ts, "params")?,
            adam_m: parse_span(ts, "adam_m")?,
            adam_v: parse_span(ts, "adam_v")?,
            step: parse_span(ts, "step")?,
            metrics: parse_span(ts, "metrics")?,
            total: ts.req_usize("total")?,
        };

        let metric_names: Vec<String> = v
            .req_array("metric_names")?
            .iter()
            .map(|m| m.as_str().unwrap_or("").to_string())
            .collect();

        let mut artifacts = BTreeMap::new();
        for (name, art) in v
            .req("artifacts")?
            .as_object()
            .ok_or_else(|| Error::Manifest("'artifacts' is not an object".into()))?
        {
            let inputs = art
                .req_array("inputs")?
                .iter()
                .map(TensorSpec::parse)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactDef {
                    name: name.clone(),
                    file: art.req_str("file")?.to_string(),
                    inputs,
                    output: TensorSpec::parse(art.req("output")?)?,
                },
            );
        }

        let fig5 = v.req("fig5")?;
        let to_usizes = |key: &str| -> Result<Vec<usize>> {
            let out: Vec<usize> = fig5
                .req_array(key)?
                .iter()
                .map(|x| x.as_usize().unwrap_or(0))
                .collect();
            Ok(out)
        };

        if train_state.total != 3 * num_params + 1 + metric_names.len() {
            return Err(Error::Manifest("inconsistent train_state layout".into()));
        }

        Ok(Manifest {
            dir,
            config,
            num_params,
            param_layout,
            train_state,
            metric_names,
            artifacts,
            fig5_train_batches: to_usizes("train_batches")?,
            fig5_gen_batches: to_usizes("gen_batches")?,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactDef> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("no artifact '{name}'")))
    }

    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }

    /// Index of a metric in the packed [step | metrics] extract output.
    pub fn metric_index(&self, name: &str) -> Option<usize> {
        self.metric_names.iter().position(|m| m == name)
    }

    /// Path of the initial checkpoint emitted by aot.py.
    pub fn init_params_path(&self) -> PathBuf {
        self.dir.join("init_params.bin")
    }
}
