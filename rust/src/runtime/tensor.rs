//! Host-side tensor helpers: build/unpack `xla::Literal`s with shape/dtype
//! validation against manifest [`TensorSpec`]s.

use crate::runtime::manifest::{Dtype, TensorSpec};
use crate::util::error::{Error, Result};

/// A host tensor paired with its logical shape — the unit that travels
/// through coordinator communication channels.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32(..) => Dtype::F32,
            HostTensor::I32(..) => Dtype::I32,
        }
    }

    pub fn elements(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            HostTensor::F32(data, shape) => lit_f32(data, shape),
            HostTensor::I32(data, shape) => lit_i32(data, shape),
        }
    }

    pub fn check(&self, spec: &TensorSpec) -> Result<()> {
        if self.dtype() != spec.dtype || self.shape() != spec.shape.as_slice() {
            return Err(Error::Shape {
                what: spec.name.clone(),
                expected: spec.shape.clone(),
                got: self.shape().to_vec(),
            });
        }
        Ok(())
    }
}

fn to_i64_shape(shape: &[usize]) -> Vec<i64> {
    shape.iter().map(|d| *d as i64).collect()
}

pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        return Err(Error::Shape {
            what: "lit_f32".into(),
            expected: shape.to_vec(),
            got: vec![data.len()],
        });
    }
    Ok(xla::Literal::vec1(data).reshape(&to_i64_shape(shape))?)
}

pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        return Err(Error::Shape {
            what: "lit_i32".into(),
            expected: shape.to_vec(),
            got: vec![data.len()],
        });
    }
    Ok(xla::Literal::vec1(data).reshape(&to_i64_shape(shape))?)
}

pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

pub fn to_vec_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}
