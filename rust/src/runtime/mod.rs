//! PJRT runtime: load AOT artifacts (`artifacts/<config>/*.hlo.txt`) and
//! execute them from the coordinator hot path.
//!
//! Each executor thread owns one [`Runtime`] (the `xla` crate's
//! `PjRtClient` is `Rc`-based and not `Send`, which conveniently mirrors the
//! paper's model of executors as self-contained SPMD process groups with
//! their own device context). Weights cross executors through host memory —
//! exactly the surface the [`crate::ddma`] channel manages.
//!
//! Interchange is HLO **text**: jax>=0.5 emits protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md). Every artifact returns a
//! single array (tuple outputs crash the shim's `ToLiteralSync`), so
//! multi-value state travels as packed vectors (see python/compile/model.py).

mod client;
mod manifest;
mod tensor;

pub use client::{ExecStats, Runtime};
pub use manifest::{ArtifactDef, Dtype, Manifest, ModelConfig, ParamEntry, TensorSpec};
pub use tensor::{lit_f32, lit_i32, to_vec_f32, to_vec_i32, HostTensor};
