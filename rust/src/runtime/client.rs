//! Per-executor PJRT runtime: compile artifacts lazily, execute them with
//! host tensors or device-resident buffers, and account execution time.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use crate::runtime::manifest::Manifest;
use crate::runtime::tensor::HostTensor;
use crate::util::error::{Error, Result};

/// Cumulative execution statistics per artifact (feeds the perf pass and the
/// Figure-5 measurements).
#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total_secs: f64,
    pub compile_secs: f64,
}

/// One PJRT CPU client + compiled-executable cache, owned by a single
/// executor thread (`PjRtClient` is not `Send`).
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<HashMap<String, ExecStats>>,
}

impl Runtime {
    pub fn load(artifact_dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
            stats: RefCell::new(HashMap::new()),
        })
    }

    /// Compile (and cache) the named artifact. Compilation happens at most
    /// once per runtime; callers may invoke this eagerly at init to keep the
    /// hot path compile-free (paper: executors compile in `init`).
    pub fn prepare(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exes.borrow().get(name) {
            return Ok(exe.clone());
        }
        let path = self.manifest.artifact_path(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        let dt = t0.elapsed().as_secs_f64();
        self.stats
            .borrow_mut()
            .entry(name.to_string())
            .or_default()
            .compile_secs += dt;
        crate::log_debug!("runtime", "compiled {name} in {dt:.2}s");
        self.exes.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    fn record(&self, name: &str, secs: f64) {
        let mut stats = self.stats.borrow_mut();
        let e = stats.entry(name.to_string()).or_default();
        e.calls += 1;
        e.total_secs += secs;
    }

    /// Execute with host tensors, validating shapes/dtypes against the
    /// manifest. Returns the single output as a literal.
    pub fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<xla::Literal> {
        let def = self.manifest.artifact(name)?.clone();
        if inputs.len() != def.inputs.len() {
            return Err(Error::Manifest(format!(
                "artifact '{name}' expects {} inputs, got {}",
                def.inputs.len(),
                inputs.len()
            )));
        }
        for (t, spec) in inputs.iter().zip(&def.inputs) {
            t.check(spec)?;
        }
        let exe = self.prepare(name)?;
        let lits = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let t0 = Instant::now();
        let bufs = exe.execute::<xla::Literal>(&lits)?;
        let out = bufs[0][0].to_literal_sync()?;
        self.record(name, t0.elapsed().as_secs_f64());
        Ok(out)
    }

    /// Upload a host tensor to a device-resident buffer.
    pub fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        match t {
            HostTensor::F32(data, shape) => {
                Ok(self.client.buffer_from_host_buffer(data, shape, None)?)
            }
            HostTensor::I32(data, shape) => {
                Ok(self.client.buffer_from_host_buffer(data, shape, None)?)
            }
        }
    }

    /// Execute with device-resident buffers (zero host copies). Used for the
    /// train-state loop: the packed state output of step t feeds step t+1.
    pub fn execute_buffers(
        &self,
        name: &str,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<xla::PjRtBuffer> {
        let exe = self.prepare(name)?;
        let t0 = Instant::now();
        let mut bufs = exe.execute_b(inputs)?;
        self.record(name, t0.elapsed().as_secs_f64());
        let mut replica = bufs.remove(0);
        Ok(replica.remove(0))
    }

    /// Fetch a device buffer to host as f32 (the only fetch dtype we need).
    pub fn fetch_f32(&self, buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
        let lit = buf.to_literal_sync()?;
        Ok(lit.to_vec::<f32>()?)
    }

    pub fn stats(&self) -> HashMap<String, ExecStats> {
        self.stats.borrow().clone()
    }

    pub fn config(&self) -> &crate::runtime::manifest::ModelConfig {
        &self.manifest.config
    }
}
