//! Run-report rendering and metric aggregation helpers shared by the
//! binary, examples and benches.

use crate::coordinator::RunReport;
use crate::util::bench::Table;
use crate::util::json::Value;
use crate::util::stats::summarize;

/// Print a human-readable report of a finished training run.
pub fn print_report(r: &RunReport) {
    println!("== {} ==", r.summary());
    if r.resumed_from_step > 0 {
        println!(
            "resumed from journal at step {} (totals span the whole run)",
            r.resumed_from_step
        );
    }
    if r.trace_dropped_events > 0 {
        println!(
            "WARNING: {} trace events dropped (recorder rings overflowed) — \
             the event log and journal are incomplete",
            r.trace_dropped_events
        );
    }
    let step_times: Vec<f64> = r.records.iter().map(|x| x.wall_secs).collect();
    if !step_times.is_empty() {
        let s = summarize(&step_times);
        println!(
            "train step: mean {:.3}s p50 {:.3}s p90 {:.3}s p99 {:.3}s",
            s.mean, s.p50, s.p90, s.p99
        );
    }
    let lags: Vec<f64> = r.records.iter().map(|x| x.mean_lag).collect();
    if !lags.is_empty() {
        println!(
            "off-policy lag: mean {:.2} steps, max {} steps",
            lags.iter().sum::<f64>() / lags.len() as f64,
            r.records.iter().map(|x| x.max_lag).max().unwrap_or(0)
        );
    }
    // channel starvation and store sampling waits are distinct quantities
    // (the scored channel does not exist in buffered mode and vice versa)
    println!(
        "backpressure: generators blocked {:.2}s sending, trainer starved \
         {:.2}s on the scored channel, {:.2}s sampling the store",
        r.gen_send_blocked_secs, r.trainer_recv_blocked_secs, r.trainer_sample_wait_secs
    );
    println!(
        "weight sync: trainer blocked {:.3}s publishing ({} coalesced), \
         generators stalled {:.3}s over {} fenced swaps",
        r.ddma_publish_blocked_secs, r.ddma_coalesced_publishes, r.gen_swap_stall_secs, r.gen_swaps
    );
    // only worth a line when the plane actually moved state (accounting-
    // only planes accrue lease-entry nanos but transfer nothing)
    if r.offload_d2h_bytes + r.offload_h2d_bytes > 0 {
        println!(
            "memplane: {:.1} MB offloaded, {:.1} MB prefetched, leases \
             blocked {:.3}s ({} prefetch hits, {} targets superseded)",
            r.offload_d2h_bytes as f64 / 1e6,
            r.offload_h2d_bytes as f64 / 1e6,
            r.offload_wait_secs,
            r.offload_prefetch_hits,
            r.offload_superseded
        );
    }
    // elastic churn: only worth a line when the fleet actually churned
    if r.node_restarts + r.fleet_scale_ups + r.fleet_scale_downs > 0 {
        println!(
            "elastic fleet: {} node restarts ({} partials migrated), \
             {} scale-ups, {} scale-downs",
            r.node_restarts, r.partials_migrated, r.fleet_scale_ups, r.fleet_scale_downs
        );
    }
    if let Some(dp) = &r.dataplane {
        println!("{}", dp.summary());
        let hist: Vec<String> = dp
            .lag_hist
            .iter()
            .enumerate()
            .filter(|(_, n)| **n > 0)
            .map(|(lag, n)| {
                if lag + 1 == dp.lag_hist.len() {
                    format!("{lag}+:{n}")
                } else {
                    format!("{lag}:{n}")
                }
            })
            .collect();
        if !hist.is_empty() {
            println!("sampled-lag histogram (lag:count): {}", hist.join(" "));
        }
        if dp.parked + dp.resumed > 0 {
            println!(
                "partial rollouts: {} parked, {} resumed",
                dp.parked, dp.resumed
            );
        }
    }
    if !r.evals.is_empty() {
        let mut t = Table::new(&["suite", "weights_version", "accuracy", "n"]);
        for e in &r.evals {
            t.row(vec![
                e.suite.clone(),
                e.weights_version.to_string(),
                format!("{:.1}%", e.accuracy * 100.0),
                e.n.to_string(),
            ]);
        }
        t.print();
    }
}

/// Reward curve as (step, reward_mean) pairs.
pub fn reward_curve(r: &RunReport) -> Vec<(u64, f64)> {
    r.records.iter().map(|x| (x.step, x.reward_mean)).collect()
}

/// Serialize a report summary to JSON (for EXPERIMENTS.md extraction).
pub fn report_json(r: &RunReport) -> Value {
    let steps = summarize(&r.records.iter().map(|x| x.wall_secs).collect::<Vec<_>>());
    Value::object(vec![
        ("mode", Value::str(r.mode.clone())),
        ("steps", Value::num(r.steps as f64)),
        ("wall_secs", Value::num(r.wall_secs)),
        ("mean_step_secs", Value::num(r.mean_step_secs())),
        ("step_secs_p50", Value::num(steps.p50)),
        ("step_secs_p90", Value::num(steps.p90)),
        ("step_secs_p99", Value::num(steps.p99)),
        ("tokens_generated", Value::num(r.tokens_generated as f64)),
        ("trajectories", Value::num(r.trajectories as f64)),
        ("chunks", Value::num(r.chunks as f64)),
        ("final_reward", Value::num(r.final_reward())),
        (
            "trace_dropped_events",
            Value::num(r.trace_dropped_events as f64),
        ),
        ("resumed_from_step", Value::num(r.resumed_from_step as f64)),
        ("ddma_publishes", Value::num(r.ddma_publishes as f64)),
        (
            "ddma_mean_publish_secs",
            Value::num(r.ddma_mean_publish_secs),
        ),
        (
            "ddma_mean_shard_max_secs",
            Value::num(r.ddma_mean_shard_max_secs),
        ),
        (
            "ddma_publish_blocked_secs",
            Value::num(r.ddma_publish_blocked_secs),
        ),
        (
            "ddma_coalesced_publishes",
            Value::num(r.ddma_coalesced_publishes as f64),
        ),
        (
            "gen_swap_stall_secs",
            Value::num(r.gen_swap_stall_secs),
        ),
        ("gen_swaps", Value::num(r.gen_swaps as f64)),
        (
            "gen_send_blocked_secs",
            Value::num(r.gen_send_blocked_secs),
        ),
        (
            "trainer_recv_blocked_secs",
            Value::num(r.trainer_recv_blocked_secs),
        ),
        (
            "trainer_sample_wait_secs",
            Value::num(r.trainer_sample_wait_secs),
        ),
        ("reward_groups", Value::num(r.reward_groups as f64)),
        (
            "reward_rows_scored",
            Value::num(r.reward_rows_scored as f64),
        ),
        ("node_restarts", Value::num(r.node_restarts as f64)),
        ("partials_migrated", Value::num(r.partials_migrated as f64)),
        ("fleet_scale_ups", Value::num(r.fleet_scale_ups as f64)),
        ("fleet_scale_downs", Value::num(r.fleet_scale_downs as f64)),
        (
            "offload_d2h_bytes",
            Value::num(r.offload_d2h_bytes as f64),
        ),
        (
            "offload_h2d_bytes",
            Value::num(r.offload_h2d_bytes as f64),
        ),
        ("offload_wait_secs", Value::num(r.offload_wait_secs)),
        (
            "offload_prefetch_hits",
            Value::num(r.offload_prefetch_hits as f64),
        ),
        (
            "offload_superseded",
            Value::num(r.offload_superseded as f64),
        ),
        (
            "dataplane",
            match &r.dataplane {
                None => Value::Null,
                Some(dp) => Value::object(vec![
                    ("occupancy", Value::num(dp.occupancy as f64)),
                    ("peak_occupancy", Value::num(dp.peak_occupancy as f64)),
                    ("watermark", Value::num(dp.watermark as f64)),
                    ("admitted", Value::num(dp.admitted as f64)),
                    ("dropped_stale", Value::num(dp.dropped_stale as f64)),
                    ("dropped_capacity", Value::num(dp.dropped_capacity as f64)),
                    ("evicted", Value::num(dp.evicted as f64)),
                    ("sampled", Value::num(dp.sampled as f64)),
                    ("parked", Value::num(dp.parked as f64)),
                    ("resumed", Value::num(dp.resumed as f64)),
                    ("sample_wait_secs", Value::num(dp.sample_wait_secs)),
                    ("admit_wait_secs", Value::num(dp.admit_wait_secs)),
                    ("mean_sampled_lag", Value::num(dp.mean_sampled_lag)),
                    ("max_sampled_lag", Value::num(dp.max_sampled_lag as f64)),
                    (
                        "lag_hist",
                        Value::Array(
                            dp.lag_hist
                                .iter()
                                .map(|n| Value::num(*n as f64))
                                .collect(),
                        ),
                    ),
                ]),
            },
        ),
        (
            "evals",
            Value::Array(
                r.evals
                    .iter()
                    .map(|e| {
                        Value::object(vec![
                            ("suite", Value::str(e.suite.clone())),
                            ("weights_version", Value::num(e.weights_version as f64)),
                            ("accuracy", Value::num(e.accuracy)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}
