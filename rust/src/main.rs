//! `llamarl` — CLI launcher for the LlamaRL reproduction.
//!
//! Subcommands:
//!   train     run RL training (sync baseline, async LlamaRL pipeline, or
//!             the buffered data-plane pipeline)
//!   simulate  cluster simulator: paper-scale step-time table (Table 3)
//!   ddma      weight-sync comparison (Table 4)
//!   timeline  discrete-event bubble analysis (Figure 2)
//!   dataplane synthetic channel-vs-store data-plane comparison (no
//!             artifacts needed)
//!   info      inspect an artifact bundle
//!   tracecheck  validate a Chrome trace file emitted by `train --trace`,
//!             or (with --log) a raw JSONL event-log/journal stream
//!   analyze   trace-analysis plane: streaming span-latency histograms,
//!             blocked-time attribution, per-step critical path, and
//!             (--des) measured-vs-simulated divergence
//!   resume    continue a killed run from its durable journal
//!   replay    re-drive a recorded run and diff the training trajectories
//!   journal   tail / filter / summarize a run journal
//!
//! Examples:
//!   llamarl train --preset nano --mode async --steps 5
//!   llamarl train --preset nano --mode async_buffered --max-staleness 4
//!   llamarl resume --journal /tmp/llamarl_out
//!   llamarl replay --journal /tmp/llamarl_out/journal.jsonl
//!   llamarl simulate
//!   llamarl dataplane --steps 60
//!   llamarl info --artifacts artifacts/nano

use llamarl::config;
use llamarl::coordinator::run_training;
use llamarl::ddma::ps_baseline::PsModel;
use llamarl::ddma::topology::DdmaModel;
use llamarl::metrics::print_report;
use llamarl::runtime::Manifest;
use llamarl::simulator::{
    simulate_timeline, solve_async, solve_sync, DesConfig, HardwareModel, LLAMA_MODELS,
    PAPER_TABLE3,
};
use llamarl::util::bench::Table;
use llamarl::util::cli::Args;
use llamarl::util::error::Result;

const BOOL_FLAGS: &[&str] = &[
    "quantize-generator",
    "sync-quantized",
    "sync-inline",
    "colocate",
    "offload-eager",
    "dump-graph",
    "no-journal",
    "elastic-resize",
    "stats",
    "des",
    "allow-drops",
    "help",
];

fn main() {
    let args = match Args::from_env(BOOL_FLAGS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    if args.flag("help") {
        print_help();
        return Ok(());
    }
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(args),
        Some("pretrain") => cmd_pretrain(args),
        Some("simulate") => cmd_simulate(),
        Some("ddma") => cmd_ddma(),
        Some("timeline") => cmd_timeline(args),
        Some("dataplane") => cmd_dataplane(args),
        Some("info") => cmd_info(args),
        Some("tracecheck") => cmd_tracecheck(args),
        Some("analyze") => cmd_analyze(args),
        Some("resume") => cmd_resume(args),
        Some("replay") => cmd_replay(args),
        Some("journal") => cmd_journal(args),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "llamarl — LlamaRL reproduction (async distributed RL for LLM post-training)

USAGE: llamarl <subcommand> [flags]

  train     --preset nano|small|e2e  --mode sync|async|async_buffered|periodic
            --steps N [--config file.json] [--workers N] [--rho X] [--lr X]
            [--quantize-generator] [--eval-every K] [--out DIR]
            [--init-checkpoint DIR]
            [--reward-workers N (scatter generation groups across N reward
             executors by group id; groups stay whole)]
            [--trainers N (data-parallel trainer replicas; each owns the
             round-robin slice of the step sequence, samples a disjoint
             store shard-slice, and publishes through its own bus
             publisher; needs the buffered store and store-shards >= N)]
            [--period-steps K (periodic mode: generators free-run for K
             steps against frozen weights, the trainer fleet fences at
             the period boundary and publishes ONE coalesced update)]
            [--dump-graph (print the resolved topology as Graphviz DOT and
             exit without training)]
            buffered data plane: [--store-capacity N] [--store-shards N]
            [--max-staleness K (0=unbounded)]
            [--admission block|drop_newest|evict_oldest]
            [--sampling fifo|freshest|staleness_weighted]
            weight-sync plane: [--sync-trainer-shards N]
            [--sync-generator-shards N] [--sync-quantized]
            [--sync-encoding full|int8|delta|topk|auto (auto measures the
             update density per publish and picks full vs delta)]
            [--sync-topk-frac X]
            [--sync-inline (disable the background streaming executor)]
            [--sync-link-groups N (0 = one worker per generator shard;
             explicit N uses bandwidth-balanced link groups)]
            memory plane: [--colocate (trainer+generator share the rank)]
            [--offload-classes grads,optim] [--offload-chunk-mb N]
            [--prefetch-depth N] [--offload-eager (no background executor)]
            tracing plane: [--trace FILE (Chrome Trace Event Format export,
             load in chrome://tracing or Perfetto; also streams the raw
             event log to OUT/trace_events.jsonl)]
            [--metrics-interval SECS (periodic telemetry snapshots to
             OUT/telemetry_snapshots.jsonl; 0 = off)]
            durable journal: on by default, streams OUT/journal.jsonl
            [--no-journal] [--journal-snapshot-secs SECS (consistent-cut
             snapshot cadence, default 0.25)]
            elastic fleets: [--restart-max N (per-replica restart budget;
             0 = any failure stops the world)] [--restart-backoff-ms MS
             (base of the exponential backoff, default 50)]
            [--chaos-kills N --chaos-seed S (seeded kill schedule spread
             round-robin over the generator fleet; CI chaos arm)]
            [--chaos-reward-kills N (seeded panic schedule over the reward
             fleet; the supervisor re-routes the dead replica's inbound
             channel slot and restarts it in place)]
            [--elastic-resize (queue-depth-driven dynamic generator
             replicas)] [--resize-max-extra N (dynamic replica cap,
             default 2)]
  pretrain  --artifacts DIR --steps N --lr X --out DIR
            supervised warm-up producing the RL init checkpoint
  simulate  reproduce Table 3 from the calibrated cluster cost model
  ddma      reproduce Table 4 (DDMA vs parameter-server weight sync)
  timeline  [--sigma X] discrete-event bubble analysis (Figure 2)
  dataplane [--steps N] [--max-staleness K] synthetic channel-vs-store
            comparison on real threads (no artifacts needed)
  info      --artifacts DIR  inspect an artifact bundle
  tracecheck --file trace.json  validate a Chrome trace export: parses the
            file with the built-in JSON reader, checks per-track B/E span
            balance (a completed export must leave no span open), and
            reports the event count; or --log FILE to validate a raw JSONL
            stream (the journal or the trace event log) with the streaming
            journal reader — --log tolerates the open spans a SIGKILL leaves
  analyze   [--journal DIR-or-FILE | --log FILE] [--out analysis.json]
            [--des] [--allow-drops]  one streaming pass over a traced run's
            event stream: per-span latency histograms (log-bucketed,
            mergeable, p50/p90/p99), per-track blocked-time attribution
            (compute/channel/sync/offload/idle), per-step critical-path
            extraction naming the bounding plane, and with --des the
            measured-vs-simulated segment ratios from re-costing the run's
            recorded config through the DES. Writes analysis.json next to
            the input (or --out), then exits nonzero on B/E imbalance or
            on dropped events (unless --allow-drops)
  resume    --journal DIR-or-FILE  reconstruct store+bus from the journal's
            latest snapshot, replay the suffix, and continue the run to its
            configured step count (a finished journal is a success no-op)
  replay    --journal FILE [--out DIR]  re-drive the recorded config into a
            fresh out dir and diff live step records against the recorded
            trajectory (bit-exact required in sync mode; report-only async)
  journal   --journal DIR-or-FILE [--tail N] [--filter KIND] [--stats]
            tail/filter records and summarize kind counts"
    );
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = config::resolve(args)?;
    if args.flag("dump-graph") {
        // Resolve and print the declarative topology as DOT instead of
        // running it. The manifest only contributes sync-mode channel
        // capacities; without artifacts the nano default (4 rows) applies.
        let graph = match Manifest::load(&cfg.artifact_dir) {
            Ok(m) => llamarl::coordinator::topology(&cfg, &m),
            Err(_) => llamarl::coordinator::topology_with_rows(&cfg, 4),
        };
        graph.check()?;
        print!("{}", graph.to_dot());
        return Ok(());
    }
    llamarl::log_info!(
        "main",
        "training: mode={:?} artifacts={} steps={}",
        cfg.mode,
        cfg.artifact_dir.display(),
        cfg.max_steps
    );
    let report = run_training(&cfg)?;
    print_report(&report);
    Ok(())
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    let cfg = llamarl::coordinator::PretrainConfig {
        artifact_dir: args.str_or("artifacts", "artifacts/nano").into(),
        steps: args.u64_or("steps", 200)?,
        lr: args.f64_or("lr", 1e-3)? as f32,
        grad_clip: args.f64_or("grad-clip", 1.0)? as f32,
        seed: args.u64_or("seed", 7)?,
        log_every: args.u64_or("log-every", 25)?,
    };
    let out = args.str_or("out", "/tmp/llamarl_pretrain");
    let report = llamarl::coordinator::run_pretraining(&cfg, &out)?;
    println!(
        "pretrained {} steps in {:.1}s, final target_logp {:.3}; checkpoint -> {}",
        report.steps, report.wall_secs, report.final_target_logp, out
    );
    Ok(())
}

fn cmd_simulate() -> Result<()> {
    println!("Cluster simulator — paper Table 3 (step seconds)\n");
    let mut t = Table::new(&[
        "model", "GPUs", "paper base", "sim base", "paper best", "sim async", "paper x", "sim x",
    ]);
    for m in LLAMA_MODELS {
        let hw = HardwareModel::paper_scale(m);
        let sync = solve_sync(&hw.problem());
        let hw8 = HardwareModel {
            fp8_generator: true,
            ..hw
        };
        let asn = solve_async(&hw8.problem());
        let paper_base = PAPER_TABLE3
            .iter()
            .find(|r| r.model == m.name && r.system == "baseline")
            .unwrap()
            .step_secs;
        let paper_best = PAPER_TABLE3
            .iter()
            .filter(|r| r.model == m.name && r.system == "llamarl")
            .map(|r| r.step_secs)
            .fold(f64::INFINITY, f64::min);
        t.row(vec![
            m.name.to_string(),
            format!("{}", hw.g0 as u64),
            format!("{paper_base:.1}"),
            format!("{:.1}", sync.step_secs),
            format!("{paper_best:.1}"),
            format!("{:.1}", asn.step_secs),
            format!("{:.2}x", paper_base / paper_best),
            format!("{:.2}x", sync.step_secs / asn.step_secs),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_ddma() -> Result<()> {
    println!("Weight synchronization — paper Table 4 (seconds)\n");
    let ddma = DdmaModel::calibrated();
    let ps = PsModel::calibrated();
    let mut t = Table::new(&["model", "OpenRLHF PS", "model PS", "paper DDMA", "model DDMA"]);
    let rows = [
        ("7B", 7e9, 128.0, Some(4.32), Some(0.04)),
        ("70B", 70e9, 128.0, Some(111.65), Some(1.15)),
        ("405B", 405e9, 512.0, None, Some(2.31)),
    ];
    for (name, params, gpus, ps_paper, ddma_paper) in rows {
        t.row(vec![
            name.to_string(),
            ps_paper.map(|x| format!("{x:.2}")).unwrap_or("-".into()),
            format!("{:.2}", ps.sync_secs(params)),
            ddma_paper.map(|x| format!("{x:.2}")).unwrap_or("-".into()),
            format!("{:.2}", ddma.sync_secs(params, gpus as usize)),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_timeline(args: &Args) -> Result<()> {
    let sigma = args.f64_or("sigma", 0.6)?;
    let cfg = DesConfig {
        gen_sigma: sigma,
        ..DesConfig::default()
    };
    let (s, a) = simulate_timeline(&cfg);
    println!("Discrete-event timelines (Figure 2), gen_sigma={sigma}\n");
    let mut t = Table::new(&["arch", "total s", "s/step", "gen idle", "train idle", "lag"]);
    t.row(vec![
        "sync".into(),
        format!("{:.1}", s.total_secs),
        format!("{:.2}", s.step_secs_mean),
        format!("{:.0}%", s.gen_idle_frac * 100.0),
        format!("{:.0}%", s.train_idle_frac * 100.0),
        "-".into(),
    ]);
    t.row(vec![
        "async".into(),
        format!("{:.1}", a.total_secs),
        format!("{:.2}", a.step_secs_mean),
        format!("{:.0}%", a.gen_idle_frac * 100.0),
        format!("{:.0}%", a.train_idle_frac * 100.0),
        format!("{:.2}", a.mean_lag_steps),
    ]);
    t.print();
    println!("\nasync speedup: {:.2}x", s.total_secs / a.total_secs);
    Ok(())
}

fn cmd_dataplane(args: &Args) -> Result<()> {
    use llamarl::dataplane::{
        run_driver, AdmissionPolicy, DriverConfig, SamplingStrategy, StoreConfig, Transport,
    };
    let steps = args.u64_or("steps", 40)?;
    let bound = args.u64_or("max-staleness", 4)?;
    let base = DriverConfig {
        train_steps: steps,
        seed: args.u64_or("seed", 0)?,
        ..DriverConfig::default()
    };
    println!("Synthetic data-plane comparison ({steps} train steps, staleness bound {bound})\n");
    let mut t = Table::new(&["transport", "rows/s", "mean lag", "max lag", "dropped", "evicted"]);
    let arms: Vec<Transport> = vec![
        Transport::Channel { capacity: 4 },
        Transport::Store(StoreConfig {
            capacity: 64,
            shards: 4,
            max_staleness: if bound == 0 { None } else { Some(bound) },
            admission: AdmissionPolicy::EvictOldest,
            sampling: SamplingStrategy::Fifo,
            seed: 0,
        }),
        Transport::Store(StoreConfig {
            capacity: 64,
            shards: 4,
            max_staleness: if bound == 0 { None } else { Some(bound) },
            admission: AdmissionPolicy::EvictOldest,
            sampling: SamplingStrategy::FreshestFirst,
            seed: 0,
        }),
    ];
    for transport in arms {
        let r = run_driver(&DriverConfig {
            transport,
            ..base.clone()
        });
        let (dropped, evicted) = r
            .dataplane
            .as_ref()
            .map(|d| (d.dropped_stale + d.dropped_capacity, d.evicted))
            .unwrap_or((0, 0));
        t.row(vec![
            r.transport.clone(),
            format!("{:.0}", r.rows_per_sec),
            format!("{:.2}", r.mean_lag),
            r.max_lag.to_string(),
            dropped.to_string(),
            evicted.to_string(),
        ]);
    }
    t.print();
    Ok(())
}

/// Resolve the journal path argument: `--journal` may name the run's out
/// dir (the conventional `journal.jsonl` inside it) or the file itself.
fn journal_path(args: &Args) -> Result<std::path::PathBuf> {
    use llamarl::util::error::Error;
    let raw = args
        .str_opt("journal")
        .map(String::from)
        .or_else(|| args.positional.first().cloned())
        .ok_or_else(|| Error::Cli("expected --journal DIR-or-FILE".into()))?;
    let p = std::path::PathBuf::from(raw);
    Ok(if p.is_dir() { p.join("journal.jsonl") } else { p })
}

fn cmd_resume(args: &Args) -> Result<()> {
    use llamarl::coordinator::PipelineConfig;
    use llamarl::journal::{find_checkpoint_state, plan_resume};
    let path = journal_path(args)?;
    let plan = plan_resume(&path)?;
    if plan.finished {
        // success no-op: lets supervisors (and the CI kill arm) race the
        // kill against run completion without a spurious failure
        println!("{}: run finished cleanly; nothing to resume", path.display());
        return Ok(());
    }
    let mut cfg = PipelineConfig::default();
    config::apply_json(&mut cfg, &plan.config)?;
    let mut state = plan.state;
    if state.start_step >= cfg.max_steps {
        // killed in the gap between the last step record and the finish
        // marker — every step is already durable
        println!(
            "{}: all {} steps already recorded; nothing to resume",
            path.display(),
            cfg.max_steps
        );
        return Ok(());
    }
    match find_checkpoint_state(&cfg.out_dir, state.start_step) {
        Some((ck_step, packed)) => {
            llamarl::log_info!("main", "resume: trainer state from ckpt_step{ck_step}");
            state.init_state = Some(packed);
        }
        None => llamarl::log_warn!(
            "main",
            "resume: no checkpoint at or below step {}; trainer weights \
             restart (trajectory counts still line up)",
            state.start_step
        ),
    }
    llamarl::log_info!(
        "main",
        "resuming {} from step {}/{} (bus v{}, {} stored rows, torn tail: {})",
        path.display(),
        state.start_step,
        cfg.max_steps,
        state.bus_version,
        state.store.as_ref().map(|s| s.rows.len()).unwrap_or(0),
        plan.truncated_tail
    );
    cfg.resume = Some(state);
    let report = run_training(&cfg)?;
    print_report(&report);
    Ok(())
}

fn cmd_replay(args: &Args) -> Result<()> {
    use llamarl::coordinator::{Mode, PipelineConfig};
    use llamarl::journal::{compare_steps, plan_resume};
    use llamarl::util::error::Error;
    let path = journal_path(args)?;
    let plan = plan_resume(&path)?;
    let recorded = plan.state.prior.records;
    if recorded.is_empty() {
        return Err(Error::Cli(format!(
            "{}: journal has no step records to replay",
            path.display()
        )));
    }
    let mut cfg = PipelineConfig::default();
    config::apply_json(&mut cfg, &plan.config)?;
    // re-drive only the recorded prefix (a killed run stops short of
    // max_steps) into a fresh out dir so the recorded journal is untouched
    cfg.max_steps = recorded.last().map(|r| r.step).unwrap_or(cfg.max_steps);
    let out = args.str_or("out", &format!("{}_replay", cfg.out_dir.display()));
    cfg.out_dir = out.into();
    cfg.resume = None;
    let strict = cfg.mode == Mode::Sync;
    llamarl::log_info!(
        "main",
        "replaying {} recorded steps (mode {:?}, {})",
        recorded.len(),
        cfg.mode,
        if strict { "strict" } else { "report-only" }
    );
    let report = run_training(&cfg)?;
    let mismatches = compare_steps(&recorded, &report.records);
    if mismatches.is_empty() {
        println!(
            "replay OK: {} steps match the recorded trajectory bit-for-bit",
            recorded.len()
        );
        return Ok(());
    }
    println!("replay diverged: {} field mismatches", mismatches.len());
    for m in mismatches.iter().take(10) {
        println!(
            "  step {} {}: recorded {} vs live {}",
            m.step, m.field, m.recorded, m.live
        );
    }
    if mismatches.len() > 10 {
        println!("  ... and {} more", mismatches.len() - 10);
    }
    if strict {
        Err(Error::Cli(
            "replay mismatch in sync mode (expected bit-exact)".into(),
        ))
    } else {
        println!("(async replay is timing-dependent; divergence is report-only)");
        Ok(())
    }
}

fn cmd_journal(args: &Args) -> Result<()> {
    use llamarl::journal::JournalReader;
    use std::collections::{BTreeMap, VecDeque};
    let path = journal_path(args)?;
    let tail = args.usize_or("tail", 0)?;
    let filter = args.str_opt("filter").map(String::from);
    let mut reader = JournalReader::open(&path)?;
    let mut counts: BTreeMap<&'static str, u64> = BTreeMap::new();
    let mut kept: VecDeque<String> = VecDeque::new();
    let mut last_seq = 0u64;
    let mut total = 0u64;
    let mut trained_rows = 0u64;
    let mut unknown = 0u64;
    while let Some(item) = reader.next_record() {
        let (seq, rec) = item?;
        total += 1;
        last_seq = last_seq.max(seq);
        *counts.entry(rec.kind()).or_insert(0) += 1;
        match &rec {
            // trained rows is the churn-independent progress measure the
            // chaos CI arm compares across runs (steps x train_batch)
            llamarl::journal::JournalRecord::Step { record } => {
                trained_rows += record.rows as u64;
            }
            // forward tolerance: kinds from newer builds are counted and
            // skipped, never a decode error
            llamarl::journal::JournalRecord::Unknown { .. } => unknown += 1,
            _ => {}
        }
        let wanted = filter.as_deref().map(|f| f == rec.kind()).unwrap_or(true);
        if tail > 0 && wanted {
            kept.push_back(rec.to_value(seq).to_string());
            if kept.len() > tail {
                kept.pop_front();
            }
        }
    }
    for line in &kept {
        println!("{line}");
    }
    if args.flag("stats") || tail == 0 {
        let steps = counts.get("step").copied().unwrap_or(0);
        let finished = counts.contains_key("finish");
        let kinds: Vec<String> = counts.iter().map(|(k, n)| format!("{k}:{n}")).collect();
        println!(
            "{}: {} records (last seq {}), {} steps, {} trained rows, finished: {}, torn tail: {}",
            path.display(),
            total,
            last_seq,
            steps,
            trained_rows,
            finished,
            reader.truncated_tail()
        );
        println!("kinds: {}", kinds.join(" "));
        if unknown > 0 {
            println!("skipped {unknown} records of unknown kind (newer-build journal)");
        }
    }
    Ok(())
}

/// Validate a raw JSONL stream (the journal or the trace event log) with
/// the streaming journal reader: counts records per kind, errors on a
/// corrupt interior line, tolerates the torn final line a SIGKILL leaves.
fn tracecheck_log(path: &str) -> Result<()> {
    use llamarl::journal::JournalReader;
    use llamarl::util::error::Error;
    use std::collections::BTreeMap;
    let mut reader = JournalReader::open(path)?;
    let mut counts: BTreeMap<&'static str, u64> = BTreeMap::new();
    let mut total = 0u64;
    while let Some(item) = reader.next_record() {
        let (_seq, rec) = item?;
        total += 1;
        *counts.entry(rec.kind()).or_insert(0) += 1;
    }
    if total == 0 && !reader.truncated_tail() {
        return Err(Error::Cli(format!("{path}: no records")));
    }
    let kinds: Vec<String> = counts.iter().map(|(k, n)| format!("{k}:{n}")).collect();
    println!(
        "{path}: {total} records ok ({}){}",
        kinds.join(" "),
        if reader.truncated_tail() {
            ", torn final line tolerated"
        } else {
            ""
        }
    );
    Ok(())
}

fn cmd_tracecheck(args: &Args) -> Result<()> {
    use llamarl::analysis::SpanStacks;
    use llamarl::util::error::Error;
    use llamarl::util::json::Value;
    use std::collections::BTreeMap;
    if let Some(log) = args.str_opt("log") {
        // --log tolerates open spans: a SIGKILLed journal legitimately
        // ends mid-span (the CI kill-and-resume arm depends on this)
        return tracecheck_log(log);
    }
    let path = args.str_or("file", "trace.json");
    let text = std::fs::read_to_string(&path)?;
    let v = Value::parse(&text)?;
    let events = v.req_array("traceEvents")?;
    if events.is_empty() {
        return Err(Error::msg(format!("{path}: traceEvents is empty")));
    }
    // tid -> thread name, from the exporter's metadata records (written
    // first, but scanned up front to be order-independent)
    let mut names: BTreeMap<String, String> = BTreeMap::new();
    for e in events {
        if e.req_str("ph")? == "M" {
            if let (Some(tid), Some(name)) = (
                e.get("tid").and_then(Value::as_f64),
                e.get("args").and_then(|a| a.get("name")).and_then(Value::as_str),
            ) {
                names.insert(format!("{tid}"), name.to_string());
            }
        }
    }
    let mut spans = 0usize;
    let mut instants = 0usize;
    let mut tracks = 0usize;
    // a Chrome export describes a COMPLETED run, so per-track B/E balance
    // is a hard invariant (unlike --log): the same checker analyze uses
    let mut stacks = SpanStacks::new();
    for e in events {
        let ph = e.req_str("ph")?;
        let tid = format!("{}", e.get("tid").and_then(Value::as_f64).unwrap_or(0.0));
        let track = names.get(&tid).cloned().unwrap_or(tid);
        let ts = e.get("ts").and_then(Value::as_f64).unwrap_or(0.0);
        match ph {
            "B" => {
                spans += 1;
                stacks.begin(&track, e.req_str("name")?, ts, 0.0);
            }
            "E" => {
                let _ = stacks.end(&track, e.req_str("name")?, ts);
            }
            "i" => instants += 1,
            "M" => tracks += 1,
            _ => {}
        }
    }
    let mut problems = stacks.violations().to_vec();
    problems.extend(stacks.unclosed());
    if !problems.is_empty() {
        for p in problems.iter().take(10) {
            eprintln!("  {p}");
        }
        return Err(Error::msg(format!(
            "{path}: {} B/E span balance violations",
            problems.len()
        )));
    }
    let dropped = v
        .get("otherData")
        .and_then(|o| o.get("dropped_events"))
        .and_then(Value::as_f64)
        .unwrap_or(0.0);
    println!(
        "{path}: {} events ({spans} spans balanced, {instants} instants, {tracks} tracks, \
         {dropped} dropped)",
        events.len()
    );
    Ok(())
}

/// `llamarl analyze`: one streaming pass over a traced run's event stream
/// (journal or raw `trace_events.jsonl`) into `analysis.json` + a human
/// report. The artifact is written BEFORE any gate fires, so CI uploads
/// it even when the run fails validation.
fn cmd_analyze(args: &Args) -> Result<()> {
    use llamarl::util::error::Error;
    let input: std::path::PathBuf = if let Some(log) = args.str_opt("log") {
        log.into()
    } else {
        let raw = args
            .str_opt("journal")
            .map(String::from)
            .or_else(|| args.positional.first().cloned())
            .ok_or_else(|| Error::Cli("expected --journal DIR-or-FILE or --log FILE".into()))?;
        let p = std::path::PathBuf::from(raw);
        if p.is_dir() {
            // prefer the journal (carries the meta config --des needs);
            // fall back to the bare event log
            let j = p.join("journal.jsonl");
            if j.exists() {
                j
            } else {
                p.join("trace_events.jsonl")
            }
        } else {
            p
        }
    };
    let analysis = llamarl::analysis::analyze_file(&input, args.flag("des"))?;
    let out = args
        .str_opt("out")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| input.with_file_name("analysis.json"));
    std::fs::write(&out, analysis.to_json().to_string())?;
    print!("{}", analysis.render());
    println!("analysis -> {}", out.display());
    if analysis.run.events == 0 {
        return Err(Error::Cli(format!(
            "{}: no trace events (was the run traced?)",
            input.display()
        )));
    }
    if !analysis.run.violations.is_empty() {
        return Err(Error::Cli(format!(
            "{}: {} B/E balance violations (see report)",
            input.display(),
            analysis.run.violations.len()
        )));
    }
    if analysis.run.dropped_events > 0 && !args.flag("allow-drops") {
        return Err(Error::Cli(format!(
            "{}: {} trace events dropped (recorder rings overflowed); \
             pass --allow-drops to analyze the incomplete log anyway",
            input.display(),
            analysis.run.dropped_events
        )));
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.str_or("artifacts", "artifacts/nano");
    let m = Manifest::load(&dir)?;
    println!("artifact bundle: {dir}");
    println!(
        "model: {} (vocab={} d={} L={} H={} S={}), {} params",
        m.config.name,
        m.config.vocab,
        m.config.d_model,
        m.config.n_layers,
        m.config.n_heads,
        m.config.max_seq,
        m.num_params
    );
    println!(
        "shapes: gen [{}x{}] chunk {}, train [{}x{}]",
        m.config.gen_batch,
        m.config.max_seq,
        m.config.gen_chunk,
        m.config.train_batch,
        m.config.train_seq
    );
    println!("artifacts:");
    for (name, a) in &m.artifacts {
        println!(
            "  {name}: {} inputs -> {:?} {:?}",
            a.inputs.len(),
            a.output.dtype,
            a.output.shape
        );
    }
    Ok(())
}
