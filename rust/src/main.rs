//! `llamarl` — CLI launcher for the LlamaRL reproduction.
//!
//! Subcommands:
//!   train     run RL training (sync baseline, async LlamaRL pipeline, or
//!             the buffered data-plane pipeline)
//!   simulate  cluster simulator: paper-scale step-time table (Table 3)
//!   ddma      weight-sync comparison (Table 4)
//!   timeline  discrete-event bubble analysis (Figure 2)
//!   dataplane synthetic channel-vs-store data-plane comparison (no
//!             artifacts needed)
//!   info      inspect an artifact bundle
//!   tracecheck  validate a Chrome trace file emitted by `train --trace`
//!
//! Examples:
//!   llamarl train --preset nano --mode async --steps 5
//!   llamarl train --preset nano --mode async_buffered --max-staleness 4
//!   llamarl simulate
//!   llamarl dataplane --steps 60
//!   llamarl info --artifacts artifacts/nano

use llamarl::config;
use llamarl::coordinator::run_training;
use llamarl::ddma::ps_baseline::PsModel;
use llamarl::ddma::topology::DdmaModel;
use llamarl::metrics::print_report;
use llamarl::runtime::Manifest;
use llamarl::simulator::{
    simulate_timeline, solve_async, solve_sync, DesConfig, HardwareModel, LLAMA_MODELS,
    PAPER_TABLE3,
};
use llamarl::util::bench::Table;
use llamarl::util::cli::Args;
use llamarl::util::error::Result;

const BOOL_FLAGS: &[&str] = &[
    "quantize-generator",
    "sync-quantized",
    "sync-inline",
    "colocate",
    "offload-eager",
    "dump-graph",
    "help",
];

fn main() {
    let args = match Args::from_env(BOOL_FLAGS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    if args.flag("help") {
        print_help();
        return Ok(());
    }
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(args),
        Some("pretrain") => cmd_pretrain(args),
        Some("simulate") => cmd_simulate(),
        Some("ddma") => cmd_ddma(),
        Some("timeline") => cmd_timeline(args),
        Some("dataplane") => cmd_dataplane(args),
        Some("info") => cmd_info(args),
        Some("tracecheck") => cmd_tracecheck(args),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "llamarl — LlamaRL reproduction (async distributed RL for LLM post-training)

USAGE: llamarl <subcommand> [flags]

  train     --preset nano|small|e2e  --mode sync|async|async_buffered
            --steps N [--config file.json] [--workers N] [--rho X] [--lr X]
            [--quantize-generator] [--eval-every K] [--out DIR]
            [--init-checkpoint DIR]
            [--reward-workers N (scatter generation groups across N reward
             executors by group id; groups stay whole)]
            [--dump-graph (print the resolved topology as Graphviz DOT and
             exit without training)]
            buffered data plane: [--store-capacity N] [--store-shards N]
            [--max-staleness K (0=unbounded)]
            [--admission block|drop_newest|evict_oldest]
            [--sampling fifo|freshest|staleness_weighted]
            weight-sync plane: [--sync-trainer-shards N]
            [--sync-generator-shards N] [--sync-quantized]
            [--sync-encoding full|int8|delta|topk|auto (auto measures the
             update density per publish and picks full vs delta)]
            [--sync-topk-frac X]
            [--sync-inline (disable the background streaming executor)]
            [--sync-link-groups N (0 = one worker per generator shard;
             explicit N uses bandwidth-balanced link groups)]
            memory plane: [--colocate (trainer+generator share the rank)]
            [--offload-classes grads,optim] [--offload-chunk-mb N]
            [--prefetch-depth N] [--offload-eager (no background executor)]
            tracing plane: [--trace FILE (Chrome Trace Event Format export,
             load in chrome://tracing or Perfetto; also streams the raw
             event log to OUT/trace_events.jsonl)]
            [--metrics-interval SECS (periodic telemetry snapshots to
             OUT/telemetry_snapshots.jsonl; 0 = off)]
  pretrain  --artifacts DIR --steps N --lr X --out DIR
            supervised warm-up producing the RL init checkpoint
  simulate  reproduce Table 3 from the calibrated cluster cost model
  ddma      reproduce Table 4 (DDMA vs parameter-server weight sync)
  timeline  [--sigma X] discrete-event bubble analysis (Figure 2)
  dataplane [--steps N] [--max-staleness K] synthetic channel-vs-store
            comparison on real threads (no artifacts needed)
  info      --artifacts DIR  inspect an artifact bundle
  tracecheck --file trace.json  validate a Chrome trace export: parses the
            file with the built-in JSON reader and reports the event count"
    );
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = config::resolve(args)?;
    if args.flag("dump-graph") {
        // Resolve and print the declarative topology as DOT instead of
        // running it. The manifest only contributes sync-mode channel
        // capacities; without artifacts the nano default (4 rows) applies.
        let graph = match Manifest::load(&cfg.artifact_dir) {
            Ok(m) => llamarl::coordinator::topology(&cfg, &m),
            Err(_) => llamarl::coordinator::topology_with_rows(&cfg, 4),
        };
        graph.check()?;
        print!("{}", graph.to_dot());
        return Ok(());
    }
    llamarl::log_info!(
        "main",
        "training: mode={:?} artifacts={} steps={}",
        cfg.mode,
        cfg.artifact_dir.display(),
        cfg.max_steps
    );
    let report = run_training(&cfg)?;
    print_report(&report);
    Ok(())
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    let cfg = llamarl::coordinator::PretrainConfig {
        artifact_dir: args.str_or("artifacts", "artifacts/nano").into(),
        steps: args.u64_or("steps", 200)?,
        lr: args.f64_or("lr", 1e-3)? as f32,
        grad_clip: args.f64_or("grad-clip", 1.0)? as f32,
        seed: args.u64_or("seed", 7)?,
        log_every: args.u64_or("log-every", 25)?,
    };
    let out = args.str_or("out", "/tmp/llamarl_pretrain");
    let report = llamarl::coordinator::run_pretraining(&cfg, &out)?;
    println!(
        "pretrained {} steps in {:.1}s, final target_logp {:.3}; checkpoint -> {}",
        report.steps, report.wall_secs, report.final_target_logp, out
    );
    Ok(())
}

fn cmd_simulate() -> Result<()> {
    println!("Cluster simulator — paper Table 3 (step seconds)\n");
    let mut t = Table::new(&[
        "model", "GPUs", "paper base", "sim base", "paper best", "sim async", "paper x", "sim x",
    ]);
    for m in LLAMA_MODELS {
        let hw = HardwareModel::paper_scale(m);
        let sync = solve_sync(&hw.problem());
        let hw8 = HardwareModel {
            fp8_generator: true,
            ..hw
        };
        let asn = solve_async(&hw8.problem());
        let paper_base = PAPER_TABLE3
            .iter()
            .find(|r| r.model == m.name && r.system == "baseline")
            .unwrap()
            .step_secs;
        let paper_best = PAPER_TABLE3
            .iter()
            .filter(|r| r.model == m.name && r.system == "llamarl")
            .map(|r| r.step_secs)
            .fold(f64::INFINITY, f64::min);
        t.row(vec![
            m.name.to_string(),
            format!("{}", hw.g0 as u64),
            format!("{paper_base:.1}"),
            format!("{:.1}", sync.step_secs),
            format!("{paper_best:.1}"),
            format!("{:.1}", asn.step_secs),
            format!("{:.2}x", paper_base / paper_best),
            format!("{:.2}x", sync.step_secs / asn.step_secs),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_ddma() -> Result<()> {
    println!("Weight synchronization — paper Table 4 (seconds)\n");
    let ddma = DdmaModel::calibrated();
    let ps = PsModel::calibrated();
    let mut t = Table::new(&["model", "OpenRLHF PS", "model PS", "paper DDMA", "model DDMA"]);
    let rows = [
        ("7B", 7e9, 128.0, Some(4.32), Some(0.04)),
        ("70B", 70e9, 128.0, Some(111.65), Some(1.15)),
        ("405B", 405e9, 512.0, None, Some(2.31)),
    ];
    for (name, params, gpus, ps_paper, ddma_paper) in rows {
        t.row(vec![
            name.to_string(),
            ps_paper.map(|x| format!("{x:.2}")).unwrap_or("-".into()),
            format!("{:.2}", ps.sync_secs(params)),
            ddma_paper.map(|x| format!("{x:.2}")).unwrap_or("-".into()),
            format!("{:.2}", ddma.sync_secs(params, gpus as usize)),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_timeline(args: &Args) -> Result<()> {
    let sigma = args.f64_or("sigma", 0.6)?;
    let cfg = DesConfig {
        gen_sigma: sigma,
        ..DesConfig::default()
    };
    let (s, a) = simulate_timeline(&cfg);
    println!("Discrete-event timelines (Figure 2), gen_sigma={sigma}\n");
    let mut t = Table::new(&["arch", "total s", "s/step", "gen idle", "train idle", "lag"]);
    t.row(vec![
        "sync".into(),
        format!("{:.1}", s.total_secs),
        format!("{:.2}", s.step_secs_mean),
        format!("{:.0}%", s.gen_idle_frac * 100.0),
        format!("{:.0}%", s.train_idle_frac * 100.0),
        "-".into(),
    ]);
    t.row(vec![
        "async".into(),
        format!("{:.1}", a.total_secs),
        format!("{:.2}", a.step_secs_mean),
        format!("{:.0}%", a.gen_idle_frac * 100.0),
        format!("{:.0}%", a.train_idle_frac * 100.0),
        format!("{:.2}", a.mean_lag_steps),
    ]);
    t.print();
    println!("\nasync speedup: {:.2}x", s.total_secs / a.total_secs);
    Ok(())
}

fn cmd_dataplane(args: &Args) -> Result<()> {
    use llamarl::dataplane::{
        run_driver, AdmissionPolicy, DriverConfig, SamplingStrategy, StoreConfig, Transport,
    };
    let steps = args.u64_or("steps", 40)?;
    let bound = args.u64_or("max-staleness", 4)?;
    let base = DriverConfig {
        train_steps: steps,
        seed: args.u64_or("seed", 0)?,
        ..DriverConfig::default()
    };
    println!("Synthetic data-plane comparison ({steps} train steps, staleness bound {bound})\n");
    let mut t = Table::new(&["transport", "rows/s", "mean lag", "max lag", "dropped", "evicted"]);
    let arms: Vec<Transport> = vec![
        Transport::Channel { capacity: 4 },
        Transport::Store(StoreConfig {
            capacity: 64,
            shards: 4,
            max_staleness: if bound == 0 { None } else { Some(bound) },
            admission: AdmissionPolicy::EvictOldest,
            sampling: SamplingStrategy::Fifo,
            seed: 0,
        }),
        Transport::Store(StoreConfig {
            capacity: 64,
            shards: 4,
            max_staleness: if bound == 0 { None } else { Some(bound) },
            admission: AdmissionPolicy::EvictOldest,
            sampling: SamplingStrategy::FreshestFirst,
            seed: 0,
        }),
    ];
    for transport in arms {
        let r = run_driver(&DriverConfig {
            transport,
            ..base.clone()
        });
        let (dropped, evicted) = r
            .dataplane
            .as_ref()
            .map(|d| (d.dropped_stale + d.dropped_capacity, d.evicted))
            .unwrap_or((0, 0));
        t.row(vec![
            r.transport.clone(),
            format!("{:.0}", r.rows_per_sec),
            format!("{:.2}", r.mean_lag),
            r.max_lag.to_string(),
            dropped.to_string(),
            evicted.to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_tracecheck(args: &Args) -> Result<()> {
    use llamarl::util::error::Error;
    use llamarl::util::json::Value;
    let path = args.str_or("file", "trace.json");
    let text = std::fs::read_to_string(&path)?;
    let v = Value::parse(&text)?;
    let events = v.req_array("traceEvents")?;
    if events.is_empty() {
        return Err(Error::msg(format!("{path}: traceEvents is empty")));
    }
    let mut spans = 0usize;
    let mut instants = 0usize;
    let mut tracks = 0usize;
    for e in events {
        match e.req_str("ph")? {
            "B" => spans += 1,
            "i" => instants += 1,
            "M" => tracks += 1,
            _ => {}
        }
    }
    let dropped = v
        .get("otherData")
        .and_then(|o| o.get("dropped_events"))
        .and_then(Value::as_f64)
        .unwrap_or(0.0);
    println!(
        "{path}: {} events ({spans} spans, {instants} instants, {tracks} tracks, \
         {dropped} dropped)",
        events.len()
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.str_or("artifacts", "artifacts/nano");
    let m = Manifest::load(&dir)?;
    println!("artifact bundle: {dir}");
    println!(
        "model: {} (vocab={} d={} L={} H={} S={}), {} params",
        m.config.name,
        m.config.vocab,
        m.config.d_model,
        m.config.n_layers,
        m.config.n_heads,
        m.config.max_seq,
        m.num_params
    );
    println!(
        "shapes: gen [{}x{}] chunk {}, train [{}x{}]",
        m.config.gen_batch,
        m.config.max_seq,
        m.config.gen_chunk,
        m.config.train_batch,
        m.config.train_seq
    );
    println!("artifacts:");
    for (name, a) in &m.artifacts {
        println!(
            "  {name}: {} inputs -> {:?} {:?}",
            a.inputs.len(),
            a.output.dtype,
            a.output.shape
        );
    }
    Ok(())
}
