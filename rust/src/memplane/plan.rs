//! Phase-aware colocation planner: which allocation classes live on-device
//! vs host in each pipeline phase, and what the phase flips cost.
//!
//! Colocation (paper best practice; also AsyncFlow/Laminar) lets trainer
//! and generator share the same GPUs: state the current phase does not need
//! is swapped to host memory and prefetched back before the phase that
//! does. The planner turns a [`MemSpec`] + hard capacities into a
//! *placement proof*:
//!
//! * every phase's device-resident set fits the per-rank HBM capacity, or
//!   planning fails with [`Error::Capacity`] — infeasible colocations are
//!   rejected before a run starts, never discovered as an OOM mid-step;
//! * retained classes ([`AllocClass::is_transient`] == false) that do not
//!   fit next to a phase's working set are offloaded **largest-first**
//!   (fewest transfers for the most freed bytes), but only if the caller
//!   listed them in `offload_classes` — the planner never silently moves
//!   state the user wanted pinned;
//! * transient classes (KV cache, activation scratch) are *dropped* outside
//!   their phase: freed and re-materialized, zero transfer bytes.
//!
//! Concurrent-phase mode models the asynchronous architectures, where
//! generate/train/sync overlap in time on disjoint executors: nothing can
//! be offloaded (a class is always needed by *someone*), so colocation is
//! feasible only when everything fits at once — and the planner says so
//! loudly instead of letting phases fight over residency.
//!
//! The phase-flip transfer volumes are costed on the same hardware model
//! the DDMA plane uses ([`DdmaModel::offload_secs`], PCIe-bound), which is
//! what the DES offload/prefetch timeline segments and the
//! `offload_overlap` bench consume.

use crate::ddma::topology::DdmaModel;
use crate::memplane::pool::{AllocClass, MemSpec};
use crate::util::error::{Error, Result};

/// Pipeline phases the coordinator leases around.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Generate,
    Train,
    Sync,
}

impl Phase {
    pub const ALL: [Phase; 3] = [Phase::Generate, Phase::Train, Phase::Sync];

    /// Classes a phase must have device-resident to run at all.
    pub fn required(self) -> &'static [AllocClass] {
        match self {
            Phase::Generate => &[AllocClass::Params, AllocClass::KvCache],
            Phase::Train => &[
                AllocClass::Params,
                AllocClass::Grads,
                AllocClass::OptimState,
                AllocClass::ActivationSlack,
            ],
            // publish reads the weight snapshot; everything else may rest
            Phase::Sync => &[AllocClass::Params],
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Phase::Generate => "generate",
            Phase::Train => "train",
            Phase::Sync => "sync",
        }
    }

    pub(crate) fn index(self) -> usize {
        self as usize
    }
}

/// Where a class lives during one phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Residency {
    /// on-device (HBM-resident)
    Device,
    /// offloaded to host memory (retained: contents preserved, D2H/H2D on
    /// the flips)
    Host,
    /// freed — transient scratch re-materialized when its phase resumes
    Dropped,
}

/// A transfer one phase flip performs for one retained class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlipMove {
    /// device -> host (offload)
    D2H(AllocClass, u64),
    /// host -> device (prefetch)
    H2D(AllocClass, u64),
}

/// The planner's proof object: per-phase residency for every class, plus
/// the capacities it was proven against.
#[derive(Debug, Clone)]
pub struct ColocationPlan {
    pub spec: MemSpec,
    pub device_cap: u64,
    pub host_cap: u64,
    pub colocated: bool,
    /// async architectures: phases overlap in time, so no class can leave
    /// the device
    pub concurrent: bool,
    residency: [[Residency; 5]; 3],
}

impl ColocationPlan {
    pub fn residency(&self, phase: Phase, class: AllocClass) -> Residency {
        self.residency[phase.index()][class.index()]
    }

    /// Device bytes the plan puts on the rank during `phase`.
    pub fn device_bytes(&self, phase: Phase) -> u64 {
        AllocClass::ALL
            .iter()
            .filter(|c| self.residency(phase, **c) == Residency::Device)
            .map(|c| self.spec.bytes(*c))
            .sum()
    }

    /// The plan's peak per-rank HBM demand across phases.
    pub fn max_phase_device_bytes(&self) -> u64 {
        Phase::ALL
            .iter()
            .map(|p| self.device_bytes(*p))
            .max()
            .unwrap_or(0)
    }

    /// Retained classes the plan ever parks on the host.
    pub fn offloaded_classes(&self) -> Vec<AllocClass> {
        AllocClass::ALL
            .into_iter()
            .filter(|c| {
                Phase::ALL
                    .iter()
                    .any(|p| self.residency(*p, *c) == Residency::Host)
            })
            .collect()
    }

    /// Retained-class moves when flipping `from -> to` (transient drops and
    /// re-materializations carry no bytes and are not listed).
    pub fn transfers(&self, from: Phase, to: Phase) -> Vec<FlipMove> {
        let mut out = Vec::new();
        for c in AllocClass::ALL {
            if c.is_transient() {
                continue;
            }
            match (self.residency(from, c), self.residency(to, c)) {
                (Residency::Device, Residency::Host) => {
                    out.push(FlipMove::D2H(c, self.spec.bytes(c)))
                }
                (Residency::Host, Residency::Device) => {
                    out.push(FlipMove::H2D(c, self.spec.bytes(c)))
                }
                _ => {}
            }
        }
        out
    }

    /// Total bytes a flip moves in each direction: `(d2h, h2d)`.
    pub fn flip_bytes(&self, from: Phase, to: Phase) -> (u64, u64) {
        let mut d2h = 0;
        let mut h2d = 0;
        for m in self.transfers(from, to) {
            match m {
                FlipMove::D2H(_, b) => d2h += b,
                FlipMove::H2D(_, b) => h2d += b,
            }
        }
        (d2h, h2d)
    }

    /// DES timeline segments on the calibrated hardware model: seconds of
    /// offload (train -> generate flip) and prefetch (generate -> train
    /// flip) transfer over the host link, chunked at `chunk_mb`.
    pub fn des_offload_costs(&self, model: &DdmaModel, chunk_mb: usize) -> (f64, f64) {
        let chunk = (chunk_mb.max(1) as f64) * 1e6;
        let (d2h, _) = self.flip_bytes(Phase::Train, Phase::Generate);
        let (_, h2d) = self.flip_bytes(Phase::Generate, Phase::Train);
        (
            model.offload_secs(d2h as f64, chunk),
            model.offload_secs(h2d as f64, chunk),
        )
    }
}

fn phase_fit_error(phase: Phase, need: u64, cap: u64, hint: &str) -> Error {
    Error::Capacity(format!(
        "colocated {} phase needs {need} B device-resident but the rank has \
         {cap} B of HBM{hint}",
        phase.name(),
    ))
}

/// Plan a placement. `offload_classes` are the retained classes the caller
/// allows off-device; `concurrent` models the async architectures (phases
/// overlap, nothing may leave). Fails with [`Error::Capacity`] when no
/// legal placement exists.
pub fn plan_colocation(
    spec: MemSpec,
    device_cap: u64,
    host_cap: u64,
    colocated: bool,
    concurrent: bool,
    offload_classes: &[AllocClass],
) -> Result<ColocationPlan> {
    for c in offload_classes {
        if c.is_transient() {
            return Err(Error::Config(format!(
                "class '{}' is transient scratch (dropped between phases); \
                 it cannot be offload-retained",
                c.name()
            )));
        }
    }
    let mut residency = [[Residency::Device; 5]; 3];
    if !colocated {
        // Disjoint ranks per role: each phase's rank holds its own classes;
        // the cross-phase classes simply do not exist on the other rank.
        // Feasibility is per-role.
        let trainer: u64 = spec.sum(Phase::Train.required().iter().copied());
        let generator: u64 = spec.sum(Phase::Generate.required().iter().copied());
        if trainer > device_cap {
            return Err(phase_fit_error(Phase::Train, trainer, device_cap, ""));
        }
        if generator > device_cap {
            return Err(phase_fit_error(Phase::Generate, generator, device_cap, ""));
        }
        for p in Phase::ALL {
            for c in AllocClass::ALL {
                if c.is_transient() && !p.required().contains(&c) {
                    residency[p.index()][c.index()] = Residency::Dropped;
                }
            }
        }
        return Ok(ColocationPlan {
            spec,
            device_cap,
            host_cap,
            colocated,
            concurrent,
            residency,
        });
    }

    if concurrent {
        // Overlapping phases: every class is live for someone at all times.
        let total = spec.total();
        if total > device_cap {
            return Err(Error::Capacity(format!(
                "colocated async needs every class device-resident at once \
                 ({total} B > {device_cap} B HBM): phases overlap, so \
                 offloading cannot help — shrink batches or un-colocate"
            )));
        }
        return Ok(ColocationPlan {
            spec,
            device_cap,
            host_cap,
            colocated,
            concurrent,
            residency,
        });
    }

    // Sequential colocation: per phase, start from everything resident,
    // drop transient scratch other phases own, then offload allowed
    // retained classes largest-first until the phase fits.
    for p in Phase::ALL {
        let row = &mut residency[p.index()];
        for c in AllocClass::ALL {
            if c.is_transient() && !p.required().contains(&c) {
                row[c.index()] = Residency::Dropped;
            }
        }
        let device_sum = |row: &[Residency; 5]| -> u64 {
            AllocClass::ALL
                .iter()
                .filter(|c| row[c.index()] == Residency::Device)
                .map(|c| spec.bytes(*c))
                .sum()
        };
        if device_sum(row) <= device_cap {
            continue;
        }
        // largest-first offload of the allowed, non-required classes
        let mut candidates: Vec<AllocClass> = offload_classes
            .iter()
            .copied()
            .filter(|c| !p.required().contains(c))
            .collect();
        candidates.sort_by_key(|c| std::cmp::Reverse(spec.bytes(*c)));
        for c in candidates {
            if device_sum(row) <= device_cap {
                break;
            }
            row[c.index()] = Residency::Host;
        }
        let need = device_sum(row);
        if need > device_cap {
            return Err(phase_fit_error(
                p,
                need,
                device_cap,
                " even with every allowed class offloaded",
            ));
        }
        let host_sum: u64 = AllocClass::ALL
            .iter()
            .filter(|c| row[c.index()] == Residency::Host)
            .map(|c| spec.bytes(*c))
            .sum();
        if host_sum > host_cap {
            return Err(Error::Capacity(format!(
                "colocated {} phase offloads {host_sum} B to host but only \
                 {host_cap} B of host memory is available",
                p.name()
            )));
        }
    }
    Ok(ColocationPlan {
        spec,
        device_cap,
        host_cap,
        colocated,
        concurrent,
        residency,
    })
}

/// The smallest device capacity the plane's pool needs to run `spec`, with
/// a fractional headroom — what the coordinator uses when no explicit
/// capacity is configured. Non-colocated deployments get the SUM of both
/// roles' demands (the pool then stands for two ranks' HBM — exactly the
/// hardware bill colocation exists to avoid); colocated concurrent gets
/// the full union; colocated sequential gets the worst single phase under
/// the allowed offloads.
pub fn auto_device_cap(
    spec: &MemSpec,
    colocated: bool,
    concurrent: bool,
    offload_classes: &[AllocClass],
    headroom: f64,
) -> u64 {
    let need = if !colocated {
        // two ranks' worth: the trainer rank plus the generator rank
        let trainer = spec.sum(Phase::Train.required().iter().copied());
        let generator = spec.sum(Phase::Generate.required().iter().copied());
        trainer + generator
    } else if concurrent {
        spec.total()
    } else {
        Phase::ALL
            .iter()
            .map(|p| {
                let mut sum = 0u64;
                for c in AllocClass::ALL {
                    let dropped = c.is_transient() && !p.required().contains(&c);
                    let offloaded =
                        offload_classes.contains(&c) && !p.required().contains(&c);
                    if !dropped && !offloaded {
                        sum += spec.bytes(c);
                    }
                }
                sum
            })
            .max()
            .unwrap_or(0)
    };
    ((need as f64) * (1.0 + headroom.max(0.0))).ceil() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: u64 = 1_000_000;

    fn spec() -> MemSpec {
        // params 32, grads 32, optim 64, kv 96, act 32 (MB)
        MemSpec::new(32 * MB, 32 * MB, 64 * MB, 96 * MB, 32 * MB)
    }

    #[test]
    fn sequential_plan_offloads_only_when_needed() {
        let s = spec();
        // cap fits everything retained at all times: nothing offloaded
        let roomy = plan_colocation(
            s,
            s.total(),
            s.total(),
            true,
            false,
            &[AllocClass::Grads, AllocClass::OptimState],
        )
        .unwrap();
        assert!(roomy.offloaded_classes().is_empty());
        assert_eq!(roomy.flip_bytes(Phase::Train, Phase::Generate), (0, 0));

        // tight cap: generate phase (params+kv = 128) cannot also hold
        // grads+optim (96) at 160 — optim (largest) goes first, and it is
        // enough
        let tight = plan_colocation(
            s,
            160 * MB,
            256 * MB,
            true,
            false,
            &[AllocClass::Grads, AllocClass::OptimState],
        )
        .unwrap();
        assert_eq!(
            tight.residency(Phase::Generate, AllocClass::OptimState),
            Residency::Host
        );
        assert_eq!(
            tight.residency(Phase::Generate, AllocClass::Grads),
            Residency::Device
        );
        assert_eq!(
            tight.residency(Phase::Train, AllocClass::OptimState),
            Residency::Device
        );
        // transient scratch is dropped, not offloaded
        assert_eq!(
            tight.residency(Phase::Generate, AllocClass::ActivationSlack),
            Residency::Dropped
        );
        let (d2h, h2d) = (
            tight.flip_bytes(Phase::Train, Phase::Generate),
            tight.flip_bytes(Phase::Generate, Phase::Train),
        );
        assert_eq!(d2h, (64 * MB, 0));
        assert_eq!(h2d, (0, 64 * MB));
    }

    #[test]
    fn infeasible_placement_is_a_capacity_error() {
        let s = spec();
        // train needs params+grads+optim+act = 160 even with kv dropped
        let err = plan_colocation(
            s,
            100 * MB,
            1024 * MB,
            true,
            false,
            &[AllocClass::Grads, AllocClass::OptimState],
        )
        .unwrap_err();
        assert!(matches!(err, Error::Capacity(_)), "{err}");
        // without permission to offload, generate (128 + 96 retained) fails
        let err2 = plan_colocation(s, 160 * MB, 1024 * MB, true, false, &[]).unwrap_err();
        assert!(matches!(err2, Error::Capacity(_)), "{err2}");
        // host too small to hold the offloaded optimizer state
        let err3 = plan_colocation(
            s,
            160 * MB,
            10 * MB,
            true,
            false,
            &[AllocClass::Grads, AllocClass::OptimState],
        )
        .unwrap_err();
        assert!(matches!(err3, Error::Capacity(_)), "{err3}");
    }

    #[test]
    fn concurrent_phases_need_the_union() {
        let s = spec();
        assert!(plan_colocation(s, s.total(), 0, true, true, &[]).is_ok());
        let err = plan_colocation(s, s.total() - 1, 0, true, true, &[]).unwrap_err();
        assert!(matches!(err, Error::Capacity(_)));
    }

    #[test]
    fn transient_classes_cannot_be_offload_retained() {
        let s = spec();
        assert!(plan_colocation(s, s.total(), 0, true, false, &[AllocClass::KvCache]).is_err());
    }

    #[test]
    fn non_colocated_checks_each_role() {
        let s = spec();
        // trainer role needs 160, generator 128
        assert!(plan_colocation(s, 160 * MB, 0, false, false, &[]).is_ok());
        assert!(plan_colocation(s, 130 * MB, 0, false, false, &[]).is_err());
    }

    #[test]
    fn auto_cap_admits_its_own_plan() {
        let s = spec();
        let off = [AllocClass::Grads, AllocClass::OptimState];
        for (colo, conc) in [(true, false), (true, true), (false, false)] {
            let cap = auto_device_cap(&s, colo, conc, &off, 0.25);
            let plan = plan_colocation(s, cap, u64::MAX, colo, conc, &off).unwrap();
            assert!(plan.max_phase_device_bytes() <= cap);
        }
    }

    #[test]
    fn des_costs_follow_flip_bytes() {
        let s = spec();
        let plan = plan_colocation(
            s,
            160 * MB,
            256 * MB,
            true,
            false,
            &[AllocClass::Grads, AllocClass::OptimState],
        )
        .unwrap();
        let model = DdmaModel::calibrated();
        let (d2h, h2d) = plan.des_offload_costs(&model, 4);
        // 64 MB over the ~64 GB/s host link: ~1 ms either way
        assert!(d2h > 0.0 && h2d > 0.0);
        assert!((d2h - h2d).abs() < 1e-9, "symmetric flip volumes");
        assert!(d2h < 0.1, "64 MB must not cost more than 100 ms: {d2h}");
    }
}
