//! The colocated offloading memory plane (paper best practice #3; cf.
//! AsyncFlow arXiv 2507.01663, Laminar arXiv 2510.12633).
//!
//! Colocation lets trainer and generator share the same GPUs without
//! doubling the cluster: state the current phase does not need — above all
//! the optimizer moments, the largest single allocation in the 4x-W0
//! trainer footprint — is swapped to host memory during generation and
//! prefetched back before the next optimizer update, overlapped with
//! compute. This module makes that a first-class, *accounted* subsystem:
//!
//! * [`pool`] — [`MemPool`]: per-rank HBM/host capacity accounting over
//!   tracked [`AllocClass`]es (params, grads, optimizer state, KV cache,
//!   activation scratch), with hard-capacity errors instead of silent
//!   overcommit, and [`MemSpec`] deriving class sizes from the same
//!   quantities as [`crate::simulator::hardware`].
//! * [`plan`] — the phase-aware colocation planner: per
//!   [`Phase`] (generate / train / sync), which classes live on-device vs
//!   host; transient scratch is dropped, retained classes are offloaded
//!   largest-first, and infeasible placements are rejected with
//!   [`crate::util::error::Error::Capacity`] **before** a run starts.
//! * [`executor`] — [`OffloadExecutor`]: the background offload/prefetch
//!   engine (long-lived worker, chunked transfers, latest-wins residency
//!   targets), reusing the streaming-worker pattern of
//!   [`crate::weightsync::executor`].
//!
//! # The colocation lease protocol
//!
//! The coordinator never moves memory itself; it brackets each phase with a
//! lease on the shared [`MemPlane`]:
//!
//! ```text
//!   lease(Generate) ─► target := Generate residency   (offload optimizer
//!       │               D2H runs behind decode)
//!       │ hint_next(Train) ─► prefetcher streams optimizer shards back
//!       │                     H2D while generation still runs, capacity-
//!       ▼                     and depth-bounded (prefetch_depth)
//!   drop(lease)
//!   lease(Train) ──► returns once the FIRST shard of every required
//!       │            class is device-resident (double buffering: shard
//!       │            i+1 streams while shard i updates)
//!       │ wait_shard(OptimState, i) before touching shard i
//!       ▼
//!   drop(lease)
//! ```
//!
//! 1. [`MemPlane::lease`] bumps the phase's refcount, publishes the merged
//!    residency target of every *active* phase to the executor, and blocks
//!    only until the phase's required classes are *entered*: transient
//!    scratch allocated, and shard 0 of each retained class resident. The
//!    rest of the stream overlaps the phase's own compute.
//! 2. [`PhaseLease::wait_shard`] is the consumer-side fence: call it before
//!    touching shard `i`; with the background prefetcher warm these waits
//!    are hits (no blocking), and the blocked time that remains is the true
//!    un-hidden transfer cost ([`OffloadMetrics::wait_secs`]).
//! 3. [`MemPlane::hint_next`] arms the prefetcher for the *next* phase
//!    while the current lease is still held — this is what hides the H2D
//!    stream behind generation. Hints are opportunistic: bounded by
//!    `prefetch_depth` shards and whatever HBM the current phase leaves
//!    free, never violating the planner's capacity proof.
//! 4. Dropping the last lease of a phase leaves residency untouched (no
//!    thrash between back-to-back phases); the next lease or hint drives
//!    the transition, and a target published mid-transition supersedes the
//!    old one at the next shard boundary (latest-wins).
//!
//! Async architectures run phases concurrently on disjoint executors; the
//! planner then requires the full union to fit (offloading cannot help) and
//! leases degrade to pure accounting — same code path, zero transfers.

pub mod executor;
pub mod plan;
pub mod pool;

use std::sync::{Arc, Mutex, Weak};
use std::time::Instant;

use crate::memplane::executor::{OffloadExecutor, OffloadMetrics};
use crate::memplane::plan::{plan_colocation, auto_device_cap, ColocationPlan, Phase, Residency};
use crate::memplane::pool::{AllocClass, MemPool, MemSpec, PoolUsage};
use crate::trace;
use crate::util::error::{Error, Result};

pub use executor::OffloadMetrics as Metrics;
pub use plan::{ColocationPlan as Plan, FlipMove, Residency as ClassResidency};
pub use pool::{AllocId, Placement, PoolUsage as Usage};

/// Arena guard: the plane materializes real buffers for every retained
/// class; paper-scale specs must go through the planner/DES path instead.
const MAX_ARENA_BYTES: u64 = 2_000_000_000;

/// Memory-plane configuration (config file keys `colocate`,
/// `offload_classes`, `offload_chunk_mb`, `prefetch_depth`).
#[derive(Debug, Clone)]
pub struct MemPlaneConfig {
    /// trainer and generator share the rank (sequential phase residency)
    pub colocate: bool,
    /// retained classes allowed off-device (default: grads + optimizer)
    pub offload_classes: Vec<AllocClass>,
    /// transfer chunk size, MB (chunk = cancellation granularity)
    pub offload_chunk_mb: usize,
    /// shards the hint prefetcher may bring in ahead of the lease
    pub prefetch_depth: usize,
    /// run transfers on the background worker (false = eager baseline:
    /// every lease pays its transfers synchronously)
    pub background: bool,
    /// shards per retained class (transfer/eviction granularity)
    pub shards_per_class: usize,
    /// per-rank HBM bytes; 0 = auto (plan requirement + 25% headroom)
    pub device_bytes: u64,
    /// host memory bytes; 0 = auto (the whole spec fits)
    pub host_bytes: u64,
    /// async architectures: phases overlap in time, nothing may offload
    pub concurrent_phases: bool,
}

impl Default for MemPlaneConfig {
    fn default() -> Self {
        MemPlaneConfig {
            colocate: false,
            offload_classes: vec![AllocClass::Grads, AllocClass::OptimState],
            offload_chunk_mb: 4,
            prefetch_depth: 8,
            background: true,
            shards_per_class: 8,
            device_bytes: 0,
            host_bytes: 0,
            concurrent_phases: false,
        }
    }
}

struct ActivePhases {
    counts: [usize; 3],
    hint: Option<Phase>,
}

/// The per-rank memory plane: planner proof + pool accountant + offload
/// executor behind the phase-lease protocol (module docs).
pub struct MemPlane {
    /// self-handle so leases can own the plane past the caller's borrow
    /// (set once by [`MemPlane::new`] via `Arc::new_cyclic`)
    me: Weak<MemPlane>,
    plan: ColocationPlan,
    pool: Arc<MemPool>,
    exec: OffloadExecutor,
    metrics: Arc<OffloadMetrics>,
    prefetch_depth: usize,
    active: Mutex<ActivePhases>,
}

impl MemPlane {
    /// Plan, account and materialize a plane for `spec`. Fails with a
    /// capacity error when no legal placement exists — a colocated config
    /// that does not fit its rank's HBM never starts running.
    pub fn new(spec: MemSpec, cfg: &MemPlaneConfig) -> Result<Arc<MemPlane>> {
        // Only sequential colocated planes ever move retained state, so
        // only they back shards with real arenas (and only they need the
        // testbed-scale guard); every other placement is accounting-only
        // and costs no memory beyond the bookkeeping.
        let materialize = cfg.colocate && !cfg.concurrent_phases;
        if materialize && spec.total() > MAX_ARENA_BYTES {
            return Err(Error::Config(format!(
                "memplane materializes real arenas for colocated offloading; \
                 {} B exceeds the {} B testbed guard — use the planner/DES \
                 path for paper-scale specs",
                spec.total(),
                MAX_ARENA_BYTES
            )));
        }
        let device_cap = if cfg.device_bytes > 0 {
            cfg.device_bytes
        } else {
            auto_device_cap(
                &spec,
                cfg.colocate,
                cfg.concurrent_phases,
                &cfg.offload_classes,
                0.25,
            )
        };
        let host_cap = if cfg.host_bytes > 0 {
            cfg.host_bytes
        } else {
            spec.total().max(1)
        };
        let plan = plan_colocation(
            spec,
            device_cap,
            host_cap,
            cfg.colocate,
            cfg.concurrent_phases,
            &cfg.offload_classes,
        )?;
        let pool = Arc::new(MemPool::new(device_cap, host_cap));
        let metrics = Arc::new(OffloadMetrics::default());
        // prefetch hits are only meaningful for classes the plan ever
        // parks off-device — always-resident classes never "hit"
        let mut hit_classes = [false; 5];
        for c in plan.offloaded_classes() {
            hit_classes[c.index()] = true;
        }
        let exec = OffloadExecutor::new(
            pool.clone(),
            &plan,
            Phase::Sync,
            cfg.shards_per_class,
            cfg.offload_chunk_mb,
            cfg.background,
            materialize,
            hit_classes,
            metrics.clone(),
        )?;
        Ok(Arc::new_cyclic(|me| MemPlane {
            me: me.clone(),
            plan,
            pool,
            exec,
            metrics,
            prefetch_depth: cfg.prefetch_depth,
            active: Mutex::new(ActivePhases {
                counts: [0; 3],
                hint: None,
            }),
        }))
    }

    /// Merged residency target of all active phases (+ hint flags); see
    /// module docs. Device wins over Host wins over Dropped, so concurrent
    /// leases can only widen residency, never evict under a peer.
    fn merged_target(&self, act: &ActivePhases) -> ([Residency; 5], [bool; 5]) {
        let mut residency = [Residency::Device; 5];
        let active: Vec<Phase> = Phase::ALL
            .iter()
            .copied()
            .filter(|p| act.counts[p.index()] > 0)
            .collect();
        for c in AllocClass::ALL {
            let i = c.index();
            residency[i] = if active
                .iter()
                .any(|p| self.plan.residency(*p, c) == Residency::Device)
            {
                Residency::Device
            } else if c.is_transient() {
                Residency::Dropped
            } else if self.plan.offloaded_classes().contains(&c) {
                Residency::Host
            } else {
                Residency::Device
            };
        }
        let mut hints = [false; 5];
        if let Some(h) = act.hint {
            for c in AllocClass::ALL {
                if !c.is_transient()
                    && self.plan.residency(h, c) == Residency::Device
                    && residency[c.index()] != Residency::Device
                {
                    hints[c.index()] = true;
                }
            }
        }
        (residency, hints)
    }

    fn publish_target(&self, act: &ActivePhases) {
        let (residency, hints) = self.merged_target(act);
        self.exec.set_target(residency, hints, self.prefetch_depth);
    }

    /// Acquire a phase lease: publish the merged residency target and block
    /// until the phase is *entered* (transient scratch live, shard 0 of
    /// every retained required class resident). Use
    /// [`PhaseLease::wait_shard`] as you walk the remaining shards.
    ///
    /// Concurrent leases are refcounted per phase and only widen residency
    /// (Device wins). On a sequential colocated plan, concurrently leasing
    /// phases whose union exceeds the rank fails loudly through the pool
    /// accountant — it does not silently overcommit.
    pub fn lease(&self, phase: Phase) -> Result<PhaseLease> {
        {
            let mut act = self.active.lock().unwrap();
            act.counts[phase.index()] += 1;
            if act.hint == Some(phase) {
                act.hint = None; // the hinted phase arrived
            }
            self.publish_target(&act);
        }
        // the refcount is live from here: a failed entry must release it,
        // or the phase would pin its residency in every future target
        if let Err(e) = self.enter_phase(phase) {
            self.release(phase);
            return Err(e);
        }
        trace::instant(trace::LEASE_ACQUIRE, phase.index() as f64);
        Ok(PhaseLease {
            plane: self.me.upgrade().expect("plane alive while leasing"),
            phase,
        })
    }

    /// The fallible half of [`MemPlane::lease`]: converge (eager) and wait
    /// for the phase's entry residency.
    fn enter_phase(&self, phase: Phase) -> Result<()> {
        if !self.exec.is_background() {
            // eager plane: the lease holder pays the whole transfer now
            let t0 = Instant::now();
            self.exec.apply_target_blocking()?;
            let m = &self.metrics;
            m.wait_events
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            m.wait_nanos.fetch_add(
                t0.elapsed().as_nanos() as u64,
                std::sync::atomic::Ordering::Relaxed,
            );
        }
        for c in phase.required() {
            self.exec.wait_shard(*c, 0)?;
        }
        Ok(())
    }

    /// Arm the prefetcher for the phase that comes next while the current
    /// lease is still held (capacity- and depth-bounded; no-op on an eager
    /// plane, which is exactly the overlap the bench measures).
    pub fn hint_next(&self, phase: Phase) {
        let mut act = self.active.lock().unwrap();
        act.hint = Some(phase);
        self.publish_target(&act);
    }

    /// Block until the executor converged the newest residency target.
    pub fn flush(&self) -> Result<()> {
        self.exec.flush()
    }

    pub fn metrics(&self) -> &OffloadMetrics {
        &self.metrics
    }

    pub fn plan(&self) -> &ColocationPlan {
        &self.plan
    }

    pub fn usage(&self) -> PoolUsage {
        self.pool.usage()
    }

    pub fn device_cap(&self) -> u64 {
        self.pool.device_cap
    }

    /// Shard-content integrity check (tests): transfers never tear data.
    pub fn verify_integrity(&self) -> Result<()> {
        self.exec.verify_integrity()
    }

    /// Per-class device-resident shard fractions (tests/benches).
    pub fn device_fracs(&self) -> Vec<(AllocClass, f64)> {
        self.exec.device_fracs()
    }

    fn release(&self, phase: Phase) {
        trace::instant(trace::LEASE_RELEASE, phase.index() as f64);
        let mut act = self.active.lock().unwrap();
        let c = &mut act.counts[phase.index()];
        debug_assert!(*c > 0, "lease refcount underflow");
        *c = c.saturating_sub(1);
        if act.counts.iter().any(|n| *n > 0) || act.hint.is_some() {
            // remaining peers (or an armed hint) keep driving the target
            self.publish_target(&act);
        }
        // all-idle: leave residency as-is — the next lease or hint drives
        // the transition, avoiding thrash between back-to-back phases
    }
}

/// An RAII phase lease (see the protocol in the module docs).
pub struct PhaseLease {
    plane: Arc<MemPlane>,
    phase: Phase,
}

impl PhaseLease {
    pub fn phase(&self) -> Phase {
        self.phase
    }

    /// Fence before touching shard `idx` of `class`; blocks only for the
    /// un-prefetched remainder of the stream.
    pub fn wait_shard(&self, class: AllocClass, idx: usize) -> Result<()> {
        self.plane.exec.wait_shard(class, idx)
    }

    /// Fence on a whole class.
    pub fn wait_class(&self, class: AllocClass) -> Result<()> {
        self.plane.exec.wait_class(class)
    }
}

impl Drop for PhaseLease {
    fn drop(&mut self) {
        self.plane.release(self.phase);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: u64 = 1_000_000;

    fn cfg(colocate: bool, background: bool) -> MemPlaneConfig {
        MemPlaneConfig {
            colocate,
            background,
            device_bytes: 48 * MB,
            host_bytes: 128 * MB,
            shards_per_class: 4,
            offload_chunk_mb: 1,
            ..MemPlaneConfig::default()
        }
    }

    fn spec() -> MemSpec {
        MemSpec::new(8 * MB, 8 * MB, 16 * MB, 24 * MB, 8 * MB)
    }

    #[test]
    fn lease_cycle_offloads_and_prefetches() {
        let plane = MemPlane::new(spec(), &cfg(true, true)).unwrap();
        for _ in 0..3 {
            let g = plane.lease(Phase::Generate).unwrap();
            plane.hint_next(Phase::Train);
            drop(g);
            let t = plane.lease(Phase::Train).unwrap();
            for s in 0..4 {
                t.wait_shard(AllocClass::OptimState, s).unwrap();
            }
            drop(t);
        }
        plane.flush().unwrap();
        plane.verify_integrity().unwrap();
        let m = plane.metrics();
        assert!(m.d2h_bytes.load(std::sync::atomic::Ordering::Relaxed) >= 16 * MB);
        assert!(m.h2d_bytes.load(std::sync::atomic::Ordering::Relaxed) >= 16 * MB);
        assert!(plane.usage().device_used <= plane.device_cap());
    }

    #[test]
    fn infeasible_plane_never_constructs() {
        let mut c = cfg(true, true);
        c.device_bytes = 30 * MB; // train needs 40 even with kv dropped
        match MemPlane::new(spec(), &c) {
            Err(err) => assert!(matches!(err, Error::Capacity(_)), "{err}"),
            Ok(_) => panic!("oversized colocation must not construct"),
        }
    }

    #[test]
    fn concurrent_leases_widen_residency() {
        let mut c = cfg(true, true);
        c.concurrent_phases = true;
        c.device_bytes = spec().total() + MB;
        let plane = MemPlane::new(spec(), &c).unwrap();
        let g = plane.lease(Phase::Generate).unwrap();
        let t = plane.lease(Phase::Train).unwrap();
        t.wait_class(AllocClass::OptimState).unwrap();
        g.wait_class(AllocClass::KvCache).unwrap();
        plane.flush().unwrap();
        // nothing ever leaves the device in concurrent mode
        assert_eq!(plane.metrics().transferred_bytes(), 0);
        drop(g);
        drop(t);
    }

    #[test]
    fn eager_plane_pays_at_the_lease() {
        let plane = MemPlane::new(spec(), &cfg(true, false)).unwrap();
        {
            let _g = plane.lease(Phase::Generate).unwrap();
            plane.hint_next(Phase::Train); // no-op without a worker
        }
        let t = plane.lease(Phase::Train).unwrap();
        t.wait_class(AllocClass::OptimState).unwrap();
        drop(t);
        let m = plane.metrics();
        assert!(m.wait_secs() > 0.0);
        assert!(m.transferred_bytes() >= 32 * MB);
        plane.verify_integrity().unwrap();
    }
}
