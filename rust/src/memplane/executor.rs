//! Background offload/prefetch engine: a long-lived worker thread drains
//! latest-wins residency targets with chunked, shard-granular transfers.
//!
//! This is the memplane's analogue of the weight-sync plane's
//! [`crate::weightsync::executor`]: the lease holder never performs
//! transfers itself — it *publishes a residency target* (which classes must
//! be device-resident) and the worker converges the shard store onto it:
//!
//! ```text
//!   lease/drop/hint ── set_target(seq, residency, hints) ──► worker
//!        │   (returns immediately; a newer target            │
//!        ▼    supersedes an unconverged older one)           ▼
//!   wait_shard(class, i) blocks on          1. free transient scratch the
//!   the done condvar until shard i             target dropped
//!   is device-resident                      2. required residency next:
//!                                              transient scratch first,
//!                                              then retained H2D shards
//!                                              ascending — evicting a
//!                                              host-parked shard whenever
//!                                              the next piece doesn't fit
//!                                           3. drain host-parked classes
//!                                              down to their hint-keep
//!                                              watermark (prefetch_depth
//!                                              when hinted, 0 otherwise)
//!                                           4. opportunistic hint
//!                                              prefetch, capacity- and
//!                                              depth-bounded
//! ```
//!
//! The required/evict interleave is what makes the generate flip cheap:
//! the KV cache grows shard by shard *as* the optimizer state drains out,
//! so the Generate lease waits only for KV shard 0 (one freed-scratch
//! slot) while the rest of the D2H stream hides behind decode. The drain
//! stops at the hint-keep watermark, so shards the next phase will need
//! anyway never make a pointless round trip. Symmetrically, required H2D
//! prefetch runs in ascending shard order, so a consumer walking shards
//! (`wait_shard(0..n)`) overlaps its compute with the remaining stream —
//! the double-buffered prefetch that puts the trainer's first optimizer
//! shard on device before generation finishes.
//!
//! Transfers are real memcpys, chunked at `offload_chunk_mb`, and every
//! placement change goes through the [`MemPool`] accountant — the engine
//! physically cannot overcommit the capacities the planner proved. Latest
//! wins: a target published while the worker is mid-pass supersedes the old
//! one at the next shard boundary; rapid phase flips waste at most one
//! shard of work.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::memplane::plan::{ColocationPlan, Phase, Residency};
use crate::memplane::pool::{AllocClass, AllocId, MemPool, Placement};
use crate::trace;
use crate::util::error::{Error, Result};

/// Shared counters for one memplane (lease side + worker side).
#[derive(Debug, Default)]
pub struct OffloadMetrics {
    /// bytes copied device -> host (offloads)
    pub d2h_bytes: AtomicU64,
    /// bytes copied host -> device (prefetches)
    pub h2d_bytes: AtomicU64,
    /// completed shard transfers
    pub shard_moves: AtomicU64,
    /// chunk copies issued (transfer granularity = offload_chunk_mb)
    pub chunks_copied: AtomicU64,
    /// residency targets superseded before the worker converged them
    /// (latest-wins cancellation)
    pub superseded_targets: AtomicU64,
    /// lease/shard residency waits issued
    pub wait_events: AtomicU64,
    /// nanoseconds lease holders spent blocked waiting for residency
    pub wait_nanos: AtomicU64,
    /// waits satisfied instantly because the prefetcher already ran
    pub prefetch_hits: AtomicU64,
}

impl OffloadMetrics {
    /// Total seconds lease holders spent blocked on residency.
    pub fn wait_secs(&self) -> f64 {
        self.wait_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn transferred_bytes(&self) -> u64 {
        self.d2h_bytes.load(Ordering::Relaxed) + self.h2d_bytes.load(Ordering::Relaxed)
    }
}

/// The residency the store must converge to. `residency` is the hard
/// target (lease-derived); `hints` marks retained classes a future phase
/// will need, prefetched opportunistically up to `prefetch_depth` shards
/// per class while free capacity allows.
#[derive(Debug, Clone, Copy)]
pub(crate) struct ResidencyTarget {
    pub seq: u64,
    pub residency: [Residency; 5],
    pub hints: [bool; 5],
    pub prefetch_depth: usize,
}

/// Deterministic fill pattern: transfers must preserve contents bit-exactly
/// (the stress test verifies residency races never tear a shard).
fn pattern(class: usize, shard: usize, i: usize) -> u64 {
    (class as u64 + 1)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add((shard as u64) << 32)
        .wrapping_add(i as u64)
}

struct ClassShard {
    words: Vec<u64>,
    on_device: bool,
    alloc: AllocId,
}

struct ClassState {
    bytes: u64,
    shard_bytes: u64,
    /// retained classes: the data-bearing shards being moved
    shards: Vec<ClassShard>,
    /// transient classes: per-shard scratch allocations (None = dropped);
    /// scratch has no contents to retain, so (re)materialization is an
    /// accounting acquire, not a copy
    transient_allocs: Vec<Option<AllocId>>,
}

impl ClassState {
    fn device_shards(&self) -> usize {
        self.shards.iter().filter(|s| s.on_device).count()
    }

    /// Is shard `idx` device-resident? `idx` past the shard count means
    /// "all of it".
    fn shard_ready(&self, transient: bool, idx: usize) -> bool {
        if self.bytes == 0 {
            return true;
        }
        if transient {
            match self.transient_allocs.get(idx) {
                Some(a) => a.is_some(),
                None => self.transient_allocs.iter().all(|a| a.is_some()),
            }
        } else {
            match self.shards.get(idx) {
                Some(s) => s.on_device,
                None => self.shards.iter().all(|s| s.on_device),
            }
        }
    }
}

struct StoreState {
    classes: Vec<ClassState>,
    target: ResidencyTarget,
    /// the last target seq the worker fully converged
    done_seq: u64,
    shutdown: bool,
    /// a hard failure (pool accounting violation) poisons the plane
    failed: Option<String>,
}

/// One unit of worker work, planned under the lock.
enum Action {
    /// free one transient shard: (class index, shard index)
    FreeTransient(usize, usize),
    /// materialize one transient shard that fits free capacity now
    AcquireTransient(usize, usize),
    /// (class index, shard index, to-device?)
    MoveShard(usize, usize, bool),
}

struct ExecInner {
    pool: Arc<MemPool>,
    /// classes whose waits may count as prefetch hits (the plan parks them
    /// off-device at some phase; always-resident classes never "hit")
    hit_classes: [bool; 5],
    chunk_words: usize,
    state: Mutex<StoreState>,
    /// serializes whole actions (plan + pool accounting + copy): the state
    /// lock is dropped during a chunked copy so waiters and new targets
    /// stay responsive, and this lock keeps a concurrent eager lease from
    /// planning against a shard whose words are mid-flight
    action_lock: Mutex<()>,
    work_cv: Condvar,
    done_cv: Condvar,
    metrics: Arc<OffloadMetrics>,
}

/// The offload engine. With `background` a worker thread converges targets
/// asynchronously; without it, [`OffloadExecutor::apply_target_blocking`]
/// runs the same convergence loop on the caller's thread (the eager
/// baseline the bench compares against).
pub struct OffloadExecutor {
    inner: Arc<ExecInner>,
    worker: Option<JoinHandle<()>>,
}

impl OffloadExecutor {
    /// Materialize the shard store in the plan's `initial` phase residency
    /// and (optionally) spawn the worker. Every shard/scratch allocation is
    /// registered with `pool` — construction fails if the initial residency
    /// does not fit, which cannot happen for a plan the planner admitted.
    ///
    /// `materialize` backs retained shards with real patterned arenas so
    /// transfers are genuine memcpys; accounting-only planes (placements
    /// that never move a retained byte: non-colocated ranks, concurrent
    /// phases) skip the allocation entirely. `hit_classes` marks the
    /// classes whose waits may legitimately count as prefetch hits (the
    /// ones the plan ever parks off-device).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        pool: Arc<MemPool>,
        plan: &ColocationPlan,
        initial: Phase,
        shards_per_class: usize,
        chunk_mb: usize,
        background: bool,
        materialize: bool,
        hit_classes: [bool; 5],
        metrics: Arc<OffloadMetrics>,
    ) -> Result<OffloadExecutor> {
        let n_shards = shards_per_class.max(1);
        let mut classes = Vec::with_capacity(5);
        let mut residency = [Residency::Device; 5];
        for c in AllocClass::ALL {
            let bytes = plan.spec.bytes(c);
            let res = plan.residency(initial, c);
            residency[c.index()] = res;
            let shard_bytes = bytes.div_ceil(n_shards as u64).max(1);
            let mut cs = ClassState {
                bytes,
                shard_bytes,
                shards: Vec::new(),
                transient_allocs: Vec::new(),
            };
            if bytes > 0 {
                let mut left = bytes;
                let mut s = 0usize;
                while left > 0 {
                    let b = left.min(shard_bytes);
                    left -= b;
                    if c.is_transient() {
                        cs.transient_allocs.push(if res == Residency::Device {
                            Some(pool.acquire(c, b, Placement::Device)?)
                        } else {
                            None
                        });
                    } else {
                        let placement = match res {
                            Residency::Host => Placement::Host,
                            _ => Placement::Device,
                        };
                        // accounting-only planes keep the pool bookkeeping
                        // but never back shards with data (their targets
                        // never move a retained byte)
                        let words = if materialize {
                            (0..(b as usize).div_ceil(8))
                                .map(|i| pattern(c.index(), s, i))
                                .collect()
                        } else {
                            Vec::new()
                        };
                        cs.shards.push(ClassShard {
                            words,
                            on_device: placement == Placement::Device,
                            alloc: pool.acquire(c, b, placement)?,
                        });
                    }
                    s += 1;
                }
            }
            classes.push(cs);
        }
        let inner = Arc::new(ExecInner {
            pool,
            hit_classes,
            chunk_words: ((chunk_mb.max(1) as u64 * 1_000_000) / 8) as usize,
            state: Mutex::new(StoreState {
                classes,
                target: ResidencyTarget {
                    seq: 0,
                    residency,
                    hints: [false; 5],
                    prefetch_depth: 0,
                },
                done_seq: 0,
                shutdown: false,
                failed: None,
            }),
            action_lock: Mutex::new(()),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            metrics,
        });
        let worker = if background {
            let w = inner.clone();
            Some(
                std::thread::Builder::new()
                    .name("memplane-offload".into())
                    .spawn(move || worker_loop(&w))
                    .expect("spawn memplane offload worker"),
            )
        } else {
            None
        };
        Ok(OffloadExecutor { inner, worker })
    }

    pub fn is_background(&self) -> bool {
        self.worker.is_some()
    }

    /// Publish a new residency target (latest-wins; returns immediately).
    pub(crate) fn set_target(&self, residency: [Residency; 5], hints: [bool; 5], depth: usize) {
        let mut st = self.inner.state.lock().unwrap();
        if st.target.seq > st.done_seq {
            self.inner
                .metrics
                .superseded_targets
                .fetch_add(1, Ordering::Relaxed);
        }
        st.target = ResidencyTarget {
            seq: st.target.seq + 1,
            residency,
            hints,
            prefetch_depth: depth,
        };
        drop(st);
        self.inner.work_cv.notify_all();
    }

    /// Eager mode: converge the current target on the caller's thread (the
    /// synchronous baseline; a background executor does this for free).
    pub(crate) fn apply_target_blocking(&self) -> Result<()> {
        debug_assert!(self.worker.is_none(), "background plane converges itself");
        while run_one_action(&self.inner)? {}
        Ok(())
    }

    /// Block until shard `idx` of `class` is device-resident (transient
    /// classes: until that scratch shard is materialized); `idx` past the
    /// shard count means the whole class. Counts a prefetch hit when no
    /// blocking was needed; the blocked time is accounted into
    /// [`OffloadMetrics::wait_nanos`].
    pub fn wait_shard(&self, class: AllocClass, idx: usize) -> Result<()> {
        let t0 = Instant::now();
        let _span = trace::span_with(trace::OFFLOAD_WAIT, idx as f64);
        let mut st = self.inner.state.lock().unwrap();
        let mut blocked = false;
        loop {
            if let Some(msg) = &st.failed {
                return Err(Error::Capacity(msg.clone()));
            }
            if st.classes[class.index()].shard_ready(class.is_transient(), idx) {
                break;
            }
            if self.worker.is_none() {
                // eager plane: the caller's lease already converged the
                // target; a miss here means the target does not want this
                // class on device at all
                return Err(Error::Capacity(format!(
                    "wait_shard({}, {idx}) under a target that parks the \
                     class off-device",
                    class.name()
                )));
            }
            blocked = true;
            st = self.inner.done_cv.wait(st).unwrap();
        }
        drop(st);
        let m = &self.inner.metrics;
        m.wait_events.fetch_add(1, Ordering::Relaxed);
        m.wait_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        if !blocked && self.worker.is_some() && self.inner.hit_classes[class.index()] {
            m.prefetch_hits.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Block until every shard of `class` is device-resident.
    pub fn wait_class(&self, class: AllocClass) -> Result<()> {
        self.wait_shard(class, usize::MAX)
    }

    /// Block until the worker has converged the newest target (tests,
    /// benches, shutdown). No-op for an eager plane.
    pub fn flush(&self) -> Result<()> {
        if self.worker.is_none() {
            return Ok(());
        }
        let mut st = self.inner.state.lock().unwrap();
        while st.failed.is_none() && st.done_seq < st.target.seq {
            st = self.inner.done_cv.wait(st).unwrap();
        }
        match &st.failed {
            Some(msg) => Err(Error::Capacity(msg.clone())),
            None => Ok(()),
        }
    }

    /// Fraction of each retained class's shards currently device-resident
    /// (stress tests assert convergence to the planned residency set).
    pub fn device_fracs(&self) -> Vec<(AllocClass, f64)> {
        let st = self.inner.state.lock().unwrap();
        AllocClass::ALL
            .iter()
            .filter(|c| !c.is_transient())
            .map(|c| {
                let cs = &st.classes[c.index()];
                let n = cs.shards.len().max(1);
                (*c, cs.device_shards() as f64 / n as f64)
            })
            .collect()
    }

    /// Verify every retained shard still holds its fill pattern — no
    /// transfer may tear or corrupt contents, whatever the race.
    pub fn verify_integrity(&self) -> Result<()> {
        let st = self.inner.state.lock().unwrap();
        for c in AllocClass::ALL {
            let cs = &st.classes[c.index()];
            for (s, shard) in cs.shards.iter().enumerate() {
                for (i, w) in shard.words.iter().enumerate() {
                    if *w != pattern(c.index(), s, i) {
                        return Err(Error::Capacity(format!(
                            "shard integrity violated: {}[{s}] word {i}",
                            c.name()
                        )));
                    }
                }
            }
        }
        Ok(())
    }
}

impl Drop for OffloadExecutor {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
        }
        self.inner.work_cv.notify_all();
        self.inner.done_cv.notify_all();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// The next device-residency acquisition the target still requires, or
/// None: `(action, bytes)`. Transient scratch first (instant, and lease
/// entry waits on it), then retained H2D in ascending shard order.
fn next_required(st: &StoreState) -> Option<(Action, u64)> {
    let t = &st.target;
    for transient_pass in [true, false] {
        for c in AllocClass::ALL {
            if c.is_transient() != transient_pass
                || t.residency[c.index()] != Residency::Device
            {
                continue;
            }
            let cs = &st.classes[c.index()];
            if c.is_transient() {
                if let Some(idx) = cs.transient_allocs.iter().position(|a| a.is_none()) {
                    return Some((
                        Action::AcquireTransient(c.index(), idx),
                        transient_shard_bytes(cs, idx),
                    ));
                }
            } else if let Some(idx) = cs.shards.iter().position(|s| !s.on_device) {
                return Some((
                    Action::MoveShard(c.index(), idx, true),
                    cs.shards[idx].words.len() as u64 * 8,
                ));
            }
        }
    }
    None
}

/// The next offloadable shard: a device-resident shard of a class the
/// target parks on host, keeping up to `prefetch_depth` shards resident
/// when the class is hinted (unless `ignore_keep`, used to make room for
/// required work). Highest shard first, so prefetch streams back
/// lowest-first.
fn next_evictable(st: &StoreState, ignore_keep: bool) -> Option<Action> {
    let t = &st.target;
    for c in AllocClass::ALL {
        if c.is_transient() || t.residency[c.index()] != Residency::Host {
            continue;
        }
        let cs = &st.classes[c.index()];
        let keep = if t.hints[c.index()] && !ignore_keep {
            t.prefetch_depth
        } else {
            0
        };
        if cs.device_shards() > keep {
            if let Some(idx) = cs.shards.iter().rposition(|s| s.on_device) {
                return Some(Action::MoveShard(c.index(), idx, false));
            }
        }
    }
    None
}

/// Plan the single highest-priority action for the current target, or None
/// when the store already satisfies it. The ordering (module docs) both
/// guarantees capacity — frees and offloads never starve behind
/// acquisitions — and interleaves transient growth (KV) with the offload
/// drain so phase entry is cheap.
fn next_action(st: &StoreState, pool: &MemPool) -> Option<Action> {
    let t = &st.target;
    // 1. free transient scratch the target no longer wants
    for c in AllocClass::ALL {
        let cs = &st.classes[c.index()];
        if c.is_transient() && t.residency[c.index()] != Residency::Device {
            if let Some(idx) = cs.transient_allocs.iter().position(|a| a.is_some()) {
                return Some(Action::FreeTransient(c.index(), idx));
            }
        }
    }
    // 2. required residency, evicting to make room when it does not fit
    if let Some((action, bytes)) = next_required(st) {
        if pool.device_free() >= bytes {
            return Some(action);
        }
        // capacity-blocked: drain a host-parked shard first, overriding
        // any hint-keep (required work always wins over prefetch)
        if let Some(evict) = next_evictable(st, true) {
            return Some(evict);
        }
        // nothing left to evict: by the planner's proof this must fit; a
        // failure in the pool here is a real accounting violation and
        // fails the plane loudly
        return Some(action);
    }
    // 3. drain host-parked classes down to their hint-keep watermark
    next_evictable(st, false)
}

fn transient_shard_bytes(cs: &ClassState, idx: usize) -> u64 {
    // the last shard may be smaller than shard_bytes
    let full = cs.shard_bytes;
    let before = full * idx as u64;
    (cs.bytes - before).min(full)
}

/// Opportunistic hint prefetch: one more shard of a hinted class, bounded
/// by depth and free device capacity. Separate from [`next_action`] so a
/// capacity miss here never fails the plane.
fn next_hint(st: &StoreState, pool: &MemPool) -> Option<Action> {
    let t = &st.target;
    for c in AllocClass::ALL {
        if c.is_transient() || !t.hints[c.index()] {
            continue;
        }
        let cs = &st.classes[c.index()];
        if t.residency[c.index()] == Residency::Device {
            continue; // already a hard requirement
        }
        if cs.device_shards() >= t.prefetch_depth {
            continue;
        }
        if let Some(idx) = cs.shards.iter().position(|s| !s.on_device) {
            if pool.device_free() >= cs.shards[idx].words.len() as u64 * 8 {
                return Some(Action::MoveShard(c.index(), idx, true));
            }
        }
    }
    None
}

/// Execute one planned action; returns whether anything was done. Chunked
/// copies drop the lock between chunks' bookkeeping so waiters and new
/// targets are never stuck behind a transfer.
fn run_one_action(inner: &ExecInner) -> Result<bool> {
    let _serial = inner.action_lock.lock().unwrap();
    let mut st = inner.state.lock().unwrap();
    if let Some(msg) = &st.failed {
        return Err(Error::Capacity(msg.clone()));
    }
    let action = next_action(&st, &inner.pool).or_else(|| next_hint(&st, &inner.pool));
    let Some(action) = action else {
        return Ok(false);
    };
    match action {
        Action::FreeTransient(ci, idx) => {
            let alloc = st.classes[ci].transient_allocs[idx].take().expect("planned");
            inner.pool.release(alloc)?;
        }
        Action::AcquireTransient(ci, idx) => {
            let class = AllocClass::ALL[ci];
            let bytes = transient_shard_bytes(&st.classes[ci], idx);
            let alloc = inner.pool.acquire(class, bytes, Placement::Device)?;
            st.classes[ci].transient_allocs[idx] = Some(alloc);
        }
        Action::MoveShard(ci, idx, to_device) => {
            let shard = &mut st.classes[ci].shards[idx];
            let alloc = shard.alloc;
            // accounting first: the pool refuses moves that would
            // overcommit the target tier, before any byte is copied
            inner.pool.relocate(
                alloc,
                if to_device {
                    Placement::Device
                } else {
                    Placement::Host
                },
            )?;
            let src = std::mem::take(&mut shard.words);
            drop(st);
            // the transfer itself: chunked copy into the destination tier
            let _span = trace::span_with(
                if to_device {
                    trace::OFFLOAD_H2D
                } else {
                    trace::OFFLOAD_D2H
                },
                idx as f64,
            );
            let mut dst: Vec<u64> = Vec::with_capacity(src.len());
            for chunk in src.chunks(inner.chunk_words.max(1)) {
                dst.extend_from_slice(chunk);
                inner.metrics.chunks_copied.fetch_add(1, Ordering::Relaxed);
            }
            let bytes = dst.len() as u64 * 8;
            let m = &inner.metrics;
            if to_device {
                m.h2d_bytes.fetch_add(bytes, Ordering::Relaxed);
            } else {
                m.d2h_bytes.fetch_add(bytes, Ordering::Relaxed);
            }
            m.shard_moves.fetch_add(1, Ordering::Relaxed);
            st = inner.state.lock().unwrap();
            let shard = &mut st.classes[ci].shards[idx];
            shard.words = dst;
            shard.on_device = to_device;
        }
    }
    drop(st);
    inner.done_cv.notify_all();
    Ok(true)
}

fn worker_loop(inner: &ExecInner) {
    loop {
        match run_one_action(inner) {
            Ok(true) => continue,
            Ok(false) => {
                let mut st = inner.state.lock().unwrap();
                // a target may have raced in between the action scan and
                // this lock — re-check BEFORE declaring convergence, so
                // done_seq never runs ahead of actual residency
                if next_action(&st, &inner.pool).is_some()
                    || next_hint(&st, &inner.pool).is_some()
                {
                    continue;
                }
                if st.done_seq < st.target.seq {
                    st.done_seq = st.target.seq;
                    inner.done_cv.notify_all();
                }
                if st.shutdown {
                    return;
                }
                let _st = inner.work_cv.wait(st).unwrap();
            }
            Err(e) => {
                let mut st = inner.state.lock().unwrap();
                st.failed = Some(e.to_string());
                inner.done_cv.notify_all();
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memplane::plan::plan_colocation;
    use crate::memplane::pool::MemSpec;

    const MB: u64 = 1_000_000;

    fn tight_plan() -> (ColocationPlan, Arc<MemPool>) {
        let spec = MemSpec::new(8 * MB, 8 * MB, 16 * MB, 24 * MB, 8 * MB);
        let plan = plan_colocation(
            spec,
            48 * MB,
            64 * MB,
            true,
            false,
            &[AllocClass::Grads, AllocClass::OptimState],
        )
        .unwrap();
        let pool = Arc::new(MemPool::new(plan.device_cap, plan.host_cap));
        (plan, pool)
    }

    fn residency_of(plan: &ColocationPlan, p: Phase) -> [Residency; 5] {
        let mut r = [Residency::Device; 5];
        for c in AllocClass::ALL {
            r[c.index()] = plan.residency(p, c);
        }
        r
    }

    #[test]
    fn background_converges_phase_flips() {
        let (plan, pool) = tight_plan();
        let metrics = Arc::new(OffloadMetrics::default());
        let exec = OffloadExecutor::new(
            pool.clone(),
            &plan,
            Phase::Sync,
            4,
            1,
            true,
            true,
            [true; 5],
            metrics.clone(),
        )
        .unwrap();
        for _ in 0..3 {
            exec.set_target(residency_of(&plan, Phase::Generate), [false; 5], 0);
            exec.wait_class(AllocClass::KvCache).unwrap();
            exec.flush().unwrap();
            assert_eq!(pool.device_bytes_of(AllocClass::OptimState), 0);
            exec.set_target(residency_of(&plan, Phase::Train), [false; 5], 0);
            exec.wait_class(AllocClass::OptimState).unwrap();
            exec.flush().unwrap();
            assert_eq!(pool.device_bytes_of(AllocClass::OptimState), 16 * MB);
        }
        exec.verify_integrity().unwrap();
        assert!(metrics.d2h_bytes.load(Ordering::Relaxed) >= 3 * 16 * MB);
        assert!(pool.usage().device_used <= pool.device_cap);
    }

    #[test]
    fn eager_plane_converges_synchronously() {
        let (plan, pool) = tight_plan();
        let metrics = Arc::new(OffloadMetrics::default());
        let exec =
            OffloadExecutor::new(pool, &plan, Phase::Train, 4, 1, false, true, [true; 5], metrics)
                .unwrap();
        exec.set_target(residency_of(&plan, Phase::Generate), [false; 5], 0);
        exec.apply_target_blocking().unwrap();
        exec.wait_class(AllocClass::KvCache).unwrap();
        // optimizer state is off-device now; waiting on it must be refused
        // (an eager plane has nobody to bring it back)
        assert!(exec.wait_shard(AllocClass::OptimState, 0).is_err());
        exec.verify_integrity().unwrap();
    }

    #[test]
    fn hints_prefetch_within_depth_and_capacity() {
        let (plan, pool) = tight_plan();
        let metrics = Arc::new(OffloadMetrics::default());
        let exec = OffloadExecutor::new(
            pool.clone(),
            &plan,
            Phase::Generate,
            8,
            1,
            true,
            true,
            [true; 5],
            metrics.clone(),
        )
        .unwrap();
        exec.flush().unwrap();
        assert_eq!(pool.device_bytes_of(AllocClass::OptimState), 0);
        // hint the optimizer back in, but only 2 shards deep
        let mut hints = [false; 5];
        hints[AllocClass::OptimState.index()] = true;
        exec.set_target(residency_of(&plan, Phase::Generate), hints, 2);
        exec.flush().unwrap();
        let frac = exec
            .device_fracs()
            .into_iter()
            .find(|(c, _)| *c == AllocClass::OptimState)
            .unwrap()
            .1;
        assert!((frac - 0.25).abs() < 1e-9, "2 of 8 shards, got {frac}");
        assert!(pool.usage().device_used <= pool.device_cap);
        exec.verify_integrity().unwrap();
    }

    #[test]
    fn transient_growth_interleaves_with_offload() {
        // generate-phase KV (24 MB) cannot fit until optimizer shards
        // drain; shard-granular interleave must still make shard 0 of KV
        // available long before the full D2H completes
        let (plan, pool) = tight_plan();
        let metrics = Arc::new(OffloadMetrics::default());
        let exec = OffloadExecutor::new(
            pool.clone(),
            &plan,
            Phase::Train,
            8,
            1,
            true,
            true,
            [true; 5],
            metrics.clone(),
        )
        .unwrap();
        exec.set_target(residency_of(&plan, Phase::Generate), [false; 5], 0);
        exec.wait_shard(AllocClass::KvCache, 0).unwrap();
        // shard 0 of KV is live; the optimizer drain may still be running
        exec.flush().unwrap();
        exec.wait_class(AllocClass::KvCache).unwrap();
        assert_eq!(pool.device_bytes_of(AllocClass::KvCache), 24 * MB);
        assert_eq!(pool.device_bytes_of(AllocClass::OptimState), 0);
        exec.verify_integrity().unwrap();
    }
}
