//! Per-rank memory pools with tracked allocation classes and hard-capacity
//! accounting.
//!
//! Every byte the colocated stack puts on a GPU belongs to one
//! [`AllocClass`] — the paper's Table-2 memory model made explicit: trainer
//! weights, gradients, optimizer state and activation scratch, plus the
//! generator's KV cache. A [`MemPool`] tracks live allocations against a
//! hard device (HBM) and host (DRAM) capacity: `acquire` on a full pool
//! returns [`Error::Capacity`] instead of overcommitting, `release` of an
//! unknown handle is a double-free error instead of a silent no-op. The
//! colocation planner ([`crate::memplane::plan`]) proves a placement fits
//! before the executor moves a byte; the pool is the runtime enforcement of
//! that proof.
//!
//! [`MemSpec`] derives per-rank class sizes from the same quantities the
//! cluster cost model uses ([`crate::simulator::hardware`]): weights are
//! `W0/mp`, the 4x-W0 trainer footprint splits into weights + grads + two
//! f32 optimizer moments, KV scales with decode concurrency and activation
//! scratch with the microbatch.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::simulator::hardware::HardwareModel;
use crate::util::error::{Error, Result};

/// Tracked allocation classes (the rows of the paper's Table-2 memory
/// model). `is_transient` classes hold scratch that is *dropped* at a phase
/// boundary (freed and re-materialized, nothing to copy); the others hold
/// state that must be *retained* — offloading them means a D2H copy and a
/// later H2D prefetch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum AllocClass {
    /// model weights (needed by both trainer and generator phases)
    Params,
    /// gradient buffer (train phase)
    Grads,
    /// optimizer moments (train phase; the dominant offload payload)
    OptimState,
    /// generator KV cache (generate phase; transient — rebuilt per batch)
    KvCache,
    /// trainer activation scratch (train phase; transient)
    ActivationSlack,
}

impl AllocClass {
    pub const ALL: [AllocClass; 5] = [
        AllocClass::Params,
        AllocClass::Grads,
        AllocClass::OptimState,
        AllocClass::KvCache,
        AllocClass::ActivationSlack,
    ];

    pub fn name(self) -> &'static str {
        match self {
            AllocClass::Params => "params",
            AllocClass::Grads => "grads",
            AllocClass::OptimState => "optim",
            AllocClass::KvCache => "kv",
            AllocClass::ActivationSlack => "act",
        }
    }

    /// Parse one class name (config/CLI): `params|grads|optim|kv|act`.
    pub fn parse(s: &str) -> Result<AllocClass> {
        match s.trim() {
            "params" => Ok(AllocClass::Params),
            "grads" => Ok(AllocClass::Grads),
            "optim" | "optimizer" => Ok(AllocClass::OptimState),
            "kv" | "kv_cache" => Ok(AllocClass::KvCache),
            "act" | "activations" => Ok(AllocClass::ActivationSlack),
            other => Err(Error::Config(format!(
                "unknown allocation class '{other}' (use params|grads|optim|kv|act)"
            ))),
        }
    }

    /// Parse a comma-separated class list, e.g. `"grads,optim"`.
    pub fn parse_list(s: &str) -> Result<Vec<AllocClass>> {
        s.split(',')
            .filter(|p| !p.trim().is_empty())
            .map(AllocClass::parse)
            .collect()
    }

    /// Transient classes are scratch: dropped (freed) when their phase
    /// ends, re-materialized when it resumes — no transfer bytes.
    pub fn is_transient(self) -> bool {
        matches!(self, AllocClass::KvCache | AllocClass::ActivationSlack)
    }

    pub(crate) fn index(self) -> usize {
        self as usize
    }
}

/// Where an allocation currently lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    Device,
    Host,
}

/// Per-rank byte sizes of every allocation class.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemSpec {
    /// bytes per class, indexed by [`AllocClass::index`]
    pub class_bytes: [u64; 5],
}

impl MemSpec {
    pub fn new(params: u64, grads: u64, optim: u64, kv: u64, act: u64) -> MemSpec {
        MemSpec {
            class_bytes: [params, grads, optim, kv, act],
        }
    }

    pub fn bytes(&self, class: AllocClass) -> u64 {
        self.class_bytes[class.index()]
    }

    pub fn total(&self) -> u64 {
        self.class_bytes.iter().sum()
    }

    pub fn sum<I: IntoIterator<Item = AllocClass>>(&self, classes: I) -> u64 {
        classes.into_iter().map(|c| self.bytes(c)).sum()
    }

    /// Per-rank spec at paper scale: the trainer's 4x-W0 footprint split
    /// into weights (`W0/mp`) + grads (`W0/mp`) + two f32 optimizer
    /// moments (`2*W0/mp`), the generator KV cache at decode concurrency
    /// `bg`, and activation scratch at microbatch `bt` — all sharded over
    /// the model-parallel degree `mp`.
    pub fn paper_rank(hw: &HardwareModel, mp: f64, bt: f64, bg: f64) -> MemSpec {
        let per = |b: f64| (b / mp).ceil().max(0.0) as u64;
        MemSpec::new(
            per(hw.w0_bytes()),
            per(hw.w0_bytes()),
            per(2.0 * hw.w0_bytes()),
            per(hw.kv_bytes_per_seq() * bg),
            per(hw.act_bytes_per_sample() * bt),
        )
    }

    /// Testbed-scale spec derived from the artifact's flat f32 parameter
    /// vector: weights + grads at 4 bytes/param, two f32 optimizer moments,
    /// KV proportional to the decode batch and activations to the train
    /// batch. Small by construction — the coordinator materializes these
    /// arenas for real.
    pub fn testbed(num_params: usize, train_batch: usize, gen_batch: usize) -> MemSpec {
        let p = num_params as u64 * 4;
        MemSpec::new(
            p,
            p,
            2 * p,
            (p / 2).max(1) * gen_batch.max(1) as u64 / 4,
            (p / 2).max(1) * train_batch.max(1) as u64 / 4,
        )
    }
}

/// Opaque handle to one live allocation (release exactly once).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct AllocId(u64);

#[derive(Debug, Clone, Copy)]
struct Allocation {
    class: AllocClass,
    bytes: u64,
    placement: Placement,
}

#[derive(Debug, Default)]
struct PoolState {
    device_used: u64,
    host_used: u64,
    next_id: u64,
    live: BTreeMap<u64, Allocation>,
}

/// Point-in-time usage snapshot.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolUsage {
    pub device_used: u64,
    pub host_used: u64,
    pub live_allocs: usize,
}

/// One rank's memory accountant: hard device + host capacities, tracked
/// live allocations. All methods are thread-safe (the offload executor's
/// worker and lease holders share one pool).
#[derive(Debug)]
pub struct MemPool {
    pub device_cap: u64,
    pub host_cap: u64,
    state: Mutex<PoolState>,
}

impl MemPool {
    pub fn new(device_cap: u64, host_cap: u64) -> MemPool {
        MemPool {
            device_cap,
            host_cap,
            state: Mutex::new(PoolState::default()),
        }
    }

    /// Reserve `bytes` for `class` at `placement`. Hard-capacity: a pool
    /// that cannot fit the request errors instead of overcommitting.
    pub fn acquire(&self, class: AllocClass, bytes: u64, placement: Placement) -> Result<AllocId> {
        let mut st = self.state.lock().unwrap();
        let (used, cap, where_) = match placement {
            Placement::Device => (&mut st.device_used, self.device_cap, "device"),
            Placement::Host => (&mut st.host_used, self.host_cap, "host"),
        };
        if used.saturating_add(bytes) > cap {
            return Err(Error::Capacity(format!(
                "{} pool overflow acquiring {bytes} B for {}: {} of {cap} B in use",
                where_,
                class.name(),
                *used,
            )));
        }
        *used += bytes;
        let id = st.next_id;
        st.next_id += 1;
        st.live.insert(
            id,
            Allocation {
                class,
                bytes,
                placement,
            },
        );
        Ok(AllocId(id))
    }

    /// Free a live allocation. Releasing an unknown (already-freed) handle
    /// is a double-free error.
    pub fn release(&self, id: AllocId) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        let alloc = st.live.remove(&id.0).ok_or_else(|| {
            Error::Capacity(format!("double free: allocation {} is not live", id.0))
        })?;
        match alloc.placement {
            Placement::Device => st.device_used -= alloc.bytes,
            Placement::Host => st.host_used -= alloc.bytes,
        }
        Ok(())
    }

    /// Move a live allocation to the other tier (the accounting half of an
    /// offload/prefetch: capacity is checked on the target side first, so a
    /// relocation can never overcommit either tier).
    pub fn relocate(&self, id: AllocId, to: Placement) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        let alloc = *st.live.get(&id.0).ok_or_else(|| {
            Error::Capacity(format!("relocate of dead allocation {}", id.0))
        })?;
        if alloc.placement == to {
            return Ok(());
        }
        let (used, cap, where_) = match to {
            Placement::Device => (st.device_used, self.device_cap, "device"),
            Placement::Host => (st.host_used, self.host_cap, "host"),
        };
        if used.saturating_add(alloc.bytes) > cap {
            return Err(Error::Capacity(format!(
                "{} pool overflow relocating {} B of {}: {} of {cap} B in use",
                where_,
                alloc.bytes,
                alloc.class.name(),
                used,
            )));
        }
        match alloc.placement {
            Placement::Device => st.device_used -= alloc.bytes,
            Placement::Host => st.host_used -= alloc.bytes,
        }
        match to {
            Placement::Device => st.device_used += alloc.bytes,
            Placement::Host => st.host_used += alloc.bytes,
        }
        st.live.get_mut(&id.0).unwrap().placement = to;
        Ok(())
    }

    pub fn usage(&self) -> PoolUsage {
        let st = self.state.lock().unwrap();
        PoolUsage {
            device_used: st.device_used,
            host_used: st.host_used,
            live_allocs: st.live.len(),
        }
    }

    pub fn device_free(&self) -> u64 {
        self.device_cap - self.state.lock().unwrap().device_used
    }

    /// Device bytes currently held by `class`.
    pub fn device_bytes_of(&self, class: AllocClass) -> u64 {
        let st = self.state.lock().unwrap();
        st.live
            .values()
            .filter(|a| a.class == class && a.placement == Placement::Device)
            .map(|a| a.bytes)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_release_roundtrip() {
        let pool = MemPool::new(100, 50);
        let a = pool.acquire(AllocClass::Params, 60, Placement::Device).unwrap();
        let b = pool.acquire(AllocClass::Grads, 40, Placement::Device).unwrap();
        assert_eq!(pool.usage().device_used, 100);
        assert!(pool
            .acquire(AllocClass::KvCache, 1, Placement::Device)
            .is_err());
        pool.release(a).unwrap();
        assert_eq!(pool.usage().device_used, 40);
        pool.release(b).unwrap();
        assert_eq!(pool.usage(), PoolUsage::default());
    }

    #[test]
    fn double_free_is_an_error() {
        let pool = MemPool::new(10, 10);
        let a = pool.acquire(AllocClass::Params, 5, Placement::Host).unwrap();
        pool.release(a).unwrap();
        assert!(matches!(pool.release(a), Err(Error::Capacity(_))));
    }

    #[test]
    fn relocate_checks_target_capacity() {
        let pool = MemPool::new(100, 30);
        let a = pool
            .acquire(AllocClass::OptimState, 60, Placement::Device)
            .unwrap();
        // host side only holds 30 — relocation must refuse, leaving the
        // allocation untouched on device
        assert!(pool.relocate(a, Placement::Host).is_err());
        assert_eq!(pool.usage().device_used, 60);
        assert_eq!(pool.usage().host_used, 0);
        let small = pool
            .acquire(AllocClass::Grads, 20, Placement::Device)
            .unwrap();
        pool.relocate(small, Placement::Host).unwrap();
        assert_eq!(pool.usage().device_used, 60);
        assert_eq!(pool.usage().host_used, 20);
        assert_eq!(pool.device_bytes_of(AllocClass::Grads), 0);
        pool.release(a).unwrap();
        pool.release(small).unwrap();
    }

    #[test]
    fn class_names_roundtrip() {
        for c in AllocClass::ALL {
            assert_eq!(AllocClass::parse(c.name()).unwrap(), c);
        }
        assert!(AllocClass::parse("hbm").is_err());
        assert_eq!(
            AllocClass::parse_list("grads, optim").unwrap(),
            vec![AllocClass::Grads, AllocClass::OptimState]
        );
        assert!(AllocClass::parse_list("").unwrap().is_empty());
    }

    #[test]
    fn paper_rank_spec_matches_4x_w0() {
        let hw = HardwareModel::paper_scale(crate::simulator::hardware::LLAMA_MODELS[1]);
        let spec = MemSpec::paper_rank(&hw, 8.0, 8.0, 16.0);
        // weights + grads + optim = 4 * W0 / mp (the paper's trainer row)
        let four_w0 = spec.bytes(AllocClass::Params)
            + spec.bytes(AllocClass::Grads)
            + spec.bytes(AllocClass::OptimState);
        let want = (4.0 * hw.w0_bytes() / 8.0) as u64;
        assert!((four_w0 as i64 - want as i64).unsigned_abs() <= 4);
        assert!(spec.bytes(AllocClass::KvCache) > 0);
        assert!(spec.bytes(AllocClass::ActivationSlack) > 0);
    }
}
