//! # LlamaRL (reproduction)
//!
//! A fully-distributed, asynchronous reinforcement-learning framework for
//! LLM post-training, reproducing *LlamaRL* (Meta GenAI, 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's system contribution: a single
//!   controller that resolves a declarative execution graph
//!   ([`coordinator::graph`]) of [`coordinator::Executor`] fleets over
//!   [`coordinator::channel`]s, with the asynchronous off-policy
//!   pipeline, [`ddma`] weight synchronization, partial rollouts, the
//!   synchronous DeepSpeed-Chat-like baseline (the same graph, stepped),
//!   and a [`simulator`] that re-derives the paper's H100-scale
//!   evaluation from its own cost model.
//! * **L2/L1 (build-time Python)** — `python/compile/` lowers the policy
//!   model (JAX) and its Pallas kernels (fused AIPO loss, decode attention)
//!   once into `artifacts/<config>/*.hlo.txt`; the [`runtime`] loads and
//!   executes them via PJRT. Python is never on the hot path.
//!
//! The crate is organised bottom-up:
//!
//! | layer | modules |
//! |---|---|
//! | substrates | [`util`] (json / cli / rng / stats / prop / bench — the offline vendor set has no serde/clap/rand/proptest/criterion) |
//! | runtime | [`runtime`] (PJRT artifact loading & execution), [`model`] (flat params, tokenizer, checkpoints, quantization) |
//! | RL | [`data`] (synthetic verifiable-reward tasks), [`rl`] (advantages, trajectories, AIPO config) |
//! | data plane | [`dataplane`] (staleness-aware rollout store: admission/eviction policies, sampling strategies, partial-rollout resumption, lag telemetry) |
//! | weight plane | [`weightsync`] (FSDP/TP shard layouts, bandwidth-balanced resharding planner, f32/int8/delta(+RLE)/top-k/adaptive-auto per-shard transfer, generation-overlapped double-buffered swap, background per-link-group streaming executor) |
//! | memory plane | [`memplane`] (per-rank HBM/host pool accounting over tracked allocation classes, phase-aware colocation planner with hard-capacity rejection, background offload/prefetch executor behind the phase-lease protocol) |
//! | system | [`coordinator`] (executors, channels, and the single-controller execution graph: declarative `NodeSpec`/`EdgeSpec` topologies per mode — sync / async / async_buffered / periodic — one generic `Graph::launch` runtime, `TelemetryHub` report assembly, reward fleets over group-routed channels with re-routable consumer slots, data-parallel trainer fleets with round-robin step partitioning and a period fence), [`ddma`] (the DDMA facade over [`weightsync`] + cluster link models, per-publisher coalescing on the streaming executor) |
//! | observability | [`trace`] (per-thread lock-free span/instant recorder, background collector → streaming JSONL event log, Chrome Trace Event Format export, periodic live telemetry snapshots — all four planes instrumented), [`journal`] (durable run-journal: snapshot records + streaming pull reader → crash-resume and deterministic replay), [`analysis`] (`llamarl analyze`: streaming log-bucketed span histograms, blocked-time attribution, per-step critical-path extraction, measured-vs-DES divergence) |
//! | evaluation | [`simulator`] (memory/cost models, Theorem 7.5 optimizer, discrete-event timelines), [`metrics`] |

pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dataplane;
pub mod ddma;
pub mod journal;
pub mod memplane;
pub mod metrics;
pub mod model;
pub mod rl;
pub mod runtime;
pub mod simulator;
pub mod trace;
pub mod util;
pub mod weightsync;

pub use util::error::{Error, Result};
