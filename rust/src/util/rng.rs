//! Deterministic PRNG (rand is not in the offline vendor set).
//!
//! PCG-XSH-RR 64/32 core with convenience samplers. Determinism matters:
//! the property-test harness ([`crate::util::prop`]) and the discrete-event
//! simulator both replay seeds for debugging.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// cached second normal from Box-Muller
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng {
            state: 0,
            inc: (seed << 1) | 1,
            spare_normal: None,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(0x9E3779B97F4A7C15 ^ seed);
        rng.next_u32();
        rng
    }

    /// Derive an independent stream (for per-worker rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xD1342543DE82EF95))
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo, "empty range");
        let span = (hi - lo) as u64;
        lo + (self.next_u64() % span) as i64
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as i64, hi as i64) as usize
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Log-normal with given mean/sigma of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate lambda.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len())]
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_looks_uniform() {
        let mut rng = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn range_bounds() {
        let mut rng = Rng::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..1000 {
            let x = rng.range(3, 7);
            assert!((3..7).contains(&x));
            seen_lo |= x == 3;
            seen_hi |= x == 6;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(13);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
