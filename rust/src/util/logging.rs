//! Leveled stderr logging + JSONL metric writers (env_logger/serde are not
//! in the offline vendor set).
//!
//! Level comes from `LLAMARL_LOG` (off|error|warn|info|debug|trace),
//! default `info`; an unrecognized value falls back to `info` with a
//! one-time warning. The JSONL writer is what examples/benches use to
//! persist curves for EXPERIMENTS.md.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, Once};
use std::time::{SystemTime, UNIX_EPOCH};

use crate::util::error::Result;
use crate::util::json::Value;

#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

/// Sentinel: level not yet resolved from the environment.
const LEVEL_UNSET: u8 = 255;
/// Sentinel: logging disabled entirely (`LLAMARL_LOG=off`).
const LEVEL_OFF: u8 = 254;

static LEVEL: AtomicU8 = AtomicU8::new(LEVEL_UNSET);
static BAD_SPEC_WARNING: Once = Once::new();

/// Map a `LLAMARL_LOG` spec to the stored level byte. `None` means the
/// spec was not recognized (caller warns once and falls back to info).
fn parse_spec(spec: &str) -> Option<u8> {
    match spec {
        "off" => Some(LEVEL_OFF),
        "error" => Some(Level::Error as u8),
        "warn" => Some(Level::Warn as u8),
        "info" => Some(Level::Info as u8),
        "debug" => Some(Level::Debug as u8),
        "trace" => Some(Level::Trace as u8),
        _ => None,
    }
}

fn level() -> u8 {
    let cur = LEVEL.load(Ordering::Relaxed);
    if cur != LEVEL_UNSET {
        return cur;
    }
    let parsed = match std::env::var("LLAMARL_LOG").as_deref() {
        Ok(spec) => parse_spec(spec).unwrap_or_else(|| {
            BAD_SPEC_WARNING.call_once(|| {
                eprintln!(
                    "[WARN llamarl::logging] unrecognized LLAMARL_LOG={spec:?} \
                     (expected off|error|warn|info|debug|trace); using info"
                );
            });
            Level::Info as u8
        }),
        Err(_) => Level::Info as u8,
    };
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    let cur = level();
    cur != LEVEL_OFF && (l as u8) <= cur
}

pub fn log(l: Level, target: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let t = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{:>10}.{:03} {} {}] {}", t.as_secs(), t.subsec_millis(), tag, target, msg);
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target,
                                   &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target,
                                   &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target,
                                   &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, $target,
                                   &format!($($arg)*))
    };
}

/// Append-only JSONL metrics file, safe to share across executor threads.
pub struct JsonlWriter {
    inner: Mutex<BufWriter<File>>,
}

impl JsonlWriter {
    pub fn create(path: impl AsRef<Path>) -> Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let f = File::create(path)?;
        Ok(JsonlWriter {
            inner: Mutex::new(BufWriter::new(f)),
        })
    }

    pub fn write(&self, v: &Value) -> Result<()> {
        let mut w = self.inner.lock().unwrap();
        writeln!(w, "{}", v.to_string())?;
        w.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parser_accepts_all_levels_and_off() {
        assert_eq!(parse_spec("off"), Some(LEVEL_OFF));
        assert_eq!(parse_spec("error"), Some(Level::Error as u8));
        assert_eq!(parse_spec("warn"), Some(Level::Warn as u8));
        assert_eq!(parse_spec("info"), Some(Level::Info as u8));
        assert_eq!(parse_spec("debug"), Some(Level::Debug as u8));
        assert_eq!(parse_spec("trace"), Some(Level::Trace as u8));
        assert_eq!(parse_spec("verbose"), None);
        assert_eq!(parse_spec(""), None);
        assert_eq!(parse_spec("INFO"), None); // specs are case-sensitive
    }

    #[test]
    fn off_level_disables_every_tier() {
        // set_level/enabled go through the same atomic the env parser
        // fills in; drive the OFF sentinel directly to keep the test
        // independent of the process environment
        let prev = LEVEL.swap(LEVEL_OFF, Ordering::Relaxed);
        assert!(!enabled(Level::Error));
        assert!(!enabled(Level::Trace));
        LEVEL.store(prev, Ordering::Relaxed);
    }

    #[test]
    fn jsonl_roundtrip() {
        let dir = std::env::temp_dir().join("llamarl_log_test");
        let path = dir.join("m.jsonl");
        let w = JsonlWriter::create(&path).unwrap();
        w.write(&Value::object(vec![("step", Value::num(1.0))])).unwrap();
        w.write(&Value::object(vec![("step", Value::num(2.0))])).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<_> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(
            Value::parse(lines[1]).unwrap().req_f64("step").unwrap(),
            2.0
        );
    }
}
