//! Substrate utilities built in-repo because the offline crate universe
//! (the `xla` crate's vendored dependency closure) lacks the usual
//! ecosystem crates. Each submodule replaces one of them:
//!
//! | module | replaces | used for |
//! |---|---|---|
//! | [`json`] | serde_json | artifact manifests, configs, metric logs |
//! | [`cli`] | clap | the `llamarl` binary and examples |
//! | [`rng`] | rand | sampling prompts, seeds, property tests |
//! | [`prop`] | proptest | coordinator/simulator invariant tests |
//! | [`bench`] | criterion | the `cargo bench` harnesses |
//! | [`stats`] | — | calibration fits, percentiles |
//! | [`logging`] | env_logger | leveled logs + JSONL metric writers |

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
