//! Property-testing harness (proptest is not in the offline vendor set).
//!
//! A case-based runner: each property receives a seeded [`Rng`]-backed
//! [`Gen`] and asserts its invariant; failures report the failing seed so
//! the case replays deterministically. Simpler than proptest (no automatic
//! shrinking — generators are written to produce small cases first, which
//! covers most of shrinking's value in practice).
//!
//! ```no_run
//! // (no_run: doctest binaries don't get the xla rpath in this image)
//! use llamarl::util::prop::{run_prop, Gen};
//! run_prop("add_commutes", 200, |g: &mut Gen| {
//!     let a = g.i64(-100, 100);
//!     let b = g.i64(-100, 100);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::rng::Rng;

pub struct Gen {
    pub rng: Rng,
    /// case index in [0, cases): generators use it to grow sizes gradually
    pub case: usize,
    pub cases: usize,
}

impl Gen {
    /// A size hint that ramps from `lo` to `hi` over the run, so early cases
    /// are small (easy to debug) and later cases stress-test.
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        let ramp_max = lo + (hi - lo) * (self.case + 1) / self.cases.max(1);
        self.rng.range_usize(lo, ramp_max.max(lo) + 1)
    }

    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range(lo, hi + 1)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_usize(lo, hi + 1)
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64(lo, hi)).collect()
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choice(xs)
    }
}

/// Run `cases` seeded cases of `prop`. Panics (with the failing seed) on the
/// first failure. Honors `LLAMARL_PROP_SEED` to replay a single case.
pub fn run_prop<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    name: &str,
    cases: usize,
    prop: F,
) {
    if let Ok(seed) = std::env::var("LLAMARL_PROP_SEED") {
        let seed: u64 = seed.parse().expect("LLAMARL_PROP_SEED must be a u64");
        let mut g = Gen {
            rng: Rng::new(seed),
            case: 0,
            cases: 1,
        };
        prop(&mut g);
        return;
    }
    let base = 0xC0FFEE ^ fxhash(name);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen {
                rng: Rng::new(seed),
                case,
                cases,
            };
            prop(&mut g);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {case}/{cases} \
                 (replay with LLAMARL_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        run_prop("sum_nonneg", 50, |g| {
            let n = g.size(0, 20);
            let xs = g.vec_f64(n, 0.0, 1.0);
            assert!(xs.iter().sum::<f64>() >= 0.0);
        });
    }

    #[test]
    #[should_panic(expected = "replay with LLAMARL_PROP_SEED=")]
    fn failing_property_reports_seed() {
        run_prop("always_fails_eventually", 50, |g| {
            assert!(g.i64(0, 10) < 10, "hit the bound");
        });
    }

    #[test]
    fn size_ramps() {
        let mut g = Gen {
            rng: Rng::new(1),
            case: 0,
            cases: 100,
        };
        for _ in 0..50 {
            assert!(g.size(0, 100) <= 1);
        }
        let mut g_late = Gen {
            rng: Rng::new(1),
            case: 99,
            cases: 100,
        };
        let max_seen = (0..50).map(|_| g_late.size(0, 100)).max().unwrap();
        assert!(max_seen > 50);
    }
}
