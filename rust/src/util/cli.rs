//! Minimal CLI argument parser (clap is not in the offline vendor set).
//!
//! Grammar: `prog [subcommand] [--key value | --flag] [positional...]`.
//! Values for known boolean flags are not consumed; everything else after
//! `--key` is treated as that key's value.

use std::collections::BTreeMap;

use crate::util::error::{Error, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    /// `bool_flags` lists options that never take a value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, bool_flags: &[&str]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        // first bare word = subcommand
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.subcommand = Some(it.next().unwrap());
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // --key=value form
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                    continue;
                }
                if bool_flags.contains(&name) {
                    args.flags.push(name.to_string());
                    continue;
                }
                match it.next() {
                    Some(v) if !v.starts_with("--") => {
                        args.options.insert(name.to_string(), v);
                    }
                    Some(v) => {
                        return Err(Error::Cli(format!(
                            "option --{name} expects a value, got '{v}'"
                        )))
                    }
                    None => {
                        return Err(Error::Cli(format!("option --{name} expects a value")))
                    }
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn from_env(bool_flags: &[&str]) -> Result<Args> {
        Args::parse(std::env::args().skip(1), bool_flags)
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Cli(format!("--{key} expects an integer, got '{v}'"))),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Cli(format!("--{key} expects an integer, got '{v}'"))),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Cli(format!("--{key} expects a number, got '{v}'"))),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str, flags: &[&str]) -> Args {
        Args::parse(s.split_whitespace().map(String::from), flags).unwrap()
    }

    #[test]
    fn basic() {
        let a = parse("train --config e2e --steps 100 --verbose pos1", &["verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.str_opt("config"), Some("e2e"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn eq_form_and_defaults() {
        let a = parse("--lr=0.5", &[]);
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.5);
        assert_eq!(a.f64_or("rho", 4.0).unwrap(), 4.0);
        assert!(a.subcommand.is_none());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(["--key".to_string()].into_iter(), &[]).is_err());
    }
}
