//! Small statistics toolkit: summary stats, percentiles, least squares.
//! Used by the cost-model calibration (simulator), the bench harness, and
//! metric reporting.

#[derive(Debug, Clone, Copy, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile_sorted(&sorted, 0.50),
        p90: percentile_sorted(&sorted, 0.90),
        p99: percentile_sorted(&sorted, 0.99),
    }
}

/// Linear-interpolated percentile of an already-sorted slice, q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

/// Ordinary least squares y ~ a + b*x. Returns (a, b, r2).
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    assert!(n >= 2.0, "need at least two points");
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let b = sxy / sxx.max(1e-300);
    let a = my - b * mx;
    let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (a + b * x);
            e * e
        })
        .sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    (a, b, r2)
}

// ---------------------------------------------------------------------------
// Streaming log-bucketed histogram (the analysis plane's latency primitive)

/// Sub-buckets per power of two: 4 mantissa bits -> 16 linear sub-buckets,
/// so a bucket spanning `[lo, lo + lo/16)` bounds the quantile estimate's
/// relative error by [`LogHistogram::RELATIVE_ERROR`].
const SUB_BITS: u32 = 4;
const SUBS: usize = 1 << SUB_BITS;
/// Smallest representable exponent: 2^-34 s ≈ 58 ps. Anything smaller
/// (including zero and negatives) lands in the shared low bucket.
const MIN_EXP: i32 = -34;
/// Largest representable exponent: values at or above 2^21 s (~24 days)
/// land in the shared high bucket.
const MAX_EXP: i32 = 20;
const N_EXPS: usize = (MAX_EXP - MIN_EXP + 1) as usize;
/// low bucket + log-linear grid + high bucket
const N_BUCKETS: usize = 2 + N_EXPS * SUBS;

/// HDR-style streaming histogram over a FIXED log-linear bucket layout:
/// base-2 exponent buckets, each split into 16 linear sub-buckets taken
/// straight from the IEEE-754 mantissa bits (so bucketing is exact — no
/// float-log boundary jitter).
///
/// Properties the analysis plane relies on:
/// * **Mergeable**: the layout is identical for every instance, so
///   [`LogHistogram::merge`] is a bucket-wise add — building one histogram
///   from a whole stream equals merging per-shard histograms of any
///   partition of that stream.
/// * **Bounded relative error**: a recorded value `v` in
///   `[2^-34, 2^21)` shares its bucket (width `≤ v/16`) with the estimate
///   its quantile reports, so `|quantile(q) - exact| ≤ exact / 16`
///   ([`LogHistogram::RELATIVE_ERROR`]) against the nearest-rank order
///   statistic. Out-of-range and non-positive values are counted in the
///   shared low/high buckets and reported as the exact tracked min/max.
/// * **No panics on garbage**: zero, negative, NaN, subnormal and huge
///   durations all land in a bucket; quantiles stay finite whenever at
///   least one finite value was recorded.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram::new()
    }
}

impl LogHistogram {
    /// Documented quantile error bound relative to the exact nearest-rank
    /// order statistic, for positive in-range values (one sub-bucket
    /// width).
    pub const RELATIVE_ERROR: f64 = 1.0 / SUBS as f64;

    pub fn new() -> LogHistogram {
        LogHistogram {
            counts: vec![0; N_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn bucket_index(v: f64) -> usize {
        // non-positive, NaN and sub-grid values share the low bucket
        if !(v >= (MIN_EXP as f64).exp2()) {
            return 0;
        }
        if !v.is_finite() {
            return N_BUCKETS - 1;
        }
        let bits = v.to_bits();
        let exp = ((bits >> 52) & 0x7ff) as i32 - 1023;
        if exp > MAX_EXP {
            return N_BUCKETS - 1;
        }
        let sub = ((bits >> (52 - SUB_BITS)) & (SUBS as u64 - 1)) as usize;
        1 + (exp - MIN_EXP) as usize * SUBS + sub
    }

    /// Lower edge / width midpoint of a grid bucket.
    fn bucket_midpoint(idx: usize) -> f64 {
        let grid = idx - 1;
        let exp = MIN_EXP + (grid / SUBS) as i32;
        let sub = (grid % SUBS) as f64;
        let base = (exp as f64).exp2();
        base * (1.0 + (sub + 0.5) / SUBS as f64)
    }

    pub fn record(&mut self, v: f64) {
        self.counts[Self::bucket_index(v)] += 1;
        self.count += 1;
        if v.is_finite() {
            self.sum += v;
        }
        // NaN fails both comparisons and leaves min/max untouched
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
    }

    /// Bucket-wise add: because the layout is fixed, merging shard
    /// histograms is exactly equivalent to having recorded the
    /// concatenated stream into one histogram.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Nearest-rank quantile estimate, `q` in [0,1]: the midpoint of the
    /// bucket holding the `ceil(q*n)`-th smallest recorded value, clamped
    /// to the exact tracked `[min, max]`. NaN when empty (matching
    /// [`percentile_sorted`] on an empty slice).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let k = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= k {
                let est = if idx == 0 {
                    // low bucket: report the exact minimum (possibly <= 0)
                    if self.min.is_finite() { self.min } else { 0.0 }
                } else if idx == N_BUCKETS - 1 {
                    if self.max.is_finite() { self.max } else { f64::INFINITY }
                } else {
                    Self::bucket_midpoint(idx)
                };
                // clamping toward the observed extremes only tightens the
                // estimate (the order statistic lies in [min, max])
                return if self.min.is_finite() && self.max.is_finite() {
                    est.clamp(self.min, self.max)
                } else {
                    est
                };
            }
        }
        unreachable!("cumulative bucket count ({cum}) < total count ({})", self.count)
    }

    /// [`LogHistogram::quantile`], with a default for the empty case (live
    /// telemetry wants a JSON-safe number, not NaN).
    pub fn quantile_or(&self, q: f64, default: f64) -> f64 {
        if self.count == 0 {
            default
        } else {
            self.quantile(q)
        }
    }
}

/// Exponentially weighted moving average tracker.
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        Ewma { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => v + self.alpha * (x - v),
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn linfit_exact() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (a, b, r2) = linfit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn log_histogram_quantiles_bound_error() {
        let mut h = LogHistogram::new();
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64 * 1e-3).collect();
        for &x in &xs {
            h.record(x);
        }
        assert_eq!(h.count(), 1000);
        for q in [0.5, 0.9, 0.99] {
            let exact = xs[((q * 1000.0).ceil() as usize).clamp(1, 1000) - 1];
            let est = h.quantile(q);
            assert!(
                (est - exact).abs() <= exact * LogHistogram::RELATIVE_ERROR + 1e-12,
                "q={q}: est {est} vs exact {exact}"
            );
        }
    }

    #[test]
    fn log_histogram_merge_equals_whole_stream() {
        let (mut a, mut b, mut whole) =
            (LogHistogram::new(), LogHistogram::new(), LogHistogram::new());
        for i in 0..500 {
            let v = (i as f64 * 0.731).sin().abs() * 10.0;
            whole.record(v);
            if i % 2 == 0 { a.record(v) } else { b.record(v) }
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(a.quantile(q), whole.quantile(q), "q={q}");
        }
    }

    #[test]
    fn log_histogram_swallows_garbage() {
        let mut h = LogHistogram::new();
        for v in [0.0, -1.0, -1e300, 1e300, f64::INFINITY, f64::NAN, 1e-300, 2.5] {
            h.record(v);
        }
        assert_eq!(h.count(), 8);
        // quantiles stay defined (clamped into the observed range)
        assert!(h.quantile(0.5).is_finite() || h.quantile(0.5).is_infinite());
        assert!(h.quantile(0.0) <= h.quantile(1.0));
        // empty histogram mirrors percentile_sorted's empty-slice NaN
        assert!(LogHistogram::new().quantile(0.5).is_nan());
        assert_eq!(LogHistogram::new().quantile_or(0.5, 0.0), 0.0);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        for _ in 0..30 {
            e.update(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-6);
    }
}
