//! Small statistics toolkit: summary stats, percentiles, least squares.
//! Used by the cost-model calibration (simulator), the bench harness, and
//! metric reporting.

#[derive(Debug, Clone, Copy, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile_sorted(&sorted, 0.50),
        p90: percentile_sorted(&sorted, 0.90),
        p99: percentile_sorted(&sorted, 0.99),
    }
}

/// Linear-interpolated percentile of an already-sorted slice, q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

/// Ordinary least squares y ~ a + b*x. Returns (a, b, r2).
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    assert!(n >= 2.0, "need at least two points");
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let b = sxy / sxx.max(1e-300);
    let a = my - b * mx;
    let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (a + b * x);
            e * e
        })
        .sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    (a, b, r2)
}

/// Exponentially weighted moving average tracker.
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        Ewma { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => v + self.alpha * (x - v),
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn linfit_exact() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (a, b, r2) = linfit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        for _ in 0..30 {
            e.update(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-6);
    }
}
