//! Bench harness (criterion is not in the offline vendor set).
//!
//! Each `rust/benches/*.rs` sets `harness = false` in Cargo.toml and drives
//! this module: warmup, timed repetitions, summary stats, and aligned table
//! printing so every paper table/figure bench prints paper-vs-measured rows.

use std::time::Instant;

use crate::util::json::Value;
use crate::util::stats::{summarize, Summary};

/// Time `f` for `iters` iterations after `warmup` runs; returns per-iteration
/// seconds.
pub fn time_fn<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

pub struct BenchReport {
    pub name: String,
    pub summary: Summary,
}

pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, f: F) -> BenchReport {
    let samples = time_fn(warmup, iters, f);
    let summary = summarize(&samples);
    BenchReport {
        name: name.to_string(),
        summary,
    }
}

impl BenchReport {
    pub fn print(&self) {
        let s = &self.summary;
        println!(
            "{:<44} mean {:>10}  p50 {:>10}  p90 {:>10}  (n={})",
            self.name,
            fmt_secs(s.mean),
            fmt_secs(s.p50),
            fmt_secs(s.p90),
            s.n
        );
    }
}

/// Iteration knob for CI smoke runs: `LLAMARL_BENCH_ROUNDS=<n>` caps a
/// bench's round/iteration counts at `n` (benches pass their full default;
/// an unset or unparsable variable leaves it unchanged). The CI bench-smoke
/// job sets a small value so every bench executes end to end in seconds
/// while local runs keep full fidelity.
pub fn bench_rounds(default: usize) -> usize {
    match std::env::var("LLAMARL_BENCH_ROUNDS") {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(n) if n > 0 => default.min(n),
            _ => default,
        },
        Err(_) => default,
    }
}

/// Emit a bench's machine-readable summary the way `tools/bench_gate.sh`
/// and CI expect it: the flat JSON object on ONE stdout line prefixed with
/// its file name, then persisted under the cargo target dir (so the gate
/// can re-check ratios without re-running the bench). Every `BENCH_*.json`
/// goes through this single `util::json` serializer — no hand-formatted
/// JSON strings in bench code.
pub fn emit_summary(file_name: &str, json: &Value) {
    let line = json.to_string();
    println!("{file_name} {line}");
    let target_dir = std::env::var("CARGO_TARGET_DIR")
        .unwrap_or_else(|_| format!("{}/../target", env!("CARGO_MANIFEST_DIR")));
    let path = format!("{target_dir}/{file_name}");
    if let Err(e) = std::fs::write(&path, &line) {
        eprintln!("warning: could not write {path}: {e}");
    }
}

pub fn fmt_secs(s: f64) -> String {
    if !s.is_finite() {
        "n/a".to_string()
    } else if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// Fixed-width table printer for paper-vs-measured rows.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let print_row = |cells: &[String]| {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:>w$}", cells[i], w = widths[i]));
            }
            println!("{line}");
        };
        print_row(&self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        println!("{}", "-".repeat(total));
        for row in &self.rows {
            print_row(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_rounds_caps_only_downward() {
        // never raises the default, regardless of env; without touching the
        // process env (racy across test threads) we exercise the unset path
        assert_eq!(bench_rounds(20).min(20), bench_rounds(20));
        assert!(bench_rounds(20) >= 1);
    }

    #[test]
    fn timing_is_positive() {
        let mut x = 0u64;
        let samples = time_fn(1, 5, || {
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
        });
        assert_eq!(samples.len(), 5);
        assert!(samples.iter().all(|s| *s >= 0.0));
        std::hint::black_box(x);
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_secs(2.5), "2.500s");
        assert_eq!(fmt_secs(0.0025), "2.500ms");
        assert_eq!(fmt_secs(2.5e-6), "2.5us");
    }
}
