//! Crate-wide error type.

use thiserror::Error;

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Error, Debug)]
pub enum Error {
    #[error("xla/pjrt error: {0}")]
    Xla(#[from] xla::Error),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("json parse error at byte {offset}: {msg}")]
    JsonParse { offset: usize, msg: String },

    #[error("manifest error: {0}")]
    Manifest(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("shape mismatch for {what}: expected {expected:?}, got {got:?}")]
    Shape {
        what: String,
        expected: Vec<usize>,
        got: Vec<usize>,
    },

    #[error("coordinator error: {0}")]
    Coordinator(String),

    #[error("channel closed: {0}")]
    ChannelClosed(String),

    #[error("cli error: {0}")]
    Cli(String),

    #[error("{0}")]
    Msg(String),
}

impl Error {
    pub fn msg(s: impl Into<String>) -> Self {
        Error::Msg(s.into())
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error::Msg(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error::Msg(s.to_string())
    }
}
