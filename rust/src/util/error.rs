//! Crate-wide error type (hand-rolled: thiserror is not in the offline
//! vendor set).

use std::fmt;

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug)]
pub enum Error {
    Xla(xla::Error),
    Io(std::io::Error),
    JsonParse { offset: usize, msg: String },
    Manifest(String),
    Config(String),
    Shape {
        what: String,
        expected: Vec<usize>,
        got: Vec<usize>,
    },
    Coordinator(String),
    ChannelClosed(String),
    Cli(String),
    /// A memory placement exceeds a hard pool capacity (the memplane never
    /// silently overcommits — infeasible colocations must fail loudly).
    Capacity(String),
    Msg(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Xla(e) => write!(f, "xla/pjrt error: {e}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::JsonParse { offset, msg } => {
                write!(f, "json parse error at byte {offset}: {msg}")
            }
            Error::Manifest(s) => write!(f, "manifest error: {s}"),
            Error::Config(s) => write!(f, "config error: {s}"),
            Error::Shape {
                what,
                expected,
                got,
            } => write!(
                f,
                "shape mismatch for {what}: expected {expected:?}, got {got:?}"
            ),
            Error::Coordinator(s) => write!(f, "coordinator error: {s}"),
            Error::ChannelClosed(s) => write!(f, "channel closed: {s}"),
            Error::Cli(s) => write!(f, "cli error: {s}"),
            Error::Capacity(s) => write!(f, "capacity error: {s}"),
            Error::Msg(s) => write!(f, "{s}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Xla(e) => Some(e),
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    pub fn msg(s: impl Into<String>) -> Self {
        Error::Msg(s.into())
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error::Msg(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error::Msg(s.to_string())
    }
}
