//! Minimal JSON parser/serializer (serde_json is not in the offline vendor
//! set). Covers the full JSON grammar; used for artifact manifests, run
//! configs and metric logs.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::error::{Error, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(s: &str) -> Result<Value> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with a readable path instead of returning None.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key)
            .ok_or_else(|| Error::Manifest(format!("missing key '{key}'")))
    }

    pub fn idx(&self, i: usize) -> Option<&Value> {
        match self {
            Value::Array(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(m) => Some(m),
            _ => None,
        }
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| Error::Manifest(format!("'{key}' is not a number")))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        Ok(self.req_f64(key)? as usize)
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| Error::Manifest(format!("'{key}' is not a string")))
    }

    pub fn req_array(&self, key: &str) -> Result<&[Value]> {
        self.req(key)?
            .as_array()
            .ok_or_else(|| Error::Manifest(format!("'{key}' is not an array")))
    }

    // -- construction helpers ---------------------------------------------

    pub fn object(pairs: Vec<(&str, Value)>) -> Value {
        Value::Object(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn array_f64(xs: &[f64]) -> Value {
        Value::Array(xs.iter().map(|x| Value::Number(*x)).collect())
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::String(s.into())
    }

    pub fn num(n: f64) -> Value {
        Value::Number(n)
    }

    // -- serialization -----------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Number(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Value::String(s) => write_escaped(out, s),
            Value::Array(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Object(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::JsonParse {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pairs
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("bad unicode escape"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // re-decode multi-byte utf8 from the source slice
                    let start = self.pos - 1;
                    let width = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + width).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = chunk.chars().next().unwrap();
                    s.push(c);
                    self.pos = start + c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid utf8 in number"))?;
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse(" -12.5e2 ").unwrap(), Value::Number(-1250.0));
        assert_eq!(
            Value::parse("\"a\\nb\\u0041\"").unwrap(),
            Value::String("a\nbA".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_i64(), Some(2));
        assert_eq!(
            v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x\"y"],"b":{"c":null,"d":true}}"#;
        let v = Value::parse(src).unwrap();
        let v2 = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_string() {
        let v = Value::parse("\"héllo \\u00e9 ↦\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo é ↦"));
        let v2 = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("12 34").is_err());
        assert!(Value::parse("'single'").is_err());
    }
}
