//! Trajectories: the unit of data flowing generator -> reward -> trainer.

use crate::data::Problem;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// policy emitted EOS
    Eos,
    /// hit the sequence-length budget
    Length,
}

/// One completed generation plus everything AIPO training needs.
#[derive(Debug, Clone)]
pub struct Trajectory {
    pub group_id: u64,
    pub replica: usize,
    pub n_replicas: usize,
    pub problem: Problem,
    /// prompt token ids (BOS + prompt chars)
    pub prompt_tokens: Vec<i32>,
    /// generated token ids (including the final EOS if any)
    pub response_tokens: Vec<i32>,
    /// behaviour log-prob mu(y_t) recorded at sampling time, one per
    /// response token
    pub behavior_logp: Vec<f32>,
    /// weights version the generator sampled under (off-policy lag =
    /// trainer_version - gen_version)
    pub gen_version: u64,
    /// how many generate_chunk calls this trajectory spanned (partial
    /// rollouts metric)
    pub chunks: u32,
    pub finish: FinishReason,
    /// rule-based score, filled by the reward executor
    pub reward: f32,
    /// sequence-level advantage, filled after group baseline computation
    pub advantage: f32,
}

impl Trajectory {
    pub fn total_len(&self) -> usize {
        self.prompt_tokens.len() + self.response_tokens.len()
    }

    pub fn decoded_response(&self, tok: &crate::model::Tokenizer) -> String {
        tok.decode(&self.response_tokens)
    }
}
