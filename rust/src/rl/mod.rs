//! RL data structures and the AIPO algorithm's host-side pieces:
//! trajectories, group advantage baselines, and train-batch packing.

mod advantage;
mod batch;
mod trajectory;

pub use advantage::{group_advantages, Baseline};
pub use batch::{pack_batch, TrainBatch};
pub use trajectory::{FinishReason, Trajectory};

/// AIPO hyper-parameters (paper §6). `rho` is the one-sided IS-ratio clip;
/// `rho <= 0` disables the correction entirely (the Figure-8 ablation arm).
#[derive(Debug, Clone, Copy)]
pub struct AipoConfig {
    pub lr: f32,
    pub rho: f32,
    pub grad_clip: f32,
    pub baseline: Baseline,
}

impl Default for AipoConfig {
    fn default() -> Self {
        AipoConfig {
            lr: 2e-4,
            // paper: rho in [2, 10] works well
            rho: 4.0,
            grad_clip: 1.0,
            baseline: Baseline::GroupMean,
        }
    }
}

impl AipoConfig {
    /// The `hyp` vector consumed by the train_step artifact. `rho <= 0` is
    /// understood by the AIPO kernel as "no off-policy correction" (w = 1).
    pub fn hyp(&self) -> [f32; 3] {
        [self.lr, self.rho, self.grad_clip]
    }
}
