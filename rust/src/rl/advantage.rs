//! Advantage baselines (paper §6): from a single prompt we sample n
//! generations and use group statistics as the variance-reducing baseline —
//! no learned critic (the paper's Figure-1 workflow).

use crate::rl::Trajectory;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Baseline {
    /// v = mean of all n rewards in the group (paper §6, Ahmadian et al.)
    GroupMean,
    /// leave-one-out mean (RLOO): v_i = mean of the other n-1 rewards
    LeaveOneOut,
    /// no baseline: advantage = raw reward
    None,
}

/// Fill `advantage` for a complete group of trajectories (same prompt).
/// Panics in debug if the group is inconsistent.
pub fn group_advantages(group: &mut [Trajectory], baseline: Baseline) {
    debug_assert!(!group.is_empty());
    debug_assert!(group.windows(2).all(|w| w[0].group_id == w[1].group_id));
    let n = group.len();
    let sum: f32 = group.iter().map(|t| t.reward).sum();
    for t in group.iter_mut() {
        let v = match baseline {
            Baseline::None => 0.0,
            Baseline::GroupMean => sum / n as f32,
            Baseline::LeaveOneOut => {
                if n > 1 {
                    (sum - t.reward) / (n - 1) as f32
                } else {
                    0.0
                }
            }
        };
        t.advantage = t.reward - v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Difficulty, Problem};
    use crate::rl::FinishReason;

    fn traj(group_id: u64, reward: f32) -> Trajectory {
        Trajectory {
            group_id,
            replica: 0,
            n_replicas: 4,
            problem: Problem {
                prompt: "1+1=".into(),
                answer: "2".into(),
                difficulty: Difficulty::Add1,
            },
            prompt_tokens: vec![1],
            response_tokens: vec![2],
            behavior_logp: vec![0.0],
            gen_version: 0,
            chunks: 1,
            finish: FinishReason::Eos,
            reward,
            advantage: 0.0,
        }
    }

    #[test]
    fn group_mean() {
        let mut g = vec![traj(0, 1.0), traj(0, 0.0), traj(0, 0.0), traj(0, 1.0)];
        group_advantages(&mut g, Baseline::GroupMean);
        assert_eq!(g[0].advantage, 0.5);
        assert_eq!(g[1].advantage, -0.5);
        let sum: f32 = g.iter().map(|t| t.advantage).sum();
        assert!(sum.abs() < 1e-6, "group-mean advantages sum to zero");
    }

    #[test]
    fn leave_one_out() {
        let mut g = vec![traj(0, 1.0), traj(0, 0.0), traj(0, 0.0), traj(0, 0.0)];
        group_advantages(&mut g, Baseline::LeaveOneOut);
        assert_eq!(g[0].advantage, 1.0);
        assert!((g[1].advantage - (-1.0 / 3.0)).abs() < 1e-6);
    }

    #[test]
    fn uniform_rewards_zero_advantage() {
        let mut g = vec![traj(0, 1.0); 4];
        group_advantages(&mut g, Baseline::GroupMean);
        assert!(g.iter().all(|t| t.advantage == 0.0));
    }

    #[test]
    fn no_baseline() {
        let mut g = vec![traj(0, 0.7)];
        group_advantages(&mut g, Baseline::None);
        assert_eq!(g[0].advantage, 0.7);
    }
}
