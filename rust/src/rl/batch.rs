//! Packing trajectories into the fixed-shape arrays the train_step artifact
//! consumes.

use crate::rl::Trajectory;
use crate::util::error::{Error, Result};

/// A packed training microbatch, shaped [b, t] row-major.
#[derive(Debug, Clone)]
pub struct TrainBatch {
    pub b: usize,
    pub t: usize,
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub blogp: Vec<f32>,
    pub adv: Vec<f32>,
    pub mask: Vec<f32>,
    pub lens: Vec<i32>,
    /// generator weight versions per row (255 = padding row)
    pub gen_versions: Vec<u64>,
    pub rewards: Vec<f32>,
    pub n_real_rows: usize,
}

/// Pack up to `b` trajectories into a [b, t] batch.
///
/// Layout per row: full = prompt ++ response; inputs are full[0..L-1],
/// targets are full[1..L]; response-token targets live at positions
/// [plen-1, plen+rlen-1) where mask=1 and blogp/advantage are aligned.
/// Missing rows are zero-padded with mask 0 (no gradient contribution).
pub fn pack_batch(trajs: &[Trajectory], b: usize, t: usize) -> Result<TrainBatch> {
    if trajs.len() > b {
        return Err(Error::Coordinator(format!(
            "pack_batch: {} trajectories > batch {b}",
            trajs.len()
        )));
    }
    let mut out = TrainBatch {
        b,
        t,
        tokens: vec![0; b * t],
        targets: vec![0; b * t],
        blogp: vec![0.0; b * t],
        adv: vec![0.0; b * t],
        mask: vec![0.0; b * t],
        lens: vec![1; b],
        gen_versions: vec![u64::MAX; b],
        rewards: vec![0.0; b],
        n_real_rows: trajs.len(),
    };
    for (row, tr) in trajs.iter().enumerate() {
        let plen = tr.prompt_tokens.len();
        let rlen = tr.response_tokens.len();
        let total = plen + rlen;
        if total > t + 1 {
            return Err(Error::Coordinator(format!(
                "trajectory length {total} exceeds train_seq+1 ({})",
                t + 1
            )));
        }
        if plen == 0 || rlen == 0 {
            return Err(Error::Coordinator("empty prompt or response".into()));
        }
        if tr.behavior_logp.len() != rlen {
            return Err(Error::Coordinator("behavior_logp/response mismatch".into()));
        }
        let mut full = Vec::with_capacity(total);
        full.extend_from_slice(&tr.prompt_tokens);
        full.extend_from_slice(&tr.response_tokens);
        let base = row * t;
        let in_len = total - 1;
        for i in 0..in_len {
            out.tokens[base + i] = full[i];
            out.targets[base + i] = full[i + 1];
        }
        for (j, &lp) in tr.behavior_logp.iter().enumerate() {
            let pos = plen - 1 + j;
            out.blogp[base + pos] = lp;
            out.adv[base + pos] = tr.advantage;
            out.mask[base + pos] = 1.0;
        }
        out.lens[row] = in_len as i32;
        out.gen_versions[row] = tr.gen_version;
        out.rewards[row] = tr.reward;
    }
    Ok(out)
}

impl TrainBatch {
    /// Masked token count (what the loss normalizes over).
    pub fn token_count(&self) -> usize {
        self.mask.iter().filter(|m| **m > 0.0).count()
    }

    /// Off-policy lag per real row given the trainer's current version.
    pub fn lags(&self, trainer_version: u64) -> Vec<u64> {
        self.gen_versions
            .iter()
            .take(self.n_real_rows)
            .map(|v| trainer_version.saturating_sub(*v))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Difficulty, Problem};
    use crate::rl::FinishReason;

    fn traj(prompt: Vec<i32>, resp: Vec<i32>) -> Trajectory {
        let n = resp.len();
        Trajectory {
            group_id: 0,
            replica: 0,
            n_replicas: 1,
            problem: Problem {
                prompt: "p".into(),
                answer: "a".into(),
                difficulty: Difficulty::Add1,
            },
            prompt_tokens: prompt,
            response_tokens: resp,
            behavior_logp: vec![-1.0; n],
            gen_version: 3,
            chunks: 1,
            finish: FinishReason::Eos,
            reward: 1.0,
            advantage: 0.5,
        }
    }

    #[test]
    fn alignment() {
        let tr = traj(vec![1, 10, 11], vec![20, 21, 2]);
        let b = pack_batch(&[tr], 2, 8).unwrap();
        // inputs: [1,10,11,20,21]; targets: [10,11,20,21,2]
        assert_eq!(&b.tokens[..5], &[1, 10, 11, 20, 21]);
        assert_eq!(&b.targets[..5], &[10, 11, 20, 21, 2]);
        // response targets at positions 2,3,4
        assert_eq!(&b.mask[..8], &[0., 0., 1., 1., 1., 0., 0., 0.]);
        assert_eq!(b.lens[0], 5);
        assert_eq!(b.adv[2], 0.5);
        assert_eq!(b.blogp[3], -1.0);
        // padding row untouched
        assert_eq!(b.lens[1], 1);
        assert!(b.mask[8..].iter().all(|m| *m == 0.0));
        assert_eq!(b.token_count(), 3);
        assert_eq!(b.lags(5), vec![2]);
    }

    #[test]
    fn rejects_oversize() {
        let tr = traj(vec![1; 6], vec![2; 6]);
        assert!(pack_batch(&[tr], 1, 8).is_err());
    }

    #[test]
    fn exact_fit_is_ok() {
        // total = t+1 exactly: inputs fill the whole row
        let tr = traj(vec![1; 4], vec![2; 5]);
        let b = pack_batch(&[tr], 1, 8).unwrap();
        assert_eq!(b.lens[0], 8);
        assert_eq!(b.token_count(), 5);
    }

    #[test]
    fn empty_trajectory_list_packs_all_padding() {
        // drain-time corner: the trainer may be asked to pack zero rows
        let b = pack_batch(&[], 3, 8).unwrap();
        assert_eq!(b.n_real_rows, 0);
        assert_eq!(b.token_count(), 0);
        assert!(b.mask.iter().all(|m| *m == 0.0));
        assert!(b.tokens.iter().all(|t| *t == 0));
        assert!(b.gen_versions.iter().all(|v| *v == u64::MAX));
        // padding rows keep lens = 1 so in-graph slicing stays valid
        assert!(b.lens.iter().all(|l| *l == 1));
        assert!(b.lags(5).is_empty(), "no real rows -> no lags");
    }

    #[test]
    fn final_partial_batch_pads_missing_rows() {
        // drain time: 2 of 4 rows present; the rest must be inert padding
        let rows = vec![traj(vec![1, 2], vec![3, 4]), traj(vec![5], vec![6, 7, 2])];
        let b = pack_batch(&rows, 4, 8).unwrap();
        assert_eq!(b.n_real_rows, 2);
        assert_eq!(b.token_count(), 2 + 3);
        assert_eq!(b.lags(3), vec![0, 0], "lags only cover real rows");
        for row in 2..4 {
            let base = row * 8;
            assert!(b.mask[base..base + 8].iter().all(|m| *m == 0.0));
            assert_eq!(b.gen_versions[row], u64::MAX);
            assert_eq!(b.rewards[row], 0.0);
        }
        // rewards of real rows survive for the report means
        assert_eq!(b.rewards[0], 1.0);
    }

    #[test]
    fn lags_saturate_when_trainer_is_behind_generator() {
        // gen_version = 3 (see traj()); a trainer at version 1 — e.g. a
        // freshest-first store handing out rows generated under a version
        // the trainer's clock hasn't caught up to — must clamp to 0, not
        // wrap to u64::MAX
        let b = pack_batch(&[traj(vec![1, 2], vec![3, 4])], 2, 8).unwrap();
        assert_eq!(b.lags(1), vec![0], "future rows clamp to zero lag");
        assert_eq!(b.lags(3), vec![0]);
        assert_eq!(b.lags(u64::MAX), vec![u64::MAX - 3]);
    }
}
