//! Run configuration: named presets + JSON config files + CLI overrides.
//!
//! The `llamarl` binary resolves a [`PipelineConfig`] as
//! `preset <- json file (--config) <- CLI flags`, so experiments are
//! reproducible from a single artifact.

use std::path::PathBuf;

use crate::coordinator::{Mode, PipelineConfig};
use crate::dataplane::{AdmissionPolicy, SamplingStrategy};
use crate::memplane::pool::AllocClass;
use crate::rl::{AipoConfig, Baseline};
use crate::util::cli::Args;
use crate::util::error::{Error, Result};
use crate::util::json::Value;
use crate::weightsync::ShardEncoding;

/// Named presets. `nano` for smoke tests, `small` for integration-scale
/// runs, `e2e` for the headline end-to-end training driver.
pub fn preset(name: &str) -> Result<PipelineConfig> {
    let mut cfg = PipelineConfig::default();
    match name {
        "nano" => {
            cfg.artifact_dir = "artifacts/nano".into();
            cfg.max_steps = 5;
            cfg.max_response = 12;
            cfg.n_generator_workers = 1;
        }
        "small" => {
            cfg.artifact_dir = "artifacts/small".into();
            cfg.max_steps = 50;
            cfg.max_response = 16;
            cfg.n_generator_workers = 1;
            cfg.eval_every = 10;
            cfg.eval_max_per_suite = 32;
        }
        "e2e" => {
            cfg.artifact_dir = "artifacts/e2e".into();
            cfg.max_steps = 300;
            cfg.max_response = 20;
            cfg.n_generator_workers = 2;
            cfg.queue_capacity = 4;
            cfg.eval_every = 25;
            cfg.eval_max_per_suite = 64;
            cfg.aipo = AipoConfig {
                lr: 3e-4,
                rho: 4.0,
                grad_clip: 1.0,
                baseline: Baseline::GroupMean,
            };
        }
        other => return Err(Error::Config(format!("unknown preset '{other}'"))),
    }
    Ok(cfg)
}

fn parse_mode(s: &str) -> Result<Mode> {
    match s {
        "sync" => Ok(Mode::Sync),
        "async" => Ok(Mode::Async),
        "async_buffered" | "buffered" => Ok(Mode::AsyncBuffered),
        "periodic" => Ok(Mode::Periodic),
        other => Err(Error::Config(format!(
            "mode must be sync|async|async_buffered|periodic, got '{other}'"
        ))),
    }
}

/// 0 means "unbounded" for the max-staleness knob (CLI/JSON friendly).
fn staleness_opt(v: u64) -> Option<u64> {
    if v == 0 {
        None
    } else {
        Some(v)
    }
}

/// `sync_encoding = full|int8|delta|topk|auto` (JSON and CLI). `auto`
/// measures the update density at encode time and picks full vs delta per
/// publish.
fn parse_encoding(s: &str) -> Result<ShardEncoding> {
    match s {
        "full" | "f32" => Ok(ShardEncoding::F32),
        "int8" => Ok(ShardEncoding::Int8),
        "delta" => Ok(ShardEncoding::Delta),
        "topk" | "top_k" => Ok(ShardEncoding::TopK),
        "auto" => Ok(ShardEncoding::Auto),
        other => Err(Error::Config(format!(
            "sync_encoding must be full|int8|delta|topk|auto, got '{other}'"
        ))),
    }
}

fn parse_baseline(s: &str) -> Result<Baseline> {
    match s {
        "group_mean" => Ok(Baseline::GroupMean),
        "rloo" => Ok(Baseline::LeaveOneOut),
        "none" => Ok(Baseline::None),
        other => Err(Error::Config(format!(
            "baseline must be group_mean|rloo|none, got '{other}'"
        ))),
    }
}

/// Apply a parsed JSON config object over `cfg`.
pub fn apply_json(cfg: &mut PipelineConfig, v: &Value) -> Result<()> {
    let obj = v
        .as_object()
        .ok_or_else(|| Error::Config("config file must be a JSON object".into()))?;
    for (k, val) in obj {
        match k.as_str() {
            "artifact_dir" => cfg.artifact_dir = PathBuf::from(val.as_str().unwrap_or("")),
            "mode" => cfg.mode = parse_mode(val.as_str().unwrap_or(""))?,
            "n_generator_workers" => cfg.n_generator_workers = val.as_usize().unwrap_or(1),
            "n_reward_workers" => {
                cfg.n_reward_workers = val.as_usize().unwrap_or(1).max(1)
            }
            "n_trainer_workers" => {
                cfg.n_trainer_workers = val.as_usize().unwrap_or(1).max(1)
            }
            "period_steps" => {
                cfg.period_steps = val.as_i64().unwrap_or(4).max(1) as u64
            }
            "queue_capacity" => cfg.queue_capacity = val.as_usize().unwrap_or(4),
            "scored_capacity" => cfg.scored_capacity = val.as_usize().unwrap_or(8),
            "store_capacity" => cfg.store.capacity = val.as_usize().unwrap_or(128).max(1),
            "store_shards" => cfg.store.shards = val.as_usize().unwrap_or(4).max(1),
            "max_staleness" => {
                cfg.store.max_staleness = staleness_opt(val.as_i64().unwrap_or(0).max(0) as u64)
            }
            "admission" => {
                cfg.store.admission = AdmissionPolicy::parse(val.as_str().unwrap_or(""))?
            }
            "sampling" => {
                cfg.store.sampling = SamplingStrategy::parse(val.as_str().unwrap_or(""))?
            }
            "sync_trainer_shards" => {
                cfg.sync.trainer_shards = val.as_usize().unwrap_or(4).max(1)
            }
            "sync_generator_shards" => {
                cfg.sync.generator_shards = val.as_usize().unwrap_or(2).max(1)
            }
            // back-compat alias for sync_encoding = int8; false never
            // unsets an encoding an earlier layer chose
            "sync_quantized" => {
                if val.as_bool().unwrap_or(false) {
                    cfg.sync.encoding = ShardEncoding::Int8;
                }
            }
            "sync_encoding" => {
                cfg.sync.encoding = parse_encoding(val.as_str().unwrap_or(""))?
            }
            "sync_background" => cfg.sync.background = val.as_bool().unwrap_or(true),
            "sync_link_groups" => cfg.sync.link_groups = val.as_usize().unwrap_or(0),
            "sync_topk_frac" => {
                cfg.sync.topk_frac = val.as_f64().unwrap_or(0.01).clamp(1e-6, 1.0)
            }
            // colocated offloading memory plane
            "colocate" => cfg.mem.colocate = val.as_bool().unwrap_or(false),
            "offload_classes" => {
                cfg.mem.offload_classes = AllocClass::parse_list(val.as_str().unwrap_or(""))?
            }
            "offload_chunk_mb" => {
                cfg.mem.offload_chunk_mb = val.as_usize().unwrap_or(4).max(1)
            }
            "prefetch_depth" => cfg.mem.prefetch_depth = val.as_usize().unwrap_or(8),
            "offload_background" => {
                cfg.mem.background = val.as_bool().unwrap_or(true)
            }
            "n_generations" => cfg.n_generations = val.as_usize().unwrap_or(4),
            "baseline" => cfg.baseline = parse_baseline(val.as_str().unwrap_or(""))?,
            "max_steps" => cfg.max_steps = val.as_i64().unwrap_or(1) as u64,
            "lr" => cfg.aipo.lr = val.as_f64().unwrap_or(2e-4) as f32,
            "rho" => cfg.aipo.rho = val.as_f64().unwrap_or(4.0) as f32,
            "grad_clip" => cfg.aipo.grad_clip = val.as_f64().unwrap_or(1.0) as f32,
            "temperature" => cfg.temperature = val.as_f64().unwrap_or(1.0) as f32,
            "top_k" => cfg.top_k = val.as_i64().unwrap_or(0) as i32,
            "quantize_generator" => cfg.quantize_generator = val.as_bool().unwrap_or(false),
            "max_response" => cfg.max_response = val.as_usize().unwrap_or(usize::MAX),
            "eval_every" => cfg.eval_every = val.as_i64().unwrap_or(0) as u64,
            "eval_max_per_suite" => cfg.eval_max_per_suite = val.as_usize().unwrap_or(64),
            "checkpoint_every" => cfg.checkpoint_every = val.as_i64().unwrap_or(0) as u64,
            "seed" => cfg.seed = val.as_i64().unwrap_or(0) as u64,
            "out_dir" => cfg.out_dir = PathBuf::from(val.as_str().unwrap_or("")),
            "init_checkpoint" => {
                cfg.init_checkpoint = Some(PathBuf::from(val.as_str().unwrap_or("")))
            }
            "trace" => cfg.trace = Some(PathBuf::from(val.as_str().unwrap_or(""))),
            "metrics_interval_secs" => {
                cfg.metrics_interval_secs = val.as_f64().unwrap_or(0.0).max(0.0)
            }
            "journal" => cfg.journal = val.as_bool().unwrap_or(true),
            "journal_snapshot_secs" => {
                cfg.journal_snapshot_secs = val.as_f64().unwrap_or(0.25).max(0.01)
            }
            // elastic fleets: restart budget, chaos injection, resize
            "restart_max" => cfg.restart_max = val.as_i64().unwrap_or(0).max(0) as u32,
            "restart_backoff_ms" => {
                cfg.restart_backoff_ms = val.as_i64().unwrap_or(50).max(1) as u64
            }
            "chaos_kills" => cfg.chaos_kills = val.as_i64().unwrap_or(0).max(0) as u64,
            "chaos_seed" => cfg.chaos_seed = val.as_i64().unwrap_or(0) as u64,
            "chaos_reward_kills" => {
                cfg.chaos_reward_kills = val.as_i64().unwrap_or(0).max(0) as u64
            }
            "elastic_resize" => cfg.elastic_resize = val.as_bool().unwrap_or(false),
            "resize_max_extra" => cfg.resize_max_extra = val.as_usize().unwrap_or(2),
            other => return Err(Error::Config(format!("unknown config key '{other}'"))),
        }
    }
    Ok(())
}

/// Apply CLI flags over `cfg` (same keys as the JSON file).
pub fn apply_cli(cfg: &mut PipelineConfig, args: &Args) -> Result<()> {
    if let Some(v) = args.str_opt("artifacts") {
        cfg.artifact_dir = PathBuf::from(v);
    }
    if let Some(v) = args.str_opt("mode") {
        cfg.mode = parse_mode(v)?;
    }
    if let Some(v) = args.str_opt("baseline") {
        cfg.baseline = parse_baseline(v)?;
    }
    cfg.n_generator_workers = args.usize_or("workers", cfg.n_generator_workers)?;
    cfg.n_reward_workers = args
        .usize_or("reward-workers", cfg.n_reward_workers)?
        .max(1);
    cfg.n_trainer_workers = args
        .usize_or("trainers", cfg.n_trainer_workers)?
        .max(1);
    cfg.period_steps = args.u64_or("period-steps", cfg.period_steps)?.max(1);
    cfg.queue_capacity = args.usize_or("queue-capacity", cfg.queue_capacity)?;
    cfg.store.capacity = args.usize_or("store-capacity", cfg.store.capacity)?.max(1);
    cfg.store.shards = args.usize_or("store-shards", cfg.store.shards)?.max(1);
    if let Some(v) = args.str_opt("max-staleness") {
        let bound: u64 = v.parse().map_err(|_| {
            Error::Cli(format!("--max-staleness expects an integer, got '{v}'"))
        })?;
        cfg.store.max_staleness = staleness_opt(bound);
    }
    if let Some(v) = args.str_opt("admission") {
        cfg.store.admission = AdmissionPolicy::parse(v)?;
    }
    if let Some(v) = args.str_opt("sampling") {
        cfg.store.sampling = SamplingStrategy::parse(v)?;
    }
    cfg.sync.trainer_shards = args
        .usize_or("sync-trainer-shards", cfg.sync.trainer_shards)?
        .max(1);
    cfg.sync.generator_shards = args
        .usize_or("sync-generator-shards", cfg.sync.generator_shards)?
        .max(1);
    if args.flag("sync-quantized") {
        cfg.sync.encoding = ShardEncoding::Int8;
    }
    if let Some(v) = args.str_opt("sync-encoding") {
        cfg.sync.encoding = parse_encoding(v)?;
    }
    if args.flag("sync-inline") {
        // opt out of the background streaming executor (the inline
        // fan-out baseline; useful for A/B runs)
        cfg.sync.background = false;
    }
    cfg.sync.link_groups = args.usize_or("sync-link-groups", cfg.sync.link_groups)?;
    cfg.sync.topk_frac = args
        .f64_or("sync-topk-frac", cfg.sync.topk_frac)?
        .clamp(1e-6, 1.0);
    if args.flag("colocate") {
        cfg.mem.colocate = true;
    }
    if let Some(v) = args.str_opt("offload-classes") {
        cfg.mem.offload_classes = AllocClass::parse_list(v)?;
    }
    cfg.mem.offload_chunk_mb = args
        .usize_or("offload-chunk-mb", cfg.mem.offload_chunk_mb)?
        .max(1);
    cfg.mem.prefetch_depth = args.usize_or("prefetch-depth", cfg.mem.prefetch_depth)?;
    if args.flag("offload-eager") {
        // opt out of the background offload executor (leases then pay
        // their transfers synchronously; the A/B the bench measures)
        cfg.mem.background = false;
    }
    cfg.n_generations = args.usize_or("n-generations", cfg.n_generations)?;
    cfg.max_steps = args.u64_or("steps", cfg.max_steps)?;
    cfg.aipo.lr = args.f64_or("lr", cfg.aipo.lr as f64)? as f32;
    cfg.aipo.rho = args.f64_or("rho", cfg.aipo.rho as f64)? as f32;
    cfg.aipo.grad_clip = args.f64_or("grad-clip", cfg.aipo.grad_clip as f64)? as f32;
    cfg.temperature = args.f64_or("temperature", cfg.temperature as f64)? as f32;
    cfg.top_k = args.u64_or("top-k", cfg.top_k as u64)? as i32;
    if args.flag("quantize-generator") {
        cfg.quantize_generator = true;
    }
    cfg.max_response = args.usize_or("max-response", cfg.max_response)?;
    cfg.eval_every = args.u64_or("eval-every", cfg.eval_every)?;
    cfg.eval_max_per_suite = args.usize_or("eval-problems", cfg.eval_max_per_suite)?;
    cfg.checkpoint_every = args.u64_or("checkpoint-every", cfg.checkpoint_every)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    if let Some(v) = args.str_opt("out") {
        cfg.out_dir = PathBuf::from(v);
    }
    if let Some(v) = args.str_opt("init-checkpoint") {
        cfg.init_checkpoint = Some(PathBuf::from(v));
    }
    if let Some(v) = args.str_opt("trace") {
        cfg.trace = Some(PathBuf::from(v));
    }
    cfg.metrics_interval_secs = args
        .f64_or("metrics-interval", cfg.metrics_interval_secs)?
        .max(0.0);
    if args.flag("no-journal") {
        cfg.journal = false;
    }
    cfg.journal_snapshot_secs = args
        .f64_or("journal-snapshot-secs", cfg.journal_snapshot_secs)?
        .max(0.01);
    cfg.restart_max = args.u64_or("restart-max", cfg.restart_max as u64)? as u32;
    cfg.restart_backoff_ms = args
        .u64_or("restart-backoff-ms", cfg.restart_backoff_ms)?
        .max(1);
    cfg.chaos_kills = args.u64_or("chaos-kills", cfg.chaos_kills)?;
    cfg.chaos_seed = args.u64_or("chaos-seed", cfg.chaos_seed)?;
    cfg.chaos_reward_kills = args.u64_or("chaos-reward-kills", cfg.chaos_reward_kills)?;
    if args.flag("elastic-resize") {
        cfg.elastic_resize = true;
    }
    cfg.resize_max_extra = args.usize_or("resize-max-extra", cfg.resize_max_extra)?;
    Ok(())
}

fn encoding_name(e: ShardEncoding) -> &'static str {
    match e {
        ShardEncoding::F32 => "full",
        ShardEncoding::Int8 => "int8",
        ShardEncoding::Delta => "delta",
        ShardEncoding::TopK => "topk",
        ShardEncoding::Auto => "auto",
    }
}

fn mode_name(m: Mode) -> &'static str {
    match m {
        Mode::Sync => "sync",
        Mode::Async => "async",
        Mode::AsyncBuffered => "async_buffered",
        Mode::Periodic => "periodic",
    }
}

fn baseline_name(b: Baseline) -> &'static str {
    match b {
        Baseline::GroupMean => "group_mean",
        Baseline::LeaveOneOut => "rloo",
        Baseline::None => "none",
    }
}

/// Serialize a fully-resolved config into the exact key set [`apply_json`]
/// accepts, so `apply_json(&mut default, &to_json(cfg))` round-trips. This
/// is the run-journal's meta record: `llamarl resume` / `llamarl replay`
/// rebuild the recorded run from it with no side channel.
pub fn to_json(cfg: &PipelineConfig) -> Value {
    let classes = cfg
        .mem
        .offload_classes
        .iter()
        .map(|c| c.name())
        .collect::<Vec<_>>()
        .join(",");
    let mut pairs = vec![
        (
            "artifact_dir",
            Value::str(cfg.artifact_dir.to_string_lossy().into_owned()),
        ),
        ("mode", Value::str(mode_name(cfg.mode))),
        ("n_generator_workers", Value::num(cfg.n_generator_workers as f64)),
        ("n_reward_workers", Value::num(cfg.n_reward_workers as f64)),
        ("n_trainer_workers", Value::num(cfg.n_trainer_workers as f64)),
        ("period_steps", Value::num(cfg.period_steps as f64)),
        ("queue_capacity", Value::num(cfg.queue_capacity as f64)),
        ("scored_capacity", Value::num(cfg.scored_capacity as f64)),
        ("store_capacity", Value::num(cfg.store.capacity as f64)),
        ("store_shards", Value::num(cfg.store.shards as f64)),
        (
            "max_staleness",
            Value::num(cfg.store.max_staleness.unwrap_or(0) as f64),
        ),
        ("admission", Value::str(cfg.store.admission.name())),
        ("sampling", Value::str(cfg.store.sampling.name())),
        ("sync_trainer_shards", Value::num(cfg.sync.trainer_shards as f64)),
        (
            "sync_generator_shards",
            Value::num(cfg.sync.generator_shards as f64),
        ),
        ("sync_encoding", Value::str(encoding_name(cfg.sync.encoding))),
        ("sync_background", Value::Bool(cfg.sync.background)),
        ("sync_link_groups", Value::num(cfg.sync.link_groups as f64)),
        ("sync_topk_frac", Value::num(cfg.sync.topk_frac)),
        ("colocate", Value::Bool(cfg.mem.colocate)),
        ("offload_classes", Value::str(classes)),
        ("offload_chunk_mb", Value::num(cfg.mem.offload_chunk_mb as f64)),
        ("prefetch_depth", Value::num(cfg.mem.prefetch_depth as f64)),
        ("offload_background", Value::Bool(cfg.mem.background)),
        ("n_generations", Value::num(cfg.n_generations as f64)),
        ("baseline", Value::str(baseline_name(cfg.baseline))),
        ("max_steps", Value::num(cfg.max_steps as f64)),
        ("lr", Value::num(cfg.aipo.lr as f64)),
        ("rho", Value::num(cfg.aipo.rho as f64)),
        ("grad_clip", Value::num(cfg.aipo.grad_clip as f64)),
        ("temperature", Value::num(cfg.temperature as f64)),
        ("top_k", Value::num(cfg.top_k as f64)),
        ("quantize_generator", Value::Bool(cfg.quantize_generator)),
        ("max_response", Value::num(cfg.max_response as f64)),
        ("eval_every", Value::num(cfg.eval_every as f64)),
        ("eval_max_per_suite", Value::num(cfg.eval_max_per_suite as f64)),
        ("checkpoint_every", Value::num(cfg.checkpoint_every as f64)),
        ("seed", Value::num(cfg.seed as f64)),
        (
            "out_dir",
            Value::str(cfg.out_dir.to_string_lossy().into_owned()),
        ),
        ("metrics_interval_secs", Value::num(cfg.metrics_interval_secs)),
        ("journal", Value::Bool(cfg.journal)),
        ("journal_snapshot_secs", Value::num(cfg.journal_snapshot_secs)),
        ("restart_max", Value::num(cfg.restart_max as f64)),
        ("restart_backoff_ms", Value::num(cfg.restart_backoff_ms as f64)),
        ("chaos_kills", Value::num(cfg.chaos_kills as f64)),
        ("chaos_seed", Value::num(cfg.chaos_seed as f64)),
        ("chaos_reward_kills", Value::num(cfg.chaos_reward_kills as f64)),
        ("elastic_resize", Value::Bool(cfg.elastic_resize)),
        ("resize_max_extra", Value::num(cfg.resize_max_extra as f64)),
    ];
    if let Some(p) = &cfg.init_checkpoint {
        pairs.push(("init_checkpoint", Value::str(p.to_string_lossy().into_owned())));
    }
    if let Some(p) = &cfg.trace {
        pairs.push(("trace", Value::str(p.to_string_lossy().into_owned())));
    }
    Value::object(pairs)
}

/// Full resolution: preset -> optional --config file -> CLI flags.
pub fn resolve(args: &Args) -> Result<PipelineConfig> {
    let preset_name = args.str_or("preset", "nano");
    let mut cfg = preset(&preset_name)?;
    if let Some(path) = args.str_opt("config") {
        let text = std::fs::read_to_string(path)?;
        apply_json(&mut cfg, &Value::parse(&text)?)?;
    }
    apply_cli(&mut cfg, args)?;
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for p in ["nano", "small", "e2e"] {
            assert!(preset(p).is_ok());
        }
        assert!(preset("bogus").is_err());
    }

    #[test]
    fn json_overrides() {
        let mut cfg = preset("nano").unwrap();
        let v = Value::parse(r#"{"mode":"sync","rho":7.5,"max_steps":99}"#).unwrap();
        apply_json(&mut cfg, &v).unwrap();
        assert_eq!(cfg.mode, Mode::Sync);
        assert_eq!(cfg.aipo.rho, 7.5);
        assert_eq!(cfg.max_steps, 99);
    }

    #[test]
    fn dataplane_overrides() {
        let mut cfg = preset("nano").unwrap();
        let v = Value::parse(
            r#"{"mode":"async_buffered","store_capacity":64,"store_shards":2,
                "max_staleness":3,"admission":"block","sampling":"freshest"}"#,
        )
        .unwrap();
        apply_json(&mut cfg, &v).unwrap();
        assert_eq!(cfg.mode, Mode::AsyncBuffered);
        assert_eq!(cfg.store.capacity, 64);
        assert_eq!(cfg.store.shards, 2);
        assert_eq!(cfg.store.max_staleness, Some(3));
        assert_eq!(cfg.store.admission, AdmissionPolicy::Block);
        assert_eq!(cfg.store.sampling, SamplingStrategy::FreshestFirst);

        // CLI layer: 0 disables the bound, mode alias resolves
        let args = Args::parse(
            ["--mode", "buffered", "--max-staleness", "0", "--sampling", "staleness_weighted"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        apply_cli(&mut cfg, &args).unwrap();
        assert_eq!(cfg.mode, Mode::AsyncBuffered);
        assert_eq!(cfg.store.max_staleness, None);
        assert_eq!(cfg.store.sampling, SamplingStrategy::StalenessWeighted);
    }

    #[test]
    fn weightsync_overrides() {
        let mut cfg = preset("nano").unwrap();
        assert!(cfg.sync.background, "background streaming is the default");
        let v = Value::parse(
            r#"{"sync_trainer_shards":8,"sync_generator_shards":4,"sync_quantized":true,
                "sync_link_groups":3}"#,
        )
        .unwrap();
        apply_json(&mut cfg, &v).unwrap();
        assert_eq!(cfg.sync.trainer_shards, 8);
        assert_eq!(cfg.sync.generator_shards, 4);
        // back-compat alias lands on the encoding enum
        assert_eq!(cfg.sync.encoding, ShardEncoding::Int8);
        assert_eq!(cfg.sync.link_groups, 3);

        let args = Args::parse(
            ["--sync-trainer-shards", "2", "--sync-generator-shards", "1"]
                .iter()
                .map(|s| s.to_string()),
            &["sync-quantized", "sync-inline"],
        )
        .unwrap();
        apply_cli(&mut cfg, &args).unwrap();
        assert_eq!(cfg.sync.trainer_shards, 2);
        assert_eq!(cfg.sync.generator_shards, 1);
        // a missing flag never unsets an earlier layer's choice
        assert_eq!(cfg.sync.encoding, ShardEncoding::Int8);
        assert!(cfg.sync.background);
    }

    #[test]
    fn weightsync_encoding_and_executor_overrides() {
        let mut cfg = preset("nano").unwrap();
        let v = Value::parse(
            r#"{"sync_encoding":"topk","sync_topk_frac":0.05,"sync_background":false}"#,
        )
        .unwrap();
        apply_json(&mut cfg, &v).unwrap();
        assert_eq!(cfg.sync.encoding, ShardEncoding::TopK);
        assert_eq!(cfg.sync.topk_frac, 0.05);
        assert!(!cfg.sync.background);

        // CLI layer: encoding name resolves, --sync-inline opts out
        let args = Args::parse(
            ["--sync-encoding", "delta"].iter().map(|s| s.to_string()),
            &["sync-inline"],
        )
        .unwrap();
        apply_cli(&mut cfg, &args).unwrap();
        assert_eq!(cfg.sync.encoding, ShardEncoding::Delta);
        assert!(!cfg.sync.background);

        let bad = Value::parse(r#"{"sync_encoding":"bf16"}"#).unwrap();
        assert!(apply_json(&mut cfg, &bad).is_err());
    }

    #[test]
    fn memplane_overrides() {
        let mut cfg = preset("nano").unwrap();
        assert!(!cfg.mem.colocate, "colocation is opt-in");
        assert!(cfg.mem.background, "background offloading is the default");
        let v = Value::parse(
            r#"{"colocate":true,"offload_classes":"optim","offload_chunk_mb":2,
                "prefetch_depth":3}"#,
        )
        .unwrap();
        apply_json(&mut cfg, &v).unwrap();
        assert!(cfg.mem.colocate);
        assert_eq!(cfg.mem.offload_classes, vec![AllocClass::OptimState]);
        assert_eq!(cfg.mem.offload_chunk_mb, 2);
        assert_eq!(cfg.mem.prefetch_depth, 3);

        let args = Args::parse(
            ["--offload-classes", "grads,optim", "--prefetch-depth", "5"]
                .iter()
                .map(|s| s.to_string()),
            &["offload-eager"],
        )
        .unwrap();
        apply_cli(&mut cfg, &args).unwrap();
        assert_eq!(
            cfg.mem.offload_classes,
            vec![AllocClass::Grads, AllocClass::OptimState]
        );
        assert_eq!(cfg.mem.prefetch_depth, 5);
        // a missing flag never unsets an earlier layer's choice
        assert!(cfg.mem.colocate);
        assert!(cfg.mem.background);

        let bad = Value::parse(r#"{"offload_classes":"hbm"}"#).unwrap();
        assert!(apply_json(&mut cfg, &bad).is_err());
    }

    #[test]
    fn reward_fleet_and_auto_encoding_overrides() {
        let mut cfg = preset("nano").unwrap();
        assert_eq!(cfg.n_reward_workers, 1, "single scorer is the default");
        let v = Value::parse(r#"{"n_reward_workers":3,"sync_encoding":"auto"}"#).unwrap();
        apply_json(&mut cfg, &v).unwrap();
        assert_eq!(cfg.n_reward_workers, 3);
        assert_eq!(cfg.sync.encoding, ShardEncoding::Auto);

        let args = Args::parse(
            ["--reward-workers", "2"].iter().map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        apply_cli(&mut cfg, &args).unwrap();
        assert_eq!(cfg.n_reward_workers, 2);
        // 0 clamps to 1 — a topology always has a reward fleet
        let v = Value::parse(r#"{"n_reward_workers":0}"#).unwrap();
        apply_json(&mut cfg, &v).unwrap();
        assert_eq!(cfg.n_reward_workers, 1);
    }

    #[test]
    fn trace_overrides() {
        let mut cfg = preset("nano").unwrap();
        assert!(cfg.trace.is_none(), "tracing is opt-in");
        assert_eq!(cfg.metrics_interval_secs, 0.0);
        let v = Value::parse(r#"{"trace":"out/t.json","metrics_interval_secs":0.5}"#).unwrap();
        apply_json(&mut cfg, &v).unwrap();
        assert_eq!(cfg.trace.as_deref(), Some(std::path::Path::new("out/t.json")));
        assert_eq!(cfg.metrics_interval_secs, 0.5);

        let args = Args::parse(
            ["--trace", "t2.json", "--metrics-interval", "1.5"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        apply_cli(&mut cfg, &args).unwrap();
        assert_eq!(cfg.trace.as_deref(), Some(std::path::Path::new("t2.json")));
        assert_eq!(cfg.metrics_interval_secs, 1.5);
    }

    #[test]
    fn to_json_round_trips() {
        let mut cfg = preset("e2e").unwrap();
        cfg.mode = Mode::AsyncBuffered;
        cfg.store.max_staleness = Some(3);
        cfg.sync.encoding = ShardEncoding::TopK;
        cfg.sync.topk_frac = 0.05;
        cfg.mem.colocate = true;
        cfg.mem.offload_classes = vec![AllocClass::Grads, AllocClass::OptimState];
        cfg.journal_snapshot_secs = 0.5;
        cfg.seed = 42;
        cfg.restart_max = 3;
        cfg.restart_backoff_ms = 25;
        cfg.chaos_kills = 4;
        cfg.chaos_seed = 99;
        cfg.chaos_reward_kills = 2;
        cfg.elastic_resize = true;
        cfg.resize_max_extra = 1;
        cfg.n_trainer_workers = 2;
        cfg.period_steps = 8;
        let v = to_json(&cfg);
        let mut rebuilt = PipelineConfig::default();
        apply_json(&mut rebuilt, &v).unwrap();
        assert_eq!(rebuilt.mode, cfg.mode);
        assert_eq!(rebuilt.artifact_dir, cfg.artifact_dir);
        assert_eq!(rebuilt.store.max_staleness, Some(3));
        assert_eq!(rebuilt.sync.encoding, ShardEncoding::TopK);
        assert_eq!(rebuilt.sync.topk_frac, 0.05);
        assert!(rebuilt.mem.colocate);
        assert_eq!(rebuilt.mem.offload_classes, cfg.mem.offload_classes);
        assert_eq!(rebuilt.max_steps, cfg.max_steps);
        assert_eq!(rebuilt.aipo.lr, cfg.aipo.lr);
        assert_eq!(rebuilt.eval_every, cfg.eval_every);
        assert_eq!(rebuilt.seed, 42);
        assert_eq!(rebuilt.journal_snapshot_secs, 0.5);
        assert!(rebuilt.journal);
        assert!(rebuilt.init_checkpoint.is_none());
        assert_eq!(rebuilt.restart_max, 3);
        assert_eq!(rebuilt.restart_backoff_ms, 25);
        assert_eq!(rebuilt.chaos_kills, 4);
        assert_eq!(rebuilt.chaos_seed, 99);
        assert_eq!(rebuilt.chaos_reward_kills, 2);
        assert!(rebuilt.elastic_resize);
        assert_eq!(rebuilt.resize_max_extra, 1);
        assert_eq!(rebuilt.n_trainer_workers, 2);
        assert_eq!(rebuilt.period_steps, 8);
    }

    #[test]
    fn trainer_fleet_and_periodic_overrides() {
        let mut cfg = preset("nano").unwrap();
        assert_eq!(cfg.n_trainer_workers, 1, "single trainer is the default");
        let v = Value::parse(
            r#"{"mode":"periodic","n_trainer_workers":2,"period_steps":6}"#,
        )
        .unwrap();
        apply_json(&mut cfg, &v).unwrap();
        assert_eq!(cfg.mode, Mode::Periodic);
        assert_eq!(cfg.n_trainer_workers, 2);
        assert_eq!(cfg.period_steps, 6);

        let args = Args::parse(
            ["--trainers", "3", "--period-steps", "2", "--chaos-reward-kills", "1"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        apply_cli(&mut cfg, &args).unwrap();
        assert_eq!(cfg.n_trainer_workers, 3);
        assert_eq!(cfg.period_steps, 2);
        assert_eq!(cfg.chaos_reward_kills, 1);
        // 0 clamps to 1 on both knobs — a topology always has a trainer
        // fleet, and a period fence needs a non-empty period
        let v = Value::parse(r#"{"n_trainer_workers":0,"period_steps":0}"#).unwrap();
        apply_json(&mut cfg, &v).unwrap();
        assert_eq!(cfg.n_trainer_workers, 1);
        assert_eq!(cfg.period_steps, 1);
    }

    #[test]
    fn elastic_flags_apply() {
        let mut cfg = preset("nano").unwrap();
        assert_eq!(cfg.restart_max, 0, "restarts are opt-in");
        assert!(!cfg.elastic_resize, "resize is opt-in");
        let args = Args::parse(
            [
                "--restart-max",
                "2",
                "--restart-backoff-ms",
                "10",
                "--chaos-kills",
                "3",
                "--chaos-seed",
                "7",
                "--resize-max-extra",
                "1",
                "--elastic-resize",
            ]
            .iter()
            .map(|s| s.to_string()),
            &["elastic-resize"],
        )
        .unwrap();
        apply_cli(&mut cfg, &args).unwrap();
        assert_eq!(cfg.restart_max, 2);
        assert_eq!(cfg.restart_backoff_ms, 10);
        assert_eq!(cfg.chaos_kills, 3);
        assert_eq!(cfg.chaos_seed, 7);
        assert!(cfg.elastic_resize);
        assert_eq!(cfg.resize_max_extra, 1);
    }

    #[test]
    fn journal_overrides() {
        let mut cfg = preset("nano").unwrap();
        assert!(cfg.journal, "journaling is on by default");
        let v = Value::parse(r#"{"journal":false,"journal_snapshot_secs":1.5}"#).unwrap();
        apply_json(&mut cfg, &v).unwrap();
        assert!(!cfg.journal);
        assert_eq!(cfg.journal_snapshot_secs, 1.5);

        let args = Args::parse(
            ["--journal-snapshot-secs", "0.5"].iter().map(|s| s.to_string()),
            &["no-journal"],
        )
        .unwrap();
        let mut cfg2 = preset("nano").unwrap();
        apply_cli(&mut cfg2, &args).unwrap();
        assert_eq!(cfg2.journal_snapshot_secs, 0.5);
        // --no-journal was not passed, so the default stands
        assert!(cfg2.journal);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut cfg = preset("nano").unwrap();
        let v = Value::parse(r#"{"typo_key":1}"#).unwrap();
        assert!(apply_json(&mut cfg, &v).is_err());
    }

    #[test]
    fn cli_overrides() {
        let mut cfg = preset("nano").unwrap();
        let args = Args::parse(
            ["--mode", "sync", "--rho", "2.0", "--quantize-generator"]
                .iter()
                .map(|s| s.to_string()),
            &["quantize-generator"],
        )
        .unwrap();
        apply_cli(&mut cfg, &args).unwrap();
        assert_eq!(cfg.mode, Mode::Sync);
        assert_eq!(cfg.aipo.rho, 2.0);
        assert!(cfg.quantize_generator);
    }
}
