//! The streaming trajectory data plane: a staleness-aware rollout store
//! between generation and training.
//!
//! LlamaRL (§4) bounds off-policy lag only *implicitly*, through bounded
//! channel backpressure. This module makes the data plane explicit, the
//! way AsyncFlow's TransferQueue and Laminar's relay buffer do: scored
//! trajectories land in a sharded [`RolloutStore`] that owns them until
//! the trainer samples a microbatch, and staleness becomes a first-class,
//! measured, *enforced* quantity instead of a side effect of channel
//! capacity.
//!
//! ```text
//!   Generator workers ──GATHER──► Reward executor
//!                                     │ push_group (admission policy)
//!                                     ▼
//!                          ┌─────────────────────┐   advance_watermark
//!                          │     RolloutStore    │◄────────┐
//!                          │  shard │ shard │ …  │         │
//!                          └─────────────────────┘     Trainer(s)
//!                                     │ sample (strategy)   ▲
//!                                     └─────────────────────┘
//! ```
//!
//! * [`store`] — the [`RolloutStore`]: sharded resident set, per-row
//!   weight-version watermarks, capacity reserved by CAS (occupancy can
//!   never exceed capacity), plus the [`PartialRollout`] resumption slot.
//! * [`policy`] — pluggable [`AdmissionPolicy`] (block / drop-newest /
//!   evict-oldest) and [`SamplingStrategy`] (FIFO / freshest-first /
//!   staleness-weighted).
//! * [`stats`] — [`DataPlaneStats`] counters and the [`DataPlaneSnapshot`]
//!   (occupancy, drop/evict counts, sampled-lag histogram) surfaced
//!   through [`crate::metrics`] and [`crate::coordinator::RunReport`].
//! * [`driver`] — a synthetic threaded harness comparing channel vs store
//!   transport with no PJRT backend (benches, examples, stress tests).
//!
//! The coordinator consumes this module through
//! `Mode::AsyncBuffered` ([`crate::coordinator::run_training`]); the
//! discrete-event analogue lives in
//! [`crate::simulator::simulate_async_buffered`].

pub mod driver;
pub mod policy;
pub mod stats;
pub mod store;

pub use driver::{run_driver, DriverConfig, DriverReport, Transport};
pub use policy::{AdmissionPolicy, SamplingStrategy};
pub use stats::{DataPlaneSnapshot, DataPlaneStats, LAG_BUCKETS};
pub use store::{
    ConsumeReason, PartialRollout, RolloutStore, StoreConfig, StoreDump, StoreObserver,
};
