//! Synthetic end-to-end driver for the trajectory data plane.
//!
//! Real producer threads, a real consumer, real transport (bounded channel
//! or [`RolloutStore`]) — only the *compute* is synthetic: generation and
//! training are modeled as sleeps with lognormal straggler jitter, so the
//! driver runs on any machine with no artifacts and no PJRT backend. This
//! is what `benches/dataplane_staleness.rs` and
//! `examples/buffered_pipeline.rs` use to compare the direct-channel async
//! pipeline against the buffered one on throughput and realized
//! off-policy lag, and what the data-plane concurrency tests stress.
//!
//! The weight clock is a shared counter standing in for the DDMA bus:
//! producers stamp each group with the version they "sampled" under, the
//! consumer bumps it once per train step, and lag is measured exactly like
//! the real pipeline measures it (consume-time version minus stamp).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::channel::{gather_channel, Inbound, Message, Outbound};
use crate::data::{Difficulty, Problem};
use crate::dataplane::stats::DataPlaneSnapshot;
use crate::dataplane::store::{RolloutStore, StoreConfig};
use crate::rl::{FinishReason, Trajectory};
use crate::util::rng::Rng;

/// Which data plane the driver routes scored groups through.
#[derive(Debug, Clone)]
pub enum Transport {
    /// direct bounded channel (the Mode::Async data path); capacity in
    /// groups
    Channel { capacity: usize },
    /// the rollout store (the Mode::AsyncBuffered data path)
    Store(StoreConfig),
}

impl Transport {
    pub fn name(&self) -> String {
        match self {
            Transport::Channel { capacity } => format!("channel(cap={capacity})"),
            Transport::Store(c) => format!(
                "store(cap={} {} {} stale<={})",
                c.capacity,
                c.admission.name(),
                c.sampling.name(),
                c.max_staleness.map_or("inf".into(), |b| b.to_string()),
            ),
        }
    }
}

#[derive(Debug, Clone)]
pub struct DriverConfig {
    pub transport: Transport,
    /// synthetic generator threads
    pub producers: usize,
    /// rows per scored group
    pub group_rows: usize,
    /// consumer train steps to run
    pub train_steps: u64,
    /// rows per training microbatch
    pub rows_per_step: usize,
    /// mean simulated per-group generation time
    pub gen_group_micros: u64,
    /// lognormal sigma of the generation time (straggler heaviness)
    pub gen_sigma: f64,
    /// simulated per-step train time
    pub train_step_micros: u64,
    pub seed: u64,
}

impl Default for DriverConfig {
    fn default() -> Self {
        DriverConfig {
            transport: Transport::Store(StoreConfig::default()),
            producers: 2,
            group_rows: 4,
            train_steps: 20,
            rows_per_step: 8,
            gen_group_micros: 2_000,
            gen_sigma: 0.6,
            train_step_micros: 3_000,
            seed: 0,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct DriverReport {
    pub transport: String,
    pub steps: u64,
    pub rows_trained: u64,
    pub groups_produced: u64,
    pub wall_secs: f64,
    pub rows_per_sec: f64,
    pub mean_lag: f64,
    pub max_lag: u64,
    /// store-side telemetry (None for the channel transport)
    pub dataplane: Option<DataPlaneSnapshot>,
}

fn synthetic_group(group_id: u64, rows: usize, gen_version: u64) -> Vec<Trajectory> {
    (0..rows)
        .map(|replica| Trajectory {
            group_id,
            replica,
            n_replicas: rows,
            problem: Problem {
                prompt: "1+1=".into(),
                answer: "2".into(),
                difficulty: Difficulty::Add1,
            },
            prompt_tokens: vec![1],
            response_tokens: vec![2],
            behavior_logp: vec![-0.7],
            gen_version,
            chunks: 1,
            finish: FinishReason::Eos,
            reward: if replica % 2 == 0 { 1.0 } else { 0.0 },
            advantage: 0.0,
        })
        .collect()
}

enum Sink {
    Channel(Outbound),
    Store(Arc<RolloutStore>),
}

/// Run one producer loop until the consumer tears the transport down.
fn produce(
    sink: Sink,
    cfg: DriverConfig,
    worker: usize,
    version: Arc<AtomicU64>,
    next_group: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
) -> u64 {
    let mut rng = Rng::new(cfg.seed ^ (worker as u64).wrapping_mul(0x9E3779B9));
    let mu = -0.5 * cfg.gen_sigma * cfg.gen_sigma;
    let mut produced = 0u64;
    while !stop.load(Ordering::Relaxed) {
        let jitter = rng.lognormal(mu, cfg.gen_sigma);
        let micros = (cfg.gen_group_micros as f64 * jitter) as u64;
        std::thread::sleep(Duration::from_micros(micros.max(1)));
        let gid = next_group.fetch_add(1, Ordering::Relaxed);
        let group = synthetic_group(gid, cfg.group_rows, version.load(Ordering::Acquire));
        let delivered = match &sink {
            Sink::Channel(out) => out.send(Message::Scored(group)).is_ok(),
            Sink::Store(store) => store.push_group(group).is_ok(),
        };
        if !delivered {
            break; // consumer tore the transport down
        }
        produced += 1;
    }
    produced
}

/// Pull up to `need` rows from the transport; None = EOF.
fn pull(
    inbound: &mut Option<Inbound>,
    store: &Option<Arc<RolloutStore>>,
    need: usize,
) -> Option<Vec<Trajectory>> {
    if let Some(store) = store {
        return store.sample(need, Duration::from_millis(100));
    }
    let rx = inbound.as_ref()?;
    let mut rows = Vec::new();
    while rows.len() < need {
        match rx.recv_timeout(Duration::from_millis(100)) {
            Ok(Message::Scored(g)) => rows.extend(g),
            Ok(Message::Trajectories(g)) => rows.extend(g),
            Ok(Message::Eof) => return None,
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return None,
            Err(_) => break, // timeout: train on what we have
        }
    }
    Some(rows)
}

/// Drive `cfg.train_steps` consumer steps against `cfg.producers` synthetic
/// generators and report throughput + realized off-policy lag.
pub fn run_driver(cfg: &DriverConfig) -> DriverReport {
    let version = Arc::new(AtomicU64::new(0));
    let next_group = Arc::new(AtomicU64::new(0));
    let stop = Arc::new(AtomicBool::new(false));

    type Plane = (Option<Outbound>, Option<Inbound>, Option<Arc<RolloutStore>>);
    let (outbound, mut inbound, store): Plane = match &cfg.transport {
        Transport::Channel { capacity } => {
            let (tx, rx) = gather_channel("driver", (*capacity).max(1));
            (Some(tx), Some(rx), None)
        }
        Transport::Store(sc) => (None, None, Some(Arc::new(RolloutStore::new(sc.clone())))),
    };

    let mut handles = Vec::new();
    for w in 0..cfg.producers.max(1) {
        let sink = match (&outbound, &store) {
            (Some(tx), _) => Sink::Channel(tx.clone()),
            (None, Some(s)) => Sink::Store(s.clone()),
            (None, None) => unreachable!("transport built above"),
        };
        let cfg = cfg.clone();
        let version = version.clone();
        let next_group = next_group.clone();
        let stop = stop.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("driver-gen-{w}"))
                .spawn(move || produce(sink, cfg, w, version, next_group, stop))
                .expect("spawn driver producer"),
        );
    }
    drop(outbound);

    let t0 = Instant::now();
    let mut rows_trained = 0u64;
    let mut steps = 0u64;
    let mut lag_sum = 0u64;
    let mut max_lag = 0u64;
    while steps < cfg.train_steps {
        let Some(rows) = pull(&mut inbound, &store, cfg.rows_per_step) else {
            break;
        };
        if rows.is_empty() {
            continue; // starved this tick; the wall clock still charges it
        }
        // simulated train step
        std::thread::sleep(Duration::from_micros(cfg.train_step_micros.max(1)));
        for t in &rows {
            let lag = steps.saturating_sub(t.gen_version);
            lag_sum += lag;
            max_lag = max_lag.max(lag);
        }
        rows_trained += rows.len() as u64;
        steps += 1;
        // "publish": advance the weight clock, exactly once per optimizer
        // step — the driver's stand-in for a DDMA publication
        version.store(steps, Ordering::Release);
        if let Some(store) = &store {
            store.advance_watermark(steps);
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    // tear the transport down so producers exit
    stop.store(true, Ordering::Relaxed);
    if let Some(store) = &store {
        store.close();
    }
    drop(inbound);
    let mut groups_produced = 0u64;
    for h in handles {
        groups_produced += h.join().expect("driver producer panicked");
    }

    DriverReport {
        transport: cfg.transport.name(),
        steps,
        rows_trained,
        groups_produced,
        wall_secs: wall,
        rows_per_sec: if wall > 0.0 {
            rows_trained as f64 / wall
        } else {
            0.0
        },
        mean_lag: if rows_trained > 0 {
            lag_sum as f64 / rows_trained as f64
        } else {
            0.0
        },
        max_lag,
        dataplane: store.map(|s| s.snapshot()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataplane::policy::{AdmissionPolicy, SamplingStrategy};

    fn quick(transport: Transport) -> DriverConfig {
        DriverConfig {
            transport,
            producers: 2,
            group_rows: 4,
            train_steps: 12,
            rows_per_step: 4,
            gen_group_micros: 200,
            gen_sigma: 0.4,
            train_step_micros: 300,
            seed: 7,
        }
    }

    #[test]
    fn channel_transport_trains_all_steps() {
        let r = run_driver(&quick(Transport::Channel { capacity: 4 }));
        assert_eq!(r.steps, 12);
        assert!(r.rows_trained >= 12);
        assert!(r.dataplane.is_none());
        assert!(r.rows_per_sec > 0.0);
    }

    #[test]
    fn store_transport_trains_and_respects_staleness_bound() {
        let bound = 2u64;
        let r = run_driver(&quick(Transport::Store(StoreConfig {
            capacity: 64,
            shards: 4,
            max_staleness: Some(bound),
            admission: AdmissionPolicy::EvictOldest,
            sampling: SamplingStrategy::Fifo,
            seed: 7,
        })));
        assert_eq!(r.steps, 12);
        let dp = r.dataplane.expect("store telemetry");
        assert!(dp.admitted > 0);
        assert!(
            dp.max_sampled_lag <= bound,
            "sampled lag {} exceeds bound {bound}",
            dp.max_sampled_lag
        );
        // realized (consume-time) lag can exceed the sampling-time lag by
        // at most the in-flight step, never more
        assert!(r.max_lag <= bound + 1, "realized lag {}", r.max_lag);
    }
}
