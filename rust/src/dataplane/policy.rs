//! Pluggable admission and sampling policies for the [`RolloutStore`]
//! (AsyncFlow's TransferQueue and Laminar's relay buffer expose the same
//! two knobs: what to keep under pressure, and what to hand the trainer
//! next).
//!
//! [`RolloutStore`]: crate::dataplane::RolloutStore

use crate::util::error::{Error, Result};

/// What the store does when a scored group arrives.
///
/// Max-staleness dropping is orthogonal and always active when
/// `StoreConfig::max_staleness` is set: rows whose off-policy lag already
/// exceeds the bound are discarded at admission (and again at sampling
/// time, since the watermark advances while rows sit in the store).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Block the producer until capacity frees up — channel-like
    /// backpressure (FIFO admission).
    Block,
    /// Reject the incoming rows when full; the resident set is never
    /// touched. Biases the store toward *older* data.
    DropNewest,
    /// Evict the oldest resident rows to make room — capacity-pressure
    /// eviction. Producers never block; biases the store toward *fresh*
    /// data.
    EvictOldest,
}

impl AdmissionPolicy {
    pub fn parse(s: &str) -> Result<AdmissionPolicy> {
        match s {
            "block" => Ok(AdmissionPolicy::Block),
            "drop_newest" => Ok(AdmissionPolicy::DropNewest),
            "evict_oldest" => Ok(AdmissionPolicy::EvictOldest),
            other => Err(Error::Config(format!(
                "admission must be block|drop_newest|evict_oldest, got '{other}'"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::Block => "block",
            AdmissionPolicy::DropNewest => "drop_newest",
            AdmissionPolicy::EvictOldest => "evict_oldest",
        }
    }
}

/// How the store assembles the trainer's next microbatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SamplingStrategy {
    /// Oldest-admitted rows first — streaming FIFO, the direct-channel
    /// behaviour.
    Fifo,
    /// Highest generator weight-version first; minimizes realized lag at
    /// the cost of starving old rows (they age out via max-staleness).
    FreshestFirst,
    /// Weighted priority: a row with off-policy lag `l` is drawn with
    /// weight `1 / (1 + l)` — fresh data is favored but stale rows still
    /// flow, trading a little lag for sample diversity.
    StalenessWeighted,
}

impl SamplingStrategy {
    pub fn parse(s: &str) -> Result<SamplingStrategy> {
        match s {
            "fifo" => Ok(SamplingStrategy::Fifo),
            "freshest" => Ok(SamplingStrategy::FreshestFirst),
            "staleness_weighted" => Ok(SamplingStrategy::StalenessWeighted),
            other => Err(Error::Config(format!(
                "sampling must be fifo|freshest|staleness_weighted, got '{other}'"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SamplingStrategy::Fifo => "fifo",
            SamplingStrategy::FreshestFirst => "freshest",
            SamplingStrategy::StalenessWeighted => "staleness_weighted",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips() {
        for p in [
            AdmissionPolicy::Block,
            AdmissionPolicy::DropNewest,
            AdmissionPolicy::EvictOldest,
        ] {
            assert_eq!(AdmissionPolicy::parse(p.name()).unwrap(), p);
        }
        for s in [
            SamplingStrategy::Fifo,
            SamplingStrategy::FreshestFirst,
            SamplingStrategy::StalenessWeighted,
        ] {
            assert_eq!(SamplingStrategy::parse(s.name()).unwrap(), s);
        }
        assert!(AdmissionPolicy::parse("bogus").is_err());
        assert!(SamplingStrategy::parse("bogus").is_err());
    }
}
