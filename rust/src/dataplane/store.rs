//! The sharded, staleness-aware rollout store.
//!
//! A [`RolloutStore`] owns scored trajectories between the reward executor
//! and the trainer(s). Unlike a bounded channel — where capacity is the
//! *only* lever and off-policy lag is a side effect of backpressure — the
//! store makes staleness first-class:
//!
//! * every resident row carries its generator weight-version; the trainer
//!   advances a **watermark** (its optimizer step) and a row's off-policy
//!   lag is `watermark - gen_version`, recomputed as the watermark moves;
//! * admission/eviction policy and sampling strategy are pluggable
//!   ([`AdmissionPolicy`], [`SamplingStrategy`]);
//! * rows whose lag exceeds `max_staleness` are discarded at admission and
//!   again at sampling time, so the trainer **never** consumes a row above
//!   the bound (property-tested in `tests/prop_dataplane.rs`);
//! * a resumption slot parks partial rollouts (prompt id -> in-flight
//!   tokens) so draining generators abandon no work.
//!
//! Concurrency: rows live in `shards` independently-locked shards keyed by
//! `group_id`, so producers contend only per shard. Sampling and eviction
//! need a global view and take the shard locks in ascending index order
//! (the single lock-ordering rule of this module — it is what makes the
//! mixed push/sample/evict paths deadlock-free). Occupancy is reserved
//! with a CAS *before* any row is inserted, which is what makes
//! "occupancy never exceeds capacity" a hard invariant rather than a race.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

use crate::data::PromptTask;
use crate::dataplane::policy::{AdmissionPolicy, SamplingStrategy};
use crate::dataplane::stats::{DataPlaneSnapshot, DataPlaneStats};
use crate::rl::Trajectory;
use crate::trace;
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct StoreConfig {
    /// maximum resident rows across all shards (hard bound)
    pub capacity: usize,
    /// number of independently-locked shards
    pub shards: usize,
    /// drop rows whose off-policy lag exceeds this many trainer steps
    /// (None = unbounded)
    pub max_staleness: Option<u64>,
    pub admission: AdmissionPolicy,
    pub sampling: SamplingStrategy,
    /// seed for staleness-weighted sampling
    pub seed: u64,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig {
            capacity: 128,
            shards: 4,
            max_staleness: Some(8),
            admission: AdmissionPolicy::EvictOldest,
            sampling: SamplingStrategy::Fifo,
            seed: 0,
        }
    }
}

/// An unfinished generation parked in the store's resumption slot: the
/// prompt plus everything sampled so far, so any generator can pick the
/// sequence back up instead of re-decoding from scratch (the data-plane
/// form of the paper's §4.2 partial rollouts).
#[derive(Debug, Clone)]
pub struct PartialRollout {
    pub task: PromptTask,
    /// prompt + generated-so-far token ids
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// behaviour log-probs for the generated suffix
    pub logps: Vec<f32>,
    /// generate_chunk calls spent so far
    pub chunks: u32,
    /// weight version the suffix was sampled under
    pub gen_version: u64,
}

/// Why resident rows left the store, as reported to a [`StoreObserver`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConsumeReason {
    /// handed to the trainer by `sample`
    Sample,
    /// displaced by `EvictOldest` admission
    Evict,
    /// aged past `max_staleness`
    Stale,
}

impl ConsumeReason {
    pub fn name(&self) -> &'static str {
        match self {
            ConsumeReason::Sample => "sample",
            ConsumeReason::Evict => "evict",
            ConsumeReason::Stale => "stale",
        }
    }

    pub fn parse(s: &str) -> Option<ConsumeReason> {
        match s {
            "sample" => Some(ConsumeReason::Sample),
            "evict" => Some(ConsumeReason::Evict),
            "stale" => Some(ConsumeReason::Stale),
            _ => None,
        }
    }
}

/// Durable-state hook: the run-journal registers one of these to record
/// every admission (with the row payloads) and every consumption (by
/// admission seq), making the journal an authoritative replica of the
/// resident set. Callbacks fire *after* all shard guards are released, so
/// implementations may take their own locks freely; the one rule is that
/// they must never call back into the store.
pub trait StoreObserver: Send + Sync {
    fn on_admit(&self, rows: &[(u64, Trajectory)]);
    fn on_consume(&self, seqs: &[u64], reason: ConsumeReason);
}

/// A consistent copy of the store's durable state: resident rows tagged
/// with their admission seqs, parked partials, and both clocks.
pub struct StoreDump {
    pub next_seq: u64,
    pub watermark: u64,
    pub rows: Vec<(u64, Trajectory)>,
    pub partials: Vec<PartialRollout>,
}

/// One resident row: the trajectory plus its global admission sequence
/// number (FIFO order across shards).
struct Entry {
    seq: u64,
    traj: Trajectory,
}

#[derive(Default)]
struct Shard {
    rows: VecDeque<Entry>,
}

pub struct RolloutStore {
    cfg: StoreConfig,
    shards: Vec<Mutex<Shard>>,
    /// resident rows; reserved via CAS before insertion
    occupancy: AtomicUsize,
    /// the trainer's clock: its latest optimizer step
    watermark: AtomicU64,
    /// global admission counter
    seq: AtomicU64,
    closed: AtomicBool,
    /// producers wait here when Block admission hits capacity; consumers
    /// wait here when the store is empty
    gate: Mutex<()>,
    cv: Condvar,
    partial: Mutex<HashMap<(u64, usize), PartialRollout>>,
    rng: Mutex<Rng>,
    observer: OnceLock<std::sync::Arc<dyn StoreObserver>>,
    pub stats: DataPlaneStats,
}

impl RolloutStore {
    pub fn new(cfg: StoreConfig) -> RolloutStore {
        assert!(cfg.capacity > 0, "store capacity must be > 0");
        let n = cfg.shards.max(1);
        let seed = cfg.seed;
        RolloutStore {
            shards: (0..n).map(|_| Mutex::new(Shard::default())).collect(),
            occupancy: AtomicUsize::new(0),
            watermark: AtomicU64::new(0),
            seq: AtomicU64::new(0),
            closed: AtomicBool::new(false),
            gate: Mutex::new(()),
            cv: Condvar::new(),
            partial: Mutex::new(HashMap::new()),
            rng: Mutex::new(Rng::new(seed ^ 0xDA7A_91A5)),
            observer: OnceLock::new(),
            cfg,
            stats: DataPlaneStats::default(),
        }
    }

    /// Register the (single) durable-state observer. Later calls are
    /// ignored — one journal per store.
    pub fn set_observer(&self, obs: std::sync::Arc<dyn StoreObserver>) {
        let _ = self.observer.set(obs);
    }

    fn observer(&self) -> Option<&std::sync::Arc<dyn StoreObserver>> {
        self.observer.get()
    }

    pub fn config(&self) -> &StoreConfig {
        &self.cfg
    }

    pub fn occupancy(&self) -> usize {
        self.occupancy.load(Ordering::Acquire)
    }

    pub fn watermark(&self) -> u64 {
        self.watermark.load(Ordering::Acquire)
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Acquire)
    }

    /// Advance the trainer clock. Rows already resident age accordingly;
    /// they are purged lazily at the next admission/sampling touch.
    pub fn advance_watermark(&self, trainer_step: u64) {
        self.watermark.fetch_max(trainer_step, Ordering::AcqRel);
    }

    /// Close the store: producers error out, consumers drain what is left
    /// and then observe EOF.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Release);
        self.cv.notify_all();
    }

    fn lag_of(&self, gen_version: u64) -> u64 {
        self.watermark().saturating_sub(gen_version)
    }

    fn is_stale(&self, gen_version: u64) -> bool {
        match self.cfg.max_staleness {
            Some(bound) => self.lag_of(gen_version) > bound,
            None => false,
        }
    }

    /// CAS-reserve `n` occupancy slots. Never overshoots capacity.
    fn try_reserve(&self, n: usize) -> bool {
        self.occupancy
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |occ| {
                if occ + n <= self.cfg.capacity {
                    Some(occ + n)
                } else {
                    None
                }
            })
            .is_ok()
    }

    fn release(&self, n: usize) {
        self.occupancy.fetch_sub(n, Ordering::AcqRel);
    }

    fn shard_for(&self, group_id: u64) -> usize {
        (group_id % self.shards.len() as u64) as usize
    }

    /// Lock every shard in ascending index order (the global lock-ordering
    /// rule; see module docs).
    fn lock_all(&self) -> Vec<MutexGuard<'_, Shard>> {
        self.shards.iter().map(|s| s.lock().unwrap()).collect()
    }

    /// Evict up to `want` globally-oldest rows. Returns the admission seqs
    /// of the rows that went.
    fn evict_oldest(&self, want: usize) -> Vec<u64> {
        let mut guards = self.lock_all();
        let mut evicted = Vec::new();
        while evicted.len() < want {
            // find the shard whose front entry is globally oldest
            let oldest = guards
                .iter()
                .enumerate()
                .filter_map(|(i, g)| g.rows.front().map(|e| (e.seq, i)))
                .min();
            match oldest {
                Some((seq, i)) => {
                    guards[i].rows.pop_front();
                    evicted.push(seq);
                }
                None => break, // store empty
            }
        }
        drop(guards);
        if !evicted.is_empty() {
            self.release(evicted.len());
            self.stats
                .evicted
                .fetch_add(evicted.len() as u64, Ordering::Relaxed);
            trace::instant(trace::STORE_EVICT, evicted.len() as f64);
            if let Some(obs) = self.observer() {
                obs.on_consume(&evicted, ConsumeReason::Evict);
            }
        }
        evicted
    }

    /// Drop resident rows that aged past max_staleness. Caller holds all
    /// shard guards. Returns the purged admission seqs; the caller reports
    /// them to the observer once the guards are released.
    fn purge_stale_locked(&self, guards: &mut [MutexGuard<'_, Shard>]) -> Vec<u64> {
        let Some(bound) = self.cfg.max_staleness else {
            return Vec::new();
        };
        let watermark = self.watermark();
        let mut purged = Vec::new();
        for g in guards.iter_mut() {
            g.rows.retain(|e| {
                let keep = watermark.saturating_sub(e.traj.gen_version) <= bound;
                if !keep {
                    purged.push(e.seq);
                }
                keep
            });
        }
        if !purged.is_empty() {
            self.release(purged.len());
            self.stats
                .dropped_stale
                .fetch_add(purged.len() as u64, Ordering::Relaxed);
            trace::instant(trace::STORE_DROP_STALE, purged.len() as f64);
        }
        purged
    }

    /// Admit a scored group. Depending on the admission policy this may
    /// block (Block), silently count a drop (DropNewest), or evict old
    /// resident rows (EvictOldest). Errors only when the store is closed.
    pub fn push_group(&self, group: Vec<Trajectory>) -> Result<()> {
        if self.is_closed() {
            return Err(Error::ChannelClosed("rollout store".into()));
        }
        // max-staleness drop at admission
        let mut rows: Vec<Trajectory> = Vec::with_capacity(group.len());
        let mut stale = 0u64;
        for t in group {
            if self.is_stale(t.gen_version) {
                stale += 1;
            } else {
                rows.push(t);
            }
        }
        if stale > 0 {
            self.stats.dropped_stale.fetch_add(stale, Ordering::Relaxed);
            trace::instant(trace::STORE_DROP_STALE, stale as f64);
        }
        // a group larger than the whole store can only ever keep its
        // newest `capacity` rows
        if rows.len() > self.cfg.capacity {
            let excess = rows.len() - self.cfg.capacity;
            rows.drain(..excess);
            self.stats
                .dropped_capacity
                .fetch_add(excess as u64, Ordering::Relaxed);
            trace::instant(trace::STORE_DROP_CAPACITY, excess as f64);
        }
        if rows.is_empty() {
            return Ok(());
        }
        let n = rows.len();

        match self.cfg.admission {
            AdmissionPolicy::Block => {
                let t0 = Instant::now();
                let mut waited = false;
                while !self.try_reserve(n) {
                    if self.is_closed() {
                        return Err(Error::ChannelClosed("rollout store".into()));
                    }
                    waited = true;
                    let guard = self.gate.lock().unwrap();
                    // re-check under the gate so a concurrent sample's
                    // notify cannot be lost between reserve and wait
                    if self.occupancy() + n > self.cfg.capacity && !self.is_closed() {
                        let _ = self
                            .cv
                            .wait_timeout(guard, Duration::from_millis(50))
                            .unwrap();
                    }
                }
                if waited {
                    self.stats.admit_wait_nanos.fetch_add(
                        u64::try_from(t0.elapsed().as_nanos()).unwrap_or(u64::MAX),
                        Ordering::Relaxed,
                    );
                }
            }
            AdmissionPolicy::DropNewest => {
                if !self.try_reserve(n) {
                    self.stats
                        .dropped_capacity
                        .fetch_add(n as u64, Ordering::Relaxed);
                    trace::instant(trace::STORE_DROP_CAPACITY, n as f64);
                    return Ok(());
                }
            }
            AdmissionPolicy::EvictOldest => {
                while !self.try_reserve(n) {
                    if self.evict_oldest(n).is_empty() {
                        // nothing evictable (a racing producer reserved the
                        // space first): yield and retry
                        std::thread::yield_now();
                    }
                }
            }
        }

        let mut journaled = self.observer().map(|_| Vec::with_capacity(n));
        for t in rows {
            let seq = self.seq.fetch_add(1, Ordering::Relaxed);
            let shard = self.shard_for(t.group_id);
            if let Some(j) = journaled.as_mut() {
                j.push((seq, t.clone()));
            }
            self.shards[shard]
                .lock()
                .unwrap()
                .rows
                .push_back(Entry { seq, traj: t });
        }
        if let (Some(obs), Some(j)) = (self.observer(), journaled) {
            obs.on_admit(&j);
        }
        self.stats.admitted.fetch_add(n as u64, Ordering::Relaxed);
        trace::instant(trace::STORE_ADMIT, n as f64);
        self.stats.note_occupancy(self.occupancy());
        self.cv.notify_all();
        Ok(())
    }

    /// Take up to `max_rows` entries per the sampling strategy, in one
    /// pass over the resident set. Caller holds all shard guards; keeping
    /// batch assembly O(occupancy) total (not per row) bounds how long
    /// producers wait on the shard locks.
    fn take_batch_locked(
        &self,
        guards: &mut [MutexGuard<'_, Shard>],
        max_rows: usize,
    ) -> Vec<Entry> {
        match self.cfg.sampling {
            SamplingStrategy::Fifo => {
                // k-way merge over the shard fronts; pops are O(1)
                let mut out = Vec::new();
                while out.len() < max_rows {
                    let oldest = guards
                        .iter()
                        .enumerate()
                        .filter_map(|(i, g)| g.rows.front().map(|e| (e.seq, i)))
                        .min();
                    match oldest {
                        Some((_, i)) => out.push(guards[i].rows.pop_front().unwrap()),
                        None => break,
                    }
                }
                out
            }
            SamplingStrategy::FreshestFirst => {
                // single scan for the top keys (version desc, admission
                // order among ties), then a single extraction pass
                let mut keys: Vec<(u64, u64)> = guards
                    .iter()
                    .flat_map(|g| g.rows.iter().map(|e| (e.traj.gen_version, e.seq)))
                    .collect();
                keys.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
                keys.truncate(max_rows);
                let mut picked =
                    Self::extract_by_seq(guards, keys.iter().map(|k| k.1).collect());
                picked.sort_by(|a, b| {
                    b.traj
                        .gen_version
                        .cmp(&a.traj.gen_version)
                        .then(a.seq.cmp(&b.seq))
                });
                picked
            }
            SamplingStrategy::StalenessWeighted => {
                // Efraimidis–Spirakis weighted sampling without
                // replacement: per-row key u^(1/w); the largest max_rows
                // keys are exactly a w-weighted draw, in one scan
                let watermark = self.watermark();
                let mut rng = self.rng.lock().unwrap();
                let mut keys: Vec<(f64, u64)> = guards
                    .iter()
                    .flat_map(|g| g.rows.iter())
                    .map(|e| {
                        let lag = watermark.saturating_sub(e.traj.gen_version);
                        let w = 1.0 / (1.0 + lag as f64);
                        (rng.f64().max(1e-12).powf(1.0 / w), e.seq)
                    })
                    .collect();
                drop(rng);
                keys.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
                keys.truncate(max_rows);
                Self::extract_by_seq(guards, keys.iter().map(|k| k.1).collect())
            }
        }
    }

    /// Remove and return the entries with the given admission seqs (one
    /// drain pass per shard; seqs are unique by construction).
    fn extract_by_seq(
        guards: &mut [MutexGuard<'_, Shard>],
        seqs: std::collections::HashSet<u64>,
    ) -> Vec<Entry> {
        let mut out = Vec::with_capacity(seqs.len());
        for g in guards.iter_mut() {
            if out.len() == seqs.len() {
                break;
            }
            let mut kept = VecDeque::with_capacity(g.rows.len());
            for e in g.rows.drain(..) {
                if seqs.contains(&e.seq) {
                    out.push(e);
                } else {
                    kept.push_back(e);
                }
            }
            g.rows = kept;
        }
        out
    }

    /// Assemble the trainer's next microbatch: up to `max_rows` rows chosen
    /// by the sampling strategy, after purging rows that aged past the
    /// staleness bound (so a returned row's lag NEVER exceeds the bound).
    ///
    /// Returns `None` once the store is closed *and* drained (EOF);
    /// `Some(vec![])` when `timeout` elapsed with nothing available.
    pub fn sample(&self, max_rows: usize, timeout: Duration) -> Option<Vec<Trajectory>> {
        let deadline = Instant::now() + timeout;
        let t0 = Instant::now();
        let _span = trace::span_with(trace::STORE_SAMPLE, max_rows as f64);
        // consumer-side starvation accounting covers every exit path —
        // timeouts and EOF included — so buffered-mode "trainer starved"
        // numbers stay comparable with channel recv accounting
        let charge_wait = || {
            self.stats.sample_wait_nanos.fetch_add(
                u64::try_from(t0.elapsed().as_nanos()).unwrap_or(u64::MAX),
                Ordering::Relaxed,
            );
        };
        loop {
            let mut out = Vec::new();
            let mut taken_seqs = Vec::new();
            let purged;
            {
                let mut guards = self.lock_all();
                purged = self.purge_stale_locked(&mut guards);
                for e in self.take_batch_locked(&mut guards, max_rows) {
                    self.stats
                        .record_sampled_lag(self.lag_of(e.traj.gen_version));
                    taken_seqs.push(e.seq);
                    out.push(e.traj);
                }
            }
            if let Some(obs) = self.observer() {
                if !purged.is_empty() {
                    obs.on_consume(&purged, ConsumeReason::Stale);
                }
                if !taken_seqs.is_empty() {
                    obs.on_consume(&taken_seqs, ConsumeReason::Sample);
                }
            }
            if !out.is_empty() {
                self.release(out.len());
                charge_wait();
                self.cv.notify_all(); // space freed for Block producers
                return Some(out);
            }
            if self.is_closed() {
                charge_wait();
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                charge_wait();
                return Some(Vec::new());
            }
            let guard = self.gate.lock().unwrap();
            if self.occupancy() == 0 && !self.is_closed() {
                let _ = self
                    .cv
                    .wait_timeout(guard, (deadline - now).min(Duration::from_millis(50)))
                    .unwrap();
            }
        }
    }

    /// [`sample`](Self::sample) restricted to the shard-slice owned by
    /// trainer replica `replica` of `n_replicas`: only shards with
    /// `index % n_replicas == replica` are locked (still in ascending
    /// index order, so the module lock rule holds on the subset) and only
    /// their rows are eligible. Because every shard belongs to exactly one
    /// replica, a fleet of trainers draining their slices concurrently
    /// never contends on shard locks and never samples the same row twice.
    /// Same return contract as `sample`: `None` at EOF (closed and this
    /// slice drained), `Some(vec![])` on timeout.
    pub fn sample_slice(
        &self,
        replica: usize,
        n_replicas: usize,
        max_rows: usize,
        timeout: Duration,
    ) -> Option<Vec<Trajectory>> {
        assert!(n_replicas > 0 && replica < n_replicas, "bad slice index");
        assert!(
            n_replicas <= self.shards.len(),
            "slice requires shards >= n_replicas"
        );
        if n_replicas == 1 {
            return self.sample(max_rows, timeout);
        }
        let deadline = Instant::now() + timeout;
        let t0 = Instant::now();
        let _span = trace::span_with(trace::STORE_SAMPLE, max_rows as f64);
        let charge_wait = || {
            self.stats.sample_wait_nanos.fetch_add(
                u64::try_from(t0.elapsed().as_nanos()).unwrap_or(u64::MAX),
                Ordering::Relaxed,
            );
        };
        loop {
            let mut out = Vec::new();
            let mut taken_seqs = Vec::new();
            let purged;
            {
                let mut guards: Vec<MutexGuard<'_, Shard>> = self
                    .shards
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| i % n_replicas == replica)
                    .map(|(_, s)| s.lock().unwrap())
                    .collect();
                purged = self.purge_stale_locked(&mut guards);
                for e in self.take_batch_locked(&mut guards, max_rows) {
                    self.stats
                        .record_sampled_lag(self.lag_of(e.traj.gen_version));
                    taken_seqs.push(e.seq);
                    out.push(e.traj);
                }
            }
            if let Some(obs) = self.observer() {
                if !purged.is_empty() {
                    obs.on_consume(&purged, ConsumeReason::Stale);
                }
                if !taken_seqs.is_empty() {
                    obs.on_consume(&taken_seqs, ConsumeReason::Sample);
                }
            }
            if !out.is_empty() {
                self.release(out.len());
                charge_wait();
                self.cv.notify_all(); // space freed for Block producers
                return Some(out);
            }
            if self.is_closed() {
                charge_wait();
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                charge_wait();
                return Some(Vec::new());
            }
            // no per-slice occupancy counter exists, so an empty slice
            // waits on the shared gate with a short bound: a row admitted
            // to another replica's slice may wake us spuriously, but the
            // timed wait keeps the loop from spinning
            let guard = self.gate.lock().unwrap();
            if !self.is_closed() {
                let _ = self
                    .cv
                    .wait_timeout(guard, (deadline - now).min(Duration::from_millis(50)))
                    .unwrap();
            }
        }
    }

    // -- resumption slot ----------------------------------------------------

    /// Park an unfinished rollout, keyed by (prompt group, replica). A
    /// later park for the same key replaces the earlier one (the newer
    /// suffix strictly supersedes it).
    pub fn park_partial(&self, p: PartialRollout) {
        let key = (p.task.group_id, p.task.replica);
        self.partial.lock().unwrap().insert(key, p);
        self.stats.parked.fetch_add(1, Ordering::Relaxed);
    }

    /// Take any parked rollout (generators resume whatever is available).
    pub fn take_partial_any(&self) -> Option<PartialRollout> {
        let mut map = self.partial.lock().unwrap();
        let key = map.keys().next().copied()?;
        let p = map.remove(&key);
        if p.is_some() {
            self.stats.resumed.fetch_add(1, Ordering::Relaxed);
        }
        p
    }

    /// Take the parked rollout for a specific prompt, if present.
    pub fn take_partial(&self, group_id: u64, replica: usize) -> Option<PartialRollout> {
        let p = self.partial.lock().unwrap().remove(&(group_id, replica));
        if p.is_some() {
            self.stats.resumed.fetch_add(1, Ordering::Relaxed);
        }
        p
    }

    pub fn partial_count(&self) -> usize {
        self.partial.lock().unwrap().len()
    }

    pub fn snapshot(&self) -> DataPlaneSnapshot {
        DataPlaneSnapshot::from_stats(&self.stats, self.occupancy(), self.watermark())
    }

    // -- durable state (run-journal) ----------------------------------------

    /// Copy the durable state out in one consistent cut: all shard locks
    /// are held while rows are gathered (ascending index order, per the
    /// module lock rule), so the dump observes no admission or sampling
    /// half-applied. Rows come back in admission order.
    pub fn dump(&self) -> StoreDump {
        let guards = self.lock_all();
        let mut rows: Vec<(u64, Trajectory)> = guards
            .iter()
            .flat_map(|g| g.rows.iter().map(|e| (e.seq, e.traj.clone())))
            .collect();
        drop(guards);
        rows.sort_by_key(|(seq, _)| *seq);
        let partials = self.partial.lock().unwrap().values().cloned().collect();
        StoreDump {
            next_seq: self.seq.load(Ordering::Acquire),
            watermark: self.watermark(),
            rows,
            partials,
        }
    }

    /// Re-seed a freshly-constructed store from a dump (crash-resume).
    /// Must run before any producer/consumer thread touches the store;
    /// admission seqs are preserved so FIFO order and journal identity
    /// survive the restart. Rows beyond capacity keep the newest.
    pub fn restore(&self, dump: StoreDump) {
        assert_eq!(self.occupancy(), 0, "restore requires an empty store");
        let mut rows = dump.rows;
        rows.sort_by_key(|(seq, _)| *seq);
        if rows.len() > self.cfg.capacity {
            let excess = rows.len() - self.cfg.capacity;
            rows.drain(..excess);
        }
        let next_seq = dump
            .next_seq
            .max(rows.last().map(|(s, _)| s + 1).unwrap_or(0));
        self.seq.store(next_seq, Ordering::Release);
        self.watermark.store(dump.watermark, Ordering::Release);
        self.occupancy.store(rows.len(), Ordering::Release);
        self.stats.note_occupancy(rows.len());
        for (seq, traj) in rows {
            let shard = self.shard_for(traj.group_id);
            self.shards[shard]
                .lock()
                .unwrap()
                .rows
                .push_back(Entry { seq, traj });
        }
        let mut partial = self.partial.lock().unwrap();
        for p in dump.partials {
            partial.insert((p.task.group_id, p.task.replica), p);
        }
        drop(partial);
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Difficulty, Problem};
    use crate::rl::FinishReason;
    use std::sync::Arc;

    fn traj(group_id: u64, gen_version: u64) -> Trajectory {
        Trajectory {
            group_id,
            replica: 0,
            n_replicas: 1,
            problem: Problem {
                prompt: "1+1=".into(),
                answer: "2".into(),
                difficulty: Difficulty::Add1,
            },
            prompt_tokens: vec![1],
            response_tokens: vec![2],
            behavior_logp: vec![-0.5],
            gen_version,
            chunks: 1,
            finish: FinishReason::Eos,
            reward: 1.0,
            advantage: 0.5,
        }
    }

    fn cfg(capacity: usize) -> StoreConfig {
        StoreConfig {
            capacity,
            shards: 3,
            max_staleness: None,
            admission: AdmissionPolicy::EvictOldest,
            sampling: SamplingStrategy::Fifo,
            seed: 1,
        }
    }

    fn drain(s: &RolloutStore, n: usize) -> Vec<Trajectory> {
        s.sample(n, Duration::from_millis(10)).unwrap()
    }

    #[test]
    fn fifo_sampling_preserves_admission_order_across_shards() {
        let s = RolloutStore::new(cfg(16));
        for i in 0..8u64 {
            s.push_group(vec![traj(i, 0)]).unwrap(); // spread over shards
        }
        let rows = drain(&s, 8);
        let ids: Vec<u64> = rows.iter().map(|t| t.group_id).collect();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
        assert_eq!(s.occupancy(), 0);
    }

    #[test]
    fn evict_oldest_keeps_occupancy_at_capacity_and_freshest_rows() {
        let s = RolloutStore::new(cfg(4));
        for i in 0..10u64 {
            s.push_group(vec![traj(i, i)]).unwrap();
            assert!(s.occupancy() <= 4, "occupancy exceeded capacity");
        }
        assert_eq!(s.occupancy(), 4);
        assert_eq!(s.snapshot().evicted, 6);
        let ids: Vec<u64> = drain(&s, 8).iter().map(|t| t.group_id).collect();
        assert_eq!(ids, vec![6, 7, 8, 9], "oldest rows were evicted");
    }

    #[test]
    fn drop_newest_rejects_overflow() {
        let mut c = cfg(3);
        c.admission = AdmissionPolicy::DropNewest;
        let s = RolloutStore::new(c);
        for i in 0..5u64 {
            s.push_group(vec![traj(i, 0)]).unwrap();
        }
        assert_eq!(s.occupancy(), 3);
        assert_eq!(s.snapshot().dropped_capacity, 2);
        let ids: Vec<u64> = drain(&s, 5).iter().map(|t| t.group_id).collect();
        assert_eq!(ids, vec![0, 1, 2], "resident rows untouched");
    }

    #[test]
    fn block_admission_backpressures_until_sampled() {
        let mut c = cfg(2);
        c.admission = AdmissionPolicy::Block;
        let s = Arc::new(RolloutStore::new(c));
        s.push_group(vec![traj(0, 0), traj(1, 0)]).unwrap();
        let s2 = s.clone();
        let t = std::thread::spawn(move || {
            s2.push_group(vec![traj(2, 0)]).unwrap();
            s2.snapshot().admit_wait_secs
        });
        std::thread::sleep(Duration::from_millis(60));
        assert_eq!(s.occupancy(), 2, "producer must be blocked");
        let got = drain(&s, 1);
        assert_eq!(got.len(), 1);
        let waited = t.join().unwrap();
        assert!(waited > 0.03, "blocked time accounted, got {waited}");
        assert_eq!(s.occupancy(), 2);
    }

    #[test]
    fn max_staleness_drops_at_admission_and_in_place() {
        let mut c = cfg(16);
        c.max_staleness = Some(2);
        let s = RolloutStore::new(c);
        s.advance_watermark(10);
        // lag 10-7=3 > 2: dropped at the door
        s.push_group(vec![traj(0, 7)]).unwrap();
        assert_eq!(s.occupancy(), 0);
        assert_eq!(s.snapshot().dropped_stale, 1);
        // lag 1: admitted...
        s.push_group(vec![traj(1, 9)]).unwrap();
        assert_eq!(s.occupancy(), 1);
        // ...then ages out as the watermark advances
        s.advance_watermark(12);
        let got = s.sample(4, Duration::from_millis(5)).unwrap();
        assert!(got.is_empty(), "aged row must not reach the trainer");
        assert_eq!(s.occupancy(), 0);
        assert_eq!(s.snapshot().dropped_stale, 2);
    }

    #[test]
    fn freshest_first_picks_highest_version() {
        let mut c = cfg(16);
        c.sampling = SamplingStrategy::FreshestFirst;
        let s = RolloutStore::new(c);
        for (gid, v) in [(0u64, 3u64), (1, 9), (2, 5), (3, 9)] {
            s.push_group(vec![traj(gid, v)]).unwrap();
        }
        let rows = drain(&s, 4);
        let versions: Vec<u64> = rows.iter().map(|t| t.gen_version).collect();
        assert_eq!(versions, vec![9, 9, 5, 3]);
        // ties broken by admission order (seq): gid 1 admitted before 3
        assert_eq!(rows[0].group_id, 1);
        assert_eq!(rows[1].group_id, 3);
    }

    #[test]
    fn staleness_weighted_still_returns_everything() {
        let mut c = cfg(16);
        c.sampling = SamplingStrategy::StalenessWeighted;
        let s = RolloutStore::new(c);
        s.advance_watermark(4);
        for (gid, v) in [(0u64, 0u64), (1, 2), (2, 4)] {
            s.push_group(vec![traj(gid, v)]).unwrap();
        }
        let mut ids: Vec<u64> = drain(&s, 3).iter().map(|t| t.group_id).collect();
        ids.sort();
        assert_eq!(ids, vec![0, 1, 2]);
        assert_eq!(s.occupancy(), 0);
    }

    #[test]
    fn close_drains_then_signals_eof() {
        let s = RolloutStore::new(cfg(8));
        s.push_group(vec![traj(0, 0)]).unwrap();
        s.close();
        assert!(s.push_group(vec![traj(1, 0)]).is_err());
        let got = s.sample(4, Duration::from_millis(5)).unwrap();
        assert_eq!(got.len(), 1, "resident rows drain after close");
        assert!(s.sample(4, Duration::from_millis(5)).is_none(), "then EOF");
    }

    #[test]
    fn partial_rollouts_park_and_resume() {
        let s = RolloutStore::new(cfg(8));
        let p = PartialRollout {
            task: PromptTask {
                group_id: 7,
                replica: 2,
                n_replicas: 4,
                problem: Problem {
                    prompt: "2+2=".into(),
                    answer: "4".into(),
                    difficulty: Difficulty::Add1,
                },
                prompt_tokens: vec![1, 5, 6],
            },
            tokens: vec![1, 5, 6, 9],
            prompt_len: 3,
            logps: vec![-0.25],
            chunks: 2,
            gen_version: 3,
        };
        s.park_partial(p.clone());
        assert_eq!(s.partial_count(), 1);
        assert!(s.take_partial(7, 0).is_none());
        let back = s.take_partial(7, 2).unwrap();
        assert_eq!(back.tokens, p.tokens);
        assert_eq!(back.chunks, 2);
        assert_eq!(s.partial_count(), 0);
        s.park_partial(p);
        assert!(s.take_partial_any().is_some());
        let snap = s.snapshot();
        assert_eq!((snap.parked, snap.resumed), (2, 2));
    }

    #[test]
    fn sample_slice_partitions_rows_disjointly() {
        // cfg uses 3 shards: replica 0 of 2 owns shards {0, 2}, replica 1
        // owns shard {1}; shard = group_id % 3
        let s = RolloutStore::new(cfg(16));
        for i in 0..6u64 {
            s.push_group(vec![traj(i, 0)]).unwrap();
        }
        let a: Vec<u64> = s
            .sample_slice(0, 2, 8, Duration::from_millis(10))
            .unwrap()
            .iter()
            .map(|t| t.group_id)
            .collect();
        let b: Vec<u64> = s
            .sample_slice(1, 2, 8, Duration::from_millis(10))
            .unwrap()
            .iter()
            .map(|t| t.group_id)
            .collect();
        assert_eq!(a, vec![0, 2, 3, 5], "slice 0 drains shards 0 and 2 in FIFO");
        assert_eq!(b, vec![1, 4], "slice 1 drains shard 1");
        assert_eq!(s.occupancy(), 0);
        // empty slice: timeout, then EOF after close
        assert!(s
            .sample_slice(1, 2, 4, Duration::from_millis(5))
            .unwrap()
            .is_empty());
        s.close();
        assert!(s.sample_slice(1, 2, 4, Duration::from_millis(5)).is_none());
    }

    #[test]
    fn oversized_group_keeps_only_newest_capacity_rows() {
        let s = RolloutStore::new(cfg(3));
        s.push_group((0..7u64).map(|i| traj(i, i)).collect()).unwrap();
        assert_eq!(s.occupancy(), 3);
        let ids: Vec<u64> = drain(&s, 4).iter().map(|t| t.group_id).collect();
        assert_eq!(ids, vec![4, 5, 6]);
    }
}
