//! Data-plane telemetry: lock-free counters updated on the hot paths and a
//! plain snapshot struct for reports ([`crate::metrics`] renders it).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Number of exact buckets in the sampled-lag histogram; lags >= this land
/// in the overflow bucket (index `LAG_BUCKETS`).
pub const LAG_BUCKETS: usize = 16;

/// Live counters owned by the [`crate::dataplane::RolloutStore`]. All
/// increments use relaxed atomics — telemetry must never serialize the
/// data path.
#[derive(Debug, Default)]
pub struct DataPlaneStats {
    /// rows accepted into the store
    pub admitted: AtomicU64,
    /// rows discarded because their lag exceeded max_staleness
    pub dropped_stale: AtomicU64,
    /// rows rejected at admission under DropNewest capacity pressure
    pub dropped_capacity: AtomicU64,
    /// resident rows evicted under EvictOldest capacity pressure
    pub evicted: AtomicU64,
    /// rows handed to the trainer
    pub sampled: AtomicU64,
    /// partial rollouts parked in the resumption slot
    pub parked: AtomicU64,
    /// partial rollouts taken back out of the resumption slot
    pub resumed: AtomicU64,
    /// time consumers spent waiting for rows, in nanoseconds
    pub sample_wait_nanos: AtomicU64,
    /// time producers spent blocked on admission (Block policy), nanoseconds
    pub admit_wait_nanos: AtomicU64,
    /// histogram of off-policy lag at sampling time; last bucket = overflow
    pub lag_hist: [AtomicU64; LAG_BUCKETS + 1],
    /// running sum of sampled lags (for the mean)
    pub lag_sum: AtomicU64,
    /// maximum sampled lag
    pub lag_max: AtomicU64,
    /// high-water mark of store occupancy, in rows
    pub peak_occupancy: AtomicUsize,
}

impl DataPlaneStats {
    pub fn record_sampled_lag(&self, lag: u64) {
        let bucket = (lag as usize).min(LAG_BUCKETS);
        self.lag_hist[bucket].fetch_add(1, Ordering::Relaxed);
        self.lag_sum.fetch_add(lag, Ordering::Relaxed);
        self.lag_max.fetch_max(lag, Ordering::Relaxed);
        self.sampled.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_occupancy(&self, occupancy: usize) {
        self.peak_occupancy.fetch_max(occupancy, Ordering::Relaxed);
    }
}

/// Point-in-time copy of the counters, plus derived quantities. This is
/// what crosses into [`crate::coordinator::RunReport`] and the benches.
#[derive(Debug, Clone, Default)]
pub struct DataPlaneSnapshot {
    pub occupancy: usize,
    pub peak_occupancy: usize,
    pub watermark: u64,
    pub admitted: u64,
    pub dropped_stale: u64,
    pub dropped_capacity: u64,
    pub evicted: u64,
    pub sampled: u64,
    pub parked: u64,
    pub resumed: u64,
    pub sample_wait_secs: f64,
    pub admit_wait_secs: f64,
    /// sampled-lag histogram; index = lag in trainer steps, last = overflow
    pub lag_hist: Vec<u64>,
    pub mean_sampled_lag: f64,
    pub max_sampled_lag: u64,
}

impl DataPlaneSnapshot {
    pub(crate) fn from_stats(
        stats: &DataPlaneStats,
        occupancy: usize,
        watermark: u64,
    ) -> DataPlaneSnapshot {
        let sampled = stats.sampled.load(Ordering::Relaxed);
        let lag_sum = stats.lag_sum.load(Ordering::Relaxed);
        DataPlaneSnapshot {
            occupancy,
            peak_occupancy: stats.peak_occupancy.load(Ordering::Relaxed),
            watermark,
            admitted: stats.admitted.load(Ordering::Relaxed),
            dropped_stale: stats.dropped_stale.load(Ordering::Relaxed),
            dropped_capacity: stats.dropped_capacity.load(Ordering::Relaxed),
            evicted: stats.evicted.load(Ordering::Relaxed),
            sampled,
            parked: stats.parked.load(Ordering::Relaxed),
            resumed: stats.resumed.load(Ordering::Relaxed),
            sample_wait_secs: stats.sample_wait_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            admit_wait_secs: stats.admit_wait_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            lag_hist: stats
                .lag_hist
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect(),
            mean_sampled_lag: if sampled > 0 {
                lag_sum as f64 / sampled as f64
            } else {
                0.0
            },
            max_sampled_lag: stats.lag_max.load(Ordering::Relaxed),
        }
    }

    /// One-line rendering for reports.
    pub fn summary(&self) -> String {
        format!(
            "store: occ {}/{} peak, admitted {}, sampled {}, dropped {} stale + {} capacity, \
             evicted {}, lag mean {:.2} max {}",
            self.occupancy,
            self.peak_occupancy,
            self.admitted,
            self.sampled,
            self.dropped_stale,
            self.dropped_capacity,
            self.evicted,
            self.mean_sampled_lag,
            self.max_sampled_lag,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lag_histogram_buckets_and_overflow() {
        let s = DataPlaneStats::default();
        s.record_sampled_lag(0);
        s.record_sampled_lag(3);
        s.record_sampled_lag(3);
        s.record_sampled_lag(LAG_BUCKETS as u64 + 40); // overflow
        let snap = DataPlaneSnapshot::from_stats(&s, 7, 9);
        assert_eq!(snap.lag_hist[0], 1);
        assert_eq!(snap.lag_hist[3], 2);
        assert_eq!(snap.lag_hist[LAG_BUCKETS], 1);
        assert_eq!(snap.sampled, 4);
        assert_eq!(snap.max_sampled_lag, LAG_BUCKETS as u64 + 40);
        assert_eq!(snap.occupancy, 7);
        assert_eq!(snap.watermark, 9);
        let mean = (0 + 3 + 3 + LAG_BUCKETS as u64 + 40) as f64 / 4.0;
        assert!((snap.mean_sampled_lag - mean).abs() < 1e-12);
    }
}
