//! The paper's system contribution (L3): executors, communication channels,
//! and the single controller (paper §5), plus the synchronous baseline, the
//! asynchronous off-policy pipeline (paper §4), and the buffered pipeline
//! over the streaming trajectory data plane ([`crate::dataplane`]).
//!
//! Topology (the Figure-1/Algorithm-2 flow, critic-free with rule-based
//! scorers):
//!
//! ```text
//!   PromptScheduler ──► Generator workers (DP) ──GATHER──► Reward executor
//!        ▲                  ▲      │ park/resume                │ ScoredSink
//!        │                  │      │ partial rollouts   ┌───────┴────────┐
//!        │   DDMA weights   │      ▼              SCATTER (async)   push (buffered)
//!        │   bus            │  ┌──────────────┐        │                │
//!        │                  │  │ RolloutStore │◄───────┼────────────────┘
//!        │                  │  │ shard│shard│… │       │
//!        │                  │  └──────┬───────┘  scored channel
//!        │                  │  sample │ ▲ watermark    │
//!        │                  │         ▼ │              ▼
//!        └─────────────── Trainer executor ◄───────────┘
//! ```
//!
//! * **Sync mode** (DeepSpeed-Chat-like baseline): one thread, one PJRT
//!   context shared by generation and training ("co-located"), strictly
//!   sequential generate → score → train ticks.
//! * **Async mode** (LlamaRL): every executor runs free on its own thread
//!   with its own PJRT context, connected by bounded channels (backpressure
//!   bounds off-policy lag) and the DDMA weights bus. Each generator owns a
//!   double-buffered [`crate::weightsync::GeneratorSlot`]: publishes stream
//!   the reshard plan into its staging buffer and the worker promotes the
//!   new version with a fenced swap at chunk boundaries, so per-trajectory
//!   weight versions always come from a complete snapshot.
//! * **AsyncBuffered mode** (streaming data plane): scored groups are
//!   admitted into a staleness-aware [`crate::dataplane::RolloutStore`];
//!   the trainer samples microbatches per a pluggable strategy and its
//!   optimizer step drives the staleness watermark, so off-policy lag is
//!   an enforced bound rather than a channel-capacity side effect.

pub mod channel;
pub mod controller;
pub mod evaluator;
pub mod executor;
pub mod generator;
pub mod pretrain;
pub mod reward;
pub mod trainer;

pub use channel::{gather_channel, scatter_channel, ChannelStats, Inbound, Message, Outbound};
pub use controller::{run_training, Mode, PipelineConfig, RunReport, WeightSyncConfig};
pub use evaluator::{eval_policy, EvalResult, EvaluatorConfig, EvaluatorExecutor};
pub use executor::{run_executor_loop, Executor, ExecutorContext, StepOutcome};
pub use generator::{GenTally, GeneratorConfig, GeneratorWorker};
pub use pretrain::{run_pretraining, PretrainConfig, PretrainReport};
pub use reward::{RewardExecutor, ScoredSink};
pub use trainer::{TrainStepRecord, Trainer, TrainerConfig, TrajectorySource};
