//! The paper's system contribution (L3): executors, communication channels,
//! and the single controller (paper §5) — expressed as a declarative
//! execution graph ([`graph`]) that one generic runtime launches for the
//! synchronous baseline, the asynchronous off-policy pipeline (paper §4),
//! and the buffered pipeline over the streaming trajectory data plane
//! ([`crate::dataplane`]).
//!
//! Topology (the Figure-1/Algorithm-2 flow, critic-free with rule-based
//! scorers; render any resolved instance with `llamarl train --dump-graph`):
//!
//! ```text
//!   PromptScheduler ──► Generator fleet (DP) ──GROUP-ROUTED──► Reward fleet
//!        ▲                  ▲      │ park/resume   (group_id % n) │ ScoredSink
//!        │                  │      │ partial rollouts     ┌───────┴────────┐
//!        │   DDMA weights   │      ▼                gather (async)    push (buffered)
//!        │   bus            │  ┌──────────────┐          │                │
//!        │                  │  │ RolloutStore │◄─────────┼────────────────┘
//!        │                  │  │ shard│shard│… │         │
//!        │                  │  └──────┬───────┘   scored channel
//!        │                  │  sample │ ▲ watermark      │
//!        │                  │         ▼ │                ▼
//!        └─────────────── Trainer executor ◄─────────────┘
//! ```
//!
//! * **[`graph`]** — the topology/runtime/telemetry subsystem: modes are
//!   *data* (`NodeSpec` fleets + `EdgeSpec` transports), launched by one
//!   `Graph::launch` with named threads, lease policies, stop/EOF
//!   propagation and panic→error joins; the `RunReport` is assembled in
//!   exactly one place (`TelemetryHub`).
//! * **Sync mode** (DeepSpeed-Chat-like baseline): the same graph driven
//!   by the stepped scheduler — one thread, one PJRT context shared by
//!   generation and training ("co-located"), strictly sequential
//!   generate → score → train ticks.
//! * **Async mode** (LlamaRL): every fleet runs free on its own threads
//!   with its own PJRT context, connected by bounded channels
//!   (backpressure bounds off-policy lag) and the DDMA weights bus. Each
//!   generator owns a double-buffered
//!   [`crate::weightsync::GeneratorSlot`]: publishes stream the reshard
//!   plan into its staging buffer and the worker promotes the new version
//!   with a fenced swap at chunk boundaries.
//! * **AsyncBuffered mode** (streaming data plane): scored groups are
//!   admitted into a staleness-aware [`crate::dataplane::RolloutStore`];
//!   the trainer samples microbatches per a pluggable strategy and its
//!   optimizer step drives the staleness watermark.
//! * **Reward fleet**: in every mode `n_reward_workers` scales scoring
//!   like generation — the group-routed channel scatters whole advantage
//!   groups by group id, so group integrity is structural.

pub mod channel;
pub mod controller;
pub mod evaluator;
pub mod executor;
pub mod generator;
pub mod graph;
pub mod pretrain;
pub mod reward;
pub mod trainer;

pub use channel::{
    gather_channel, routed_channel, scatter_channel, ChannelStats, Inbound, Message, Outbound,
};
pub use controller::{run_training, Mode, PipelineConfig, RunReport, WeightSyncConfig};
pub use evaluator::{eval_policy, EvalResult, EvaluatorConfig, EvaluatorExecutor};
pub use executor::{run_executor_loop, Executor, ExecutorContext, StepOutcome};
pub use generator::{GenTally, GeneratorConfig, GeneratorWorker};
pub use graph::{topology, topology_with_rows, Graph, LaunchEnv, TelemetryHub};
pub use pretrain::{run_pretraining, PretrainConfig, PretrainReport};
pub use reward::{RewardExecutor, ScoredSink};
pub use trainer::{TrainStepRecord, Trainer, TrainerConfig, TrajectorySource};
