//! Communication channels between executors (paper §5.1.2).
//!
//! A channel is a directed, *bounded* link with a distribution paradigm:
//!
//! * **GATHER**  — many outbound processes, one inbound executor (generator
//!   workers -> reward executor). Implemented as a cloned-producer mpsc.
//! * **SCATTER** — one outbound executor, chunks round-robined over inbound
//!   processes (reward -> trainer microbatch streams).
//! * **GROUP-ROUTED** — many outbound processes, `n` inbound processes,
//!   each trajectory delivered to consumer `group_id % n` (generator
//!   workers -> reward *fleet*): a prompt's whole advantage group is
//!   scored by exactly one reward node, whatever worker decoded each
//!   replica. EOF broadcasts to every consumer, so fan-in drain counting
//!   works per consumer.
//! * **BROADCAST** — identical copy to every inbound process.
//!
//! Boundedness is load-bearing: a full channel blocks the sender, which is
//! the backpressure that (a) keeps memory bounded and (b) caps off-policy
//! lag in the async pipeline (a generator can run at most
//! `capacity / rows-per-step` steps ahead of the trainer).
//!
//! Consumer slots are *re-routable*: when a consumer's panic destroys its
//! receiver (mpsc receivers cannot be cloned or salvaged off a dead
//! stack), its supervisor mints a replacement via [`Outbound::reroute`]
//! and every producer clone transparently retries onto the fresh queue —
//! the elasticity path that makes a reward-fleet panic restartable
//! instead of terminal.
//!
//! Weight updates use the dedicated DDMA bus ([`crate::ddma::WeightsBus`])
//! rather than a message channel — matching the paper's distinction between
//! data channels and the DDMA weights path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SendError, SyncSender, TrySendError};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use crate::rl::Trajectory;
use crate::trace;
use crate::util::error::{Error, Result};

/// Data messages flowing between executors.
#[derive(Debug)]
pub enum Message {
    /// raw generations (generator -> reward)
    Trajectories(Vec<Trajectory>),
    /// scored + advantage-filled groups (reward -> trainer)
    Scored(Vec<Trajectory>),
    /// drain marker: the upstream executor finished
    Eof,
}

/// Shared channel telemetry (backpressure accounting for the perf pass and
/// the bubble benches).
#[derive(Debug, Default)]
pub struct ChannelStats {
    pub messages: AtomicU64,
    pub items: AtomicU64,
    pub send_blocked_nanos: AtomicU64,
    pub recv_blocked_nanos: AtomicU64,
}

impl ChannelStats {
    pub fn send_blocked_secs(&self) -> f64 {
        self.send_blocked_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn recv_blocked_secs(&self) -> f64 {
        self.recv_blocked_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Accumulate blocked time into `counter` without ever wrapping: the
    /// u128->u64 narrowing and the running sum both saturate, so a stuck
    /// sender (or a clock-skewed suspend/resume making one interval huge)
    /// can pin the counter at u64::MAX but never overflow it back to a
    /// small — effectively "negative" — value.
    fn add_blocked(counter: &AtomicU64, dt: Duration) {
        let nanos = u64::try_from(dt.as_nanos()).unwrap_or(u64::MAX);
        let _ = counter.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
            Some(cur.saturating_add(nanos))
        });
    }

    pub fn add_send_blocked(&self, dt: Duration) {
        Self::add_blocked(&self.send_blocked_nanos, dt);
    }

    pub fn add_recv_blocked(&self, dt: Duration) {
        Self::add_blocked(&self.recv_blocked_nanos, dt);
    }
}

/// One consumer slot: the live sender plus an epoch the supervisor bumps
/// when it re-routes a dead consumer (see [`Outbound::reroute`]). Slots are
/// shared across every `Outbound` clone, so a swap is visible to all
/// producers at once.
struct Slot {
    epoch: u64,
    tx: SyncSender<Message>,
}

/// Sending half. Cloneable for GATHER / GROUP-ROUTED (many producers).
pub struct Outbound {
    pub name: String,
    slots: Arc<Vec<RwLock<Slot>>>,
    /// per-consumer queue bound, reused when a slot is re-routed
    capacity: usize,
    next: std::cell::Cell<usize>,
    /// deliver each trajectory to consumer `group_id % n` instead of
    /// round-robining whole messages (see [`routed_channel`])
    route_by_group: bool,
    pub stats: Arc<ChannelStats>,
}

impl Clone for Outbound {
    fn clone(&self) -> Self {
        Outbound {
            name: self.name.clone(),
            slots: self.slots.clone(),
            capacity: self.capacity,
            next: std::cell::Cell::new(0),
            route_by_group: self.route_by_group,
            stats: self.stats.clone(),
        }
    }
}

/// Receiving half (one per inbound process).
pub struct Inbound {
    pub name: String,
    rx: Receiver<Message>,
    pub stats: Arc<ChannelStats>,
}

fn count_items(m: &Message) -> u64 {
    match m {
        Message::Trajectories(v) | Message::Scored(v) => v.len() as u64,
        Message::Eof => 0,
    }
}

impl Outbound {
    /// The slot's live sender, cloned OUT of the lock — a blocking send
    /// must never hold the slot lock, or a re-route could not swap the
    /// sender from under a backpressured producer.
    fn sender(&self, idx: usize) -> (u64, SyncSender<Message>) {
        let s = self.slots[idx].read().unwrap();
        (s.epoch, s.tx.clone())
    }

    /// Send to one consumer slot, retrying across re-routes. A dead slot is
    /// either being re-routed by its supervisor (a fresh receiver swaps in
    /// before the restart backoff even starts) or gone for good (shutdown);
    /// wait a bounded grace for the epoch to advance and retry on the new
    /// channel, so a reward replica's panic is invisible to producers
    /// instead of a ChannelClosed cascade.
    fn send_slot(&self, idx: usize, mut msg: Message) -> Result<()> {
        loop {
            let (epoch, tx) = self.sender(idx);
            match tx.send(msg) {
                Ok(()) => return Ok(()),
                Err(SendError(m)) => {
                    msg = m;
                    let deadline = Instant::now() + Duration::from_millis(200);
                    loop {
                        if self.slots[idx].read().unwrap().epoch != epoch {
                            break; // re-routed: retry on the fresh sender
                        }
                        if Instant::now() >= deadline {
                            return Err(Error::ChannelClosed(self.name.clone()));
                        }
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
            }
        }
    }

    /// Replace consumer slot `idx` with a freshly minted queue and hand
    /// back its receiving half — the supervisor's recovery path for a
    /// consumer whose panic destroyed the old receiver. Every producer
    /// clone sees the swap (slots are shared); messages still queued in
    /// the dead receiver are lost, which is the same contract as the
    /// consumer having died before draining them. Stats carry over so
    /// channel telemetry stays cumulative across re-routes.
    pub fn reroute(&self, idx: usize) -> Inbound {
        let (tx, rx) = sync_channel(self.capacity);
        let mut slot = self.slots[idx].write().unwrap();
        slot.epoch += 1;
        slot.tx = tx;
        Inbound {
            name: self.name.clone(),
            rx,
            stats: self.stats.clone(),
        }
    }

    /// Blocking send with backpressure accounting. SCATTER round-robins the
    /// message to one inbound process; GATHER/BROADCAST have a single slot;
    /// GROUP-ROUTED splits the message's trajectories by `group_id % n`
    /// and delivers each part to its owning consumer.
    pub fn send(&self, msg: Message) -> Result<()> {
        if self.route_by_group && self.slots.len() > 1 {
            return self.send_routed(msg);
        }
        let items = count_items(&msg);
        let idx = self.next.get() % self.slots.len();
        self.next.set(idx + 1);
        let t0 = Instant::now();
        let span = trace::span(trace::SEND_BLOCKED);
        self.send_slot(idx, msg)?;
        drop(span);
        // (send on a non-full channel is ~free; anything measurable is
        // backpressure block time)
        self.stats.add_send_blocked(t0.elapsed());
        self.stats.messages.fetch_add(1, Ordering::Relaxed);
        self.stats.items.fetch_add(items, Ordering::Relaxed);
        Ok(())
    }

    /// Non-blocking send; returns the message back if the channel is full.
    /// Not supported on a multi-consumer GROUP-ROUTED channel — a split
    /// delivery cannot be un-sent when one part's consumer is full, so
    /// rather than silently violating group integrity the message is
    /// handed back unsent (use the blocking [`Outbound::send`] there).
    pub fn try_send(&self, msg: Message) -> std::result::Result<(), Message> {
        if self.route_by_group && self.slots.len() > 1 {
            return Err(msg);
        }
        let items = count_items(&msg);
        let idx = self.next.get() % self.slots.len();
        let (_, tx) = self.sender(idx);
        match tx.try_send(msg) {
            Ok(()) => {
                self.next.set(idx + 1);
                self.stats.messages.fetch_add(1, Ordering::Relaxed);
                self.stats.items.fetch_add(items, Ordering::Relaxed);
                Ok(())
            }
            Err(TrySendError::Full(m)) | Err(TrySendError::Disconnected(m)) => Err(m),
        }
    }

    /// GROUP-ROUTED delivery: split the trajectories by `group_id % n` and
    /// send each non-empty part to its owning consumer, so every replica of
    /// a prompt's advantage group lands on the same inbound process. EOF
    /// broadcasts (same as [`Outbound::send_eof`]).
    fn send_routed(&self, msg: Message) -> Result<()> {
        let n = self.slots.len();
        let (scored, items) = match msg {
            Message::Trajectories(v) => (false, v),
            Message::Scored(v) => (true, v),
            Message::Eof => {
                self.send_eof();
                return Ok(());
            }
        };
        let mut parts: Vec<Vec<Trajectory>> = (0..n).map(|_| Vec::new()).collect();
        for t in items {
            parts[(t.group_id % n as u64) as usize].push(t);
        }
        let t0 = Instant::now();
        let _span = trace::span(trace::SEND_BLOCKED);
        for (i, part) in parts.into_iter().enumerate() {
            if part.is_empty() {
                continue;
            }
            let count = part.len() as u64;
            let wrapped = if scored {
                Message::Scored(part)
            } else {
                Message::Trajectories(part)
            };
            self.send_slot(i, wrapped)?;
            self.stats.items.fetch_add(count, Ordering::Relaxed);
        }
        // one message + one blocked-time sample per send() CALL, however
        // many parts it split into — keeps the counter comparable with the
        // non-routed path and across reward-fleet sizes
        self.stats.messages.fetch_add(1, Ordering::Relaxed);
        self.stats.add_send_blocked(t0.elapsed());
        Ok(())
    }

    /// Signal EOF to every inbound process.
    pub fn send_eof(&self) {
        for i in 0..self.slots.len() {
            let (_, tx) = self.sender(i);
            let _ = tx.send(Message::Eof);
        }
    }
}

impl Inbound {
    /// Blocking receive with starvation accounting.
    pub fn recv(&self) -> Result<Message> {
        let t0 = Instant::now();
        let span = trace::span(trace::RECV_BLOCKED);
        let m = self
            .rx
            .recv()
            .map_err(|_| Error::ChannelClosed(self.name.clone()))?;
        drop(span);
        self.stats.add_recv_blocked(t0.elapsed());
        Ok(m)
    }

    pub fn recv_timeout(&self, d: Duration) -> std::result::Result<Message, RecvTimeoutError> {
        let t0 = Instant::now();
        let span = trace::span(trace::RECV_BLOCKED);
        let r = self.rx.recv_timeout(d);
        drop(span);
        self.stats.add_recv_blocked(t0.elapsed());
        r
    }

    pub fn try_recv(&self) -> Option<Message> {
        self.rx.try_recv().ok()
    }
}

/// GATHER: many producers (clone the Outbound), one consumer.
pub fn gather_channel(name: &str, capacity: usize) -> (Outbound, Inbound) {
    let (tx, mut rxs) = fan_out_channel(name, capacity, 1, false);
    (tx, rxs.pop().expect("one consumer"))
}

fn fan_out_channel(
    name: &str,
    capacity: usize,
    n: usize,
    route_by_group: bool,
) -> (Outbound, Vec<Inbound>) {
    let stats = Arc::new(ChannelStats::default());
    let mut slots = Vec::with_capacity(n);
    let mut inbounds = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = sync_channel(capacity);
        slots.push(RwLock::new(Slot { epoch: 0, tx }));
        inbounds.push(Inbound {
            name: name.to_string(),
            rx,
            stats: stats.clone(),
        });
    }
    (
        Outbound {
            name: name.to_string(),
            slots: Arc::new(slots),
            capacity,
            next: std::cell::Cell::new(0),
            route_by_group,
            stats,
        },
        inbounds,
    )
}

/// SCATTER: one producer, `n` consumers, round-robin delivery.
pub fn scatter_channel(name: &str, capacity: usize, n: usize) -> (Outbound, Vec<Inbound>) {
    fan_out_channel(name, capacity, n, false)
}

/// GROUP-ROUTED GATHER: many producers (clone the Outbound), `n` consumers;
/// each trajectory is delivered to consumer `group_id % n`, so a prompt's
/// whole advantage group — every one of its n_generations replicas,
/// whichever generator worker decoded it — is scored by exactly one
/// consumer. `capacity` bounds each consumer's queue independently.
pub fn routed_channel(name: &str, capacity: usize, n: usize) -> (Outbound, Vec<Inbound>) {
    fan_out_channel(name, capacity, n, true)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn traj(group_id: u64) -> Trajectory {
        use crate::data::{Difficulty, Problem};
        Trajectory {
            group_id,
            replica: 0,
            n_replicas: 1,
            problem: Problem {
                prompt: "1+1=".into(),
                answer: "2".into(),
                difficulty: Difficulty::Add1,
            },
            prompt_tokens: vec![1],
            response_tokens: vec![2],
            behavior_logp: vec![-0.5],
            gen_version: 0,
            chunks: 1,
            finish: crate::rl::FinishReason::Eos,
            reward: 0.0,
            advantage: 0.0,
        }
    }

    #[test]
    fn gather_many_producers() {
        let (tx, rx) = gather_channel("g", 16);
        let mut handles = vec![];
        for i in 0..4 {
            let tx = tx.clone();
            handles.push(std::thread::spawn(move || {
                tx.send(Message::Trajectories(vec![traj(i)])).unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut seen = vec![];
        for _ in 0..4 {
            if let Message::Trajectories(v) = rx.recv().unwrap() {
                seen.push(v[0].group_id);
            }
        }
        seen.sort();
        assert_eq!(seen, vec![0, 1, 2, 3]);
        assert_eq!(rx.stats.messages.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn scatter_round_robins() {
        let (tx, rxs) = scatter_channel("s", 4, 2);
        for i in 0..4 {
            tx.send(Message::Scored(vec![traj(i)])).unwrap();
        }
        let get = |rx: &Inbound| match rx.recv().unwrap() {
            Message::Scored(v) => v[0].group_id,
            _ => panic!(),
        };
        assert_eq!(get(&rxs[0]), 0);
        assert_eq!(get(&rxs[1]), 1);
        assert_eq!(get(&rxs[0]), 2);
        assert_eq!(get(&rxs[1]), 3);
    }

    #[test]
    fn bounded_channel_backpressures() {
        let (tx, rx) = gather_channel("bp", 1);
        tx.send(Message::Trajectories(vec![traj(0)])).unwrap();
        // second send must block until the consumer drains
        let t = std::thread::spawn(move || {
            tx.send(Message::Trajectories(vec![traj(1)])).unwrap();
            tx.stats.send_blocked_secs()
        });
        std::thread::sleep(Duration::from_millis(50));
        let _ = rx.recv().unwrap();
        let blocked = t.join().unwrap();
        assert!(blocked > 0.03, "sender should have blocked, got {blocked}");
    }

    #[test]
    fn eof_reaches_all_consumers() {
        let (tx, rxs) = scatter_channel("eof", 2, 3);
        tx.send_eof();
        for rx in &rxs {
            assert!(matches!(rx.recv().unwrap(), Message::Eof));
        }
    }

    #[test]
    fn routed_channel_keeps_groups_on_one_consumer() {
        let n = 3;
        let (tx, rxs) = routed_channel("routed", 64, n);
        // one mixed message: groups 0..6, two replicas each — the split
        // must land every replica of group g on consumer g % n
        let mut batch = Vec::new();
        for gid in 0..6u64 {
            batch.push(traj(gid));
            batch.push(traj(gid));
        }
        tx.send(Message::Trajectories(batch)).unwrap();
        for (i, rx) in rxs.iter().enumerate() {
            let Message::Trajectories(v) = rx.recv().unwrap() else {
                panic!("expected trajectories");
            };
            assert_eq!(v.len(), 4, "two groups x two replicas per consumer");
            assert!(v.iter().all(|t| t.group_id % n as u64 == i as u64));
        }
    }

    #[test]
    fn routed_eof_broadcasts_per_producer() {
        // fan-in drain contract: each producer's EOF reaches EVERY
        // consumer, so a consumer expecting k producers counts k EOFs
        let (tx, rxs) = routed_channel("routed_eof", 4, 2);
        let tx2 = tx.clone();
        tx.send(Message::Eof).unwrap(); // routed send of Eof broadcasts too
        tx2.send_eof();
        for rx in &rxs {
            assert!(matches!(rx.recv().unwrap(), Message::Eof));
            assert!(matches!(rx.recv().unwrap(), Message::Eof));
        }
    }

    #[test]
    fn reroute_swaps_consumer_slot_for_all_producers() {
        let n = 2;
        let (tx, mut rxs) = routed_channel("reroute", 4, n);
        // consumer 1 "panics": its receiver is destroyed with no salvage
        drop(rxs.remove(1));
        // a second producer clone sends a group owned by the dead slot; it
        // must ride out the gap and land on the re-routed queue
        let tx2 = tx.clone();
        let sender = std::thread::spawn(move || tx2.send(Message::Trajectories(vec![traj(1)])));
        std::thread::sleep(Duration::from_millis(10));
        let fresh = tx.reroute(1);
        sender.join().unwrap().expect("send retries onto the fresh slot");
        let Message::Trajectories(v) = fresh.recv().unwrap() else {
            panic!("expected trajectories on the re-routed receiver");
        };
        assert_eq!(v[0].group_id, 1);
        // slot 0 was untouched throughout
        tx.send(Message::Trajectories(vec![traj(0)])).unwrap();
        assert!(matches!(rxs[0].recv().unwrap(), Message::Trajectories(_)));
    }

    #[test]
    fn dead_slot_without_reroute_still_reports_closed() {
        let (tx, rx) = gather_channel("dead", 2);
        drop(rx);
        // nobody re-routes: after the bounded grace the producer gets the
        // same ChannelClosed the shutdown path has always relied on
        let err = tx.send(Message::Trajectories(vec![traj(0)]));
        assert!(err.is_err());
    }

    #[test]
    fn try_send_full_returns_message() {
        let (tx, _rx) = gather_channel("full", 1);
        assert!(tx.try_send(Message::Trajectories(vec![traj(0)])).is_ok());
        assert!(tx.try_send(Message::Trajectories(vec![traj(1)])).is_err());
    }

    #[test]
    fn blocked_time_accounting_saturates_instead_of_wrapping() {
        let stats = ChannelStats::default();
        // near-overflow accumulator + a huge interval (clock-skew style):
        // must pin at u64::MAX, never wrap to a small value
        stats
            .send_blocked_nanos
            .store(u64::MAX - 5, Ordering::Relaxed);
        stats.add_send_blocked(Duration::from_secs(3600));
        assert_eq!(stats.send_blocked_nanos.load(Ordering::Relaxed), u64::MAX);
        assert!(stats.send_blocked_secs() >= (u64::MAX - 5) as f64 / 1e9);

        // an interval whose nanos exceed u64 (u128 source) also saturates
        let recv = ChannelStats::default();
        recv.add_recv_blocked(Duration::from_secs(u64::MAX / 1_000_000_000 + 10));
        assert_eq!(recv.recv_blocked_nanos.load(Ordering::Relaxed), u64::MAX);
        // monotonic: further adds keep it pinned
        recv.add_recv_blocked(Duration::from_secs(1));
        assert_eq!(recv.recv_blocked_nanos.load(Ordering::Relaxed), u64::MAX);
        assert!(recv.recv_blocked_secs() > 0.0);
    }
}
