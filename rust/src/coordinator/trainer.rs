//! Trainer executor: AIPO policy updates over the packed train state.
//!
//! The train state [params | m | v | step | metrics] lives DEVICE-RESIDENT
//! across steps (`execute_b` feeds step t's output buffer straight into step
//! t+1); only the small inputs (token batches) are uploaded per step, and
//! only the tiny `extract_metrics` slice plus the `extract_params` weight
//! snapshot (for DDMA publication) are fetched. That keeps the hot loop free
//! of 3P-sized host round-trips — the CPU analogue of keeping FSDP shards on
//! device.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::channel::{Inbound, Message};
use crate::coordinator::executor::{Executor, ExecutorContext, StepOutcome};
use crate::dataplane::RolloutStore;
use crate::memplane::plan::Phase;
use crate::memplane::pool::AllocClass;
use crate::model::{save_checkpoint, Checkpoint};
use crate::rl::{pack_batch, AipoConfig, Trajectory};
use crate::runtime::{HostTensor, Runtime};
use crate::util::error::Result;
use crate::util::json::Value;
use crate::util::logging::JsonlWriter;

#[derive(Debug, Clone)]
pub struct TrainerConfig {
    pub artifact_dir: std::path::PathBuf,
    pub aipo: AipoConfig,
    pub max_steps: u64,
    /// publish weights to the DDMA bus every k optimizer steps
    pub publish_every: u64,
    pub checkpoint_every: u64,
    /// crash-resume: optimizer step to continue counting from (0 for a
    /// fresh run); the step clock and `max_steps` horizon pick up exactly
    /// where the journaled run left off
    pub start_step: u64,
    /// crash-resume: packed train state recovered from the newest on-disk
    /// checkpoint (None: `init()` builds fresh state from the bus's
    /// version-front weights)
    pub resume_state: Option<Vec<f32>>,
    /// data-parallel fleet position: this replica's 0-based index. The
    /// global step sequence is partitioned round-robin — replica `r` of
    /// `n` owns exactly the steps `s` with `s % n == (r + 1) % n`, so the
    /// fleet covers `1..=max_steps` disjointly with no claim protocol.
    pub replica: usize,
    /// fleet size (1 = the classic single trainer)
    pub n_replicas: usize,
    /// bus publisher index minted by `WeightsBus::register_publisher`
    /// (0 is the pre-registered built-in publisher)
    pub publisher: usize,
    /// shared fleet coordination (finish countdown + periodic fence);
    /// None for a solo trainer outside periodic mode
    pub fleet: Option<Arc<FleetState>>,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            artifact_dir: "artifacts/nano".into(),
            aipo: AipoConfig::default(),
            max_steps: 10,
            publish_every: 1,
            checkpoint_every: 0,
            start_step: 0,
            resume_state: None,
            replica: 0,
            n_replicas: 1,
            publisher: 0,
            fleet: None,
        }
    }
}

/// Shared coordination state for a data-parallel trainer fleet. Two
/// concerns live here because they share the fleet's lifetime:
///
/// * the **finish countdown** — replicas exhaust disjoint step slices at
///   different times, and only the LAST one may request the global stop
///   and close the store (an early finisher closing the store would
///   starve peers that still own later steps);
/// * the **period fence** (`Mode::Periodic`) — before a replica executes
///   global step `s` it waits until every step of the previous period has
///   completed (`completed >= ((s - 1) / period) * period`), so the fleet
///   steps synchronously at period boundaries while generators free-run
///   against the store. `period == 0` disables the fence (pure async
///   fleet). The fence cannot deadlock: a step's fence depends only on
///   strictly smaller steps, and each replica executes its own slice in
///   increasing order, so the smallest incomplete step is always runnable.
#[derive(Debug)]
pub struct FleetState {
    /// trainers still running; decremented once per replica at finish
    active: AtomicUsize,
    /// completed global steps across the fleet (the period-fence clock;
    /// starts at the resume step)
    completed: Mutex<u64>,
    cv: Condvar,
    /// period length in global steps; 0 = no fence
    period: u64,
}

impl FleetState {
    pub fn new(n_replicas: usize, period: u64, start_step: u64) -> FleetState {
        FleetState {
            active: AtomicUsize::new(n_replicas.max(1)),
            completed: Mutex::new(start_step),
            cv: Condvar::new(),
            period,
        }
    }

    /// Count one replica out; true when this was the last active one.
    pub fn finish_one(&self) -> bool {
        self.active.fetch_sub(1, Ordering::AcqRel) == 1
    }

    /// Block until the fence for global step `step` opens (every step of
    /// the previous period has been trained). Returns false when the stop
    /// signal fired while waiting; the wait polls `should_stop` so a
    /// global stop never strands a replica at a boundary.
    pub fn fence_wait(&self, step: u64, should_stop: impl Fn() -> bool) -> bool {
        if self.period == 0 {
            return true;
        }
        let boundary = ((step.saturating_sub(1)) / self.period) * self.period;
        let mut done = self.completed.lock().unwrap();
        while *done < boundary {
            if should_stop() {
                return false;
            }
            let (d, _) = self
                .cv
                .wait_timeout(done, Duration::from_millis(50))
                .unwrap();
            done = d;
        }
        true
    }

    /// Record one completed global step and wake fence waiters.
    pub fn step_done(&self) {
        *self.completed.lock().unwrap() += 1;
        self.cv.notify_all();
    }
}

/// Per-step record the trainer exposes for reports/benches.
#[derive(Debug, Clone, Default)]
pub struct TrainStepRecord {
    pub step: u64,
    /// trainer-fleet replica that executed the step (0 for a solo trainer)
    pub replica: usize,
    pub wall_secs: f64,
    pub loss: f64,
    pub reward_mean: f64,
    pub mean_ratio: f64,
    pub clip_frac: f64,
    pub approx_kl: f64,
    pub entropy: f64,
    pub grad_norm: f64,
    pub mean_lag: f64,
    pub max_lag: u64,
    pub rows: usize,
}

/// Where the trainer's microbatches come from: the scored channel
/// (Mode::Sync / Mode::Async) or the rollout store (Mode::AsyncBuffered).
/// With a store, microbatch assembly — sampling strategy, staleness
/// enforcement — belongs to the store; the trainer only reports its clock
/// back via the watermark.
pub enum TrajectorySource {
    /// bounded channel fed by `producers` reward workers; each sends one
    /// EOF at drain, and the stream only ends once ALL have (fan-in)
    Channel { rx: Inbound, producers: usize },
    Store(Arc<RolloutStore>),
}

pub struct Trainer {
    cfg: TrainerConfig,
    ctx: Arc<ExecutorContext>,
    /// dropped on finish so blocked upstream senders unblock (shutdown
    /// path); dropping a Store source closes the store
    source: Option<TrajectorySource>,
    log: Option<Arc<JsonlWriter>>,
    runtime: Option<Runtime>,
    state_buf: Option<xla::PjRtBuffer>,
    step: u64,
    pending: VecDeque<Trajectory>,
    eof: bool,
    /// channel-source EOFs received so far (fan-in: the stream ends when
    /// every producer's EOF has arrived)
    eofs_seen: usize,
    started: Option<Instant>,
    pub records: Vec<TrainStepRecord>,
    /// seconds blocked inside `WeightsBus::publish` (the DDMA handoff;
    /// enqueue-only when the background executor runs)
    pub publish_secs_total: f64,
    /// seconds fetching the weight snapshot off-device (extract_params) —
    /// a cost common to every sync design, kept out of the handoff number
    pub extract_secs_total: f64,
}

impl Trainer {
    pub fn new(
        cfg: TrainerConfig,
        ctx: Arc<ExecutorContext>,
        source: TrajectorySource,
        log: Option<Arc<JsonlWriter>>,
    ) -> Trainer {
        let start_step = cfg.start_step;
        Trainer {
            cfg,
            ctx,
            source: Some(source),
            log,
            runtime: None,
            state_buf: None,
            step: start_step,
            pending: VecDeque::new(),
            eof: false,
            eofs_seen: 0,
            started: None,
            records: Vec::new(),
            publish_secs_total: 0.0,
            extract_secs_total: 0.0,
        }
    }

    fn runtime(&self) -> &Runtime {
        self.runtime.as_ref().expect("init() not called")
    }

    /// Fresh train state: the bus's current weight front zero-padded to the
    /// full packed layout [params | m | v | step | metrics].
    fn fresh_state(&self, rt: &Runtime) -> Vec<f32> {
        let snap = self.ctx.weights.latest();
        let total = rt.manifest.train_state.total;
        let mut state = Vec::with_capacity(total);
        state.extend_from_slice(&snap.data);
        state.resize(total, 0.0);
        debug_assert_eq!(snap.data.len(), rt.manifest.num_params);
        state
    }

    /// Pull from the trajectory source until we can fill a microbatch (or
    /// EOF). For a Store source the store assembles the rows (sampling
    /// strategy + staleness bound); here we only loop until enough arrive.
    fn fill_pending(&mut self) -> Result<()> {
        let need = self.runtime().config().train_batch;
        let Some(source) = self.source.as_ref() else {
            return Ok(());
        };
        while self.pending.len() < need && !self.eof {
            match source {
                TrajectorySource::Channel { rx, producers } => {
                    match rx.recv_timeout(Duration::from_millis(50)) {
                        Ok(Message::Scored(g)) => self.pending.extend(g),
                        Ok(Message::Trajectories(_)) => {
                            return Err(crate::util::error::Error::Coordinator(
                                "trainer received unscored trajectories".into(),
                            ))
                        }
                        Ok(Message::Eof) => {
                            self.eofs_seen += 1;
                            if self.eofs_seen >= *producers {
                                self.eof = true;
                            }
                        }
                        Err(_) => {
                            if self.ctx.should_stop() {
                                return Ok(());
                            }
                        }
                    }
                }
                TrajectorySource::Store(store) => {
                    let want = need - self.pending.len();
                    // fleet replicas drain disjoint shard-slices (no lock
                    // contention, no double-sampling); a solo trainer
                    // samples the whole store
                    match store.sample_slice(
                        self.cfg.replica,
                        self.cfg.n_replicas.max(1),
                        want,
                        Duration::from_millis(50),
                    ) {
                        None => self.eof = true, // closed and drained
                        Some(rows) => {
                            let starved = rows.is_empty();
                            self.pending.extend(rows);
                            if starved && self.ctx.should_stop() {
                                return Ok(());
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Tear down the trajectory source (shutdown path): dropping a channel
    /// unblocks senders with ChannelClosed; a store is closed explicitly so
    /// Block-admission producers wake up too. Idempotent; the graph runtime
    /// also calls it after a trainer *error*, where `step()`'s own teardown
    /// never ran — without it, reward workers blocked in a full scored
    /// channel could never observe the stop and the join would hang.
    pub(crate) fn drop_source(&mut self) {
        if let Some(TrajectorySource::Store(store)) = &self.source {
            store.close();
        }
        self.source = None;
    }

    fn run_train_step(&mut self, rows: Vec<Trajectory>) -> Result<TrainStepRecord> {
        // per-step span on the trainer's own track: async modes have no
        // stepped `train` phase, so this is what the analysis plane anchors
        // step windows on (in stepped mode it nests inside the phase span)
        let global_step = self.next_step();
        let _span = crate::trace::span_with(crate::trace::TRAIN_STEP, global_step as f64);
        let t0 = Instant::now();
        // Memplane Train lease: the optimizer update requires grads +
        // moments device-resident. The lease returns once the FIRST
        // optimizer shard is back (double-buffered prefetch); the
        // remaining stream overlaps batch packing/upload, and the
        // wait_class fence below is the last point it must have finished.
        let train_lease = match &self.ctx.mem {
            Some(m) => Some(m.lease(Phase::Train)?),
            None => None,
        };
        let rt = self.runtime.as_ref().unwrap();
        let mcfg = rt.config();
        let (b, t) = (mcfg.train_batch, mcfg.train_seq);
        let batch = pack_batch(&rows, b, t)?;

        let tokens_b = rt.upload(&HostTensor::I32(batch.tokens.clone(), vec![b, t]))?;
        let targets_b = rt.upload(&HostTensor::I32(batch.targets.clone(), vec![b, t]))?;
        let blogp_b = rt.upload(&HostTensor::F32(batch.blogp.clone(), vec![b, t]))?;
        let adv_b = rt.upload(&HostTensor::F32(batch.adv.clone(), vec![b, t]))?;
        let mask_b = rt.upload(&HostTensor::F32(batch.mask.clone(), vec![b, t]))?;
        let lens_b = rt.upload(&HostTensor::I32(batch.lens.clone(), vec![b]))?;
        let hyp = self.cfg.aipo.hyp();
        let hyp_b = rt.upload(&HostTensor::F32(hyp.to_vec(), vec![3]))?;

        // residency fence: every optimizer shard must have landed before
        // the fused update runs (prefetch hits when the plane overlapped
        // the stream behind the uploads above)
        if let Some(l) = &train_lease {
            l.wait_class(AllocClass::OptimState)?;
            l.wait_class(AllocClass::Grads)?;
        }
        let new_state = rt.execute_buffers(
            "train_step",
            &[
                self.state_buf.as_ref().unwrap(),
                &tokens_b,
                &targets_b,
                &blogp_b,
                &adv_b,
                &mask_b,
                &lens_b,
                &hyp_b,
            ],
        )?;
        self.state_buf = Some(new_state);
        self.step = global_step;
        // fleet replicas complete out of order; the shared clock is the
        // max completed step (fetch_max, like the store watermark)
        self.ctx
            .trainer_step
            .fetch_max(self.step, std::sync::atomic::Ordering::SeqCst);
        // the store's staleness clock follows the optimizer step
        if let Some(TrajectorySource::Store(store)) = &self.source {
            store.advance_watermark(self.step);
        }
        if let Some(fleet) = &self.cfg.fleet {
            fleet.step_done();
        }

        // fetch [step | metrics]
        let met_buf =
            rt.execute_buffers("extract_metrics", &[self.state_buf.as_ref().unwrap()])?;
        let met = rt.fetch_f32(&met_buf)?;
        let m = |name: &str| -> f64 {
            rt.manifest
                .metric_index(name)
                .map(|i| met[1 + i] as f64)
                .unwrap_or(f64::NAN)
        };

        // DDMA publication. The device fetch (extract_params) is a cost
        // every sync design pays; the publish call itself is the part the
        // background executor turns into enqueue-and-return, so the two are
        // accounted separately — `publish_secs_total` is the trainer-side
        // blocked time on the bus handoff only (it should track
        // `WeightsBus::publish_blocked_secs`).
        if self.cfg.publish_every > 0 && self.step % self.cfg.publish_every == 0 {
            // Sync lease: publication only needs the weight snapshot; it
            // nests inside the Train lease (Device residency only widens),
            // marking the phase boundary for the memplane's accounting.
            let _sync_lease = match &self.ctx.mem {
                Some(m) => Some(m.lease(Phase::Sync)?),
                None => None,
            };
            let tf = Instant::now();
            let p_buf =
                rt.execute_buffers("extract_params", &[self.state_buf.as_ref().unwrap()])?;
            let params = rt.fetch_f32(&p_buf)?;
            self.extract_secs_total += tf.elapsed().as_secs_f64();
            let tp = Instant::now();
            self.ctx.weights.publish_from(self.cfg.publisher, params);
            self.publish_secs_total += tp.elapsed().as_secs_f64();
        }

        let lags = batch.lags(self.step.saturating_sub(1));
        let mean_lag = if lags.is_empty() {
            0.0
        } else {
            lags.iter().sum::<u64>() as f64 / lags.len() as f64
        };
        let reward_mean = if batch.n_real_rows > 0 {
            batch.rewards[..batch.n_real_rows].iter().sum::<f32>() as f64
                / batch.n_real_rows as f64
        } else {
            0.0
        };

        let rec = TrainStepRecord {
            step: self.step,
            replica: self.cfg.replica,
            wall_secs: t0.elapsed().as_secs_f64(),
            loss: m("loss"),
            reward_mean,
            mean_ratio: m("mean_ratio"),
            clip_frac: m("clip_frac"),
            approx_kl: m("approx_kl"),
            entropy: m("entropy"),
            grad_norm: m("grad_norm"),
            mean_lag,
            max_lag: lags.iter().copied().max().unwrap_or(0),
            rows: batch.n_real_rows,
        };
        self.ctx.live.record_step(rec.wall_secs);
        if let Some(log) = &self.log {
            let elapsed = self.started.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
            log.write(&Value::object(vec![
                ("kind", Value::str("train")),
                ("step", Value::num(rec.step as f64)),
                ("elapsed", Value::num(elapsed)),
                ("wall_secs", Value::num(rec.wall_secs)),
                ("loss", Value::num(rec.loss)),
                ("reward_mean", Value::num(rec.reward_mean)),
                ("mean_ratio", Value::num(rec.mean_ratio)),
                ("clip_frac", Value::num(rec.clip_frac)),
                ("approx_kl", Value::num(rec.approx_kl)),
                ("entropy", Value::num(rec.entropy)),
                ("grad_norm", Value::num(rec.grad_norm)),
                ("mean_lag", Value::num(rec.mean_lag)),
                ("max_lag", Value::num(rec.max_lag as f64)),
                ("rows", Value::num(rec.rows as f64)),
            ]))?;
        }
        // durable copy: resume restarts the clock from the last journaled
        // step record, replay re-drives against this exact trajectory
        if let Some(journal) = &self.ctx.journal {
            journal.write(&crate::journal::JournalRecord::Step { record: rec.clone() })?;
        }
        Ok(rec)
    }

    /// Fetch the full packed train state (for checkpointing/inspection).
    pub fn fetch_state(&self) -> Result<Vec<f32>> {
        let rt = self.runtime.as_ref().unwrap();
        rt.fetch_f32(self.state_buf.as_ref().unwrap())
    }

    pub fn current_step(&self) -> u64 {
        self.step
    }

    /// The next global step this replica owns: the smallest `s > step`
    /// with `s % n == (replica + 1) % n` (round-robin partition of
    /// `1..=max_steps`; the identity partition for a solo trainer).
    fn next_step(&self) -> u64 {
        let n = self.cfg.n_replicas.max(1) as u64;
        if n == 1 {
            return self.step + 1;
        }
        let want = (self.cfg.replica as u64 + 1) % n;
        let s = self.step + 1;
        s + (want + n - s % n) % n
    }

    /// Finish-path bookkeeping: only the LAST replica to finish requests
    /// the global stop and closes the store; an early finisher just drops
    /// its source handle so peers keep draining their own slices.
    fn finish(&mut self) {
        let last = match &self.cfg.fleet {
            Some(f) => f.finish_one(),
            None => true,
        };
        if last {
            self.ctx.request_stop();
            self.drop_source();
        } else {
            self.source = None;
        }
    }
}

impl Executor for Trainer {
    fn name(&self) -> String {
        // fleet replicas get indexed names — the same identities the DOT
        // dump and trace tracks use ("tracks: trainer-0..trainer-N")
        if self.cfg.n_replicas > 1 {
            format!("trainer-{}", self.cfg.replica)
        } else {
            "trainer".into()
        }
    }

    fn init(&mut self) -> Result<()> {
        let rt = Runtime::load(&self.cfg.artifact_dir)?;
        rt.prepare("train_step")?;
        rt.prepare("extract_metrics")?;
        rt.prepare("extract_params")?;
        let total = rt.manifest.train_state.total;
        // Crash-resume: prefer the checkpointed packed state (params +
        // optimizer moments + step counter all intact); fall back to fresh
        // state from the bus's version-front weights.
        let state = match self.cfg.resume_state.take() {
            Some(s) if s.len() == total => s,
            Some(s) => {
                crate::log_warn!(
                    "trainer",
                    "resume state len {} != train_state.total {}; re-initializing",
                    s.len(),
                    total
                );
                self.fresh_state(&rt)
            }
            None => self.fresh_state(&rt),
        };
        self.state_buf = Some(rt.upload(&HostTensor::F32(state, vec![total]))?);
        self.runtime = Some(rt);
        // publish the resumed clock so store staleness/lag math is correct
        // from the first sampled batch (fetch_max: a fleet peer may have
        // completed a step before this replica finished init)
        self.ctx
            .trainer_step
            .fetch_max(self.step, std::sync::atomic::Ordering::SeqCst);
        if let Some(TrajectorySource::Store(store)) = &self.source {
            store.advance_watermark(self.step);
        }
        self.started = Some(Instant::now());
        Ok(())
    }

    fn set_step(&mut self, _step: u64) {}

    fn step(&mut self) -> Result<StepOutcome> {
        if self.next_step() > self.cfg.max_steps {
            // this replica's step slice is exhausted; the last finisher
            // requests the stop and unblocks any upstream sender stuck on
            // a full channel/store
            self.finish();
            return Ok(StepOutcome::Finished);
        }
        // periodic mode: hold at the period boundary until the previous
        // period is fully trained (generators keep free-running meanwhile)
        if let Some(fleet) = self.cfg.fleet.clone() {
            if !fleet.fence_wait(self.next_step(), || self.ctx.should_stop()) {
                fleet.finish_one();
                self.drop_source();
                return Ok(StepOutcome::Finished);
            }
        }
        self.fill_pending()?;
        let b = self.runtime().config().train_batch;
        if self.pending.is_empty() {
            return if self.eof || self.ctx.should_stop() {
                if let Some(fleet) = &self.cfg.fleet {
                    fleet.finish_one();
                }
                self.drop_source();
                Ok(StepOutcome::Finished)
            } else {
                Ok(StepOutcome::Idle)
            };
        }
        // Allow a final partial batch at drain time.
        if self.pending.len() < b && !self.eof && !self.ctx.should_stop() {
            return Ok(StepOutcome::Idle);
        }
        let take = self.pending.len().min(b);
        let rows: Vec<Trajectory> = self.pending.drain(..take).collect();
        let rec = self.run_train_step(rows)?;
        self.records.push(rec);
        Ok(StepOutcome::Progress)
    }

    fn save_checkpoint(&mut self) -> Result<()> {
        if self.cfg.checkpoint_every == 0 || self.runtime.is_none() {
            return Ok(());
        }
        let state = self.fetch_state()?;
        let dir = self.ctx.out_dir.join(format!("ckpt_step{}", self.step));
        save_checkpoint(
            &dir,
            &Checkpoint {
                step: self.step,
                weights_version: self.ctx.weights.version(),
                state,
            },
        )?;
        crate::log_info!("trainer", "checkpoint at step {} -> {}", self.step, dir.display());
        Ok(())
    }
}
