//! Reward executor: rule-based scoring + group advantage baselines.
//!
//! The paper's Figure-1 flow uses rule-based scorers ("lightweight Python
//! programs" co-located with light compute); here it is a lightweight Rust
//! executor. It GATHERs raw trajectories from all generator workers, scores
//! them by exact match, buffers until a prompt's full group of n generations
//! is present, computes the group-baseline advantages (paper §6), and hands
//! the scored group downstream through a [`ScoredSink`] — either SCATTERed
//! over a bounded channel to the trainer (Mode::Async) or admitted into the
//! staleness-aware rollout store (Mode::AsyncBuffered).

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::channel::{Inbound, Message, Outbound};
use crate::coordinator::executor::{Executor, ExecutorContext, StepOutcome};
use crate::data::task;
use crate::dataplane::RolloutStore;
use crate::model::Tokenizer;
use crate::rl::{group_advantages, Baseline, Trajectory};
use crate::util::error::Result;

/// Where scored groups go: the direct channel of the classic async
/// pipeline, or the rollout store of the buffered one. The reward executor
/// is agnostic — admission policy, eviction and staleness bookkeeping all
/// live behind this seam.
///
/// A reward *fleet* shares one sink: channel EOFs fan in naturally (the
/// trainer counts one per producer), while a shared store must only close
/// once the LAST worker drains — the cloned sink carries that countdown
/// latch.
#[derive(Clone)]
pub enum ScoredSink {
    Channel(Outbound),
    /// shared store + remaining-producers latch (fan-in close)
    Store(Arc<RolloutStore>, Arc<AtomicUsize>),
}

impl ScoredSink {
    /// Store sink shared by `producers` reward workers; clone it once per
    /// worker. The store closes when the last clone signals EOF.
    pub fn shared_store(store: Arc<RolloutStore>, producers: usize) -> ScoredSink {
        ScoredSink::Store(store, Arc::new(AtomicUsize::new(producers.max(1))))
    }

    pub fn send_group(&self, group: Vec<Trajectory>) -> Result<()> {
        match self {
            ScoredSink::Channel(out) => out.send(Message::Scored(group)),
            ScoredSink::Store(store, _) => store.push_group(group),
        }
    }

    pub fn send_eof(&self) {
        match self {
            ScoredSink::Channel(out) => out.send_eof(),
            ScoredSink::Store(store, latch) => {
                // countdown never underflows: a second EOF from the same
                // worker (impossible today, cheap to guard) is a no-op
                let sub = |v: usize| v.checked_sub(1);
                if latch.fetch_update(Ordering::AcqRel, Ordering::Acquire, sub) == Ok(1) {
                    store.close();
                }
            }
        }
    }
}

pub struct RewardExecutor {
    ctx: Arc<ExecutorContext>,
    inbound: Inbound,
    out: ScoredSink,
    baseline: Baseline,
    tokenizer: Tokenizer,
    groups: HashMap<u64, Vec<Trajectory>>,
    n_producers: usize,
    eofs_seen: usize,
    // telemetry
    pub scored: u64,
    pub groups_emitted: u64,
    pub rows_forwarded: u64,
    pub reward_sum: f64,
}

impl RewardExecutor {
    pub fn new(
        ctx: Arc<ExecutorContext>,
        inbound: Inbound,
        out: ScoredSink,
        baseline: Baseline,
        vocab: usize,
        n_producers: usize,
    ) -> Result<RewardExecutor> {
        Ok(RewardExecutor {
            ctx,
            inbound,
            out,
            baseline,
            tokenizer: Tokenizer::new(vocab)?,
            groups: HashMap::new(),
            n_producers,
            eofs_seen: 0,
            scored: 0,
            groups_emitted: 0,
            rows_forwarded: 0,
            reward_sum: 0.0,
        })
    }

    fn ingest(&mut self, trajs: Vec<Trajectory>) -> Result<()> {
        // the reward fleet's own scoring timeline: async modes have no
        // stepped `score` phase, so without this span the fleet is
        // invisible in the trace (value = rows scored in this pass)
        let _span = crate::trace::span_with(crate::trace::REWARD_SCORE, trajs.len() as f64);
        for mut t in trajs {
            let response = t.decoded_response(&self.tokenizer);
            t.reward = task::score(&t.problem, &response);
            self.reward_sum += t.reward as f64;
            self.scored += 1;
            let gid = t.group_id;
            let n = t.n_replicas;
            let group = self.groups.entry(gid).or_default();
            group.push(t);
            if group.len() == n {
                let mut full = self.groups.remove(&gid).unwrap();
                group_advantages(&mut full, self.baseline);
                self.groups_emitted += 1;
                self.rows_forwarded += full.len() as u64;
                self.out.send_group(full)?;
            }
        }
        Ok(())
    }

    /// Flush incomplete groups at drain time (their baseline uses whatever
    /// replicas arrived).
    fn flush(&mut self) -> Result<()> {
        let keys: Vec<u64> = self.groups.keys().copied().collect();
        for k in keys {
            let mut g = self.groups.remove(&k).unwrap();
            group_advantages(&mut g, self.baseline);
            self.groups_emitted += 1;
            self.rows_forwarded += g.len() as u64;
            self.out.send_group(g)?;
        }
        Ok(())
    }

    /// Decompose a dead executor into what a supervised replacement needs:
    /// the inbound queue (an mpsc receiver — not cloneable, so it must be
    /// recovered, not copied), the EOFs already counted, and any buffered
    /// incomplete groups. The rows were already scored (reward set, tallies
    /// counted), so the replacement re-adopts them via [`Self::adopt`]
    /// rather than re-ingesting.
    pub(crate) fn salvage(self) -> (Inbound, usize, Vec<Trajectory>) {
        let buffered = self.groups.into_values().flatten().collect();
        (self.inbound, self.eofs_seen, buffered)
    }

    /// Restore salvaged state from a previous attempt. Buffered rows slot
    /// straight into the group map (already scored — see [`Self::salvage`]);
    /// a group completed by later arrivals emits through the normal ingest
    /// path. Incomplete groups can never be complete here: completion
    /// removes them from the map before any salvage.
    pub(crate) fn adopt(&mut self, eofs_seen: usize, buffered: Vec<Trajectory>) {
        self.eofs_seen = self.eofs_seen.max(eofs_seen);
        for t in buffered {
            self.groups.entry(t.group_id).or_default().push(t);
        }
    }

    /// Non-blocking ingestion of one pending message; used by the sync
    /// baseline driver. Returns true if a message was processed.
    pub fn drain_once(&mut self) -> Result<bool> {
        match self.inbound.try_recv() {
            Some(Message::Trajectories(trajs)) => {
                self.ingest(trajs)?;
                Ok(true)
            }
            Some(Message::Eof) => {
                self.eofs_seen += 1;
                Ok(true)
            }
            Some(Message::Scored(_)) => Err(crate::util::error::Error::Coordinator(
                "reward executor received Scored message".into(),
            )),
            None => Ok(false),
        }
    }
}

impl RewardExecutor {
    /// Map a downstream ChannelClosed to a graceful finish when the job is
    /// stopping (the trainer drops its inbound on finish).
    fn graceful(&self, e: crate::util::error::Error) -> Result<StepOutcome> {
        use crate::util::error::Error;
        if self.ctx.should_stop() && matches!(e, Error::ChannelClosed(_)) {
            Ok(StepOutcome::Finished)
        } else {
            Err(e)
        }
    }
}

impl Executor for RewardExecutor {
    fn name(&self) -> String {
        "reward".into()
    }

    fn init(&mut self) -> Result<()> {
        Ok(())
    }

    fn set_step(&mut self, _step: u64) {}

    fn step(&mut self) -> Result<StepOutcome> {
        match self.inbound.recv_timeout(Duration::from_millis(50)) {
            Ok(Message::Trajectories(trajs)) => match self.ingest(trajs) {
                Ok(()) => Ok(StepOutcome::Progress),
                Err(e) => self.graceful(e),
            },
            Ok(Message::Scored(_)) => Err(crate::util::error::Error::Coordinator(
                "reward executor received Scored message".into(),
            )),
            Ok(Message::Eof) => {
                self.eofs_seen += 1;
                if self.eofs_seen >= self.n_producers {
                    if let Err(e) = self.flush() {
                        return self.graceful(e);
                    }
                    self.out.send_eof();
                    return Ok(StepOutcome::Finished);
                }
                Ok(StepOutcome::Progress)
            }
            Err(_) => {
                if self.ctx.should_stop() {
                    if let Err(e) = self.flush() {
                        return self.graceful(e);
                    }
                    self.out.send_eof();
                    return Ok(StepOutcome::Finished);
                }
                Ok(StepOutcome::Idle)
            }
        }
    }
}
