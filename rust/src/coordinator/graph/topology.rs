//! The topology layer: the training job as *data*.
//!
//! A [`Graph`] is a declarative description of the executor fleet and the
//! links between them — how many replicas of each [`NodeKind`], which
//! memory-plane lease each node's thread holds ([`LeasePolicy`]), whether
//! it receives streamed weight versions, and what [`EdgeKind`] carries the
//! trajectories. The four execution modes are four small *descriptions*
//! built by [`topology`]; one generic runtime
//! ([`super::runtime`]) launches any of them. Sync is not a separate
//! engine: it is the same graph with step-sized channel capacities, driven
//! by the stepped scheduler instead of free-running threads.

use std::time::Duration;

use crate::coordinator::controller::{Mode, PipelineConfig};
use crate::memplane::plan::Phase;
use crate::runtime::Manifest;
use crate::util::error::{Error, Result};

/// The executor fleets a training topology is built from (paper §5.1.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    /// data-parallel inference replicas (continuous batching)
    Generator,
    /// rule-based scoring + group advantages; a fleet receives generation
    /// groups scattered by group id
    Reward,
    /// the AIPO optimizer fleet (Algorithm 1's "local executor"). Replica
    /// 0 runs on the controller thread; extra replicas (store-backed modes
    /// only) are data-parallel threads that sample disjoint shard-slices,
    /// partition the global step sequence round-robin, and publish through
    /// the bus's multi-publisher path
    Trainer,
    /// optional held-out benchmark runs every K weight versions
    Evaluator,
}

impl NodeKind {
    pub fn label(self) -> &'static str {
        match self {
            NodeKind::Generator => "generator",
            NodeKind::Reward => "reward",
            NodeKind::Trainer => "trainer",
            NodeKind::Evaluator => "evaluator",
        }
    }
}

/// How a node's thread interacts with the colocated offloading memory
/// plane ([`crate::memplane`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LeasePolicy {
    /// no lease at spawn — the executor manages its own phase brackets
    /// internally (the trainer takes Train/Sync leases per step)
    None,
    /// hold the phase lease for the thread's whole lifetime (async modes:
    /// phases overlap, so the lease is feasibility + accounting)
    Lifetime(Phase),
    /// the stepped scheduler brackets each step with the lease and hints
    /// the next phase so the prefetcher can overlap the flip (sync mode)
    PerStep(Phase),
}

/// What the supervisor does when a replica of this fleet dies (error or
/// panic). `Never` preserves the pre-elastic behavior: the first failure
/// lands in the global `FailState` and stops the world. `BoundedRetries`
/// keeps the death local to the supervisor — the replica's partial
/// rollouts are parked for a survivor, the thread backs off
/// (exponentially, doubling per attempt) and respawns a fresh worker that
/// re-seeds weights from the bus front; only exhausting `max` attempts
/// escalates to the global stop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RestartPolicy {
    Never,
    BoundedRetries { max: u32, backoff: Duration },
}

impl RestartPolicy {
    /// The backoff to sleep before restart attempt `attempt` (0-based:
    /// the first restart is attempt 0), or `None` when the policy says
    /// the failure must escalate instead. Exponential: `backoff << attempt`,
    /// with the shift capped so the duration arithmetic can't overflow.
    pub fn backoff_for(&self, attempt: u32) -> Option<Duration> {
        match self {
            RestartPolicy::Never => None,
            RestartPolicy::BoundedRetries { max, backoff } => {
                (attempt < *max).then(|| *backoff * 2u32.saturating_pow(attempt.min(16)))
            }
        }
    }

    /// Total restarts the policy allows (0 for `Never`).
    pub fn max_restarts(&self) -> u32 {
        match self {
            RestartPolicy::Never => 0,
            RestartPolicy::BoundedRetries { max, .. } => *max,
        }
    }
}

/// One executor fleet in the topology.
#[derive(Debug, Clone, Copy)]
pub struct NodeSpec {
    pub kind: NodeKind,
    /// replica count; 0 means the node is absent from this run
    pub replicas: usize,
    pub lease: LeasePolicy,
    /// register a double-buffered weight-sync [`crate::weightsync::GeneratorSlot`]
    /// per replica (async modes: publishes stream in behind decode)
    pub sync_slot: bool,
    /// per-replica supervision on failure (see [`RestartPolicy`])
    pub restart: RestartPolicy,
}

/// The transport an edge runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeKind {
    /// bounded group-routed gather: many producers, one consumer per
    /// downstream replica, each trajectory delivered to replica
    /// `group_id % n` (group integrity for the advantage baseline)
    GroupRouted { capacity: usize },
    /// bounded gather: many producers, one consumer
    Gather { capacity: usize },
    /// the sharded staleness-aware [`crate::dataplane::RolloutStore`]
    Store,
}

/// One directed link between two fleets.
#[derive(Debug, Clone, Copy)]
pub struct EdgeSpec {
    pub name: &'static str,
    pub from: NodeKind,
    pub to: NodeKind,
    pub kind: EdgeKind,
}

/// A complete declarative topology: what [`Graph::launch`] runs. The
/// graph IS the mode — `mode_name` labels it for reports/DOT, `stepped`
/// selects the scheduler, and everything else is nodes and edges.
#[derive(Debug, Clone)]
pub struct Graph {
    /// the mode string reports carry
    /// ("sync" / "async" / "async_buffered" / "periodic")
    pub mode_name: &'static str,
    /// drive the graph with the stepped one-thread scheduler (strictly
    /// sequential generate → score → train ticks) instead of free-running
    /// threads; the nodes and edges are the same either way
    pub stepped: bool,
    pub nodes: Vec<NodeSpec>,
    pub edges: Vec<EdgeSpec>,
}

/// Build the topology for `cfg` (mode, fleet sizes, channel capacities).
/// The manifest only contributes the sync mode's rows-per-step (channels
/// must absorb one whole step without blocking).
pub fn topology(cfg: &PipelineConfig, manifest: &Manifest) -> Graph {
    topology_with_rows(cfg, manifest.config.train_batch)
}

/// [`topology`] with the per-step row count passed explicitly (lets tests
/// and `--dump-graph` describe a topology without loading artifacts).
pub fn topology_with_rows(cfg: &PipelineConfig, rows_per_step: usize) -> Graph {
    let n_reward = cfg.n_reward_workers.max(1);
    // the generator/reward fleets are restartable when configured; the
    // trainer fleet (owns the optimizer clock) and evaluator never are —
    // their failure is always a global stop
    let fleet_restart = if cfg.restart_max > 0 {
        RestartPolicy::BoundedRetries {
            max: cfg.restart_max,
            backoff: Duration::from_millis(cfg.restart_backoff_ms.max(1)),
        }
    } else {
        RestartPolicy::Never
    };
    let evaluator = NodeSpec {
        kind: NodeKind::Evaluator,
        replicas: usize::from(cfg.eval_every > 0),
        lease: LeasePolicy::None,
        sync_slot: false,
        restart: RestartPolicy::Never,
    };
    // the configured fleet size lands in the spec for every mode;
    // `check()` rejects the combinations the runtime cannot execute
    // (stepped scheduler, channel scored edge) with an explicit error
    // instead of silently running with one trainer
    let trainer = NodeSpec {
        kind: NodeKind::Trainer,
        replicas: cfg.n_trainer_workers.max(1),
        lease: LeasePolicy::None, // brackets its own Train/Sync leases per step
        sync_slot: false,
        restart: RestartPolicy::Never,
    };
    match cfg.mode {
        Mode::Sync => {
            // one thread drives everything; channels must absorb a whole
            // step's traffic (worst case: one message per trajectory)
            let cap = (2 * rows_per_step).max(64);
            Graph {
                mode_name: "sync",
                stepped: true,
                nodes: vec![
                    NodeSpec {
                        kind: NodeKind::Generator,
                        replicas: 1,
                        lease: LeasePolicy::PerStep(Phase::Generate),
                        sync_slot: false, // re-attaches to the DDMA master directly
                        // the stepped scheduler has no supervisor thread
                        restart: RestartPolicy::Never,
                    },
                    NodeSpec {
                        kind: NodeKind::Reward,
                        replicas: n_reward,
                        lease: LeasePolicy::None,
                        sync_slot: false,
                        restart: RestartPolicy::Never,
                    },
                    trainer,
                    evaluator,
                ],
                edges: vec![
                    EdgeSpec {
                        name: "generations",
                        from: NodeKind::Generator,
                        to: NodeKind::Reward,
                        kind: EdgeKind::GroupRouted { capacity: cap },
                    },
                    EdgeSpec {
                        name: "scored",
                        from: NodeKind::Reward,
                        to: NodeKind::Trainer,
                        kind: EdgeKind::Gather { capacity: cap },
                    },
                ],
            }
        }
        Mode::Async | Mode::AsyncBuffered | Mode::Periodic => {
            // periodic is the buffered topology plus a trainer-side period
            // fence (runtime concern); the graph shape is identical
            let buffered = matches!(cfg.mode, Mode::AsyncBuffered | Mode::Periodic);
            Graph {
                mode_name: match cfg.mode {
                    Mode::Async => "async",
                    Mode::Periodic => "periodic",
                    _ => "async_buffered",
                },
                stepped: false,
                nodes: vec![
                    NodeSpec {
                        kind: NodeKind::Generator,
                        replicas: cfg.n_generator_workers.max(1),
                        lease: LeasePolicy::Lifetime(Phase::Generate),
                        sync_slot: true,
                        restart: fleet_restart,
                    },
                    NodeSpec {
                        kind: NodeKind::Reward,
                        replicas: n_reward,
                        lease: LeasePolicy::None,
                        sync_slot: false,
                        restart: fleet_restart,
                    },
                    trainer,
                    evaluator,
                ],
                edges: vec![
                    EdgeSpec {
                        name: "generations",
                        from: NodeKind::Generator,
                        to: NodeKind::Reward,
                        kind: EdgeKind::GroupRouted { capacity: cfg.queue_capacity },
                    },
                    EdgeSpec {
                        name: "scored",
                        from: NodeKind::Reward,
                        to: NodeKind::Trainer,
                        kind: if buffered {
                            EdgeKind::Store
                        } else {
                            EdgeKind::Gather { capacity: cfg.scored_capacity }
                        },
                    },
                ],
            }
        }
    }
}

impl Graph {
    /// The node spec for `kind` (absent nodes — replicas 0 — still have a
    /// spec; a missing entry means the topology never mentions the kind).
    pub fn node(&self, kind: NodeKind) -> Option<&NodeSpec> {
        self.nodes.iter().find(|n| n.kind == kind)
    }

    /// Replica count for `kind` (0 when absent).
    pub fn replicas(&self, kind: NodeKind) -> usize {
        self.node(kind).map(|n| n.replicas).unwrap_or(0)
    }

    /// The edge delivering into `kind`.
    pub fn edge_into(&self, kind: NodeKind) -> Option<&EdgeSpec> {
        self.edges.iter().find(|e| e.to == kind)
    }

    /// Structural validation, run before anything spawns: every launchable
    /// topology has at least one trainer, generator, and reward replica, a
    /// group-routed generations edge (group integrity), and a scored edge
    /// the trainer can consume. The stepped scheduler drives a single
    /// generator and a single trainer; a trainer *fleet* (replicas > 1)
    /// additionally requires the store scored edge — disjoint shard-slice
    /// sampling is the partitioning mechanism, and a gather channel has no
    /// equivalent.
    pub fn check(&self) -> Result<()> {
        let fail = |msg: String| Err(Error::Coordinator(format!("invalid topology: {msg}")));
        if self.replicas(NodeKind::Trainer) == 0 {
            return fail("at least one trainer replica required".into());
        }
        if self.replicas(NodeKind::Trainer) > 1 {
            if self.stepped {
                return fail(
                    "the stepped scheduler drives exactly one trainer; trainer \
                     fleets require free-running threads"
                        .into(),
                );
            }
            if self.edge_into(NodeKind::Trainer).map(|e| e.kind) != Some(EdgeKind::Store) {
                return fail(
                    "trainer fleets require the store scored edge (disjoint \
                     shard-slice sampling is the step partitioning mechanism)"
                        .into(),
                );
            }
        }
        if self.replicas(NodeKind::Generator) == 0 {
            return fail("at least one generator replica required".into());
        }
        if self.replicas(NodeKind::Reward) == 0 {
            return fail("at least one reward replica required".into());
        }
        if self.stepped {
            // the stepped scheduler must be able to honor every declared
            // field — reject combinations it cannot execute rather than
            // silently running with different semantics
            if self.replicas(NodeKind::Generator) != 1 {
                return fail("the stepped scheduler drives exactly one generator".into());
            }
            if let Some(g) = self.node(NodeKind::Generator) {
                if g.sync_slot {
                    return fail(
                        "stepped generators re-attach to the DDMA master; sync slots \
                         require free-running threads"
                            .into(),
                    );
                }
                if matches!(g.lease, LeasePolicy::Lifetime(_)) {
                    return fail(
                        "lifetime leases require free-running threads; stepped graphs \
                         use per-step leases"
                            .into(),
                    );
                }
            }
            if self.edge_into(NodeKind::Trainer).map(|e| e.kind) == Some(EdgeKind::Store) {
                return fail("the stepped scheduler requires a channel scored edge".into());
            }
        }
        for n in &self.nodes {
            if n.restart == RestartPolicy::Never {
                continue;
            }
            // the supervisor layer exists only around fleet worker
            // threads; the trainer IS the controller thread and the
            // stepped scheduler runs every node inline
            if matches!(n.kind, NodeKind::Trainer | NodeKind::Evaluator) {
                return fail(format!(
                    "{} nodes cannot be restartable (no supervisor wraps them)",
                    n.kind.label()
                ));
            }
            if self.stepped {
                return fail(
                    "restart policies require free-running threads; the stepped \
                     scheduler has no supervisor"
                        .into(),
                );
            }
        }
        for e in &self.edges {
            if self.node(e.from).is_none() || self.node(e.to).is_none() {
                return fail(format!("edge '{}' references a missing node", e.name));
            }
        }
        match self.edge_into(NodeKind::Reward) {
            Some(e) if matches!(e.kind, EdgeKind::GroupRouted { .. }) => {}
            Some(e) => {
                return fail(format!(
                    "generations edge '{}' must be group-routed so advantage \
                     groups stay whole",
                    e.name
                ))
            }
            None => return fail("reward fleet has no inbound edge".into()),
        }
        match self.edge_into(NodeKind::Trainer) {
            Some(e) if matches!(e.kind, EdgeKind::Gather { .. } | EdgeKind::Store) => {}
            Some(e) => {
                return fail(format!(
                    "scored edge '{}' must be a gather channel or the store",
                    e.name
                ))
            }
            None => return fail("trainer has no inbound edge".into()),
        }
        Ok(())
    }

    /// Render the resolved topology as Graphviz DOT (`--dump-graph`).
    pub fn to_dot(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "digraph llamarl {{\n  label=\"{} topology ({})\";\n  rankdir=LR;\n  \
             node [shape=box, fontname=\"monospace\"];\n",
            self.mode_name,
            if self.stepped {
                "stepped scheduler"
            } else {
                "free-running threads"
            }
        ));
        for n in &self.nodes {
            if n.replicas == 0 {
                continue;
            }
            let lease = match n.lease {
                LeasePolicy::None => String::new(),
                LeasePolicy::Lifetime(p) => format!("\\nlease: {p:?} (lifetime)"),
                LeasePolicy::PerStep(p) => format!("\\nlease: {p:?} (per step)"),
            };
            let slot = if n.sync_slot { "\\nweight-sync slot" } else { "" };
            let restart = match n.restart {
                RestartPolicy::Never => String::new(),
                RestartPolicy::BoundedRetries { max, backoff } => {
                    format!("\\nrestart: <= {max}x, backoff {}ms", backoff.as_millis())
                }
            };
            // replicated nodes run one named thread per replica; single
            // nodes one thread. The same names are the telemetry/trace
            // track identities, so a dumped graph maps 1:1 onto the
            // tracks in trace exports and snapshot series.
            let tracks = match n.replicas {
                1 => format!("\\ntrack: {}", n.kind.label()),
                r => format!(
                    "\\ntracks: {}-0..{}-{}",
                    n.kind.label(),
                    n.kind.label(),
                    r - 1
                ),
            };
            out.push_str(&format!(
                "  {} [label=\"{} x{}{}{}{}{}\"];\n",
                n.kind.label(),
                n.kind.label(),
                n.replicas,
                tracks,
                lease,
                slot,
                restart
            ));
        }
        for e in &self.edges {
            let kind = match e.kind {
                EdgeKind::GroupRouted { capacity } => format!("group-routed, cap {capacity}"),
                EdgeKind::Gather { capacity } => format!("gather, cap {capacity}"),
                EdgeKind::Store => "rollout store".to_string(),
            };
            out.push_str(&format!(
                "  {} -> {} [label=\"{} ({})\"];\n",
                e.from.label(),
                e.to.label(),
                e.name,
                kind
            ));
        }
        // the DDMA weights path is not a data edge; show it dashed
        out.push_str("  trainer -> generator [style=dashed, label=\"DDMA weights bus\"];\n");
        out.push_str("}\n");
        out
    }
}
