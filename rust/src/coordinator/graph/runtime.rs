//! The runtime layer: one generic [`Graph::launch`] for every topology.
//!
//! This is the code the three hand-rolled mode drivers used to triplicate,
//! written once: edge construction, weight-sync slot registration, named
//! thread spawning with panic→error conversion, memory-plane lease
//! handling per [`LeasePolicy`], stop/EOF propagation, and the join
//! protocol. Two schedulers drive the same node/edge machinery:
//!
//! * **threaded** — every replica free-runs on its own named OS thread
//!   (its own PJRT context = its own "processing group"); the trainer runs
//!   on the controller thread (Algorithm 1's "local executor"). Any node
//!   error or panic is recorded into a shared first-error slot, the global
//!   stop fans out (and the store closes, waking blocked admission /
//!   sampling), and every thread joins cleanly — the error surfaces from
//!   `launch`, never a hung join.
//! * **stepped** — the synchronous baseline: the SAME graph, driven
//!   strictly sequentially on one thread (generate → score → train ticks
//!   with the all-rows-finish straggler bubble). Nothing about the
//!   topology changes except the channel capacities it was declared with.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::channel::{gather_channel, routed_channel, ChannelStats, Inbound, Outbound};
use crate::coordinator::controller::{Mode, PipelineConfig, RunReport};
use crate::coordinator::evaluator::{eval_policy, EvaluatorConfig, EvaluatorExecutor};
use crate::coordinator::executor::{
    run_executor_loop, run_executor_loop_initialized, Executor, ExecutorContext, StepOutcome,
};
use crate::coordinator::generator::{GenTally, GeneratorConfig, GeneratorWorker};
use crate::coordinator::graph::supervisor::{supervise, ChaosSchedule};
use crate::coordinator::graph::telemetry::{ElasticStats, RewardTally, TelemetryHub};
use crate::coordinator::graph::topology::{EdgeKind, Graph, LeasePolicy, NodeKind};
use crate::coordinator::reward::{RewardExecutor, ScoredSink};
use crate::coordinator::trainer::{FleetState, Trainer, TrainerConfig, TrajectorySource};
use crate::data::{task, PromptScheduler};
use crate::dataplane::{RolloutStore, StoreConfig, StoreDump};
use crate::journal::{JournalRecord, SnapshotDaemon, SnapshotRecord, StoreSnapshot};
use crate::memplane::plan::Phase;
use crate::runtime::Manifest;
use crate::trace::{self, Sampler};
use crate::util::error::{Error, Result};
use crate::util::logging::JsonlWriter;

/// Everything a launch needs beyond the graph itself: the resolved config,
/// the loaded manifest, and the per-run shared state the controller built
/// (executor context with the weight-sync and memory planes, the prompt
/// scheduler, the metrics writer).
pub struct LaunchEnv<'a> {
    pub cfg: &'a PipelineConfig,
    pub manifest: &'a Manifest,
    pub ctx: Arc<ExecutorContext>,
    pub scheduler: Arc<PromptScheduler>,
    pub log: Arc<JsonlWriter>,
}

impl Graph {
    /// Launch this topology and run it to completion. Validates the graph,
    /// builds the edges, spawns (or steps) the fleets, and assembles the
    /// report through the [`TelemetryHub`] — the single entry point all
    /// three modes run through.
    pub fn launch(&self, env: &LaunchEnv<'_>) -> Result<RunReport> {
        self.check()?;
        if self.stepped {
            run_stepped(self, env)
        } else {
            run_threaded(self, env)
        }
    }
}

fn gen_cfg(cfg: &PipelineConfig, worker: usize) -> GeneratorConfig {
    GeneratorConfig {
        artifact_dir: cfg.artifact_dir.clone(),
        temperature: cfg.temperature,
        top_k: cfg.top_k,
        quantize_int8: cfg.quantize_generator,
        max_response: cfg.max_response,
        seed: cfg.seed.wrapping_add(1000 + worker as u64),
        fail_after_chunks: cfg.debug_fail_generator_after,
    }
}

fn trainer_cfg(cfg: &PipelineConfig) -> TrainerConfig {
    TrainerConfig {
        artifact_dir: cfg.artifact_dir.clone(),
        aipo: cfg.aipo,
        max_steps: cfg.max_steps,
        // periodic mode coalesces publication to ONE bus publish per
        // period — the boundary step's owner publishes for the fleet
        publish_every: if matches!(cfg.mode, Mode::Periodic) {
            cfg.period_steps.max(1)
        } else {
            1
        },
        checkpoint_every: cfg.checkpoint_every,
        // crash-resume: the optimizer clock continues from the journaled
        // step, seeded from the newest on-disk checkpoint when one exists
        start_step: cfg.resume.as_ref().map(|r| r.start_step).unwrap_or(0),
        resume_state: cfg.resume.as_ref().and_then(|r| r.init_state.clone()),
        replica: 0,
        n_replicas: 1,
        publisher: 0,
        fleet: None,
    }
}

/// The scored edge, materialized.
enum ScoredPlane {
    Channel {
        tx: Outbound,
        rx: Inbound,
        stats: Arc<ChannelStats>,
    },
    Store(Arc<RolloutStore>),
}

struct BuiltEdges {
    gen_tx: Outbound,
    gen_rxs: Vec<Inbound>,
    gen_stats: Arc<ChannelStats>,
    scored: ScoredPlane,
}

/// Materialize the graph's edges: the group-routed generations channel
/// (one bounded queue per reward replica) and the scored plane (bounded
/// gather channel or the rollout store). When the run-journal is on, the
/// store is wired to it as its durable replica (admit/consume records),
/// and a crash-resumed run re-seeds the store from the recovered cut
/// BEFORE the observer attaches (restored rows are not re-journaled).
fn build_edges(
    graph: &Graph,
    cfg: &PipelineConfig,
    journal: Option<&Arc<crate::journal::JournalWriter>>,
) -> Result<BuiltEdges> {
    let gen_edge = graph
        .edge_into(NodeKind::Reward)
        .ok_or_else(|| Error::Coordinator("reward fleet has no inbound edge".into()))?;
    let EdgeKind::GroupRouted { capacity } = gen_edge.kind else {
        return Err(Error::Coordinator("generations edge must be group-routed".into()));
    };
    let n_reward = graph.replicas(NodeKind::Reward);
    let (gen_tx, gen_rxs) = routed_channel(gen_edge.name, capacity, n_reward);
    let gen_stats = gen_tx.stats.clone();

    let scored_edge = graph
        .edge_into(NodeKind::Trainer)
        .ok_or_else(|| Error::Coordinator("trainer has no inbound edge".into()))?;
    let scored = match scored_edge.kind {
        EdgeKind::Gather { capacity } => {
            let (tx, rx) = gather_channel(scored_edge.name, capacity);
            let stats = tx.stats.clone();
            ScoredPlane::Channel { tx, rx, stats }
        }
        EdgeKind::Store => {
            let store = Arc::new(RolloutStore::new(StoreConfig {
                seed: cfg.seed ^ 0xB0FF_E12D,
                ..cfg.store.clone()
            }));
            if let Some(st) = cfg.resume.as_ref().and_then(|r| r.store.clone()) {
                store.restore(StoreDump {
                    next_seq: st.next_seq,
                    watermark: st.watermark,
                    rows: st.rows,
                    partials: st.partials,
                });
            }
            if let Some(j) = journal {
                store.set_observer(j.clone());
            }
            ScoredPlane::Store(store)
        }
        EdgeKind::GroupRouted { .. } => {
            return Err(Error::Coordinator(
                "scored edge must be a gather channel or the store".into(),
            ))
        }
    };
    Ok(BuiltEdges {
        gen_tx,
        gen_rxs,
        gen_stats,
        scored,
    })
}

/// First-error slot shared by every node thread. Recording an error (or a
/// converted panic) requests the global stop and closes the store, so
/// every other node unwinds through its graceful drain path and the
/// subsequent joins cannot hang.
struct FailState {
    first: Mutex<Option<Error>>,
    ctx: Arc<ExecutorContext>,
    store: Option<Arc<RolloutStore>>,
}

impl FailState {
    fn new(ctx: Arc<ExecutorContext>, store: Option<Arc<RolloutStore>>) -> Arc<FailState> {
        Arc::new(FailState {
            first: Mutex::new(None),
            ctx,
            store,
        })
    }

    fn record(&self, node: &str, e: Error) {
        {
            let mut slot = self.first.lock().unwrap();
            if slot.is_none() {
                *slot = Some(Error::Coordinator(format!("node {node} failed: {e}")));
            }
        }
        self.ctx.request_stop();
        if let Some(s) = &self.store {
            s.close();
        }
    }

    fn take(&self) -> Option<Error> {
        self.first.lock().unwrap().take()
    }
}

/// Spawn one node replica on a named thread. The body's error — or panic,
/// converted — lands in the shared [`FailState`] (stopping the whole
/// graph); the tally comes back through the join.
fn spawn_node<T, F>(name: String, fail: Arc<FailState>, body: F) -> JoinHandle<Option<T>>
where
    F: FnOnce() -> Result<T> + Send + 'static,
    T: Send + 'static,
{
    let reported = name.clone();
    std::thread::Builder::new()
        .name(name)
        .spawn(move || {
            // the thread name doubles as the trace track identity
            trace::instant(trace::NODE_START, 0.0);
            if let Some(j) = &fail.ctx.journal {
                j.note_node(&reported, "start");
            }
            let out = match catch_unwind(AssertUnwindSafe(body)) {
                Ok(Ok(tally)) => Some(tally),
                Ok(Err(e)) => {
                    fail.record(&reported, e);
                    None
                }
                Err(_) => {
                    fail.record(&reported, Error::msg("panicked"));
                    None
                }
            };
            trace::instant(trace::NODE_STOP, 0.0);
            if let Some(j) = &fail.ctx.journal {
                j.note_node(&reported, "stop");
            }
            out
        })
        .expect("spawn graph node thread")
}

/// Join a node thread; the in-thread catch_unwind already converted
/// panics, so an Err here (a panic escaping the guard) is a backstop.
fn join_node<T>(h: JoinHandle<Option<T>>, kind: &str, idx: usize) -> Result<Option<T>> {
    h.join().map_err(|_| {
        Error::Coordinator(format!("node {kind}-{idx} panicked outside the runtime guard"))
    })
}

/// Everything the elastic fleet controller needs to spawn dynamic
/// generator replicas on the same edges the static fleet uses.
struct FleetCtl {
    ctx: Arc<ExecutorContext>,
    scheduler: Arc<PromptScheduler>,
    out: Outbound,
    store: Arc<RolloutStore>,
    fail: Arc<FailState>,
    elastic: Arc<ElasticStats>,
    gcfg: GeneratorConfig,
    base_seed: u64,
    base_replicas: usize,
    max_extra: usize,
    low_water: usize,
    capacity: usize,
    sync_slot: bool,
}

/// Queue-depth-driven elastic resize (buffered topologies only): scale the
/// generator fleet UP when the trainer is starving (store occupancy stays
/// below one training batch — the condition that surfaces as
/// `trainer_sample_wait_secs`), and DOWN when admission backs up (occupancy
/// pins above 3/4 capacity, where the store starts evicting). Dynamic
/// replicas never signal EOF — drain fan-in counts are sized to the static
/// fleet — and register their own weight-sync slots, seeded from the bus
/// front like any late subscriber. A retired replica parks its in-flight
/// partials for the static fleet to resume. The returned handle joins
/// every dynamic replica and hands back their summed tally.
fn spawn_fleet_controller(f: FleetCtl) -> JoinHandle<GenTally> {
    std::thread::Builder::new()
        .name("fleet-controller".into())
        .spawn(move || {
            let mut live: Vec<(Arc<AtomicBool>, JoinHandle<Option<GenTally>>)> = Vec::new();
            let mut retired: Vec<JoinHandle<Option<GenTally>>> = Vec::new();
            let mut next_id = f.base_replicas;
            let (mut low_streak, mut high_streak) = (0u32, 0u32);
            while !f.ctx.should_stop() {
                std::thread::sleep(Duration::from_millis(20));
                let occ = f.store.snapshot().occupancy;
                low_streak = if occ < f.low_water { low_streak + 1 } else { 0 };
                high_streak = if occ * 4 > f.capacity * 3 { high_streak + 1 } else { 0 };
                if low_streak >= 5 && live.len() < f.max_extra {
                    low_streak = 0;
                    let id = next_id;
                    next_id += 1;
                    let from = f.base_replicas + live.len();
                    live.push(spawn_dynamic_generator(&f, id));
                    f.elastic.scale_ups.fetch_add(1, Ordering::Relaxed);
                    note_resize(&f, from, from + 1, format!("occupancy {occ} < batch {}", f.low_water));
                } else if high_streak >= 5 && !live.is_empty() {
                    high_streak = 0;
                    let (flag, h) = live.pop().expect("non-empty");
                    flag.store(true, Ordering::Relaxed);
                    retired.push(h);
                    let from = f.base_replicas + live.len() + 1;
                    f.elastic.scale_downs.fetch_add(1, Ordering::Relaxed);
                    note_resize(&f, from, from - 1, format!("occupancy {occ} > 3/4 of {}", f.capacity));
                }
            }
            // shutdown: retire everything still live, then fold the tallies
            let mut tally = GenTally::default();
            for (flag, h) in live {
                flag.store(true, Ordering::Relaxed);
                retired.push(h);
            }
            for h in retired {
                if let Ok(Some(t)) = h.join() {
                    tally.add(&t);
                }
            }
            tally
        })
        .expect("spawn fleet controller thread")
}

fn note_resize(f: &FleetCtl, from: usize, to: usize, reason: String) {
    crate::log_info!("graph", "fleet resize: generator {from} -> {to} ({reason})");
    // mirror the journal record as a trace instant so resizes show up on
    // the fleet-controller track in Chrome exports (value = new size)
    trace::instant(trace::FLEET_RESIZE, to as f64);
    if let Some(j) = &f.ctx.journal {
        j.write_infallible(&JournalRecord::FleetResize {
            node: "generator".into(),
            from: from as u64,
            to: to as u64,
            reason,
        });
    }
}

/// One dynamic generator replica: the static worker loop minus EOF (fan-in
/// counts stay exact) plus a retire flag the controller flips to shed it.
fn spawn_dynamic_generator(
    f: &FleetCtl,
    id: usize,
) -> (Arc<AtomicBool>, JoinHandle<Option<GenTally>>) {
    let retire = Arc::new(AtomicBool::new(false));
    let flag = retire.clone();
    let ctx = f.ctx.clone();
    let scheduler = f.scheduler.clone();
    let out = f.out.clone();
    let store = f.store.clone();
    let mut gcfg = f.gcfg.clone();
    gcfg.seed = f.base_seed.wrapping_add(1000 + id as u64);
    let sync_slot = f.sync_slot;
    // deliberately NO memory-plane lease: a dynamic replica is
    // opportunistic, and a capacity-full lease error must not escalate to
    // a global stop the way a static replica's launch failure does
    let h = spawn_node(format!("generator-dyn-{id}"), f.fail.clone(), move || {
        let mut gen = GeneratorWorker::new(id, gcfg, ctx.clone(), scheduler, out);
        gen.suppress_eof();
        gen.set_resume_store(store);
        if sync_slot {
            gen.set_sync_slot(ctx.weights.register_generator());
        }
        gen.init()?;
        while !ctx.should_stop() && !retire.load(Ordering::Relaxed) {
            if matches!(gen.step()?, StepOutcome::Finished) {
                break;
            }
        }
        // hand in-flight work back: parked partials resume on the static
        // fleet's next refill
        gen.drain()?;
        Ok(gen.tally())
    });
    (flag, h)
}

/// Start the `--metrics-interval` live-telemetry sampler when configured.
/// The handle keeps the snapshot thread alive; stopping (or dropping) it
/// writes one final snapshot so the series covers the whole run.
fn start_sampler(
    cfg: &PipelineConfig,
    hub: &TelemetryHub,
    ctx: Arc<ExecutorContext>,
) -> Result<Option<Sampler>> {
    if cfg.metrics_interval_secs <= 0.0 {
        return Ok(None);
    }
    Ok(Some(Sampler::start(
        cfg.out_dir.join("telemetry_snapshots.jsonl"),
        cfg.metrics_interval_secs,
        hub.live_sampler(ctx),
    )?))
}

/// Gather one consistent cut of the run's durable state for the journal's
/// snapshot records. Called from inside [`JournalWriter::write_snapshot`]'s
/// closure, i.e. under the journal writer lock and NEVER under store shard
/// locks (`RolloutStore::dump` takes and releases them internally —
/// journal → shards is the one legal lock order).
///
/// [`JournalWriter::write_snapshot`]: crate::journal::JournalWriter::write_snapshot
fn build_snapshot(ctx: &ExecutorContext, store: Option<&RolloutStore>) -> SnapshotRecord {
    let mut snap = SnapshotRecord {
        trainer_step: ctx.trainer_step.load(Ordering::SeqCst),
        bus_version: ctx.weights.version(),
        bus_publishes: ctx.weights.publish_count(),
        slot_fronts: ctx.weights.subscriber_fronts(),
        store: store.map(|s| {
            let d = s.dump();
            StoreSnapshot {
                next_seq: d.next_seq,
                watermark: d.watermark,
                rows: d.rows,
                partials: d.partials,
            }
        }),
        ..SnapshotRecord::default()
    };
    if let Some(m) = &ctx.mem {
        let u = m.usage();
        snap.mem_device_used = u.device_used;
        snap.mem_host_used = u.host_used;
    }
    snap
}

/// Start the journal's periodic snapshot daemon when the journal is on.
fn start_snapshotter(
    cfg: &PipelineConfig,
    ctx: &Arc<ExecutorContext>,
    store: Option<Arc<RolloutStore>>,
) -> Option<SnapshotDaemon> {
    let journal = ctx.journal.clone()?;
    let ctx = ctx.clone();
    Some(SnapshotDaemon::start(
        journal,
        cfg.journal_snapshot_secs,
        move || build_snapshot(&ctx, store.as_deref()),
    ))
}

/// The free-running scheduler: one named thread per replica, trainer on
/// the controller thread (async / async-buffered modes).
fn run_threaded(graph: &Graph, env: &LaunchEnv<'_>) -> Result<RunReport> {
    let cfg = env.cfg;
    let BuiltEdges {
        gen_tx,
        gen_rxs,
        gen_stats,
        scored,
    } = build_edges(graph, cfg, env.ctx.journal.as_ref())?;
    let n_reward = graph.replicas(NodeKind::Reward);
    let (shared_sink, source, scored_stats, store) = match scored {
        ScoredPlane::Channel { tx, rx, stats } => (
            ScoredSink::Channel(tx),
            TrajectorySource::Channel { rx, producers: n_reward },
            Some(stats),
            None,
        ),
        ScoredPlane::Store(s) => (
            ScoredSink::shared_store(s.clone(), n_reward),
            TrajectorySource::Store(s.clone()),
            None,
            Some(s),
        ),
    };
    let mut hub = TelemetryHub::new(graph.mode_name, gen_stats, scored_stats, store.clone());
    let fail = FailState::new(env.ctx.clone(), store.clone());
    let sampler = start_sampler(cfg, &hub, env.ctx.clone())?;
    let snapshotter = start_snapshotter(cfg, &env.ctx, store.clone());

    // generator fleet: each replica registers its weight-sync slot (when
    // the topology says so) and holds its lease per the node's policy.
    // Replicas run *supervised*: within the node's restart budget an error
    // (or an injected chaos kill) parks the worker's in-flight partials,
    // journals the restart, and respawns a fresh worker on the SAME edges —
    // the cloned outbound, the shared store, and the slot registered once
    // below, whose front re-seeds the new worker's weights.
    let gen_node = *graph
        .node(NodeKind::Generator)
        .expect("check(): generator present");
    let chaos = ChaosSchedule::new(cfg.chaos_seed, cfg.chaos_kills, gen_node.replicas);
    let elastic = hub.elastic();
    let mut gen_handles = Vec::new();
    for w in 0..gen_node.replicas {
        let ctx = env.ctx.clone();
        let scheduler = env.scheduler.clone();
        let out = gen_tx.clone();
        let gcfg = gen_cfg(cfg, w);
        let sync_slot = gen_node.sync_slot.then(|| env.ctx.weights.register_generator());
        let resume = store.clone();
        let lease = gen_node.lease;
        let restart = gen_node.restart;
        let elastic = elastic.clone();
        gen_handles.push(spawn_node(format!("generator-{w}"), fail.clone(), move || {
            // Lifetime lease: async phases overlap on disjoint executors,
            // so the lease is feasibility + accounting, never an offload
            // stall
            let _lease = match (lease, &ctx.mem) {
                (LeasePolicy::Lifetime(p), Some(m)) => Some(m.lease(p)?),
                _ => None,
            };
            let mut tally = GenTally::default();
            // partials parked by the failing attempt, read by on_restart
            let parked = Cell::new(0u64);
            supervise(
                restart,
                || ctx.should_stop(),
                |attempt, backoff, err| {
                    let migrated = parked.replace(0);
                    elastic.note_restart(migrated);
                    // journaled below AND traced here: restarts were
                    // invisible in Chrome exports before the analysis plane
                    trace::instant(trace::NODE_RESTART, f64::from(attempt) + 1.0);
                    crate::log_warn!(
                        "graph",
                        "generator-{w} restart #{}: {err} (backoff {backoff:?}, {migrated} partials parked)",
                        attempt + 1
                    );
                    if let Some(j) = &ctx.journal {
                        j.write_infallible(&JournalRecord::NodeRestart {
                            node: format!("generator-{w}"),
                            attempt: u64::from(attempt) + 1,
                            backoff_ms: backoff.as_millis() as u64,
                            migrated,
                            error: err.to_string(),
                        });
                    }
                },
                |attempt| {
                    let mut gcfg = gcfg.clone();
                    // chaos injection: the seeded (worker, attempt) schedule
                    // generalizes the single-shot debug hook, which keeps
                    // precedence when both are set
                    if gcfg.fail_after_chunks.is_none() {
                        gcfg.fail_after_chunks = chaos.and_then(|c| c.kill_after(w, attempt));
                    }
                    let mut gen =
                        GeneratorWorker::new(w, gcfg, ctx.clone(), scheduler.clone(), out.clone());
                    if let Some(s) = &resume {
                        gen.set_resume_store(s.clone());
                    }
                    if let Some(slot) = &sync_slot {
                        gen.set_sync_slot(slot.clone());
                    }
                    let r = run_executor_loop(&mut gen, &ctx, None);
                    if r.is_err() {
                        // the executor loop skips drain() on error — park
                        // live slots here so survivors resume them
                        parked.set(gen.park_for_restart());
                    }
                    tally.add(&gen.tally());
                    r
                },
            )?;
            // Done or Stopped (global shutdown during backoff): either way
            // the replica exits clean with whatever it accomplished
            Ok(tally)
        }));
    }

    // elastic fleet controller (opt-in, buffered topologies only): watches
    // the store's queue depth and grows/shrinks the generator fleet with
    // dynamic replicas — spawned here so it can clone the generations edge
    // before the static fan-in count is sealed below
    let fleet = match (&store, cfg.elastic_resize) {
        (Some(s), true) => Some(spawn_fleet_controller(FleetCtl {
            ctx: env.ctx.clone(),
            scheduler: env.scheduler.clone(),
            out: gen_tx.clone(),
            store: s.clone(),
            fail: fail.clone(),
            elastic: elastic.clone(),
            gcfg: gen_cfg(cfg, 0),
            base_seed: cfg.seed,
            base_replicas: gen_node.replicas,
            max_extra: cfg.resize_max_extra,
            low_water: env.manifest.config.train_batch,
            capacity: cfg.store.capacity,
            sync_slot: gen_node.sync_slot,
        })),
        _ => None,
    };
    // reward fleet: group-routed inbound queues, one shared scored sink.
    // Supervised like the generators, with two twists: the inbound
    // receiver is not cloneable, so a dead attempt is *salvaged* — its
    // queue, EOF count, and buffered (already-scored) partial groups carry
    // into the replacement executor instead of being rebuilt; and when a
    // PANIC destroys the receiver with the unwound stack (no salvage
    // possible), the restart hook re-routes the replica's consumer slot to
    // a freshly minted queue before the backoff even starts, so producers
    // retry onto it transparently. The reroute handles are cloned BEFORE
    // gen_tx drops below — an Outbound clone keeps no EOF state (fan-in
    // counts are message-based), it only keeps the shared slots reachable.
    let n_gen = gen_node.replicas;
    let vocab = env.manifest.config.vocab;
    let reward_node = *graph.node(NodeKind::Reward).expect("check(): reward present");
    let reward_chaos = ChaosSchedule::new(
        cfg.chaos_seed ^ 0x5EED_CAFE,
        cfg.chaos_reward_kills,
        n_reward,
    );
    let mut reward_handles = Vec::new();
    for (r, rx) in gen_rxs.into_iter().enumerate() {
        let ctx = env.ctx.clone();
        let sink = shared_sink.clone();
        let baseline = cfg.baseline;
        let restart = reward_node.restart;
        let elastic = elastic.clone();
        let reroute_tx = gen_tx.clone();
        reward_handles.push(spawn_node(format!("reward-{r}"), fail.clone(), move || {
            let mut tally = RewardTally::default();
            // RefCell because both supervise closures need the slot: the
            // restart hook refills it after a panic, the attempt drains it
            let carried = std::cell::RefCell::new(Some((rx, 0usize, Vec::new())));
            supervise(
                restart,
                || ctx.should_stop(),
                |attempt, backoff, err| {
                    if carried.borrow().is_none() {
                        // the panicked attempt took the receiver down with
                        // its stack; group-routing makes the re-route cheap:
                        // mint a fresh queue for this consumer slot and swap
                        // it in for every producer. Rows/EOFs queued in the
                        // dead receiver are lost — the replacement converges
                        // through the stop path like any starved replica.
                        *carried.borrow_mut() = Some((reroute_tx.reroute(r), 0, Vec::new()));
                    }
                    elastic.note_restart(0);
                    trace::instant(trace::NODE_RESTART, f64::from(attempt) + 1.0);
                    crate::log_warn!(
                        "graph",
                        "reward-{r} restart #{}: {err} (backoff {backoff:?})",
                        attempt + 1
                    );
                    if let Some(j) = &ctx.journal {
                        j.write_infallible(&JournalRecord::NodeRestart {
                            node: format!("reward-{r}"),
                            attempt: u64::from(attempt) + 1,
                            backoff_ms: backoff.as_millis() as u64,
                            migrated: 0,
                            error: err.to_string(),
                        });
                    }
                },
                |attempt| {
                    let (rx, eofs, buffered) =
                        carried.borrow_mut().take().ok_or_else(|| {
                            Error::Coordinator(format!("reward-{r}: inbound not recoverable"))
                        })?;
                    let mut rew =
                        RewardExecutor::new(ctx.clone(), rx, sink.clone(), baseline, vocab, n_gen)?;
                    rew.adopt(eofs, buffered);
                    let res = match reward_chaos.and_then(|c| c.kill_after(r, attempt)) {
                        // chaos: drive a few drain passes then die mid-
                        // flight — a PANIC, not an error, so salvage can't
                        // save the receiver and the re-route above must
                        Some(k) => (|| -> Result<()> {
                            rew.init()?;
                            let mut made = 0u64;
                            loop {
                                match rew.step()? {
                                    StepOutcome::Finished => return Ok(()),
                                    StepOutcome::Progress => {
                                        made += 1;
                                        if made >= k {
                                            panic!("chaos: reward-{r} killed after {k} messages");
                                        }
                                    }
                                    _ => {}
                                }
                            }
                        })(),
                        None => run_executor_loop(&mut rew, &ctx, None),
                    };
                    tally.add(&RewardTally {
                        scored: rew.scored,
                        groups: rew.groups_emitted,
                        reward_sum: rew.reward_sum,
                    });
                    match res {
                        Ok(()) => Ok(()),
                        Err(e) => {
                            *carried.borrow_mut() = Some(rew.salvage());
                            Err(e)
                        }
                    }
                },
            )?;
            Ok(tally)
        }));
    }
    drop(gen_tx);
    // only the reward workers' sink clones may signal EOF (store latch /
    // channel senders)
    drop(shared_sink);

    let eval_handle = if graph.replicas(NodeKind::Evaluator) > 0 {
        let ctx = env.ctx.clone();
        let ecfg = EvaluatorConfig {
            artifact_dir: cfg.artifact_dir.clone(),
            every_versions: cfg.eval_every,
            max_per_suite: cfg.eval_max_per_suite,
        };
        let log = env.log.clone();
        Some(spawn_node("evaluator".into(), fail.clone(), move || {
            let mut e = EvaluatorExecutor::new(ecfg, ctx.clone(), Some(log));
            run_executor_loop(&mut e, &ctx, None)?;
            Ok(e.results)
        }))
    } else {
        None
    };

    // Trainer fleet: replica 0 runs on the controller thread (Algorithm
    // 1's "local executor"); replicas 1..N are data-parallel peers on
    // their own threads, each draining a disjoint shard-slice of the
    // store and publishing through its own registered bus publisher. The
    // shared FleetState carries the finish countdown (only the LAST
    // finisher may stop the world) and, in periodic mode, the period
    // fence that re-synchronizes the fleet every `period_steps`.
    let n_trainers = graph.replicas(NodeKind::Trainer).max(1);
    let periodic = matches!(cfg.mode, Mode::Periodic);
    let fleet_state = (n_trainers > 1 || periodic).then(|| {
        Arc::new(FleetState::new(
            n_trainers,
            if periodic { cfg.period_steps.max(1) } else { 0 },
            cfg.resume.as_ref().map(|r| r.start_step).unwrap_or(0),
        ))
    });
    let mut trainer_handles = Vec::new();
    for t in 1..n_trainers {
        let ctx = env.ctx.clone();
        let log = env.log.clone();
        let mut tcfg = trainer_cfg(cfg);
        tcfg.replica = t;
        tcfg.n_replicas = n_trainers;
        tcfg.publisher = env.ctx.weights.register_publisher();
        tcfg.fleet = fleet_state.clone();
        // checkpointing stays with replica 0: one writer per ckpt dir
        tcfg.checkpoint_every = 0;
        let src = TrajectorySource::Store(
            store.clone().expect("check(): trainer fleets require the store edge"),
        );
        trainer_handles.push(spawn_node(format!("trainer-{t}"), fail.clone(), move || {
            let mut tr = Trainer::new(tcfg, ctx.clone(), src, Some(log));
            run_executor_loop(&mut tr, &ctx, None)?;
            Ok((tr.current_step(), std::mem::take(&mut tr.records)))
        }));
    }

    // Trainer replica 0 on the controller thread. Init (artifact
    // compilation) runs OUTSIDE the measured wall clock; the
    // generator/reward/peer-trainer threads warm up concurrently.
    let mut tcfg0 = trainer_cfg(cfg);
    tcfg0.n_replicas = n_trainers;
    tcfg0.fleet = fleet_state;
    let mut trainer = Trainer::new(tcfg0, env.ctx.clone(), source, Some(env.log.clone()));
    let ckpt = (cfg.checkpoint_every > 0).then_some(cfg.checkpoint_every);
    // the controller thread hosts the trainer; name its trace track so
    // publish/store spans land on a "trainer" timeline
    trace::set_track("trainer");
    let mut t0 = Instant::now();
    match trainer.init() {
        Ok(()) => {
            t0 = Instant::now();
            if let Err(e) = run_executor_loop_initialized(&mut trainer, &env.ctx, ckpt) {
                fail.record("trainer", e);
            }
        }
        Err(e) => fail.record("trainer", e),
    }

    // join the data-parallel peers BEFORE the global fan-out: an early-
    // finishing replica 0 must not stop the world while peers still own
    // later steps (the LAST finisher requests the stop itself, and on any
    // node error FailState already fanned the stop out)
    for (i, h) in trainer_handles.into_iter().enumerate() {
        if let Some((steps, records)) = join_node(h, "trainer", i + 1)? {
            hub.add_trainer(steps, records);
        }
    }

    // shutdown fan-out: stop every loop, tear down the trainer's source
    // (idempotent — on a trainer ERROR its own step() teardown never ran,
    // and a blocked `send` into a full scored channel cannot observe the
    // stop flag; dropping the receiver is what unblocks it), and close the
    // store so blocked admission/sampling wakes. Then join everything.
    trainer.drop_source();
    env.ctx.request_stop();
    if let Some(s) = &store {
        s.close();
    }
    for (w, h) in gen_handles.into_iter().enumerate() {
        if let Some(t) = join_node(h, "generator", w)? {
            hub.add_generator(&t);
        }
    }
    if let Some(h) = fleet {
        let t = h
            .join()
            .map_err(|_| Error::Coordinator("fleet controller panicked".into()))?;
        hub.add_generator(&t);
    }
    for (r, h) in reward_handles.into_iter().enumerate() {
        if let Some(t) = join_node(h, "reward", r)? {
            hub.add_reward(&t);
        }
    }
    if let Some(h) = eval_handle {
        if let Some(evals) = join_node(h, "evaluator", 0)? {
            hub.add_evals(evals);
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    if let Some(e) = fail.take() {
        return Err(e);
    }
    // settle background planes before reading plane-wide counters
    env.ctx.weights.flush();
    if let Some(m) = &env.ctx.mem {
        m.flush()?;
    }
    if let Some(s) = sampler {
        s.stop();
    }
    // final consistent cut after the planes settled (ahead of the
    // controller's finish record)
    if let Some(d) = snapshotter {
        d.stop();
    }
    Ok(hub.finish(env.ctx.as_ref(), &trainer, wall))
}

/// The stepped scheduler: the same graph, driven strictly sequentially on
/// one thread (the synchronous on-policy baseline). Generation runs under
/// a per-step Generate lease with the Train prefetch hint armed, scoring
/// drains every reward replica to empty, and one optimizer step closes
/// the tick.
fn run_stepped(graph: &Graph, env: &LaunchEnv<'_>) -> Result<RunReport> {
    let cfg = env.cfg;
    let ctx = &env.ctx;
    let BuiltEdges {
        gen_tx,
        gen_rxs,
        gen_stats,
        scored,
    } = build_edges(graph, cfg, env.ctx.journal.as_ref())?;
    let n_reward = graph.replicas(NodeKind::Reward);
    let ScoredPlane::Channel { tx, rx, stats } = scored else {
        return Err(Error::Coordinator(
            "the stepped scheduler requires a channel scored edge".into(),
        ));
    };
    let mut hub = TelemetryHub::new(graph.mode_name, gen_stats, Some(stats), None);
    let sampler = start_sampler(cfg, &hub, env.ctx.clone())?;
    let snapshotter = start_snapshotter(cfg, ctx, None);
    // one thread drives every phase here; the generate/score/train spans
    // below mark which phase the controller timeline is in
    trace::set_track("controller");

    let mut gen =
        GeneratorWorker::new(0, gen_cfg(cfg, 0), ctx.clone(), env.scheduler.clone(), gen_tx);
    let mut rewards = Vec::with_capacity(n_reward);
    for rx in gen_rxs {
        rewards.push(RewardExecutor::new(
            ctx.clone(),
            rx,
            ScoredSink::Channel(tx.clone()),
            cfg.baseline,
            env.manifest.config.vocab,
            1,
        )?);
    }
    drop(tx);
    let mut trainer = Trainer::new(
        trainer_cfg(cfg),
        ctx.clone(),
        TrajectorySource::Channel { rx, producers: n_reward },
        Some(env.log.clone()),
    );

    gen.init()?;
    for r in rewards.iter_mut() {
        r.init()?;
    }
    trainer.init()?;

    let gen_lease_phase = match graph.node(NodeKind::Generator).map(|n| n.lease) {
        Some(LeasePolicy::PerStep(p)) => Some(p),
        _ => None,
    };
    let rows_per_step = env.manifest.config.train_batch;
    // the topology is the source of truth for whether evals run; the
    // stepped scheduler co-locates the declared evaluator node on the
    // generator's PJRT context instead of spawning it
    let run_evals = graph.replicas(NodeKind::Evaluator) > 0 && cfg.eval_every > 0;
    let suites = task::eval_suites(cfg.eval_max_per_suite);
    // Crash-resume: the tick loop continues from the recorded step. The
    // generator's tally restarts at zero, so progress ticks carry the
    // journaled prior on top of the live counters — tick totals stay
    // cumulative across any number of kill/resume cycles.
    let start_step = cfg.resume.as_ref().map(|r| r.start_step).unwrap_or(0);
    let rows_u64 = rows_per_step as u64;
    let (prior_tokens, prior_chunks) = cfg
        .resume
        .as_ref()
        .map(|r| (r.prior.tokens, r.prior.chunks))
        .unwrap_or((0, 0));
    let t0 = Instant::now();

    for step in start_step..cfg.max_steps {
        // Phase 1: generation — all rows complete under current weights.
        // The Generate lease swaps offloadable trainer state to host
        // behind decode, and the Train hint arms the prefetcher so the
        // first optimizer shard is back on device before the batch ends.
        {
            let _span = trace::span_with(trace::GENERATE, step as f64);
            let _gen_lease = match (&ctx.mem, gen_lease_phase) {
                (Some(m), Some(p)) => Some(m.lease(p)?),
                _ => None,
            };
            if let (Some(m), Some(_)) = (&ctx.mem, gen_lease_phase) {
                m.hint_next(Phase::Train);
            }
            gen.generate_batch_sync(rows_per_step)?;
        }
        // Phase 2: scoring — drain every reward replica to empty.
        {
            let _span = trace::span_with(trace::SCORE, step as f64);
            loop {
                let mut progressed = false;
                for r in rewards.iter_mut() {
                    progressed |= r.drain_once()?;
                }
                if !progressed {
                    break;
                }
            }
        }
        // Phase 3: one train step (+ weight publication); the trainer
        // brackets itself with Train/Sync leases.
        {
            let _span = trace::span_with(trace::TRAIN, step as f64);
            match trainer.step()? {
                StepOutcome::Progress => {}
                other => {
                    return Err(Error::Coordinator(format!(
                        "stepped trainer did not progress at step {step}: {other:?}"
                    )))
                }
            }
        }
        // Progress tick, AFTER the step record: a kill between the two
        // resumes one step back, never one step ahead. Trajectory count is
        // exact (train_batch rows per tick); tokens/chunks ride the tally.
        if let Some(j) = &ctx.journal {
            let t = gen.tally();
            j.write(&JournalRecord::Tick {
                step: step + 1,
                tokens: prior_tokens + t.tokens,
                trajectories: (step + 1) * rows_u64,
                chunks: prior_chunks + t.chunks,
            })?;
        }
        if cfg.checkpoint_every > 0 && (step + 1) % cfg.checkpoint_every == 0 {
            // the stepped loop drives checkpointing itself (the threaded
            // path gets it from run_executor_loop)
            trainer.save_checkpoint()?;
        }
        if run_evals && (step + 1) % cfg.eval_every == 0 {
            // co-located: eval borrows the generator's PJRT context
            let snap = ctx.weights.latest();
            hub.add_evals(eval_policy(
                gen.runtime_ref(),
                &snap.data,
                &suites,
                cfg.eval_max_per_suite,
                snap.version,
            )?);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    // settle background planes before reading plane-wide counters
    ctx.weights.flush();
    if let Some(m) = &ctx.mem {
        m.flush()?;
    }
    if let Some(s) = sampler {
        s.stop();
    }
    if let Some(d) = snapshotter {
        d.stop();
    }
    hub.add_generator(&gen.tally());
    for r in &rewards {
        hub.add_reward(&RewardTally {
            scored: r.scored,
            groups: r.groups_emitted,
            reward_sum: r.reward_sum,
        });
    }
    Ok(hub.finish(ctx.as_ref(), &trainer, wall))
}
