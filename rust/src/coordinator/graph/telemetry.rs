//! The telemetry layer: every node reports its end-of-run tally into one
//! [`TelemetryHub`], and the [`crate::coordinator::RunReport`] is assembled
//! in exactly ONE place — [`TelemetryHub::finish`].
//!
//! This is where the old drivers' triplicated 25-field report blocks went,
//! and it fixes their semantic drift: `trainer_recv_blocked_secs` is now
//! *always* the scored-channel starvation time (0 when there is no scored
//! channel) and the buffered store's sampling wait is its own field,
//! `trainer_sample_wait_secs` — the two quantities the old async and
//! buffered drivers used to cram into one name.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::coordinator::channel::ChannelStats;
use crate::coordinator::controller::RunReport;
use crate::coordinator::evaluator::EvalResult;
use crate::coordinator::executor::ExecutorContext;
use crate::coordinator::generator::GenTally;
use crate::coordinator::trainer::{TrainStepRecord, Trainer};
use crate::dataplane::RolloutStore;
use crate::util::json::Value;

/// End-of-run counters a reward worker hands back.
#[derive(Debug, Clone, Copy, Default)]
pub struct RewardTally {
    /// trajectories scored
    pub scored: u64,
    /// complete advantage groups emitted downstream
    pub groups: u64,
    pub reward_sum: f64,
}

impl RewardTally {
    pub fn add(&mut self, other: &RewardTally) {
        self.scored += other.scored;
        self.groups += other.groups;
        self.reward_sum += other.reward_sum;
    }
}

/// Live elastic-fleet counters, shared between the supervisors, the
/// fleet controller, the live sampler and the final report. Written from
/// node threads as churn happens, so the `--metrics-interval` series
/// shows restarts while the run is still going.
#[derive(Debug, Default)]
pub struct ElasticStats {
    /// supervised replica restarts (error or panic, within budget)
    pub restarts: AtomicU64,
    /// partial rollouts parked by dying replicas for survivors to resume
    pub partials_migrated: AtomicU64,
    /// dynamic generator replicas spawned by the fleet controller
    pub scale_ups: AtomicU64,
    /// dynamic generator replicas retired by the fleet controller
    pub scale_downs: AtomicU64,
}

impl ElasticStats {
    pub fn note_restart(&self, migrated: u64) {
        self.restarts.fetch_add(1, Ordering::Relaxed);
        self.partials_migrated.fetch_add(migrated, Ordering::Relaxed);
    }
}

/// Collects per-node tallies while a graph runs; one per launch.
pub struct TelemetryHub {
    mode_name: &'static str,
    gen_stats: Arc<ChannelStats>,
    scored_stats: Option<Arc<ChannelStats>>,
    store: Option<Arc<RolloutStore>>,
    elastic: Arc<ElasticStats>,
    gen: GenTally,
    reward: RewardTally,
    evals: Vec<EvalResult>,
    /// step records handed back by data-parallel trainer peers (replicas
    /// 1..N); replica 0's live on the controller's Trainer and the two
    /// sets merge by step in [`TelemetryHub::finish`]
    trainer_records: Vec<TrainStepRecord>,
    /// highest global step any peer completed (fleet clock = max)
    trainer_steps: u64,
}

impl TelemetryHub {
    pub fn new(
        mode_name: &'static str,
        gen_stats: Arc<ChannelStats>,
        scored_stats: Option<Arc<ChannelStats>>,
        store: Option<Arc<RolloutStore>>,
    ) -> TelemetryHub {
        TelemetryHub {
            mode_name,
            gen_stats,
            scored_stats,
            store,
            elastic: Arc::new(ElasticStats::default()),
            gen: GenTally::default(),
            reward: RewardTally::default(),
            evals: Vec::new(),
            trainer_records: Vec::new(),
            trainer_steps: 0,
        }
    }

    /// The shared elastic-fleet counter block (supervisors and the fleet
    /// controller hold clones of this handle).
    pub fn elastic(&self) -> Arc<ElasticStats> {
        self.elastic.clone()
    }

    pub fn add_generator(&mut self, tally: &GenTally) {
        self.gen.add(tally);
    }

    pub fn add_reward(&mut self, tally: &RewardTally) {
        self.reward.add(tally);
    }

    pub fn add_evals(&mut self, evals: Vec<EvalResult>) {
        self.evals.extend(evals);
    }

    /// Fold in one data-parallel trainer peer's end-of-run state: its step
    /// records join the merged per-step series and its clock raises the
    /// fleet's step high-water mark (the fleet clock is a max, matching
    /// `ctx.trainer_step`'s fetch_max discipline).
    pub fn add_trainer(&mut self, steps: u64, records: Vec<TrainStepRecord>) {
        self.trainer_steps = self.trainer_steps.max(steps);
        self.trainer_records.extend(records);
    }

    /// Build the closure the `--metrics-interval` sampler drives: clones
    /// of the hub's shared counter handles, read lock-free into one flat
    /// JSONL object per tick — the same counters [`TelemetryHub::finish`]
    /// aggregates at run end, observable while the run is still going.
    pub fn live_sampler(&self, ctx: Arc<ExecutorContext>) -> impl Fn() -> Value + Send + 'static {
        let mode = self.mode_name;
        let gen_stats = self.gen_stats.clone();
        let scored_stats = self.scored_stats.clone();
        let store = self.store.clone();
        let elastic = self.elastic.clone();
        move || {
            let mut pairs = vec![
                ("mode", Value::str(mode)),
                (
                    "trainer_step",
                    Value::num(ctx.trainer_step.load(Ordering::Relaxed) as f64),
                ),
                ("ddma_publishes", Value::num(ctx.weights.publish_count() as f64)),
                (
                    "ddma_publish_blocked_secs",
                    Value::num(ctx.weights.publish_blocked_secs()),
                ),
                (
                    "ddma_coalesced_publishes",
                    Value::num(ctx.weights.coalesced_publishes() as f64),
                ),
                (
                    "gen_send_blocked_secs",
                    Value::num(gen_stats.send_blocked_secs()),
                ),
            ];
            // live latency quantiles from the streaming histograms (the
            // same log-bucketed core `llamarl analyze` rebuilds offline) —
            // before these, percentiles existed only in the end-of-run
            // summarize() pass
            let (step_p50, step_p99) = ctx.live.step_quantiles(0.0);
            pairs.push(("step_secs_p50", Value::num(step_p50)));
            pairs.push(("step_secs_p99", Value::num(step_p99)));
            let (swap_p50, swap_p99) = ctx.live.swap_quantiles(0.0);
            pairs.push(("swap_stall_secs_p50", Value::num(swap_p50)));
            pairs.push(("swap_stall_secs_p99", Value::num(swap_p99)));
            if let Some(s) = &scored_stats {
                pairs.push((
                    "trainer_recv_blocked_secs",
                    Value::num(s.recv_blocked_secs()),
                ));
            }
            if let Some(s) = &store {
                let d = s.snapshot();
                pairs.push(("store_occupancy", Value::num(d.occupancy as f64)));
                pairs.push(("store_admitted", Value::num(d.admitted as f64)));
                pairs.push(("store_evicted", Value::num(d.evicted as f64)));
                pairs.push(("store_dropped_stale", Value::num(d.dropped_stale as f64)));
                pairs.push(("store_sampled", Value::num(d.sampled as f64)));
                pairs.push(("store_sample_wait_secs", Value::num(d.sample_wait_secs)));
            }
            if let Some(m) = &ctx.mem {
                let mm = m.metrics();
                pairs.push((
                    "offload_d2h_bytes",
                    Value::num(mm.d2h_bytes.load(Ordering::Relaxed) as f64),
                ));
                pairs.push((
                    "offload_h2d_bytes",
                    Value::num(mm.h2d_bytes.load(Ordering::Relaxed) as f64),
                ));
                pairs.push(("offload_wait_secs", Value::num(mm.wait_secs())));
                pairs.push((
                    "offload_prefetch_hits",
                    Value::num(mm.prefetch_hits.load(Ordering::Relaxed) as f64),
                ));
            }
            // journal lag: how far the durable record trails the live run
            if let Some(j) = &ctx.journal {
                pairs.push((
                    "journal_bytes_written",
                    Value::num(j.bytes_written() as f64),
                ));
                pairs.push((
                    "journal_records_flushed",
                    Value::num(j.records_flushed() as f64),
                ));
                pairs.push((
                    "journal_secs_since_snapshot",
                    Value::num(j.secs_since_snapshot()),
                ));
            }
            pairs.push((
                "node_restarts",
                Value::num(elastic.restarts.load(Ordering::Relaxed) as f64),
            ));
            pairs.push((
                "partials_migrated",
                Value::num(elastic.partials_migrated.load(Ordering::Relaxed) as f64),
            ));
            pairs.push((
                "fleet_scale_ups",
                Value::num(elastic.scale_ups.load(Ordering::Relaxed) as f64),
            ));
            pairs.push((
                "fleet_scale_downs",
                Value::num(elastic.scale_downs.load(Ordering::Relaxed) as f64),
            ));
            pairs.push((
                "trace_dropped_events",
                Value::num(crate::trace::dropped_events() as f64),
            ));
            Value::object(pairs)
        }
    }

    /// Assemble the run report — the only constructor of a populated
    /// [`RunReport`] in the codebase. Call after the weight-sync and
    /// memory planes have been flushed, so plane-wide counters are final.
    pub fn finish(self, ctx: &ExecutorContext, trainer: &Trainer, wall_secs: f64) -> RunReport {
        let dataplane = self.store.as_ref().map(|s| s.snapshot());
        // channel-sourced starvation vs store-sourced sampling wait: the
        // two distinct fields the old drivers crammed into one name
        let recv_blocked = match &self.scored_stats {
            Some(s) => s.recv_blocked_secs(),
            None => 0.0,
        };
        let sample_wait = match &dataplane {
            Some(d) => d.sample_wait_secs,
            None => 0.0,
        };
        // merge the controller trainer's records with any peers': one
        // series ordered by global step, whichever replica executed it
        let mut records = trainer.records.clone();
        records.extend(self.trainer_records);
        records.sort_by_key(|r| r.step);
        let mut report = RunReport {
            mode: self.mode_name.into(),
            steps: trainer.current_step().max(self.trainer_steps),
            wall_secs,
            records,
            evals: self.evals,
            tokens_generated: self.gen.tokens,
            trajectories: self.gen.trajectories,
            chunks: self.gen.chunks,
            weight_refreshes: self.gen.weight_refreshes,
            reward_groups: self.reward.groups,
            reward_rows_scored: self.reward.scored,
            ddma_publishes: ctx.weights.publish_count(),
            ddma_mean_publish_secs: ctx.weights.mean_publish_secs(),
            ddma_mean_shard_max_secs: ctx.weights.mean_shard_max_secs(),
            ddma_publish_blocked_secs: ctx.weights.publish_blocked_secs(),
            ddma_coalesced_publishes: ctx.weights.coalesced_publishes(),
            gen_swap_stall_secs: self.gen.swap_stall_secs,
            gen_swaps: self.gen.swaps,
            gen_send_blocked_secs: self.gen_stats.send_blocked_secs(),
            trainer_recv_blocked_secs: recv_blocked,
            trainer_sample_wait_secs: sample_wait,
            node_restarts: self.elastic.restarts.load(Ordering::Relaxed),
            partials_migrated: self.elastic.partials_migrated.load(Ordering::Relaxed),
            fleet_scale_ups: self.elastic.scale_ups.load(Ordering::Relaxed),
            fleet_scale_downs: self.elastic.scale_downs.load(Ordering::Relaxed),
            dataplane,
            metrics_path: None,
            ..RunReport::default()
        };
        report.fill_mem_telemetry(ctx);
        report
    }
}
