//! The supervisor layer: per-replica restart with bounded retries.
//!
//! Before this layer, any node failure landed in the runtime's global
//! first-error slot and stopped the world — correct for a trainer, but
//! wrong for a fleet replica on a large cluster where worker churn is
//! routine (the paper pitches the single-controller design at thousands
//! of devices). [`supervise`] wraps one replica's lifecycle: each attempt
//! runs under its own panic guard, a failure consults the node's
//! [`RestartPolicy`], and within budget the replica backs off
//! (exponentially) and respawns instead of escalating. Only an exhausted
//! budget (or `RestartPolicy::Never`) returns the error to the caller —
//! which in the graph runtime means the old global-stop path, unchanged.
//!
//! What makes a restart *safe* lives in the planes, not here:
//!
//! * **partial rollouts** — the attempt body parks its in-flight
//!   sequences in the rollout store's resumption slot before returning
//!   the error, so a surviving or restarted replica reclaims them via the
//!   normal refill path (no duplicate admission seqs: parked work has not
//!   been admitted yet).
//! * **weights** — a respawned worker starts with no parameter buffer and
//!   re-seeds from its weight-sync slot's front (the slot is registered
//!   once per logical replica and survives the worker it fed).
//! * **accounting** — tallies accumulate across attempts; restarts and
//!   migrated-partial counts surface through the telemetry hub and the
//!   journal's `node_restart` records.
//!
//! [`ChaosSchedule`] is the test/CI injection surface: a seeded,
//! deterministic map from (worker, attempt) to a kill-after-N-chunks
//! fault, generalizing the single-shot `fail_after_chunks` debug hook
//! into the randomized kill schedules the chaos CI arm drives.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::{Duration, Instant};

use crate::coordinator::graph::topology::RestartPolicy;
use crate::util::error::{Error, Result};

/// How a supervised replica's lifecycle ended when it did NOT escalate.
#[derive(Debug)]
pub enum Supervised<T> {
    /// the attempt body completed (possibly after restarts)
    Done(T),
    /// the global stop arrived while backing off between attempts; the
    /// replica exits quietly (the run is shutting down anyway)
    Stopped,
}

/// Run `attempt` under the node's restart policy. Each attempt executes
/// inside its own panic guard (a panic restarts like an error does, but
/// skips the attempt's own error-path cleanup). On failure within budget,
/// `on_restart(attempt_no, backoff, err)` fires (journal/telemetry hook),
/// then the thread backs off — interruptibly: a global stop during the
/// sleep exits with [`Supervised::Stopped`] instead of respawning. An
/// exhausted budget returns the last error, which in the graph runtime
/// escalates to the global stop exactly as before this layer existed.
pub fn supervise<T>(
    policy: RestartPolicy,
    should_stop: impl Fn() -> bool,
    mut on_restart: impl FnMut(u32, Duration, &Error),
    mut attempt: impl FnMut(u32) -> Result<T>,
) -> Result<Supervised<T>> {
    let mut n: u32 = 0;
    loop {
        let err = match catch_unwind(AssertUnwindSafe(|| attempt(n))) {
            Ok(Ok(v)) => return Ok(Supervised::Done(v)),
            Ok(Err(e)) => e,
            Err(_) => Error::msg("panicked"),
        };
        let Some(delay) = policy.backoff_for(n) else {
            return Err(err);
        };
        on_restart(n, delay, &err);
        let t0 = Instant::now();
        while t0.elapsed() < delay {
            if should_stop() {
                return Ok(Supervised::Stopped);
            }
            let left = delay.saturating_sub(t0.elapsed());
            std::thread::sleep(left.min(Duration::from_millis(2)));
        }
        if should_stop() {
            return Ok(Supervised::Stopped);
        }
        n += 1;
    }
}

/// A seeded, deterministic kill schedule over (worker, attempt): the
/// chaos-mode generalization of the `fail_after_chunks` debug hook. Kill
/// `j` (0-based) lands on worker `j % workers` at that worker's attempt
/// `j / workers`, so `kills` faults spread round-robin across the fleet
/// and a worker's restart budget only needs to cover its own share. The
/// chunk count for each fault derives from the seed (1..=3 chunks in),
/// so two runs with the same seed inject byte-identical schedules.
#[derive(Debug, Clone, Copy)]
pub struct ChaosSchedule {
    seed: u64,
    kills: u64,
    workers: u64,
}

impl ChaosSchedule {
    /// `None` when no kills are scheduled (`kills == 0`) — callers skip
    /// the lookup entirely.
    pub fn new(seed: u64, kills: u64, workers: usize) -> Option<ChaosSchedule> {
        (kills > 0).then_some(ChaosSchedule {
            seed,
            kills,
            workers: workers.max(1) as u64,
        })
    }

    /// The fault for this worker's attempt: kill after N chunks, or run
    /// clean. Attempt numbers past the schedule always run clean, which
    /// is what lets a bounded-retry policy converge.
    pub fn kill_after(&self, worker: usize, attempt: u32) -> Option<u64> {
        let j = (attempt as u64).checked_mul(self.workers)?.checked_add(worker as u64)?;
        if worker as u64 >= self.workers || j >= self.kills {
            return None;
        }
        Some(1 + splitmix(self.seed ^ j.wrapping_mul(0x9E37_79B9_7F4A_7C15)) % 3)
    }

    /// Restarts any single worker needs to absorb its share of the
    /// schedule (the chaos test sizes `restart_max` from this).
    pub fn max_kills_per_worker(&self) -> u64 {
        self.kills.div_ceil(self.workers)
    }
}

fn splitmix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    fn retries(max: u32, backoff_ms: u64) -> RestartPolicy {
        RestartPolicy::BoundedRetries {
            max,
            backoff: Duration::from_millis(backoff_ms),
        }
    }

    #[test]
    fn backoff_schedule_doubles_and_exhausts() {
        let p = retries(3, 10);
        assert_eq!(p.backoff_for(0), Some(Duration::from_millis(10)));
        assert_eq!(p.backoff_for(1), Some(Duration::from_millis(20)));
        assert_eq!(p.backoff_for(2), Some(Duration::from_millis(40)));
        assert_eq!(p.backoff_for(3), None, "budget of 3 restarts is spent");
        assert_eq!(RestartPolicy::Never.backoff_for(0), None);
        // the shift cap keeps huge attempt numbers from overflowing
        let far = retries(u32::MAX, 10).backoff_for(1000).unwrap();
        assert_eq!(far, Duration::from_millis(10) * (1 << 16));
    }

    #[test]
    fn never_policy_escalates_first_failure() {
        let mut calls = 0;
        let r: Result<Supervised<()>> = supervise(
            RestartPolicy::Never,
            || false,
            |_, _, _| panic!("must not restart"),
            |_| {
                calls += 1;
                Err(Error::msg("boom"))
            },
        );
        assert!(r.is_err());
        assert_eq!(calls, 1);
    }

    #[test]
    fn bounded_retries_recover_then_exhaust() {
        // fails twice, then succeeds — within a budget of 2
        let mut restarts = Vec::new();
        let r = supervise(
            retries(2, 1),
            || false,
            |n, d, _| restarts.push((n, d)),
            |n| {
                if n < 2 {
                    Err(Error::msg("flaky"))
                } else {
                    Ok(n)
                }
            },
        )
        .unwrap();
        assert!(matches!(r, Supervised::Done(2)));
        assert_eq!(restarts.len(), 2);
        assert!(restarts[1].1 > restarts[0].1, "backoff grows");

        // always fails — budget of 2 means exactly 3 attempts then Err
        let mut attempts = 0;
        let r: Result<Supervised<()>> = supervise(
            retries(2, 1),
            || false,
            |_, _, _| {},
            |_| {
                attempts += 1;
                Err(Error::msg("dead"))
            },
        );
        assert!(r.is_err());
        assert_eq!(attempts, 3);
    }

    #[test]
    fn panics_restart_like_errors() {
        let r = supervise(
            retries(1, 1),
            || false,
            |_, _, _| {},
            |n| {
                if n == 0 {
                    panic!("worker crashed hard");
                }
                Ok("recovered")
            },
        )
        .unwrap();
        assert!(matches!(r, Supervised::Done("recovered")));
    }

    #[test]
    fn global_stop_interrupts_backoff() {
        let stop = Arc::new(AtomicBool::new(false));
        let s2 = stop.clone();
        let t0 = Instant::now();
        let r: Result<Supervised<()>> = supervise(
            retries(1, 60_000), // a minute of backoff — must not be slept
            move || stop.load(Ordering::Relaxed),
            move |_, _, _| s2.store(true, Ordering::Relaxed),
            |_| Err(Error::msg("died during shutdown")),
        );
        assert!(matches!(r, Ok(Supervised::Stopped)));
        assert!(t0.elapsed() < Duration::from_secs(10), "stop must cut the sleep short");
    }

    #[test]
    fn chaos_schedule_is_seeded_and_round_robin() {
        assert!(ChaosSchedule::new(7, 0, 4).is_none(), "no kills, no schedule");
        let s = ChaosSchedule::new(42, 5, 3).unwrap();
        let t = ChaosSchedule::new(42, 5, 3).unwrap();
        let mut scheduled = 0;
        for w in 0..3 {
            for a in 0..4u32 {
                assert_eq!(s.kill_after(w, a), t.kill_after(w, a), "same seed, same schedule");
                if let Some(k) = s.kill_after(w, a) {
                    scheduled += 1;
                    assert!((1..=3).contains(&k));
                }
            }
        }
        assert_eq!(scheduled, 5, "every scheduled kill lands exactly once");
        // round-robin: 5 kills over 3 workers = attempts (2,2,1)
        assert!(s.kill_after(0, 0).is_some() && s.kill_after(0, 1).is_some());
        assert!(s.kill_after(2, 1).is_none());
        assert_eq!(s.max_kills_per_worker(), 2);
        // attempts past the schedule run clean — the fleet converges
        for w in 0..3 {
            assert!(s.kill_after(w, 9).is_none());
        }
    }
}
