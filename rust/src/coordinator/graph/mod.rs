//! The single-controller execution graph (paper §5.1.3, Algorithm 1).
//!
//! The controller used to be three hand-rolled ~140-line mode drivers that
//! each re-implemented thread spawning, lease handling, EOF/stop plumbing
//! and a triplicated report block. This subsystem makes the topology
//! *data* — the way AsyncFlow exposes the RL pipeline as a rewirable
//! streaming dataflow and Laminar treats trajectory flow between
//! disaggregated workers as a first-class graph — and keeps exactly one
//! runtime:
//!
//! * [`topology`] — [`NodeSpec`] / [`EdgeSpec`] / [`Graph`]: executor
//!   fleets (generator / reward / trainer / evaluator) with replica
//!   counts, memory-plane [`LeasePolicy`], weight-sync slot needs, and
//!   bounded [`EdgeKind`] transports. `Mode::{Sync, Async, AsyncBuffered}`
//!   are three small descriptions built by [`topology()`]; sync is the
//!   same graph driven by the stepped scheduler rather than free-running
//!   threads. [`Graph::to_dot`] renders the resolved topology
//!   (`llamarl train --dump-graph`).
//! * [`runtime`] — one generic [`Graph::launch`]: edge construction,
//!   generator slot registration, named-thread spawning, lease policies,
//!   stop/EOF propagation, panic→error conversion, clean joins — written
//!   once, tested once (`tests/graph_runtime.rs`).
//! * [`telemetry`] — the [`TelemetryHub`] every node reports its tally
//!   into; the `RunReport` is assembled in exactly one place, with the
//!   scored-channel starvation time (`trainer_recv_blocked_secs`) and the
//!   store sampling wait (`trainer_sample_wait_secs`) as distinct fields.
//!
//! Reward scoring is a *fleet* like generation: `n_reward_workers`
//! scatters generation groups across N reward executors by group id over
//! the group-routed channel, so every replica of a prompt's advantage
//! group is scored by exactly one node (group integrity), removing the
//! single-scorer bottleneck of the old async drivers.

pub mod runtime;
pub mod telemetry;
pub mod topology;

pub use runtime::LaunchEnv;
pub use telemetry::{RewardTally, TelemetryHub};
pub use topology::{
    topology, topology_with_rows, EdgeKind, EdgeSpec, Graph, LeasePolicy, NodeKind, NodeSpec,
};
