//! The single-controller execution graph (paper §5.1.3, Algorithm 1).
//!
//! The controller used to be three hand-rolled ~140-line mode drivers that
//! each re-implemented thread spawning, lease handling, EOF/stop plumbing
//! and a triplicated report block. This subsystem makes the topology
//! *data* — the way AsyncFlow exposes the RL pipeline as a rewirable
//! streaming dataflow and Laminar treats trajectory flow between
//! disaggregated workers as a first-class graph — and keeps exactly one
//! runtime:
//!
//! * [`topology`] — [`NodeSpec`] / [`EdgeSpec`] / [`Graph`]: executor
//!   fleets (generator / reward / trainer / evaluator) with replica
//!   counts, memory-plane [`LeasePolicy`], weight-sync slot needs, and
//!   bounded [`EdgeKind`] transports. `Mode::{Sync, Async, AsyncBuffered}`
//!   are three small descriptions built by [`topology()`]; sync is the
//!   same graph driven by the stepped scheduler rather than free-running
//!   threads. [`Graph::to_dot`] renders the resolved topology
//!   (`llamarl train --dump-graph`).
//! * [`runtime`] — one generic [`Graph::launch`]: edge construction,
//!   generator slot registration, named-thread spawning, lease policies,
//!   stop/EOF propagation, panic→error conversion, clean joins — written
//!   once, tested once (`tests/graph_runtime.rs`).
//! * [`telemetry`] — the [`TelemetryHub`] every node reports its tally
//!   into; the `RunReport` is assembled in exactly one place, with the
//!   scored-channel starvation time (`trainer_recv_blocked_secs`) and the
//!   store sampling wait (`trainer_sample_wait_secs`) as distinct fields.
//!
//! Reward scoring is a *fleet* like generation: `n_reward_workers`
//! scatters generation groups across N reward executors by group id over
//! the group-routed channel, so every replica of a prompt's advantage
//! group is scored by exactly one node (group integrity), removing the
//! single-scorer bottleneck of the old async drivers.
//!
//! # Restart protocol (elastic fleets)
//!
//! Generator and reward replicas are *supervised* ([`supervisor`]): when
//! a node's [`topology::RestartPolicy`] grants retries, a replica's error
//! or panic stays local instead of landing in the global first-error
//! slot. The dying attempt parks its in-flight partial rollouts in the
//! store's resumption slot (reclaimed by any survivor's next refill — the
//! rows were never admitted, so no admission seq can duplicate), the
//! supervisor journals a `node_restart` record, sleeps an exponential
//! backoff (interruptibly — a global stop cancels the respawn), then
//! builds a fresh worker on the SAME retained edges: the cloned outbound
//! channel, the shared store handle, and the weight-sync slot registered
//! once at launch, whose front re-seeds the new worker's parameters on
//! its first chunk. Exhausting the budget falls through to the old
//! global-stop path unchanged. When `elastic_resize` is on, a fleet
//! controller thread also watches the store's queue depth and spawns (or
//! retires) dynamic generator replicas between `n_generator_workers` and
//! `n_generator_workers + resize_max_extra`, journaling `fleet_resize`
//! records; dynamic replicas never signal EOF, so drain fan-in counts
//! stay exact.

pub mod runtime;
pub mod supervisor;
pub mod telemetry;
pub mod topology;

pub use runtime::LaunchEnv;
pub use supervisor::{supervise, ChaosSchedule, Supervised};
pub use telemetry::{ElasticStats, RewardTally, TelemetryHub};
pub use topology::{
    topology, topology_with_rows, EdgeKind, EdgeSpec, Graph, LeasePolicy, NodeKind, NodeSpec,
    RestartPolicy,
};
