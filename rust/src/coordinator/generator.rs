//! Generator executor: the offloaded inference engine (paper §4.1).
//!
//! Memory placement is owned by [`crate::memplane`]: the controller (sync
//! mode) or the worker's spawn wrapper (async modes) brackets generation
//! with a `Phase::Generate` lease, so the KV cache is materialized — and
//! offloadable trainer state swapped out — before the first decode chunk
//! runs, with the prefetch back overlapped behind decode.
//!
//! Each worker is one data-parallel inference replica with its own PJRT
//! context. It keeps `gen_batch` sequence slots continuously batched: every
//! `step()` runs ONE `generate_chunk` artifact call (up to C tokens for the
//! whole batch in a single PJRT execution — prefill + Pallas decode
//! attention + sampling all in-graph), finishes whatever sequences hit EOS,
//! refills their slots with fresh prompts, and leaves unfinished sequences
//! in place — which is exactly the paper's partial-rollout strategy (§4.2):
//! long generations span multiple chunks/iterations instead of blocking the
//! batch (straggler mitigation).
//!
//! Off-policy bookkeeping: in async modes each worker owns a double-buffered
//! [`crate::weightsync::GeneratorSlot`] — new weight versions stream into
//! its staging buffer while the worker decodes, and the worker promotes them
//! with a fenced swap at chunk boundaries (sync mode re-attaches to the DDMA
//! bus directly). Every trajectory records the weight version that finished
//! it and the per-token behaviour log-probs mu(y_t) recorded by the sampler
//! inside the artifact. With `quantize_int8` the uploaded weights
//! are an int8 round-trip of the published snapshot — the "quantized
//! behaviour policy" off-policy source of §4.3/Table 3.

use std::sync::Arc;

use crate::coordinator::channel::{Message, Outbound};
use crate::coordinator::executor::{Executor, ExecutorContext, StepOutcome};
use crate::data::{PromptScheduler, PromptTask};
use crate::dataplane::{PartialRollout, RolloutStore};
use crate::model::{simulate_int8_roundtrip, VersionedParams};
use crate::rl::{FinishReason, Trajectory};
use crate::runtime::{HostTensor, Runtime};
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;
use crate::weightsync::GeneratorSlot;

#[derive(Debug, Clone)]
pub struct GeneratorConfig {
    pub artifact_dir: std::path::PathBuf,
    pub temperature: f32,
    pub top_k: i32,
    /// run the behaviour policy on int8-roundtripped weights
    pub quantize_int8: bool,
    /// cap on response tokens (forces FinishReason::Length past it)
    pub max_response: usize,
    pub seed: u64,
    /// FAULT-INJECTION TEST HOOK: error out after this many decode chunks.
    /// Exercises the graph runtime's error propagation (a mid-run node
    /// failure must stop the whole topology and surface through a clean
    /// join); never settable from JSON/CLI.
    pub fail_after_chunks: Option<u64>,
}

impl Default for GeneratorConfig {
    fn default() -> Self {
        GeneratorConfig {
            artifact_dir: "artifacts/nano".into(),
            temperature: 1.0,
            top_k: 0,
            quantize_int8: false,
            max_response: usize::MAX,
            seed: 0,
            fail_after_chunks: None,
        }
    }
}

/// End-of-run counters a generator thread hands back to the controller.
#[derive(Debug, Clone, Copy, Default)]
pub struct GenTally {
    pub tokens: u64,
    pub trajectories: u64,
    pub chunks: u64,
    pub weight_refreshes: u64,
    /// total decode stall the fenced weight swaps cost this worker (the
    /// whole per-publish price in overlapped mode: one pointer exchange)
    pub swap_stall_secs: f64,
    /// fenced swaps that promoted a version
    pub swaps: u64,
}

impl GenTally {
    /// Accumulate another worker's tally (controller-side aggregation).
    pub fn add(&mut self, other: &GenTally) {
        self.tokens += other.tokens;
        self.trajectories += other.trajectories;
        self.chunks += other.chunks;
        self.weight_refreshes += other.weight_refreshes;
        self.swap_stall_secs += other.swap_stall_secs;
        self.swaps += other.swaps;
    }
}

/// One continuous-batching slot.
struct Slot {
    task: PromptTask,
    /// prompt + generated so far
    tokens: Vec<i32>,
    prompt_len: usize,
    logps: Vec<f32>,
    chunks: u32,
    version: u64,
}

pub struct GeneratorWorker {
    pub worker_id: usize,
    cfg: GeneratorConfig,
    ctx: Arc<ExecutorContext>,
    scheduler: Arc<PromptScheduler>,
    out: Outbound,
    rng: Rng,
    // populated by init() on the executor thread (PJRT is thread-local)
    runtime: Option<Runtime>,
    params_buf: Option<xla::PjRtBuffer>,
    local_version: u64,
    slots: Vec<Option<Slot>>,
    /// data-plane resumption slot (Mode::AsyncBuffered): unfinished
    /// sequences are parked here at drain time and reclaimed on refill
    resume: Option<Arc<RolloutStore>>,
    /// double-buffered weight-sync receive slot (async modes): new versions
    /// stream into its staging buffer while this worker decodes; the fenced
    /// swap happens here, at chunk boundaries
    sync_slot: Option<Arc<GeneratorSlot>>,
    /// false for dynamic (fleet-resize) replicas: downstream EOF fan-in
    /// counts are sized to the static fleet, so an elastically added
    /// worker must never signal drain
    eof_on_finish: bool,
    // telemetry
    pub chunks_run: u64,
    pub tokens_generated: u64,
    pub trajectories_emitted: u64,
    pub weight_refreshes: u64,
}

impl GeneratorWorker {
    pub fn new(
        worker_id: usize,
        cfg: GeneratorConfig,
        ctx: Arc<ExecutorContext>,
        scheduler: Arc<PromptScheduler>,
        out: Outbound,
    ) -> GeneratorWorker {
        let rng = Rng::new(cfg.seed ^ (worker_id as u64).wrapping_mul(0x9E3779B9));
        GeneratorWorker {
            worker_id,
            cfg,
            ctx,
            scheduler,
            out,
            rng,
            runtime: None,
            params_buf: None,
            local_version: u64::MAX,
            slots: Vec::new(),
            resume: None,
            sync_slot: None,
            eof_on_finish: true,
            chunks_run: 0,
            tokens_generated: 0,
            trajectories_emitted: 0,
            weight_refreshes: 0,
        }
    }

    fn runtime(&self) -> &Runtime {
        self.runtime.as_ref().expect("init() not called")
    }

    /// Borrow the worker's PJRT runtime (the sync baseline co-locates eval
    /// on the generator's context).
    pub fn runtime_ref(&self) -> &Runtime {
        self.runtime()
    }

    /// Attach the data-plane resumption slot (Mode::AsyncBuffered): at
    /// drain time in-flight sequences are parked instead of decoded to
    /// completion, and refills reclaim parked work before asking the
    /// scheduler for fresh prompts.
    pub fn set_resume_store(&mut self, store: Arc<RolloutStore>) {
        self.resume = Some(store);
    }

    /// This worker's end-of-run counters, including the sync slot's
    /// swap-stall telemetry (how much decode time weight refreshes cost).
    pub fn tally(&self) -> GenTally {
        let (swap_stall_secs, swaps) = match &self.sync_slot {
            Some(slot) => (slot.stall_secs(), slot.swaps()),
            None => (0.0, 0),
        };
        GenTally {
            tokens: self.tokens_generated,
            trajectories: self.trajectories_emitted,
            chunks: self.chunks_run,
            weight_refreshes: self.weight_refreshes,
            swap_stall_secs,
            swaps,
        }
    }

    /// Attach this worker's double-buffered weight-sync slot (async modes).
    /// Publishes stream into the slot's staging buffer off-thread; this
    /// worker promotes them with the fenced swap at chunk boundaries, so
    /// every trajectory's `gen_version` comes from a complete, atomically
    /// swapped version.
    pub fn set_sync_slot(&mut self, slot: Arc<GeneratorSlot>) {
        self.sync_slot = Some(slot);
    }

    /// Park every in-flight sequence that has generated at least one token;
    /// pristine slots are simply released (the scheduler re-issues their
    /// prompts). Returns how many were parked.
    fn park_live_slots(&mut self) -> usize {
        let Some(store) = &self.resume else {
            return 0;
        };
        let mut parked = 0;
        for slot in self.slots.iter_mut() {
            let Some(s) = slot.take() else { continue };
            if s.tokens.len() > s.prompt_len {
                store.park_partial(PartialRollout {
                    tokens: s.tokens,
                    prompt_len: s.prompt_len,
                    logps: s.logps,
                    chunks: s.chunks,
                    gen_version: s.version,
                    task: s.task,
                });
                parked += 1;
            }
        }
        parked
    }

    /// Mark this worker as a dynamic (fleet-resize) replica: it must
    /// never signal EOF, because every drain fan-in count downstream was
    /// sized to the static fleet at launch.
    pub(crate) fn suppress_eof(&mut self) {
        self.eof_on_finish = false;
    }

    /// Crash path: a supervised replica parks its in-flight sequences
    /// before the supervisor backs off and respawns it, so a survivor (or
    /// the replacement) resumes them through the normal refill path. The
    /// executor loop only runs `drain()` on clean exits — an erroring
    /// `step()` propagates first — so the supervisor calls this
    /// explicitly on the error path. Returns how many were parked.
    pub(crate) fn park_for_restart(&mut self) -> u64 {
        self.park_live_slots() as u64
    }

    /// Upload a weight snapshot to this worker's PJRT context.
    fn upload_params(&mut self, snap: &VersionedParams) -> Result<()> {
        let rt = self.runtime.as_ref().unwrap();
        let host: HostTensor = if self.cfg.quantize_int8 {
            let q = simulate_int8_roundtrip(&snap.data, &rt.manifest.param_layout);
            HostTensor::F32(q, vec![rt.manifest.num_params])
        } else {
            HostTensor::F32(snap.data.as_ref().clone(), vec![rt.manifest.num_params])
        };
        self.params_buf = Some(rt.upload(&host)?);
        self.local_version = snap.version;
        self.weight_refreshes += 1;
        Ok(())
    }

    /// Refresh weights at a chunk boundary. With a weight-sync slot the new
    /// version streamed in while the previous chunk decoded; the fenced swap
    /// here costs one pointer exchange, and decode stays on version N until
    /// N+1 is complete. Without a slot (sync mode) this re-attaches to the
    /// DDMA bus directly.
    fn refresh_weights(&mut self) -> Result<()> {
        if let Some(slot) = self.sync_slot.clone() {
            if self.params_buf.is_none() {
                let snap = slot.attach();
                return self.upload_params(&snap);
            }
            let stall_before = slot.stall_secs();
            if let Some(snap) = slot.swap_at_boundary() {
                // per-promotion stall sample for the live p50/p99 series
                // (the slot only tracks the cumulative total)
                self.ctx
                    .live
                    .record_swap_stall((slot.stall_secs() - stall_before).max(0.0));
                return self.upload_params(&snap);
            }
            return Ok(());
        }
        let bus_version = self.ctx.weights.version();
        if self.params_buf.is_some() && bus_version == self.local_version {
            return Ok(());
        }
        let snap = self.ctx.weights.latest();
        self.upload_params(&snap)
    }

    fn fill_slots(&mut self) {
        let stop = self.ctx.should_stop();
        let max_seq = self.runtime().config().max_seq;
        for slot in self.slots.iter_mut() {
            if slot.is_none() && !stop {
                // reclaim parked partial rollouts (work a drained worker
                // left in the store) before drawing fresh prompts
                if let Some(p) = self.resume.as_ref().and_then(|s| s.take_partial_any()) {
                    *slot = Some(Slot {
                        tokens: p.tokens,
                        prompt_len: p.prompt_len,
                        logps: p.logps,
                        chunks: p.chunks,
                        version: p.gen_version,
                        task: p.task,
                    });
                    continue;
                }
                let task = self.scheduler.next();
                debug_assert!(task.prompt_tokens.len() + 2 < max_seq);
                *slot = Some(Slot {
                    tokens: task.prompt_tokens.clone(),
                    prompt_len: task.prompt_tokens.len(),
                    logps: Vec::new(),
                    chunks: 0,
                    version: 0,
                    task,
                });
            }
        }
    }

    /// Run one generate_chunk over the current slots; returns finished
    /// trajectories.
    fn run_chunk(&mut self) -> Result<Vec<Trajectory>> {
        if let Some(k) = self.cfg.fail_after_chunks {
            if self.chunks_run >= k {
                return Err(Error::Coordinator(format!(
                    "generator[{}] injected failure after {k} chunks (test hook)",
                    self.worker_id
                )));
            }
        }
        // one decode-chunk span per artifact call: the async-mode analogue
        // of the stepped `generate` phase (nests inside it in sync mode)
        let _span = crate::trace::span_with(crate::trace::GEN_CHUNK, self.chunks_run as f64);
        let rt = self.runtime.as_ref().unwrap();
        let mcfg = rt.config().clone();
        let (b, s, c) = (mcfg.gen_batch, mcfg.max_seq, mcfg.gen_chunk);

        let mut tokens = vec![mcfg.pad_id; b * s];
        let mut lens = vec![1i32; b];
        let mut frozen = vec![1i32; b];
        for (i, slot) in self.slots.iter().enumerate() {
            if let Some(slot) = slot {
                let n = slot.tokens.len();
                tokens[i * s..i * s + n].copy_from_slice(&slot.tokens);
                lens[i] = n as i32;
                frozen[i] = 0;
            }
        }
        let seed = self.rng.next_u32() as i32;

        let tokens_b = rt.upload(&HostTensor::I32(tokens, vec![b, s]))?;
        let lens_b = rt.upload(&HostTensor::I32(lens.clone(), vec![b]))?;
        let frozen_b = rt.upload(&HostTensor::I32(frozen, vec![b]))?;
        let seed_b = rt.upload(&HostTensor::I32(vec![seed], vec![1]))?;
        let temp_b = rt.upload(&HostTensor::F32(vec![self.cfg.temperature], vec![1]))?;
        let topk_b = rt.upload(&HostTensor::I32(vec![self.cfg.top_k], vec![1]))?;

        let out_buf = rt.execute_buffers(
            "generate_chunk",
            &[
                self.params_buf.as_ref().unwrap(),
                &tokens_b,
                &lens_b,
                &frozen_b,
                &seed_b,
                &temp_b,
                &topk_b,
            ],
        )?;
        let out = rt.fetch_f32(&out_buf)?;
        self.chunks_run += 1;

        let row_w = 2 * c + 2;
        let mut finished = Vec::new();
        for i in 0..b {
            let Some(slot) = self.slots[i].as_mut() else {
                continue;
            };
            let row = &out[i * row_w..(i + 1) * row_w];
            let old_len = slot.tokens.len();
            let new_len = row[2 * c] as usize;
            let done = row[2 * c + 1] > 0.5;
            let n_new = new_len - old_len;
            for j in 0..n_new {
                slot.tokens.push(row[j] as i32);
                slot.logps.push(row[c + j]);
            }
            self.tokens_generated += n_new as u64;
            slot.chunks += 1;
            slot.version = self.local_version;

            let resp_len = slot.tokens.len() - slot.prompt_len;
            let truncated = resp_len >= self.cfg.max_response;
            if done || truncated {
                let slot = self.slots[i].take().unwrap();
                if resp_len == 0 {
                    crate::log_warn!("generator", "dropping empty trajectory");
                    continue;
                }
                let finish = if done
                    && *slot.tokens.last().unwrap() == mcfg.eos_id
                {
                    FinishReason::Eos
                } else {
                    FinishReason::Length
                };
                finished.push(Trajectory {
                    group_id: slot.task.group_id,
                    replica: slot.task.replica,
                    n_replicas: slot.task.n_replicas,
                    problem: slot.task.problem,
                    prompt_tokens: slot.tokens[..slot.prompt_len].to_vec(),
                    response_tokens: slot.tokens[slot.prompt_len..].to_vec(),
                    behavior_logp: slot.logps,
                    gen_version: slot.version,
                    chunks: slot.chunks,
                    finish,
                    reward: 0.0,
                    advantage: 0.0,
                });
            }
        }
        Ok(finished)
    }
}

impl Executor for GeneratorWorker {
    fn name(&self) -> String {
        format!("generator[{}]", self.worker_id)
    }

    fn init(&mut self) -> Result<()> {
        let rt = Runtime::load(&self.cfg.artifact_dir)?;
        rt.prepare("generate_chunk")?;
        let b = rt.config().gen_batch;
        if self.cfg.max_response < 2 {
            return Err(Error::Config("max_response must be >= 2".into()));
        }
        self.slots = (0..b).map(|_| None).collect();
        self.runtime = Some(rt);
        self.refresh_weights()?;
        Ok(())
    }

    fn set_step(&mut self, _step: u64) {}

    fn step(&mut self) -> Result<StepOutcome> {
        self.refresh_weights()?;
        self.fill_slots();
        if self.slots.iter().all(|s| s.is_none()) {
            // stop requested and every in-flight sequence drained
            if self.eof_on_finish {
                self.out.send_eof();
            }
            return Ok(StepOutcome::Finished);
        }
        let finished = self.run_chunk()?;
        if !finished.is_empty() {
            self.trajectories_emitted += finished.len() as u64;
            // blocking send = the bounded-channel backpressure that caps
            // off-policy lag
            if self.out.send(Message::Trajectories(finished)).is_err() {
                // downstream exited; only graceful if a stop was requested
                return if self.ctx.should_stop() {
                    Ok(StepOutcome::Finished)
                } else {
                    Err(Error::ChannelClosed("generator output".into()))
                };
            }
        }
        Ok(StepOutcome::Progress)
    }

    /// Loop exit (stop requested mid-flight): with a data plane attached,
    /// park in-flight sequences in the store's resumption slot instead of
    /// abandoning their decoded tokens. The executor loop calls this after
    /// its stop check, which is the only place a stop can strand work.
    fn drain(&mut self) -> Result<()> {
        let parked = self.park_live_slots();
        if parked > 0 {
            crate::log_debug!(
                "generator",
                "worker {} parked {parked} partial rollouts at drain",
                self.worker_id
            );
        }
        Ok(())
    }
}

impl GeneratorWorker {
    /// Synchronous-baseline generation (DeepSpeed-Chat-like): start from an
    /// empty batch, feed exactly `n_rows` prompts, and run chunks until
    /// every one of them completes — the all-rows-finish barrier whose
    /// straggler tail is the idle "bubble" of paper Fig. 2(a). Emits the
    /// trajectories downstream and returns the number of chunk calls.
    pub fn generate_batch_sync(&mut self, n_rows: usize) -> Result<u64> {
        assert!(
            self.slots.iter().all(|s| s.is_none()),
            "sync generation starts from an empty batch"
        );
        self.refresh_weights()?;
        let mut to_start = n_rows;
        let mut emitted = 0usize;
        let mut chunks = 0u64;
        while emitted < n_rows {
            for slot in self.slots.iter_mut() {
                if slot.is_none() && to_start > 0 {
                    let task = self.scheduler.next();
                    *slot = Some(Slot {
                        tokens: task.prompt_tokens.clone(),
                        prompt_len: task.prompt_tokens.len(),
                        logps: Vec::new(),
                        chunks: 0,
                        version: 0,
                        task,
                    });
                    to_start -= 1;
                }
            }
            let finished = self.run_chunk()?;
            chunks += 1;
            if !finished.is_empty() {
                emitted += finished.len();
                self.trajectories_emitted += finished.len() as u64;
                self.out.send(Message::Trajectories(finished))?;
            }
        }
        Ok(chunks)
    }
}
