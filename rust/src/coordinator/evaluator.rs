//! Evaluator: greedy benchmark runs against held-out suites (Figure 6).
//!
//! [`eval_policy`] is the core routine (also used by the sync baseline
//! driver); [`EvaluatorExecutor`] wraps it as an optional async executor
//! that re-evaluates every K published weight versions without ever
//! blocking the training pipeline.

use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::executor::{Executor, ExecutorContext, StepOutcome};
use crate::data::{task, EvalSuite};
use crate::model::Tokenizer;
use crate::runtime::{HostTensor, Runtime};
use crate::util::error::Result;
use crate::util::json::Value;
use crate::util::logging::JsonlWriter;

#[derive(Debug, Clone)]
pub struct EvalResult {
    pub suite: String,
    pub accuracy: f64,
    pub n: usize,
    pub weights_version: u64,
}

/// Greedy-decode every problem in each suite and report exact-match
/// accuracy. `params` are uploaded once and reused across suites.
pub fn eval_policy(
    rt: &Runtime,
    params: &[f32],
    suites: &[EvalSuite],
    max_per_suite: usize,
    weights_version: u64,
) -> Result<Vec<EvalResult>> {
    let mcfg = rt.config().clone();
    let (b, s, c) = (mcfg.gen_batch, mcfg.max_seq, mcfg.gen_chunk);
    let tok = Tokenizer::new(mcfg.vocab)?;
    let params_buf = rt.upload(&HostTensor::F32(params.to_vec(), vec![rt.manifest.num_params]))?;
    let max_chunks = s.div_ceil(c) + 1;

    let mut results = Vec::new();
    for suite in suites {
        let problems = &suite.problems[..suite.problems.len().min(max_per_suite)];
        let mut correct = 0usize;
        for batch in problems.chunks(b) {
            // set up slot buffers
            let mut tokens = vec![mcfg.pad_id; b * s];
            let mut lens = vec![1i32; b];
            let mut frozen = vec![1i32; b];
            let mut bufs: Vec<Vec<i32>> = Vec::with_capacity(batch.len());
            for (i, p) in batch.iter().enumerate() {
                let ids = tok.encode_prompt(&p.prompt)?;
                tokens[i * s..i * s + ids.len()].copy_from_slice(&ids);
                lens[i] = ids.len() as i32;
                frozen[i] = 0;
                bufs.push(ids);
            }
            let mut done = vec![false; b];
            for slot in batch.len()..b {
                done[slot] = true;
            }
            for _ in 0..max_chunks {
                if done.iter().all(|d| *d) {
                    break;
                }
                let tokens_b = rt.upload(&HostTensor::I32(tokens.clone(), vec![b, s]))?;
                let lens_b = rt.upload(&HostTensor::I32(lens.clone(), vec![b]))?;
                let frozen_b = rt.upload(&HostTensor::I32(frozen.clone(), vec![b]))?;
                let seed_b = rt.upload(&HostTensor::I32(vec![0], vec![1]))?;
                let temp_b = rt.upload(&HostTensor::F32(vec![0.0], vec![1]))?; // greedy
                let topk_b = rt.upload(&HostTensor::I32(vec![0], vec![1]))?;
                let out_buf = rt.execute_buffers(
                    "generate_chunk",
                    &[&params_buf, &tokens_b, &lens_b, &frozen_b, &seed_b, &temp_b, &topk_b],
                )?;
                let out = rt.fetch_f32(&out_buf)?;
                let row_w = 2 * c + 2;
                for i in 0..batch.len() {
                    if done[i] {
                        continue;
                    }
                    let row = &out[i * row_w..(i + 1) * row_w];
                    let new_len = row[2 * c] as usize;
                    let n_new = new_len - lens[i] as usize;
                    for j in 0..n_new {
                        let t = row[j] as i32;
                        tokens[i * s + lens[i] as usize + j] = t;
                        bufs[i].push(t);
                    }
                    lens[i] = new_len as i32;
                    if row[2 * c + 1] > 0.5 {
                        done[i] = true;
                        frozen[i] = 1;
                    }
                }
            }
            for (i, p) in batch.iter().enumerate() {
                let prompt_len = tok.encode_prompt(&p.prompt)?.len();
                let resp = tok.decode(&bufs[i][prompt_len..]);
                if task::score(p, &resp) > 0.5 {
                    correct += 1;
                }
            }
        }
        results.push(EvalResult {
            suite: suite.name.to_string(),
            accuracy: correct as f64 / problems.len().max(1) as f64,
            n: problems.len(),
            weights_version,
        });
    }
    Ok(results)
}

pub struct EvaluatorConfig {
    pub artifact_dir: std::path::PathBuf,
    /// evaluate every k published weight versions
    pub every_versions: u64,
    pub max_per_suite: usize,
}

pub struct EvaluatorExecutor {
    cfg: EvaluatorConfig,
    ctx: Arc<ExecutorContext>,
    log: Option<Arc<JsonlWriter>>,
    runtime: Option<Runtime>,
    suites: Vec<EvalSuite>,
    last_version: u64,
    pub results: Vec<EvalResult>,
}

impl EvaluatorExecutor {
    pub fn new(
        cfg: EvaluatorConfig,
        ctx: Arc<ExecutorContext>,
        log: Option<Arc<JsonlWriter>>,
    ) -> EvaluatorExecutor {
        let suites = task::eval_suites(cfg.max_per_suite);
        EvaluatorExecutor {
            cfg,
            ctx,
            log,
            runtime: None,
            suites,
            last_version: 0,
            results: Vec::new(),
        }
    }

    fn eval_now(&mut self, version: u64) -> Result<()> {
        let rt = self.runtime.as_ref().unwrap();
        let snap = self.ctx.weights.latest();
        let results = eval_policy(rt, &snap.data, &self.suites, self.cfg.max_per_suite, version)?;
        for r in &results {
            crate::log_info!(
                "evaluator",
                "v{} {}: {:.1}% ({} problems)",
                version,
                r.suite,
                r.accuracy * 100.0,
                r.n
            );
            if let Some(log) = &self.log {
                log.write(&Value::object(vec![
                    ("kind", Value::str("eval")),
                    ("weights_version", Value::num(version as f64)),
                    ("suite", Value::str(r.suite.clone())),
                    ("accuracy", Value::num(r.accuracy)),
                    ("n", Value::num(r.n as f64)),
                ]))?;
            }
        }
        self.results.extend(results);
        Ok(())
    }
}

impl Executor for EvaluatorExecutor {
    fn name(&self) -> String {
        "evaluator".into()
    }

    fn init(&mut self) -> Result<()> {
        let rt = Runtime::load(&self.cfg.artifact_dir)?;
        rt.prepare("generate_chunk")?;
        self.runtime = Some(rt);
        // baseline eval at version 0
        self.eval_now(0)?;
        Ok(())
    }

    fn set_step(&mut self, _step: u64) {}

    fn step(&mut self) -> Result<StepOutcome> {
        let v = self.ctx.weights.version();
        if v >= self.last_version + self.cfg.every_versions {
            self.last_version = v;
            self.eval_now(v)?;
            return Ok(StepOutcome::Progress);
        }
        if self.ctx.should_stop() {
            // final eval on the last weights
            if v > self.last_version {
                self.last_version = v;
                self.eval_now(v)?;
            }
            return Ok(StepOutcome::Finished);
        }
        std::thread::sleep(Duration::from_millis(20));
        Ok(StepOutcome::Idle)
    }
}
