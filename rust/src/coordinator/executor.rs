//! The Executor abstraction (paper §5.1.1).
//!
//! An executor is a self-contained unit with `init` / `set_step` / `step` /
//! `save_checkpoint` / output exposure, attached to its own processing group
//! (here: its own OS thread + PJRT context). The [`ExecutorContext`] carries
//! the shared coordination state (stop flag, DDMA bus handle, metrics dir) —
//! the analogue of Algorithm 1's `executor_context` holding the distributed
//! groups.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::ddma::WeightsBus;
use crate::journal::JournalWriter;
use crate::memplane::MemPlane;
use crate::util::error::Result;
use crate::util::stats::LogHistogram;

/// Streaming latency histograms shared run-wide: executors record into
/// them as work completes, and the `--metrics-interval` sampler reads
/// live p50/p99 quantiles out — the same mergeable log-bucketed core
/// `llamarl analyze` rebuilds offline from the event log. The mutexes
/// are uncontended (a few records per second at most), so recording is
/// off every hot path.
#[derive(Debug, Default)]
pub struct LiveStats {
    /// trainer optimizer-step wall seconds, one sample per step
    pub step_time: Mutex<LogHistogram>,
    /// per-promotion fenced-swap stall seconds (generator weight refresh)
    pub swap_stall: Mutex<LogHistogram>,
}

impl LiveStats {
    pub fn record_step(&self, secs: f64) {
        self.step_time.lock().unwrap().record(secs);
    }

    pub fn record_swap_stall(&self, secs: f64) {
        self.swap_stall.lock().unwrap().record(secs);
    }

    /// (p50, p99) of step wall time so far; `default` when no steps yet.
    pub fn step_quantiles(&self, default: f64) -> (f64, f64) {
        let h = self.step_time.lock().unwrap();
        (h.quantile_or(0.5, default), h.quantile_or(0.99, default))
    }

    /// (p50, p99) of per-swap stall so far; `default` when no swaps yet.
    pub fn swap_quantiles(&self, default: f64) -> (f64, f64) {
        let h = self.swap_stall.lock().unwrap();
        (h.quantile_or(0.5, default), h.quantile_or(0.99, default))
    }
}

/// What a `step()` accomplished — the controller uses this to drive
/// progress/draining decisions without knowing executor internals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepOutcome {
    /// useful work was done
    Progress,
    /// nothing to do right now (e.g. inbound channel empty)
    Idle,
    /// upstream finished and all local work is drained
    Finished,
}

/// Shared coordination state, one per training job.
pub struct ExecutorContext {
    /// controller sets this to request a global stop
    pub stop: AtomicBool,
    /// trainer's optimizer step (the global training clock)
    pub trainer_step: AtomicU64,
    /// DDMA weights bus (trainer -> generators)
    pub weights: WeightsBus,
    /// colocated offloading memory plane; executors bracket their phases
    /// with [`MemPlane::lease`] (None only in tests that bypass the
    /// controller)
    pub mem: Option<Arc<MemPlane>>,
    /// where executors write metrics/checkpoints
    pub out_dir: PathBuf,
    /// durable run-journal (None when journaling is disabled); executors
    /// append step records, node lifecycle and version mints through it
    pub journal: Option<Arc<JournalWriter>>,
    /// live streaming latency histograms (step time, swap stall) feeding
    /// the `--metrics-interval` quantile fields
    pub live: LiveStats,
}

impl ExecutorContext {
    pub fn new(weights: WeightsBus, out_dir: PathBuf) -> Arc<Self> {
        ExecutorContext::with_mem(weights, None, out_dir)
    }

    pub fn with_mem(
        weights: WeightsBus,
        mem: Option<Arc<MemPlane>>,
        out_dir: PathBuf,
    ) -> Arc<Self> {
        ExecutorContext::with_journal(weights, mem, out_dir, None)
    }

    pub fn with_journal(
        weights: WeightsBus,
        mem: Option<Arc<MemPlane>>,
        out_dir: PathBuf,
        journal: Option<Arc<JournalWriter>>,
    ) -> Arc<Self> {
        Arc::new(ExecutorContext {
            stop: AtomicBool::new(false),
            trainer_step: AtomicU64::new(0),
            weights,
            mem,
            out_dir,
            journal,
            live: LiveStats::default(),
        })
    }

    pub fn should_stop(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }

    pub fn request_stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

/// Base executor interface (paper §5.1.1). Implementations: generator
/// workers, the reward executor, the trainer, the evaluator.
pub trait Executor {
    fn name(&self) -> String;

    /// Construct models / compile artifacts / warm caches. Called once on
    /// the executor's own thread before the first step.
    fn init(&mut self) -> Result<()>;

    /// Informs the executor of the current controller tick (sync mode) or is
    /// self-reported (async mode).
    fn set_step(&mut self, step: u64);

    /// One unit of work: a decode chunk, a score pass, a train step.
    fn step(&mut self) -> Result<StepOutcome>;

    /// Hand off in-flight work when the loop exits (stop requested or
    /// Finished) — e.g. generators park partial rollouts in the data
    /// plane's resumption slot. Default: nothing in flight.
    fn drain(&mut self) -> Result<()> {
        Ok(())
    }

    /// Persist state under `ctx.out_dir`. Default: stateless.
    fn save_checkpoint(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Run an executor's SPMD loop (Algorithm 1 lines 8–17) until it finishes,
/// errors, or the context requests a stop.
pub fn run_executor_loop<E: Executor + ?Sized>(
    exec: &mut E,
    ctx: &ExecutorContext,
    checkpoint_every: Option<u64>,
) -> Result<()> {
    exec.init()?;
    run_executor_loop_initialized(exec, ctx, checkpoint_every)
}

/// The SPMD loop for an executor whose `init()` already ran (the controller
/// uses this to keep artifact compilation out of the measured wall clock).
pub fn run_executor_loop_initialized<E: Executor + ?Sized>(
    exec: &mut E,
    ctx: &ExecutorContext,
    checkpoint_every: Option<u64>,
) -> Result<()> {
    let mut local_step: u64 = 0;
    loop {
        if ctx.should_stop() {
            break;
        }
        exec.set_step(local_step);
        match exec.step()? {
            StepOutcome::Finished => break,
            StepOutcome::Progress => {
                local_step += 1;
                if let Some(k) = checkpoint_every {
                    if k > 0 && local_step % k == 0 {
                        exec.save_checkpoint()?;
                    }
                }
            }
            StepOutcome::Idle => {
                // Don't spin: executors are channel-driven, idle means the
                // inbound side is momentarily empty.
                std::thread::yield_now();
            }
        }
    }
    exec.drain()?;
    exec.save_checkpoint()?;
    Ok(())
}
