//! The single Controller (paper §5.1.3, Algorithm 1): wires executors and
//! channels into one training job and runs it to `max_steps`.
//!
//! Two execution architectures behind one entry point ([`run_training`]):
//!
//! * [`Mode::Sync`] — the DeepSpeed-Chat-like baseline (paper §8.1): one
//!   thread drives generate → score → train strictly sequentially; every
//!   step's batch is generated to completion under the current weights
//!   (fully on-policy, with the all-rows-finish straggler bubble).
//! * [`Mode::Async`] — LlamaRL: each executor free-runs on its own thread
//!   (its own PJRT context = its own "processing group"), connected by
//!   bounded GATHER/SCATTER channels; the trainer publishes weights over
//!   the DDMA bus; generation is continuously batched with partial
//!   rollouts. Off-policy lag is bounded by channel capacity and corrected
//!   by AIPO.
//! * [`Mode::AsyncBuffered`] — the streaming data plane: scored groups
//!   land in a sharded [`RolloutStore`] instead of a SCATTER channel. The
//!   store enforces an explicit max-staleness bound, applies a pluggable
//!   admission/eviction policy and sampling strategy, and parks partial
//!   rollouts at drain time. Generators never block on the trainer.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::channel::{gather_channel, scatter_channel};
use crate::coordinator::evaluator::{eval_policy, EvalResult, EvaluatorConfig, EvaluatorExecutor};
use crate::coordinator::executor::{run_executor_loop, Executor, ExecutorContext, StepOutcome};
use crate::coordinator::generator::{GenTally, GeneratorConfig, GeneratorWorker};
use crate::coordinator::reward::{RewardExecutor, ScoredSink};
use crate::coordinator::trainer::{TrainStepRecord, Trainer, TrainerConfig, TrajectorySource};
use crate::data::{task, PromptScheduler};
use crate::dataplane::{DataPlaneSnapshot, RolloutStore, StoreConfig};
use crate::ddma::{BusOptions, WeightsBus};
use crate::memplane::plan::Phase;
use crate::memplane::pool::MemSpec;
use crate::memplane::{MemPlane, MemPlaneConfig};
use crate::model::load_init_params;
use crate::rl::{AipoConfig, Baseline};
use crate::runtime::Manifest;
use crate::util::error::{Error, Result};
use crate::util::logging::JsonlWriter;
use crate::weightsync::{Layout, ShardEncoding};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Sync,
    Async,
    AsyncBuffered,
}

/// Sharded weight-sync plane configuration: how each publish is resharded
/// from the trainer's FSDP layout into the generators' TP layout, which
/// wire encoding the shards use, and whether the fan-out runs on the
/// background streaming executor (see [`crate::weightsync`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WeightSyncConfig {
    /// trainer-side FSDP shard count (source ranks of the reshard plan)
    pub trainer_shards: usize,
    /// generator-side TP shard count (destination ranks; per-tensor split
    /// when the manifest's param layout allows it)
    pub generator_shards: usize,
    /// shard wire encoding: full f32, int8 (1 byte/elem + per-shard scale,
    /// dequantized at attach), exact delta, or top-k sparse delta
    pub encoding: ShardEncoding,
    /// run publishes through the background streaming executor
    /// (enqueue-and-return, per-link-group worker threads) instead of the
    /// inline fan-out on the trainer thread
    pub background: bool,
    /// background link-group worker threads (0 = one per generator shard)
    pub link_groups: usize,
    /// kept-update fraction per shard for [`ShardEncoding::TopK`]
    pub topk_frac: f64,
}

impl Default for WeightSyncConfig {
    fn default() -> Self {
        WeightSyncConfig {
            trainer_shards: 4,
            generator_shards: 2,
            encoding: ShardEncoding::F32,
            background: true,
            link_groups: 0,
            topk_frac: 0.01,
        }
    }
}

#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub artifact_dir: PathBuf,
    pub mode: Mode,
    /// data-parallel generator workers (async mode)
    pub n_generator_workers: usize,
    /// gen->reward channel capacity, in messages (bounds off-policy lag)
    pub queue_capacity: usize,
    /// reward->trainer channel capacity, in groups
    pub scored_capacity: usize,
    /// rollout-store configuration (Mode::AsyncBuffered); the store's seed
    /// is derived from `seed` at run time
    pub store: StoreConfig,
    /// sharded weight-sync plane configuration
    pub sync: WeightSyncConfig,
    /// colocated offloading memory plane (`colocate`, `offload_classes`,
    /// `offload_chunk_mb`, `prefetch_depth`); `concurrent_phases` is
    /// derived from the mode at run time
    pub mem: MemPlaneConfig,
    /// generations per prompt (the advantage group, paper n=4)
    pub n_generations: usize,
    pub baseline: Baseline,
    pub max_steps: u64,
    pub aipo: AipoConfig,
    pub temperature: f32,
    pub top_k: i32,
    pub quantize_generator: bool,
    pub max_response: usize,
    /// evaluate every k weight versions (0 disables)
    pub eval_every: u64,
    pub eval_max_per_suite: usize,
    pub checkpoint_every: u64,
    pub seed: u64,
    pub out_dir: PathBuf,
    /// start RL from this pretrained checkpoint (bare params) instead of
    /// the random init — see coordinator::pretrain
    pub init_checkpoint: Option<PathBuf>,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            artifact_dir: "artifacts/nano".into(),
            mode: Mode::Async,
            n_generator_workers: 1,
            queue_capacity: 4,
            scored_capacity: 8,
            store: StoreConfig::default(),
            sync: WeightSyncConfig::default(),
            mem: MemPlaneConfig::default(),
            n_generations: 4,
            baseline: Baseline::GroupMean,
            max_steps: 5,
            aipo: AipoConfig::default(),
            temperature: 1.0,
            top_k: 0,
            quantize_generator: false,
            max_response: 32,
            eval_every: 0,
            eval_max_per_suite: 64,
            checkpoint_every: 0,
            seed: 0,
            out_dir: std::env::temp_dir().join("llamarl_run"),
            init_checkpoint: None,
        }
    }
}

/// Everything a finished run reports (examples and benches consume this).
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    pub mode: String,
    pub steps: u64,
    pub wall_secs: f64,
    pub records: Vec<TrainStepRecord>,
    pub evals: Vec<EvalResult>,
    pub tokens_generated: u64,
    pub trajectories: u64,
    pub chunks: u64,
    pub weight_refreshes: u64,
    pub ddma_publishes: u64,
    pub ddma_mean_publish_secs: f64,
    /// mean per-publish time of the slowest shard — the modelled parallel
    /// DDMA cost of the reshard plan (0 when no generator slot is registered)
    pub ddma_mean_shard_max_secs: f64,
    /// total seconds the trainer thread spent blocked inside
    /// `WeightsBus::publish` — with the background executor this is the
    /// enqueue handoff only, inline the whole encode + fan-out
    pub ddma_publish_blocked_secs: f64,
    /// background publishes superseded by a newer version before streaming
    /// (latest-wins coalescing; 0 for the inline plane)
    pub ddma_coalesced_publishes: u64,
    /// total decode-side stall the fenced weight swaps imposed across
    /// generator workers, and how many swaps completed
    pub gen_swap_stall_secs: f64,
    pub gen_swaps: u64,
    pub gen_send_blocked_secs: f64,
    pub trainer_recv_blocked_secs: f64,
    /// memplane telemetry: bytes the offload executor swapped to host
    /// (D2H) and prefetched back (H2D) across phase flips
    pub offload_d2h_bytes: u64,
    pub offload_h2d_bytes: u64,
    /// total seconds phase leases blocked waiting for residency (the
    /// un-hidden part of the offload stream)
    pub offload_wait_secs: f64,
    /// shard waits the background prefetcher satisfied without blocking
    pub offload_prefetch_hits: u64,
    /// residency targets superseded before the executor converged them
    /// (latest-wins phase flips)
    pub offload_superseded: u64,
    /// rollout-store telemetry (Mode::AsyncBuffered only)
    pub dataplane: Option<DataPlaneSnapshot>,
    pub metrics_path: Option<PathBuf>,
}

impl RunReport {
    /// Copy the memory-plane counters out of the executor context (called
    /// once per finished run, after the final flush).
    fn fill_mem_telemetry(&mut self, ctx: &ExecutorContext) {
        use std::sync::atomic::Ordering;
        if let Some(m) = &ctx.mem {
            let mm = m.metrics();
            self.offload_d2h_bytes = mm.d2h_bytes.load(Ordering::Relaxed);
            self.offload_h2d_bytes = mm.h2d_bytes.load(Ordering::Relaxed);
            self.offload_wait_secs = mm.wait_secs();
            self.offload_prefetch_hits = mm.prefetch_hits.load(Ordering::Relaxed);
            self.offload_superseded = mm.superseded_targets.load(Ordering::Relaxed);
        }
    }
}

impl RunReport {
    pub fn mean_step_secs(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.wall_secs / self.steps as f64
        }
    }

    pub fn final_reward(&self) -> f64 {
        self.records.last().map(|r| r.reward_mean).unwrap_or(0.0)
    }

    pub fn summary(&self) -> String {
        format!(
            "{} mode: {} steps in {:.1}s ({:.2}s/step), {} trajs, {} tokens, \
             final reward {:.3}, ddma {:.1}ms/publish",
            self.mode,
            self.steps,
            self.wall_secs,
            self.mean_step_secs(),
            self.trajectories,
            self.tokens_generated,
            self.final_reward(),
            self.ddma_mean_publish_secs * 1e3,
        )
    }
}

fn gen_cfg(cfg: &PipelineConfig, worker: usize) -> GeneratorConfig {
    GeneratorConfig {
        artifact_dir: cfg.artifact_dir.clone(),
        temperature: cfg.temperature,
        top_k: cfg.top_k,
        quantize_int8: cfg.quantize_generator,
        max_response: cfg.max_response,
        seed: cfg.seed.wrapping_add(1000 + worker as u64),
    }
}

fn trainer_cfg(cfg: &PipelineConfig) -> TrainerConfig {
    TrainerConfig {
        artifact_dir: cfg.artifact_dir.clone(),
        aipo: cfg.aipo,
        max_steps: cfg.max_steps,
        publish_every: 1,
        checkpoint_every: cfg.checkpoint_every,
    }
}

/// Entry point: build the topology for `cfg.mode` and train to completion.
pub fn run_training(cfg: &PipelineConfig) -> Result<RunReport> {
    std::fs::create_dir_all(&cfg.out_dir)?;
    let manifest = Manifest::load(&cfg.artifact_dir)?;
    let init = match &cfg.init_checkpoint {
        None => load_init_params(&manifest)?,
        Some(path) => {
            let ckpt = crate::model::load_checkpoint(path)?;
            if ckpt.state.len() != manifest.num_params {
                return Err(Error::Config(format!(
                    "checkpoint {} has {} params, artifacts expect {}",
                    path.display(),
                    ckpt.state.len(),
                    manifest.num_params
                )));
            }
            ckpt.state
        }
    };
    if cfg.mode == Mode::Sync && manifest.config.train_batch % cfg.n_generations != 0 {
        return Err(Error::Config(format!(
            "sync mode requires train_batch ({}) divisible by n_generations ({}) \
             so every step's groups complete",
            manifest.config.train_batch, cfg.n_generations
        )));
    }
    if cfg.n_generations == 0 || cfg.max_steps == 0 {
        return Err(Error::Config("n_generations and max_steps must be > 0".into()));
    }
    // Build the weight-sync plane: FSDP source layout from the configured
    // trainer shard count, TP destination layout split per-tensor via the
    // manifest's param map (falling back to a flat split if the map has
    // gaps), the configured wire encoding, and — by default — the
    // background streaming executor so the trainer's publish is
    // enqueue-and-return.
    let n_params = init.len();
    let src_layout = Layout::fsdp(n_params, cfg.sync.trainer_shards.max(1));
    let g_shards = cfg.sync.generator_shards.max(1);
    let dst_layout = Layout::tp(n_params, g_shards, &manifest.param_layout)
        .unwrap_or_else(|_| Layout::tp_flat(n_params, g_shards));
    let mut bus_opts = BusOptions::new(src_layout, dst_layout);
    bus_opts.encoding = cfg.sync.encoding;
    // Sync mode registers no generator slots (the single thread re-attaches
    // to the master directly), so background workers would wake per publish
    // to stream to nobody — and the enqueue-only blocked-time metric would
    // stop being comparable to the baseline. Force the inline plane there.
    bus_opts.background = cfg.sync.background && cfg.mode != Mode::Sync;
    bus_opts.link_groups = cfg.sync.link_groups;
    bus_opts.topk_frac = cfg.sync.topk_frac;
    let bus = WeightsBus::with_options(init, bus_opts)?;
    // Build the colocated offloading memory plane: a testbed-scale MemSpec
    // derived from the artifact's parameter count, with `concurrent_phases`
    // following the mode (async architectures overlap generate/train/sync
    // on disjoint executors, so nothing may leave the device and the
    // planner must prove the union fits). Infeasible colocations fail HERE,
    // before any executor spawns.
    let mem_cfg = MemPlaneConfig {
        concurrent_phases: cfg.mode != Mode::Sync,
        ..cfg.mem.clone()
    };
    let spec = MemSpec::testbed(
        n_params,
        manifest.config.train_batch,
        manifest.config.gen_batch,
    );
    let mem = MemPlane::new(spec, &mem_cfg)?;
    let ctx = ExecutorContext::with_mem(bus, Some(mem), cfg.out_dir.clone());
    let scheduler = Arc::new(PromptScheduler::new(
        cfg.seed,
        manifest.config.vocab,
        cfg.n_generations,
    )?);
    let metrics_path = cfg.out_dir.join("metrics.jsonl");
    let log = Arc::new(JsonlWriter::create(&metrics_path)?);

    let mut report = match cfg.mode {
        Mode::Sync => run_sync(cfg, &manifest, ctx, scheduler, log)?,
        Mode::Async => run_async(cfg, &manifest, ctx, scheduler, log)?,
        Mode::AsyncBuffered => run_async_buffered(cfg, &manifest, ctx, scheduler, log)?,
    };
    report.metrics_path = Some(metrics_path);
    Ok(report)
}

/// Synchronous on-policy baseline: single thread, sequential phases.
fn run_sync(
    cfg: &PipelineConfig,
    manifest: &Manifest,
    ctx: Arc<ExecutorContext>,
    scheduler: Arc<PromptScheduler>,
    log: Arc<JsonlWriter>,
) -> Result<RunReport> {
    // Sync mode runs all executors on ONE thread; channels must absorb a
    // whole step's traffic without blocking (worst case: one message per
    // trajectory, one group per n_generations rows).
    let rows_per_step = manifest.config.train_batch;
    let (gen_tx, gen_rx) = gather_channel("generations", (2 * rows_per_step).max(64));
    let (scored_tx, mut scored_rxs) =
        scatter_channel("scored", (2 * rows_per_step).max(64), 1);

    let mut gen = GeneratorWorker::new(0, gen_cfg(cfg, 0), ctx.clone(), scheduler, gen_tx);
    let mut reward = RewardExecutor::new(
        ctx.clone(),
        gen_rx,
        ScoredSink::Channel(scored_tx),
        cfg.baseline,
        manifest.config.vocab,
        1,
    )?;
    let mut trainer = Trainer::new(
        trainer_cfg(cfg),
        ctx.clone(),
        TrajectorySource::Channel(scored_rxs.remove(0)),
        Some(log.clone()),
    );

    gen.init()?;
    reward.init()?;
    trainer.init()?;

    let suites = task::eval_suites(cfg.eval_max_per_suite);
    let mut evals = Vec::new();
    let t0 = Instant::now();

    for step in 0..cfg.max_steps {
        // Phase 1: generation — all rows complete under current weights.
        // The Generate lease swaps offloadable trainer state (optimizer
        // moments, grads) to host behind decode, and the Train hint arms
        // the prefetcher so the first optimizer shard is back on device
        // before the batch finishes.
        {
            let _gen_lease = match &ctx.mem {
                Some(m) => Some(m.lease(Phase::Generate)?),
                None => None,
            };
            if let Some(m) = &ctx.mem {
                m.hint_next(Phase::Train);
            }
            gen.generate_batch_sync(rows_per_step)?;
        }
        // Phase 2: scoring.
        while reward.drain_once()? {}
        // Phase 3: one train step (+ weight publication = in-place update);
        // the trainer brackets itself with Train/Sync leases.
        match trainer.step()? {
            StepOutcome::Progress => {}
            other => {
                return Err(Error::Coordinator(format!(
                    "sync trainer did not progress at step {step}: {other:?}"
                )))
            }
        }
        if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
            let snap = ctx.weights.latest();
            // co-located: eval borrows the generator's PJRT context
            evals.extend(eval_policy(
                gen.runtime_ref(),
                &snap.data,
                &suites,
                cfg.eval_max_per_suite,
                snap.version,
            )?);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    // settle any background stream before reading plane-wide counters
    ctx.weights.flush();
    if let Some(m) = &ctx.mem {
        m.flush()?;
    }

    let mut report = RunReport {
        mode: "sync".into(),
        steps: trainer.current_step(),
        wall_secs: wall,
        records: trainer.records.clone(),
        evals,
        tokens_generated: gen.tokens_generated,
        trajectories: gen.trajectories_emitted,
        chunks: gen.chunks_run,
        weight_refreshes: gen.weight_refreshes,
        ddma_publishes: ctx.weights.publish_count(),
        ddma_mean_publish_secs: ctx.weights.mean_publish_secs(),
        ddma_mean_shard_max_secs: ctx.weights.mean_shard_max_secs(),
        ddma_publish_blocked_secs: ctx.weights.publish_blocked_secs(),
        ddma_coalesced_publishes: ctx.weights.coalesced_publishes(),
        gen_swap_stall_secs: 0.0,
        gen_swaps: 0,
        gen_send_blocked_secs: 0.0,
        trainer_recv_blocked_secs: 0.0,
        dataplane: None,
        metrics_path: None,
        ..RunReport::default()
    };
    report.fill_mem_telemetry(&ctx);
    Ok(report)
}

/// Asynchronous off-policy pipeline: executor-per-thread, bounded channels.
fn run_async(
    cfg: &PipelineConfig,
    manifest: &Manifest,
    ctx: Arc<ExecutorContext>,
    scheduler: Arc<PromptScheduler>,
    log: Arc<JsonlWriter>,
) -> Result<RunReport> {
    let n_workers = cfg.n_generator_workers.max(1);
    let (gen_tx, gen_rx) = gather_channel("generations", cfg.queue_capacity);
    let (scored_tx, mut scored_rxs) = scatter_channel("scored", cfg.scored_capacity, 1);
    let gen_stats_ch = gen_tx.stats.clone();
    let scored_stats_ch = scored_tx.stats.clone();

    let mut gen_handles = Vec::new();
    for w in 0..n_workers {
        let ctx = ctx.clone();
        let scheduler = scheduler.clone();
        let out = gen_tx.clone();
        let gcfg = gen_cfg(cfg, w);
        // every publish streams the reshard plan into this slot's staging
        // buffer; the worker swaps it in (fenced) at chunk boundaries
        let sync_slot = ctx.weights.register_generator();
        gen_handles.push(
            std::thread::Builder::new()
                .name(format!("generator-{w}"))
                .spawn(move || -> Result<GenTally> {
                    // the worker holds its Generate lease for its whole
                    // lifetime: async phases overlap, so the lease is
                    // feasibility + accounting, never an offload stall
                    let _gen_lease = match &ctx.mem {
                        Some(m) => Some(m.lease(Phase::Generate)?),
                        None => None,
                    };
                    let mut gen = GeneratorWorker::new(w, gcfg, ctx.clone(), scheduler, out);
                    gen.set_sync_slot(sync_slot);
                    run_executor_loop(&mut gen, &ctx, None)?;
                    Ok(gen.tally())
                })
                .expect("spawn generator"),
        );
    }
    drop(gen_tx);

    let reward_handle = {
        let ctx = ctx.clone();
        let vocab = manifest.config.vocab;
        let baseline = cfg.baseline;
        std::thread::Builder::new()
            .name("reward".into())
            .spawn(move || -> Result<(u64, u64, f64)> {
                let mut r = RewardExecutor::new(
                    ctx.clone(),
                    gen_rx,
                    ScoredSink::Channel(scored_tx),
                    baseline,
                    vocab,
                    n_workers,
                )?;
                run_executor_loop(&mut r, &ctx, None)?;
                Ok((r.scored, r.groups_emitted, r.reward_sum))
            })
            .expect("spawn reward")
    };

    let eval_handle = if cfg.eval_every > 0 {
        let ctx = ctx.clone();
        let ecfg = EvaluatorConfig {
            artifact_dir: cfg.artifact_dir.clone(),
            every_versions: cfg.eval_every,
            max_per_suite: cfg.eval_max_per_suite,
        };
        let log = log.clone();
        Some(
            std::thread::Builder::new()
                .name("evaluator".into())
                .spawn(move || -> Result<Vec<EvalResult>> {
                    let mut e = EvaluatorExecutor::new(ecfg, ctx.clone(), Some(log));
                    run_executor_loop(&mut e, &ctx, None)?;
                    Ok(e.results)
                })
                .expect("spawn evaluator"),
        )
    } else {
        None
    };

    // Trainer runs on the controller thread (Algorithm 1's "local executor").
    // Init (artifact compilation) runs OUTSIDE the measured wall clock, like
    // the sync driver's; the generator/reward threads warm up concurrently.
    let scored_rx = scored_rxs.remove(0);
    let mut trainer = Trainer::new(
        trainer_cfg(cfg),
        ctx.clone(),
        TrajectorySource::Channel(scored_rx),
        Some(log),
    );
    trainer.init()?;
    let t0 = Instant::now();
    crate::coordinator::executor::run_executor_loop_initialized(
        &mut trainer,
        &ctx,
        if cfg.checkpoint_every > 0 {
            Some(cfg.checkpoint_every)
        } else {
            None
        },
    )?;
    ctx.request_stop();

    let mut tally = GenTally::default();
    for h in gen_handles {
        let t = h.join().map_err(|_| Error::msg("generator panicked"))??;
        tally.add(&t);
    }
    let _ = reward_handle
        .join()
        .map_err(|_| Error::msg("reward panicked"))??;
    let evals = match eval_handle {
        Some(h) => h.join().map_err(|_| Error::msg("evaluator panicked"))??,
        None => Vec::new(),
    };
    let wall = t0.elapsed().as_secs_f64();
    // settle any background stream before reading plane-wide counters
    ctx.weights.flush();
    if let Some(m) = &ctx.mem {
        m.flush()?;
    }

    let mut report = RunReport {
        mode: "async".into(),
        steps: trainer.current_step(),
        wall_secs: wall,
        records: trainer.records.clone(),
        evals,
        tokens_generated: tally.tokens,
        trajectories: tally.trajectories,
        chunks: tally.chunks,
        weight_refreshes: tally.weight_refreshes,
        ddma_publishes: ctx.weights.publish_count(),
        ddma_mean_publish_secs: ctx.weights.mean_publish_secs(),
        ddma_mean_shard_max_secs: ctx.weights.mean_shard_max_secs(),
        ddma_publish_blocked_secs: ctx.weights.publish_blocked_secs(),
        ddma_coalesced_publishes: ctx.weights.coalesced_publishes(),
        gen_swap_stall_secs: tally.swap_stall_secs,
        gen_swaps: tally.swaps,
        gen_send_blocked_secs: gen_stats_ch.send_blocked_secs(),
        trainer_recv_blocked_secs: scored_stats_ch.recv_blocked_secs(),
        dataplane: None,
        metrics_path: None,
        ..RunReport::default()
    };
    report.fill_mem_telemetry(&ctx);
    Ok(report)
}

/// Buffered asynchronous pipeline (the streaming data plane): generators
/// GATHER into the reward executor exactly as in async mode, but scored
/// groups are admitted into a sharded [`RolloutStore`] instead of a
/// SCATTER channel. The trainer samples microbatches from the store (per
/// the configured strategy) and advances the staleness watermark with its
/// optimizer step; generators park partial rollouts in the store at drain
/// time instead of decoding stragglers to completion.
fn run_async_buffered(
    cfg: &PipelineConfig,
    manifest: &Manifest,
    ctx: Arc<ExecutorContext>,
    scheduler: Arc<PromptScheduler>,
    log: Arc<JsonlWriter>,
) -> Result<RunReport> {
    let n_workers = cfg.n_generator_workers.max(1);
    let (gen_tx, gen_rx) = gather_channel("generations", cfg.queue_capacity);
    let gen_stats_ch = gen_tx.stats.clone();
    let store = Arc::new(RolloutStore::new(StoreConfig {
        seed: cfg.seed ^ 0xB0FF_E12D,
        ..cfg.store.clone()
    }));

    let mut gen_handles = Vec::new();
    for w in 0..n_workers {
        let ctx = ctx.clone();
        let scheduler = scheduler.clone();
        let out = gen_tx.clone();
        let store = store.clone();
        let gcfg = gen_cfg(cfg, w);
        let sync_slot = ctx.weights.register_generator();
        gen_handles.push(
            std::thread::Builder::new()
                .name(format!("generator-{w}"))
                .spawn(move || -> Result<GenTally> {
                    let _gen_lease = match &ctx.mem {
                        Some(m) => Some(m.lease(Phase::Generate)?),
                        None => None,
                    };
                    let mut gen = GeneratorWorker::new(w, gcfg, ctx.clone(), scheduler, out);
                    gen.set_resume_store(store);
                    gen.set_sync_slot(sync_slot);
                    run_executor_loop(&mut gen, &ctx, None)?;
                    Ok(gen.tally())
                })
                .expect("spawn generator"),
        );
    }
    drop(gen_tx);

    let reward_handle = {
        let ctx = ctx.clone();
        let vocab = manifest.config.vocab;
        let baseline = cfg.baseline;
        let sink = ScoredSink::Store(store.clone());
        std::thread::Builder::new()
            .name("reward".into())
            .spawn(move || -> Result<(u64, u64, f64)> {
                let mut r = RewardExecutor::new(ctx.clone(), gen_rx, sink, baseline, vocab, n_workers)?;
                run_executor_loop(&mut r, &ctx, None)?;
                Ok((r.scored, r.groups_emitted, r.reward_sum))
            })
            .expect("spawn reward")
    };

    let eval_handle = if cfg.eval_every > 0 {
        let ctx = ctx.clone();
        let ecfg = EvaluatorConfig {
            artifact_dir: cfg.artifact_dir.clone(),
            every_versions: cfg.eval_every,
            max_per_suite: cfg.eval_max_per_suite,
        };
        let log = log.clone();
        Some(
            std::thread::Builder::new()
                .name("evaluator".into())
                .spawn(move || -> Result<Vec<EvalResult>> {
                    let mut e = EvaluatorExecutor::new(ecfg, ctx.clone(), Some(log));
                    run_executor_loop(&mut e, &ctx, None)?;
                    Ok(e.results)
                })
                .expect("spawn evaluator"),
        )
    } else {
        None
    };

    // Trainer on the controller thread, sampling from the store.
    let mut trainer = Trainer::new(
        trainer_cfg(cfg),
        ctx.clone(),
        TrajectorySource::Store(store.clone()),
        Some(log),
    );
    trainer.init()?;
    let t0 = Instant::now();
    crate::coordinator::executor::run_executor_loop_initialized(
        &mut trainer,
        &ctx,
        if cfg.checkpoint_every > 0 {
            Some(cfg.checkpoint_every)
        } else {
            None
        },
    )?;
    ctx.request_stop();
    store.close();

    let mut tally = GenTally::default();
    for h in gen_handles {
        let t = h.join().map_err(|_| Error::msg("generator panicked"))??;
        tally.add(&t);
    }
    let _ = reward_handle
        .join()
        .map_err(|_| Error::msg("reward panicked"))??;
    let evals = match eval_handle {
        Some(h) => h.join().map_err(|_| Error::msg("evaluator panicked"))??,
        None => Vec::new(),
    };
    let wall = t0.elapsed().as_secs_f64();
    let snapshot = store.snapshot();
    // settle any background stream before reading plane-wide counters
    ctx.weights.flush();
    if let Some(m) = &ctx.mem {
        m.flush()?;
    }

    let mut report = RunReport {
        mode: "async_buffered".into(),
        steps: trainer.current_step(),
        wall_secs: wall,
        records: trainer.records.clone(),
        evals,
        tokens_generated: tally.tokens,
        trajectories: tally.trajectories,
        chunks: tally.chunks,
        weight_refreshes: tally.weight_refreshes,
        ddma_publishes: ctx.weights.publish_count(),
        ddma_mean_publish_secs: ctx.weights.mean_publish_secs(),
        ddma_mean_shard_max_secs: ctx.weights.mean_shard_max_secs(),
        ddma_publish_blocked_secs: ctx.weights.publish_blocked_secs(),
        ddma_coalesced_publishes: ctx.weights.coalesced_publishes(),
        gen_swap_stall_secs: tally.swap_stall_secs,
        gen_swaps: tally.swaps,
        gen_send_blocked_secs: gen_stats_ch.send_blocked_secs(),
        trainer_recv_blocked_secs: snapshot.sample_wait_secs,
        dataplane: Some(snapshot),
        metrics_path: None,
        ..RunReport::default()
    };
    report.fill_mem_telemetry(&ctx);
    Ok(report)
}
