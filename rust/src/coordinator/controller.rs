//! The single Controller (paper §5.1.3, Algorithm 1): resolves the
//! declarative execution graph for the configured mode, builds the shared
//! planes (weight-sync, memory), and launches it through the one generic
//! graph runtime ([`crate::coordinator::graph`]).
//!
//! Four modes, four *topology descriptions* — one runtime:
//!
//! * [`Mode::Sync`] — the DeepSpeed-Chat-like baseline (paper §8.1): the
//!   same graph driven by the stepped scheduler, strictly sequential
//!   generate → score → train ticks (fully on-policy, with the
//!   all-rows-finish straggler bubble).
//! * [`Mode::Async`] — LlamaRL: every fleet free-runs on its own threads
//!   (own PJRT context = own "processing group"), connected by bounded
//!   group-routed/gather channels; the trainer publishes weights over the
//!   DDMA bus; off-policy lag is bounded by channel capacity and corrected
//!   by AIPO.
//! * [`Mode::AsyncBuffered`] — the streaming data plane: scored groups
//!   land in a sharded [`RolloutStore`](crate::dataplane::RolloutStore)
//!   with an enforced max-staleness bound instead of a scored channel.
//! * [`Mode::Periodic`] — periodic asynchrony: the buffered data plane
//!   plus a period fence — generators free-run for `period_steps` trainer
//!   steps, the trainer fleet steps synchronously at the boundary, one
//!   coalesced publish per period.
//!
//! In every mode reward scoring is a fleet (`n_reward_workers`), scattered
//! over generation groups by group id with group integrity preserved. In
//! the store-backed modes training is a fleet too (`n_trainer_workers`):
//! replicas sample disjoint shard-slices, partition the global step
//! sequence round-robin, and publish through the bus's multi-publisher
//! path.

use std::path::PathBuf;
use std::sync::Arc;

use crate::coordinator::evaluator::EvalResult;
use crate::coordinator::executor::ExecutorContext;
use crate::coordinator::graph::{topology, LaunchEnv};
use crate::coordinator::trainer::TrainStepRecord;
use crate::data::PromptScheduler;
use crate::dataplane::{DataPlaneSnapshot, StoreConfig};
use crate::ddma::{BusOptions, WeightsBus};
use crate::journal::{JournalRecord, JournalWriter, ResumeState};
use crate::memplane::pool::MemSpec;
use crate::memplane::{MemPlane, MemPlaneConfig};
use crate::model::load_init_params;
use crate::rl::{AipoConfig, Baseline};
use crate::runtime::Manifest;
use crate::trace::{chrome, Collector};
use crate::util::error::{Error, Result};
use crate::util::logging::JsonlWriter;
use crate::weightsync::{Layout, ShardEncoding};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Sync,
    Async,
    AsyncBuffered,
    /// Periodic asynchrony (PAPERS.md, arXiv 2511.18871): generators
    /// free-run against the rollout store for `period_steps` trainer
    /// steps, the trainer fleet steps synchronously at each period
    /// boundary, and exactly one coalesced publish goes out per period
    /// — recovering most of async throughput while bounding off-policy
    /// lag to one period.
    Periodic,
}

/// Sharded weight-sync plane configuration: how each publish is resharded
/// from the trainer's FSDP layout into the generators' TP layout, which
/// wire encoding the shards use, and whether the fan-out runs on the
/// background streaming executor (see [`crate::weightsync`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WeightSyncConfig {
    /// trainer-side FSDP shard count (source ranks of the reshard plan)
    pub trainer_shards: usize,
    /// generator-side TP shard count (destination ranks; per-tensor split
    /// when the manifest's param layout allows it)
    pub generator_shards: usize,
    /// shard wire encoding: full f32, int8 (1 byte/elem + per-shard scale,
    /// dequantized at attach), exact delta, top-k sparse delta, or
    /// adaptive per-publish full-vs-delta selection (`auto`)
    pub encoding: ShardEncoding,
    /// run publishes through the background streaming executor
    /// (enqueue-and-return, per-link-group worker threads) instead of the
    /// inline fan-out on the trainer thread
    pub background: bool,
    /// background link-group worker threads (0 = one per generator shard)
    pub link_groups: usize,
    /// kept-update fraction per shard for [`ShardEncoding::TopK`]
    pub topk_frac: f64,
}

impl Default for WeightSyncConfig {
    fn default() -> Self {
        WeightSyncConfig {
            trainer_shards: 4,
            generator_shards: 2,
            encoding: ShardEncoding::F32,
            background: true,
            link_groups: 0,
            topk_frac: 0.01,
        }
    }
}

#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub artifact_dir: PathBuf,
    pub mode: Mode,
    /// data-parallel generator workers (async modes)
    pub n_generator_workers: usize,
    /// reward-scoring fleet size: generation groups scatter across N
    /// reward executors by group id — every replica of a prompt's group
    /// is scored by exactly one node, so the advantage baseline stays
    /// intact while scoring throughput scales
    pub n_reward_workers: usize,
    /// data-parallel trainer fleet size (store-backed modes only): each
    /// replica samples a disjoint shard-slice of the rollout store and
    /// the fleet partitions the global step sequence round-robin, all
    /// replicas publishing through one shared reshard plan via the bus's
    /// multi-publisher path. Requires `store.shards >= n_trainer_workers`.
    pub n_trainer_workers: usize,
    /// Mode::Periodic period length, in global trainer steps: generators
    /// free-run for one period, the trainer fleet fences at each period
    /// boundary and publishes exactly once per period
    pub period_steps: u64,
    /// gen->reward capacity per reward replica, in messages (bounds
    /// off-policy lag)
    pub queue_capacity: usize,
    /// reward->trainer channel capacity, in groups
    pub scored_capacity: usize,
    /// rollout-store configuration (Mode::AsyncBuffered); the store's seed
    /// is derived from `seed` at run time
    pub store: StoreConfig,
    /// sharded weight-sync plane configuration
    pub sync: WeightSyncConfig,
    /// colocated offloading memory plane (`colocate`, `offload_classes`,
    /// `offload_chunk_mb`, `prefetch_depth`); `concurrent_phases` is
    /// derived from the topology at run time
    pub mem: MemPlaneConfig,
    /// generations per prompt (the advantage group, paper n=4)
    pub n_generations: usize,
    pub baseline: Baseline,
    pub max_steps: u64,
    pub aipo: AipoConfig,
    pub temperature: f32,
    pub top_k: i32,
    pub quantize_generator: bool,
    pub max_response: usize,
    /// evaluate every k weight versions (0 disables)
    pub eval_every: u64,
    pub eval_max_per_suite: usize,
    pub checkpoint_every: u64,
    pub seed: u64,
    pub out_dir: PathBuf,
    /// start RL from this pretrained checkpoint (bare params) instead of
    /// the random init — see coordinator::pretrain
    pub init_checkpoint: Option<PathBuf>,
    /// arm the tracing plane and export a Chrome Trace Event Format file
    /// here at run end; the streaming JSONL event log rides along at
    /// `out_dir/trace_events.jsonl` (see [`crate::trace`])
    pub trace: Option<PathBuf>,
    /// periodic live-telemetry snapshot cadence in seconds (0 disables);
    /// snapshots append to `out_dir/telemetry_snapshots.jsonl`
    pub metrics_interval_secs: f64,
    /// write the durable run-journal to `out_dir/journal.jsonl` (on by
    /// default; `--no-journal` disables) — see [`crate::journal`]
    pub journal: bool,
    /// cadence of the journal's consistent snapshot records, in seconds
    pub journal_snapshot_secs: f64,
    /// crash-resume state reconstructed from a recorded journal by
    /// [`crate::journal::plan_resume`] (`llamarl resume`). Never settable
    /// from JSON/CLI — only the resume path threads it through.
    pub resume: Option<ResumeState>,
    /// supervised restarts each generator/reward replica may consume
    /// before its failure escalates to the global stop (0 = Never, the
    /// pre-elastic behavior; async modes only)
    pub restart_max: u32,
    /// base backoff before the first supervised restart, in milliseconds
    /// (doubles per attempt)
    pub restart_backoff_ms: u64,
    /// CHAOS MODE: inject this many seeded generator kills, spread
    /// round-robin across the fleet's (worker, attempt) grid — the CI
    /// chaos arm's randomized kill schedule (0 disables)
    pub chaos_kills: u64,
    /// seed for the chaos kill schedule (same seed = same schedule)
    pub chaos_seed: u64,
    /// CHAOS MODE: inject this many seeded reward-replica PANICS (not
    /// errors), spread round-robin across the reward fleet — exercises
    /// the inbound-receiver re-creation path, where the dying attempt's
    /// receiver is lost and the supervisor re-routes a fresh one
    /// (0 disables)
    pub chaos_reward_kills: u64,
    /// enable the queue-depth-driven fleet controller: spawn dynamic
    /// generator replicas while the trainer starves on the store, retire
    /// them when admission backs up (Mode::AsyncBuffered only)
    pub elastic_resize: bool,
    /// cap on dynamic replicas the fleet controller may add
    pub resize_max_extra: usize,
    /// FAULT-INJECTION TEST HOOK: make every generator error out after N
    /// decode chunks, exercising the graph runtime's error propagation.
    /// Never settable from JSON/CLI.
    pub debug_fail_generator_after: Option<u64>,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            artifact_dir: "artifacts/nano".into(),
            mode: Mode::Async,
            n_generator_workers: 1,
            n_reward_workers: 1,
            n_trainer_workers: 1,
            period_steps: 4,
            queue_capacity: 4,
            scored_capacity: 8,
            store: StoreConfig::default(),
            sync: WeightSyncConfig::default(),
            mem: MemPlaneConfig::default(),
            n_generations: 4,
            baseline: Baseline::GroupMean,
            max_steps: 5,
            aipo: AipoConfig::default(),
            temperature: 1.0,
            top_k: 0,
            quantize_generator: false,
            max_response: 32,
            eval_every: 0,
            eval_max_per_suite: 64,
            checkpoint_every: 0,
            seed: 0,
            out_dir: std::env::temp_dir().join("llamarl_run"),
            init_checkpoint: None,
            trace: None,
            metrics_interval_secs: 0.0,
            journal: true,
            journal_snapshot_secs: 0.25,
            resume: None,
            restart_max: 0,
            restart_backoff_ms: 50,
            chaos_kills: 0,
            chaos_seed: 0,
            chaos_reward_kills: 0,
            elastic_resize: false,
            resize_max_extra: 2,
            debug_fail_generator_after: None,
        }
    }
}

/// Everything a finished run reports (examples and benches consume this).
/// Assembled in exactly one place:
/// [`crate::coordinator::graph::TelemetryHub::finish`].
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    pub mode: String,
    pub steps: u64,
    pub wall_secs: f64,
    pub records: Vec<TrainStepRecord>,
    pub evals: Vec<EvalResult>,
    pub tokens_generated: u64,
    pub trajectories: u64,
    pub chunks: u64,
    pub weight_refreshes: u64,
    /// complete advantage groups the reward fleet emitted downstream
    pub reward_groups: u64,
    /// trajectories the reward fleet scored
    pub reward_rows_scored: u64,
    pub ddma_publishes: u64,
    pub ddma_mean_publish_secs: f64,
    /// mean per-publish time of the slowest shard — the modelled parallel
    /// DDMA cost of the reshard plan (0 when no generator slot is registered)
    pub ddma_mean_shard_max_secs: f64,
    /// total seconds the trainer thread spent blocked inside
    /// `WeightsBus::publish` — with the background executor this is the
    /// enqueue handoff only, inline the whole encode + fan-out
    pub ddma_publish_blocked_secs: f64,
    /// background publishes superseded by a newer version before streaming
    /// (latest-wins coalescing; 0 for the inline plane)
    pub ddma_coalesced_publishes: u64,
    /// total decode-side stall the fenced weight swaps imposed across
    /// generator workers, and how many swaps completed
    pub gen_swap_stall_secs: f64,
    pub gen_swaps: u64,
    pub gen_send_blocked_secs: f64,
    /// seconds the trainer starved on the scored CHANNEL (sync / async
    /// modes; 0 when the trainer samples a store instead)
    pub trainer_recv_blocked_secs: f64,
    /// seconds the trainer waited inside rollout-STORE sampling
    /// (Mode::AsyncBuffered; 0 otherwise) — kept distinct from the channel
    /// field above, which the pre-graph drivers conflated
    pub trainer_sample_wait_secs: f64,
    /// supervised replica restarts absorbed without a global stop
    pub node_restarts: u64,
    /// partial rollouts parked by dying replicas and migrated through the
    /// store's resumption slot to a survivor
    pub partials_migrated: u64,
    /// dynamic generator replicas the fleet controller spawned / retired
    pub fleet_scale_ups: u64,
    pub fleet_scale_downs: u64,
    /// memplane telemetry: bytes the offload executor swapped to host
    /// (D2H) and prefetched back (H2D) across phase flips
    pub offload_d2h_bytes: u64,
    pub offload_h2d_bytes: u64,
    /// total seconds phase leases blocked waiting for residency (the
    /// un-hidden part of the offload stream)
    pub offload_wait_secs: f64,
    /// shard waits the background prefetcher satisfied without blocking
    pub offload_prefetch_hits: u64,
    /// residency targets superseded before the executor converged them
    /// (latest-wins phase flips)
    pub offload_superseded: u64,
    /// rollout-store telemetry (Mode::AsyncBuffered only)
    pub dataplane: Option<DataPlaneSnapshot>,
    pub metrics_path: Option<PathBuf>,
    /// trace events lost to full recorder rings (0 in a healthy traced
    /// run; always 0 untraced) — nonzero prints a warning at run finish
    pub trace_dropped_events: u64,
    /// optimizer step a crash-resumed run continued from (0: fresh run)
    pub resumed_from_step: u64,
}

impl RunReport {
    /// Copy the memory-plane counters out of the executor context (called
    /// once per finished run, after the final flush).
    pub(crate) fn fill_mem_telemetry(&mut self, ctx: &ExecutorContext) {
        use std::sync::atomic::Ordering;
        if let Some(m) = &ctx.mem {
            let mm = m.metrics();
            self.offload_d2h_bytes = mm.d2h_bytes.load(Ordering::Relaxed);
            self.offload_h2d_bytes = mm.h2d_bytes.load(Ordering::Relaxed);
            self.offload_wait_secs = mm.wait_secs();
            self.offload_prefetch_hits = mm.prefetch_hits.load(Ordering::Relaxed);
            self.offload_superseded = mm.superseded_targets.load(Ordering::Relaxed);
        }
    }

    pub fn mean_step_secs(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.wall_secs / self.steps as f64
        }
    }

    pub fn final_reward(&self) -> f64 {
        self.records.last().map(|r| r.reward_mean).unwrap_or(0.0)
    }

    pub fn summary(&self) -> String {
        format!(
            "{} mode: {} steps in {:.1}s ({:.2}s/step), {} trajs, {} tokens, \
             final reward {:.3}, ddma {:.1}ms/publish",
            self.mode,
            self.steps,
            self.wall_secs,
            self.mean_step_secs(),
            self.trajectories,
            self.tokens_generated,
            self.final_reward(),
            self.ddma_mean_publish_secs * 1e3,
        )
    }
}

/// Entry point: resolve the execution graph for `cfg.mode`, build the
/// shared planes, and launch it to completion.
pub fn run_training(cfg: &PipelineConfig) -> Result<RunReport> {
    std::fs::create_dir_all(&cfg.out_dir)?;
    let manifest = Manifest::load(&cfg.artifact_dir)?;
    // Crash-resume: the bus's starting weights come from the recovered
    // packed trainer state (its params prefix), so generators pick up the
    // checkpointed policy, not the random init.
    let resumed_params: Option<Vec<f32>> = cfg
        .resume
        .as_ref()
        .and_then(|r| r.init_state.as_ref())
        .filter(|s| s.len() >= manifest.num_params)
        .map(|s| s[..manifest.num_params].to_vec());
    let init = match (resumed_params, &cfg.init_checkpoint) {
        (Some(params), _) => params,
        (None, None) => load_init_params(&manifest)?,
        (None, Some(path)) => {
            let ckpt = crate::model::load_checkpoint(path)?;
            if ckpt.state.len() != manifest.num_params {
                return Err(Error::Config(format!(
                    "checkpoint {} has {} params, artifacts expect {}",
                    path.display(),
                    ckpt.state.len(),
                    manifest.num_params
                )));
            }
            ckpt.state
        }
    };
    if cfg.mode == Mode::Sync && manifest.config.train_batch % cfg.n_generations != 0 {
        return Err(Error::Config(format!(
            "sync mode requires train_batch ({}) divisible by n_generations ({}) \
             so every step's groups complete",
            manifest.config.train_batch, cfg.n_generations
        )));
    }
    if cfg.n_generations == 0 || cfg.max_steps == 0 {
        return Err(Error::Config("n_generations and max_steps must be > 0".into()));
    }
    // Trainer fleets partition the store by shard slice: replica k owns
    // shards where `shard % n_trainers == k`, so every replica must own
    // at least one shard or it would spin on an empty slice forever.
    if cfg.n_trainer_workers > 1 && cfg.store.shards < cfg.n_trainer_workers {
        return Err(Error::Config(format!(
            "n_trainer_workers ({}) requires store_shards >= trainers (got {})",
            cfg.n_trainer_workers, cfg.store.shards
        )));
    }
    if cfg.mode == Mode::Periodic && cfg.period_steps == 0 {
        return Err(Error::Config("period_steps must be > 0".into()));
    }

    // Resolve the declarative topology FIRST: the planes below derive
    // their mode-dependent behaviour from it (stepped vs free-running).
    // `Graph::launch` validates it before anything is built or spawned.
    let graph = topology(cfg, &manifest);

    // Build the weight-sync plane: FSDP source layout from the configured
    // trainer shard count, TP destination layout split per-tensor via the
    // manifest's param map (falling back to a flat split if the map has
    // gaps), the configured wire encoding, and — by default — the
    // background streaming executor so the trainer's publish is
    // enqueue-and-return.
    let n_params = init.len();
    let src_layout = Layout::fsdp(n_params, cfg.sync.trainer_shards.max(1));
    let g_shards = cfg.sync.generator_shards.max(1);
    let dst_layout = Layout::tp(n_params, g_shards, &manifest.param_layout)
        .unwrap_or_else(|_| Layout::tp_flat(n_params, g_shards));
    let mut bus_opts = BusOptions::new(src_layout, dst_layout);
    bus_opts.encoding = cfg.sync.encoding;
    // The stepped scheduler registers no generator slots (the single
    // thread re-attaches to the master directly), so background workers
    // would wake per publish to stream to nobody — and the enqueue-only
    // blocked-time metric would stop being comparable to the baseline.
    // Force the inline plane there.
    bus_opts.background = cfg.sync.background && !graph.stepped;
    bus_opts.link_groups = cfg.sync.link_groups;
    bus_opts.topk_frac = cfg.sync.topk_frac;
    // crash-resume: version mints continue above the recorded bus front
    bus_opts.initial_version = cfg.resume.as_ref().map(|r| r.bus_version).unwrap_or(0);
    let bus = WeightsBus::with_options(init, bus_opts)?;
    // Build the colocated offloading memory plane: a testbed-scale MemSpec
    // derived from the artifact's parameter count, with `concurrent_phases`
    // following the topology (free-running graphs overlap
    // generate/train/sync on disjoint executors, so nothing may leave the
    // device and the planner must prove the union fits). Infeasible
    // colocations fail HERE, before any executor spawns.
    let mem_cfg = MemPlaneConfig {
        concurrent_phases: !graph.stepped,
        ..cfg.mem.clone()
    };
    let spec = MemSpec::testbed(
        n_params,
        manifest.config.train_batch,
        manifest.config.gen_batch,
    );
    let mem = MemPlane::new(spec, &mem_cfg)?;

    // Open the durable run-journal (on by default). A fresh run starts a
    // new journal whose record 0 is the fully-resolved config; a resumed
    // run APPENDS, continuing the seq stream above the recorded tail so
    // the journal stays a single replayable document across crashes.
    let journal: Option<Arc<JournalWriter>> = if cfg.journal {
        let path = cfg.out_dir.join("journal.jsonl");
        let w = match &cfg.resume {
            Some(r) => JournalWriter::append(&path, r.next_seq)?,
            None => {
                let w = JournalWriter::create(&path)?;
                w.write(&JournalRecord::Meta {
                    config: crate::config::to_json(cfg),
                })?;
                w
            }
        };
        Some(Arc::new(w))
    } else {
        None
    };

    let ctx =
        ExecutorContext::with_journal(bus, Some(mem), cfg.out_dir.clone(), journal.clone());
    if let Some(jw) = &journal {
        // journal every weight-sync version mint (suffix replay advances
        // the bus front past the last snapshot with these)
        let jw = jw.clone();
        ctx.weights.set_mint_hook(Box::new(move |version, publisher| {
            jw.write_infallible(&JournalRecord::Mint { version, publisher });
        }));
    }
    let scheduler = Arc::new(PromptScheduler::new(
        cfg.seed,
        manifest.config.vocab,
        cfg.n_generations,
    )?);
    // crash-resume: replay the prompt stream past what the recorded run
    // consumed, so the resumed run's problems continue the same fixed-seed
    // sequence instead of restarting it
    let prior_trajectories = cfg.resume.as_ref().map(|r| {
        if graph.stepped {
            // stepped mode consumes exactly train_batch prompts per step
            // (exact even when the kill landed between a step record and
            // its progress tick)
            r.start_step * manifest.config.train_batch as u64
        } else {
            r.prior.trajectories
        }
    });
    if let Some(n) = prior_trajectories {
        scheduler.fast_forward(n);
    }
    let metrics_path = cfg.out_dir.join("metrics.jsonl");
    let log = Arc::new(JsonlWriter::create(&metrics_path)?);

    // Arm the tracing plane (opt-in via --trace): the recorder + collector
    // live for exactly the duration of the launch, streaming the JSONL
    // event log incrementally; the Chrome export happens after the graph
    // joins — on the error path too, where a timeline is most useful.
    // When the journal is on, drained events are mirrored into it too.
    let collector = match &cfg.trace {
        Some(_) => Some(Collector::start_with_journal(
            cfg.out_dir.join("trace_events.jsonl"),
            journal.clone(),
        )?),
        None => None,
    };

    let env = LaunchEnv {
        cfg,
        manifest: &manifest,
        ctx,
        scheduler,
        log,
    };
    let launched = graph.launch(&env);
    let mut trace_dropped = 0u64;
    if let Some(c) = collector {
        let exported = c.finish().and_then(|trace_log| {
            trace_dropped = trace_log.dropped;
            match &cfg.trace {
                Some(path) => chrome::export(&trace_log, path),
                None => Ok(()),
            }
        });
        // never mask the run's own error with an export error
        if launched.is_ok() {
            exported?;
        }
    }
    let mut report = launched?;
    report.metrics_path = Some(metrics_path);
    report.trace_dropped_events = trace_dropped;
    if trace_dropped > 0 {
        crate::log_warn!(
            "trace",
            "{trace_dropped} trace events dropped (recorder rings overflowed); \
             the event log and journal are incomplete"
        );
    }
    // Merge the journaled prefix into the resumed run's report so curves
    // and totals describe the WHOLE run, not just the post-crash suffix.
    if let Some(r) = &cfg.resume {
        report.resumed_from_step = r.start_step;
        let mut records = r.prior.records.clone();
        records.extend(std::mem::take(&mut report.records));
        report.records = records;
        report.trajectories += prior_trajectories.unwrap_or(0);
        report.tokens_generated += r.prior.tokens;
        report.chunks += r.prior.chunks;
    }
    if let Some(jw) = &journal {
        jw.write(&JournalRecord::Finish {
            steps: report.steps,
            trajectories: report.trajectories,
        })?;
    }
    Ok(report)
}
