//! Supervised pretraining: produces the "base model" checkpoint RL starts
//! from.
//!
//! The paper post-trains *pretrained* Llama-3.1 models; RL from a random
//! init gets zero reward signal (exact-match over a 60-way vocabulary is
//! never hit by chance). The closest in-repo equivalent is supervised
//! next-token training on (prompt, gold answer) pairs of the same task
//! distribution, which conveniently reuses the AIPO train_step artifact
//! verbatim: with advantage = 1, mask on answer tokens and rho <= 0 (w = 1),
//! the AIPO gradient  -w*A*grad log pi  is exactly the MLE gradient.

use std::path::Path;

use crate::data::TaskGen;
use crate::model::{load_init_params, save_checkpoint, Checkpoint, Tokenizer};
use crate::rl::{pack_batch, FinishReason, Trajectory};
use crate::runtime::{HostTensor, Runtime};
use crate::util::error::Result;

#[derive(Debug, Clone)]
pub struct PretrainConfig {
    pub artifact_dir: std::path::PathBuf,
    pub steps: u64,
    pub lr: f32,
    pub grad_clip: f32,
    pub seed: u64,
    /// report mean target logp every k steps (0 = never)
    pub log_every: u64,
}

impl Default for PretrainConfig {
    fn default() -> Self {
        PretrainConfig {
            artifact_dir: "artifacts/nano".into(),
            steps: 200,
            lr: 1e-3,
            grad_clip: 1.0,
            seed: 7,
            log_every: 25,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct PretrainReport {
    pub steps: u64,
    pub final_target_logp: f64,
    pub wall_secs: f64,
}

/// Build a supervised "trajectory": response = gold answer + EOS, with
/// behaviour logp zeroed (unused at rho <= 0) and advantage 1.
fn supervised_traj(tok: &Tokenizer, gen: &mut TaskGen) -> Result<Trajectory> {
    let p = gen.next();
    let prompt_tokens = tok.encode_prompt(&p.prompt)?;
    let mut response = tok.encode(&p.answer)?;
    response.push(crate::model::EOS_ID);
    let n = response.len();
    Ok(Trajectory {
        group_id: 0,
        replica: 0,
        n_replicas: 1,
        problem: p,
        prompt_tokens,
        response_tokens: response,
        behavior_logp: vec![0.0; n],
        gen_version: 0,
        chunks: 0,
        finish: FinishReason::Eos,
        reward: 1.0,
        advantage: 1.0,
    })
}

/// Run supervised pretraining and write the resulting params checkpoint to
/// `out` (consumed by PipelineConfig::init_checkpoint).
pub fn run_pretraining(cfg: &PretrainConfig, out: impl AsRef<Path>) -> Result<PretrainReport> {
    let t0 = std::time::Instant::now();
    let rt = Runtime::load(&cfg.artifact_dir)?;
    rt.prepare("train_step")?;
    rt.prepare("extract_metrics")?;
    rt.prepare("extract_params")?;
    let mcfg = rt.config().clone();
    let tok = Tokenizer::new(mcfg.vocab)?;
    let mut gen = TaskGen::training_mixture(cfg.seed);

    let init = load_init_params(&rt.manifest)?;
    let total = rt.manifest.train_state.total;
    let mut state_host = init;
    state_host.resize(total, 0.0);
    let mut state = rt.upload(&HostTensor::F32(state_host, vec![total]))?;

    let (b, t) = (mcfg.train_batch, mcfg.train_seq);
    // rho <= 0: AIPO kernel degrades to plain MLE (w = 1)
    let hyp = [cfg.lr, -1.0, cfg.grad_clip];
    let mut last_logp = f64::NAN;

    for step in 0..cfg.steps {
        let rows: Vec<Trajectory> = (0..b)
            .map(|_| supervised_traj(&tok, &mut gen))
            .collect::<Result<_>>()?;
        let batch = pack_batch(&rows, b, t)?;
        let tokens_b = rt.upload(&HostTensor::I32(batch.tokens, vec![b, t]))?;
        let targets_b = rt.upload(&HostTensor::I32(batch.targets, vec![b, t]))?;
        let blogp_b = rt.upload(&HostTensor::F32(batch.blogp, vec![b, t]))?;
        let adv_b = rt.upload(&HostTensor::F32(batch.adv, vec![b, t]))?;
        let mask_b = rt.upload(&HostTensor::F32(batch.mask, vec![b, t]))?;
        let lens_b = rt.upload(&HostTensor::I32(batch.lens, vec![b]))?;
        let hyp_b = rt.upload(&HostTensor::F32(hyp.to_vec(), vec![3]))?;
        state = rt.execute_buffers(
            "train_step",
            &[&state, &tokens_b, &targets_b, &blogp_b, &adv_b, &mask_b, &lens_b, &hyp_b],
        )?;
        if cfg.log_every > 0 && (step + 1) % cfg.log_every == 0 {
            let met_buf = rt.execute_buffers("extract_metrics", &[&state])?;
            let met = rt.fetch_f32(&met_buf)?;
            let idx = rt.manifest.metric_index("target_logp").unwrap();
            last_logp = met[1 + idx] as f64;
            crate::log_info!(
                "pretrain",
                "step {} target_logp {:.3}",
                step + 1,
                last_logp
            );
        }
    }
    // final metrics + checkpoint (bare params via extract_params)
    let met_buf = rt.execute_buffers("extract_metrics", &[&state])?;
    let met = rt.fetch_f32(&met_buf)?;
    if let Some(idx) = rt.manifest.metric_index("target_logp") {
        last_logp = met[1 + idx] as f64;
    }
    let p_buf = rt.execute_buffers("extract_params", &[&state])?;
    let params = rt.fetch_f32(&p_buf)?;
    save_checkpoint(
        &out,
        &Checkpoint {
            step: cfg.steps,
            weights_version: 0,
            state: params,
        },
    )?;
    Ok(PretrainReport {
        steps: cfg.steps,
        final_target_logp: last_logp,
        wall_secs: t0.elapsed().as_secs_f64(),
    })
}
