//! Host-side model state: flat parameter vectors, checkpoints, the
//! char-level tokenizer, and generator-side quantization.

mod checkpoint;
mod params;
mod quant;
mod tokenizer;

pub use checkpoint::{load_checkpoint, save_checkpoint, Checkpoint};
pub use params::{load_init_params, VersionedParams};
pub use quant::{
    dequantize_int8, int8_error_bound, quantize_int8, simulate_int8_roundtrip, QuantizedParams,
};
pub use tokenizer::{Tokenizer, BOS_ID, EOS_ID, PAD_ID};
