//! Char-level tokenizer for the synthetic verifiable-reward tasks.
//!
//! Id conventions (shared with python/compile/configs.py): 0=PAD, 1=BOS,
//! 2=EOS, 3.. = character set. The charset covers the arithmetic task
//! grammar plus enough letters for word-problem templates; it must fit in
//! the smallest config's vocab (nano: 64 -> charset <= 61).

use crate::util::error::{Error, Result};

pub const PAD_ID: i32 = 0;
pub const BOS_ID: i32 = 1;
pub const EOS_ID: i32 = 2;

const CHARSET: &str = "0123456789+-*/=().,? abcdefghijklmnopqrstuvwxyz";

#[derive(Debug, Clone)]
pub struct Tokenizer {
    vocab: usize,
}

impl Tokenizer {
    pub fn new(vocab: usize) -> Result<Tokenizer> {
        if vocab < 3 + CHARSET.chars().count() {
            return Err(Error::Config(format!(
                "vocab {} too small for charset ({} chars + 3 specials)",
                vocab,
                CHARSET.chars().count()
            )));
        }
        Ok(Tokenizer { vocab })
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    pub fn encode_char(c: char) -> Option<i32> {
        CHARSET.find(c).map(|i| i as i32 + 3)
    }

    pub fn decode_char(id: i32) -> Option<char> {
        if id < 3 {
            return None;
        }
        CHARSET.chars().nth((id - 3) as usize)
    }

    /// Encode text (no BOS/EOS added). Errors on out-of-charset chars.
    pub fn encode(&self, text: &str) -> Result<Vec<i32>> {
        text.chars()
            .map(|c| {
                Self::encode_char(c)
                    .ok_or_else(|| Error::Config(format!("char '{c}' not in charset")))
            })
            .collect()
    }

    /// Encode with BOS prefix (the standard prompt form).
    pub fn encode_prompt(&self, text: &str) -> Result<Vec<i32>> {
        let mut out = vec![BOS_ID];
        out.extend(self.encode(text)?);
        Ok(out)
    }

    /// Decode ids, stopping at EOS, skipping PAD/BOS.
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut out = String::new();
        for &id in ids {
            if id == EOS_ID {
                break;
            }
            if let Some(c) = Self::decode_char(id) {
                out.push(c);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let tok = Tokenizer::new(64).unwrap();
        let text = "12+34=46";
        let ids = tok.encode(text).unwrap();
        assert_eq!(tok.decode(&ids), text);
    }

    #[test]
    fn decode_stops_at_eos() {
        let tok = Tokenizer::new(64).unwrap();
        let mut ids = tok.encode("9*9=81").unwrap();
        ids.push(EOS_ID);
        ids.extend(tok.encode("junk").unwrap());
        assert_eq!(tok.decode(&ids), "9*9=81");
    }

    #[test]
    fn prompt_has_bos() {
        let tok = Tokenizer::new(64).unwrap();
        let ids = tok.encode_prompt("1+1=").unwrap();
        assert_eq!(ids[0], BOS_ID);
    }

    #[test]
    fn rejects_unknown_char() {
        let tok = Tokenizer::new(64).unwrap();
        assert!(tok.encode("日").is_err());
    }

    #[test]
    fn vocab_guard() {
        assert!(Tokenizer::new(16).is_err());
    }
}
