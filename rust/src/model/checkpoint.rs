//! Checkpoint save/load: raw little-endian f32 train state plus a JSON
//! sidecar with step/version metadata (paper: each executor checkpoints
//! independently under controller triggers).

use std::path::Path;

use crate::model::params::{bytes_to_f32, f32_to_bytes};
use crate::util::error::{Error, Result};
use crate::util::json::Value;

#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub step: u64,
    pub weights_version: u64,
    /// packed train state [params | m | v | step | metrics] or bare params
    pub state: Vec<f32>,
}

pub fn save_checkpoint(dir: impl AsRef<Path>, ckpt: &Checkpoint) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join("state.bin"), f32_to_bytes(&ckpt.state))?;
    let meta = Value::object(vec![
        ("step", Value::num(ckpt.step as f64)),
        ("weights_version", Value::num(ckpt.weights_version as f64)),
        ("state_len", Value::num(ckpt.state.len() as f64)),
    ]);
    std::fs::write(dir.join("meta.json"), meta.to_string())?;
    Ok(())
}

pub fn load_checkpoint(dir: impl AsRef<Path>) -> Result<Checkpoint> {
    let dir = dir.as_ref();
    let meta = Value::parse(&std::fs::read_to_string(dir.join("meta.json"))?)?;
    let state = bytes_to_f32(&std::fs::read(dir.join("state.bin"))?);
    let expect = meta.req_usize("state_len")?;
    if state.len() != expect {
        return Err(Error::Manifest(format!(
            "checkpoint state length {} != recorded {}",
            state.len(),
            expect
        )));
    }
    Ok(Checkpoint {
        step: meta.req_f64("step")? as u64,
        weights_version: meta.req_f64("weights_version")? as u64,
        state,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("llamarl_ckpt_test");
        let ckpt = Checkpoint {
            step: 42,
            weights_version: 7,
            state: vec![1.0, -2.5, 3.75],
        };
        save_checkpoint(&dir, &ckpt).unwrap();
        let back = load_checkpoint(&dir).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.weights_version, 7);
        assert_eq!(back.state, ckpt.state);
    }
}
