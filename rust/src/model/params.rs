//! Flat parameter vectors and weight versioning.
//!
//! All model parameters live in one `f32[P]` buffer whose layout is described
//! by the artifact manifest — this is what makes DDMA weight synchronization
//! a single sharded buffer handoff (paper §5.2) instead of a per-tensor walk.

use std::sync::Arc;

use crate::runtime::Manifest;
use crate::util::error::{Error, Result};

/// A published snapshot of policy weights. `version` is the trainer step that
/// produced it; trajectories record the version they were sampled under so
/// off-policy lag is always measurable (paper Fig. 2: 1..n steps of delay).
#[derive(Debug, Clone)]
pub struct VersionedParams {
    pub version: u64,
    pub data: Arc<Vec<f32>>,
}

impl VersionedParams {
    pub fn new(version: u64, data: Vec<f32>) -> Self {
        VersionedParams {
            version,
            data: Arc::new(data),
        }
    }
}

/// Read the initial checkpoint emitted by `python -m compile.aot`
/// (raw little-endian f32), validating length against the manifest.
pub fn load_init_params(manifest: &Manifest) -> Result<Vec<f32>> {
    let path = manifest.init_params_path();
    let bytes = std::fs::read(&path).map_err(|e| {
        Error::Manifest(format!("cannot read {}: {e}", path.display()))
    })?;
    if bytes.len() != manifest.num_params * 4 {
        return Err(Error::Manifest(format!(
            "init checkpoint has {} bytes, expected {} (P={})",
            bytes.len(),
            manifest.num_params * 4,
            manifest.num_params
        )));
    }
    Ok(bytes_to_f32(&bytes))
}

pub(crate) fn bytes_to_f32(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

pub(crate) fn f32_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_bytes_roundtrip() {
        let xs = vec![0.0f32, -1.5, 3.25e7, f32::MIN_POSITIVE];
        assert_eq!(bytes_to_f32(&f32_to_bytes(&xs)), xs);
    }
}
