//! Generator-side weight quantization.
//!
//! The paper runs the 405B generator in fp8 to halve its memory and allow a
//! smaller model-parallel degree (§4.3, Table 3). On this CPU testbed we
//! implement int8 symmetric per-tensor quantization for real: the trainer
//! publishes f32 weights, the generator optionally quantize-dequantizes them
//! before upload. This exercises the same off-policy source (the behaviour
//! policy mu is a *quantized* snapshot of pi, so pi/mu != 1 even at zero
//! lag) that AIPO's correction must absorb — see examples/offpolicy_ablation.
//! Cluster-scale fp8 effects (smaller W0 -> smaller admissible mp) are
//! modelled in [`crate::simulator`].

use crate::runtime::ParamEntry;

#[derive(Debug, Clone)]
pub struct QuantizedParams {
    pub data: Vec<i8>,
    /// one scale per param-layout entry (per-tensor symmetric)
    pub scales: Vec<f32>,
}

/// Quantize a flat f32 param vector per-tensor to int8.
pub fn quantize_int8(params: &[f32], layout: &[ParamEntry]) -> QuantizedParams {
    let mut data = vec![0i8; params.len()];
    let mut scales = Vec::with_capacity(layout.len());
    for (i, entry) in layout.iter().enumerate() {
        let start = entry.offset;
        let len: usize = entry.shape.iter().product();
        let end = start + len;
        let chunk = &params[start..end];
        let maxabs = chunk.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        let scale = if maxabs > 0.0 { maxabs / 127.0 } else { 1.0 };
        scales.push(scale);
        for (dst, x) in data[start..end].iter_mut().zip(chunk) {
            *dst = (x / scale).round().clamp(-127.0, 127.0) as i8;
        }
        debug_assert_eq!(scales.len(), i + 1);
    }
    QuantizedParams { data, scales }
}

pub fn dequantize_int8(q: &QuantizedParams, layout: &[ParamEntry]) -> Vec<f32> {
    let mut out = vec![0f32; q.data.len()];
    for (entry, scale) in layout.iter().zip(&q.scales) {
        let start = entry.offset;
        let len: usize = entry.shape.iter().product();
        for (dst, x) in out[start..start + len].iter_mut().zip(&q.data[start..start + len]) {
            *dst = *x as f32 * scale;
        }
    }
    out
}

/// Quantize-dequantize round trip: what the generator actually loads when
/// `quantize_generator` is enabled.
pub fn simulate_int8_roundtrip(params: &[f32], layout: &[ParamEntry]) -> Vec<f32> {
    dequantize_int8(&quantize_int8(params, layout), layout)
}

/// Worst-case absolute round-trip error for a tensor whose max |x| is
/// `maxabs`: half a quantization step (`scale / 2`, since `round` is
/// nearest), padded for the f32 rounding incurred by the divide/multiply
/// pair. The property test `int8_roundtrip_error_within_bound` exercises
/// this across scales, and the weight-sync quantized transfer path
/// ([`crate::weightsync::transfer::run_transfer`]) measures against it on
/// every plan it executes.
pub fn int8_error_bound(maxabs: f32) -> f32 {
    let scale = if maxabs > 0.0 { maxabs / 127.0 } else { 1.0 };
    0.5 * scale * (1.0 + 1e-4) + f32::EPSILON
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout(sizes: &[usize]) -> Vec<ParamEntry> {
        let mut out = Vec::new();
        let mut off = 0;
        for (i, s) in sizes.iter().enumerate() {
            out.push(ParamEntry {
                name: format!("p{i}"),
                shape: vec![*s],
                offset: off,
            });
            off += s;
        }
        out
    }

    #[test]
    fn roundtrip_error_is_bounded() {
        let lay = layout(&[64, 32]);
        let params: Vec<f32> = (0..96).map(|i| (i as f32 - 48.0) * 0.01).collect();
        let rt = simulate_int8_roundtrip(&params, &lay);
        let max_per_tensor = 0.48f32; // maxabs of first tensor
        for (a, b) in params.iter().zip(&rt) {
            assert!((a - b).abs() <= max_per_tensor / 127.0 + 1e-6);
        }
    }

    #[test]
    fn zero_tensor_is_exact() {
        let lay = layout(&[8]);
        let params = vec![0.0f32; 8];
        assert_eq!(simulate_int8_roundtrip(&params, &lay), params);
    }

    #[test]
    fn quantization_changes_values() {
        let lay = layout(&[100]);
        let params: Vec<f32> = (0..100).map(|i| (i as f32 * 0.7).sin()).collect();
        let rt = simulate_int8_roundtrip(&params, &lay);
        assert_ne!(params, rt, "int8 roundtrip should not be exact");
    }

    #[test]
    fn int8_roundtrip_error_within_bound() {
        // Property: quantize -> dequantize error stays within
        // int8_error_bound per tensor, across tensor counts, sizes, and
        // twelve decades of scale — the acceptance check the weight-sync
        // quantized transfer path leans on.
        crate::util::prop::run_prop("int8_roundtrip_bound", 200, |g| {
            let n_tensors = g.usize(1, 5);
            let sizes: Vec<usize> = (0..n_tensors).map(|_| g.size(0, 200)).collect();
            let lay = layout(&sizes);
            let total: usize = sizes.iter().sum();
            let mut params = Vec::with_capacity(total);
            for &s in &sizes {
                // per-tensor scale spanning twelve decades
                let mag = 10f64.powf(g.f64(-6.0, 6.0)) as f32;
                for _ in 0..s {
                    params.push((g.f64(-1.0, 1.0) as f32) * mag);
                }
            }
            let rt = simulate_int8_roundtrip(&params, &lay);
            for (entry, _) in lay.iter().zip(&sizes) {
                let len: usize = entry.shape.iter().product();
                let chunk = &params[entry.offset..entry.offset + len];
                let maxabs = chunk.iter().fold(0.0f32, |m, x| m.max(x.abs()));
                let bound = int8_error_bound(maxabs);
                for (a, b) in chunk.iter().zip(&rt[entry.offset..entry.offset + len]) {
                    assert!(
                        (a - b).abs() <= bound,
                        "err {} > bound {bound} (maxabs {maxabs})",
                        (a - b).abs()
                    );
                }
            }
        });
    }
}
