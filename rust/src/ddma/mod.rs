//! Distributed Direct Memory Access (DDMA) weight synchronization
//! (paper §5.2).
//!
//! The paper's DDMA replaces the parameter-server pattern with fully
//! distributed zero-copy GPU-to-GPU shard transfers over NVLink/IB, updating
//! terabyte-scale weights in ~2 s (Table 4). In this single-host testbed the
//! *protocol* is real and the *links* are modelled. Since the weight-sync
//! plane landed, this module is a **facade over [`crate::weightsync`]**:
//!
//! * [`WeightsBus`] — the in-process DDMA path. A publish executes the
//!   resharding plan between the trainer-side FSDP layout and the
//!   generator-side TP layout ([`crate::weightsync::plan_reshard`]):
//!   per-shard [`crate::weightsync::ShardPacket`]s (f32 / int8 / delta /
//!   top-k / density-adaptive auto) stream into every registered
//!   generator's double-buffered
//!   [`crate::weightsync::GeneratorSlot`], where decode keeps running on
//!   version N until the fenced swap at a sequence boundary. With
//!   [`BusOptions::background`] the fan-out runs on the
//!   [`crate::weightsync::StreamExecutor`]'s per-link-group worker threads
//!   and `publish` is **enqueue-and-return** — the trainer-side blocked
//!   time collapses to the version mint (tracked separately as
//!   [`WeightsBus::publish_blocked_secs`]); inline mode (the baseline the
//!   bench compares against) pays the whole encode + fan-out on the
//!   publisher's thread. The bus also keeps a master snapshot slot (always
//!   exact f32, swapped inline in both modes) so `latest()` / `wait_for()`
//!   serve non-streaming readers (trainer init, evaluator, sync mode)
//!   exactly as before. Versions are monotonic and minted under one lock
//!   even with multiple registered publishers
//!   ([`WeightsBus::register_publisher`]), so `wait_for` observers see a
//!   single total order; every trajectory records the version it sampled
//!   under, so off-policy lag is always measurable.
//! * [`ShardedCopy`] — the sharded memcpy the trainer performs to produce a
//!   publishable snapshot (the analogue of each GPU pushing only its own
//!   shard; real measured bandwidth feeds Table 4's "measured" column).
//! * [`topology`] — NVLink/IB link model producing cluster-scale DDMA
//!   timings for the paper's 8B/70B/405B rows, including the cost of a
//!   planner schedule ([`topology::DdmaModel::plan_secs`]).
//! * [`ps_baseline`] — the parameter-server + weight-reload cost model
//!   calibrated to OpenRLHF's published numbers (Table 4 comparison).

pub mod ps_baseline;
pub mod topology;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, RwLock};
use std::time::Instant;

use crate::model::VersionedParams;
use crate::trace;
use crate::util::error::Result;
use crate::weightsync::executor::{begin_on, fan_out_op, PublishJob};
use crate::weightsync::{
    plan_reshard, GeneratorSlot, Layout, ReshardPlan, ShardEncoding, StreamExecutor, SyncMetrics,
};

/// Construction options for [`WeightsBus::with_options`].
#[derive(Debug, Clone)]
pub struct BusOptions {
    /// trainer-side source layout
    pub src: Layout,
    /// generator-side destination layout
    pub dst: Layout,
    /// wire encoding for shard payloads
    pub encoding: ShardEncoding,
    /// spawn the background streaming executor: `publish` becomes
    /// enqueue-and-return, per-link-group worker threads drain the fan-out
    pub background: bool,
    /// link-group worker threads (0 = one per destination rank)
    pub link_groups: usize,
    /// per-shard kept fraction for [`ShardEncoding::TopK`]
    pub topk_frac: f64,
    /// version the initial snapshot carries and the mint counter continues
    /// from (crash-resume restores the recorded bus front here; 0 for a
    /// fresh run)
    pub initial_version: u64,
}

impl BusOptions {
    pub fn new(src: Layout, dst: Layout) -> BusOptions {
        BusOptions {
            src,
            dst,
            encoding: ShardEncoding::F32,
            background: false,
            link_groups: 0,
            topk_frac: 0.01,
            initial_version: 0,
        }
    }
}

/// The in-process DDMA weights path between trainer and generators: a facade
/// over the sharded weight-sync plane.
pub struct WeightsBus {
    plan: ReshardPlan,
    encoding: ShardEncoding,
    topk_frac: f64,
    /// master snapshot (always exact f32) for non-streaming readers
    slot: RwLock<Arc<VersionedParams>>,
    /// per-generator double-buffered receive slots (shared with the
    /// background executor's workers)
    subscribers: Arc<Mutex<Vec<Arc<GeneratorSlot>>>>,
    version: AtomicU64,
    /// publisher-blocked time, fan-out timing, bytes, coalescing/fence
    /// counters — shared with the executor when one is running
    metrics: Arc<SyncMetrics>,
    /// per-publisher publish counts; index = publisher id (0 pre-registered)
    publishers: Mutex<Vec<u64>>,
    /// the background streaming plane (None = inline fan-out on the
    /// publisher's thread)
    executor: Option<StreamExecutor>,
    /// serializes publishers (and slot/publisher registration) across the
    /// whole mint/stream/swap sequence, so the notify lock below is only
    /// ever held for the microsecond counter-update + wakeup
    publish_lock: Mutex<()>,
    notify: (Mutex<u64>, Condvar),
    /// run-journal hook: called with (version, publisher) after every mint
    /// (under the publish lock, after the version store — so journal mint
    /// order is version order)
    mint_hook: OnceLock<Box<dyn Fn(u64, usize) + Send + Sync>>,
}

impl WeightsBus {
    /// Create the bus with version-0 initial weights and the trivial
    /// single-shard plan (monolithic behaviour).
    pub fn new(init: Vec<f32>) -> WeightsBus {
        let n = init.len();
        WeightsBus::with_layouts(
            init,
            Layout::fsdp(n, 1),
            Layout::tp_flat(n, 1),
            ShardEncoding::F32,
        )
        .expect("single-shard layouts are always valid")
    }

    /// Create the bus over an explicit trainer-side source layout,
    /// generator-side destination layout, and shard encoding, with the
    /// inline fan-out (the pre-executor baseline). The resharding plan is
    /// computed once and reused by every publish.
    pub fn with_layouts(
        init: Vec<f32>,
        src: Layout,
        dst: Layout,
        encoding: ShardEncoding,
    ) -> Result<WeightsBus> {
        let mut opts = BusOptions::new(src, dst);
        opts.encoding = encoding;
        WeightsBus::with_options(init, opts)
    }

    /// Full constructor: layouts, encoding, and (optionally) the background
    /// streaming executor with its link-group thread count.
    pub fn with_options(init: Vec<f32>, opts: BusOptions) -> Result<WeightsBus> {
        let plan = plan_reshard(&opts.src, &opts.dst)?;
        let subscribers: Arc<Mutex<Vec<Arc<GeneratorSlot>>>> = Arc::new(Mutex::new(Vec::new()));
        let metrics = Arc::new(SyncMetrics::default());
        let executor = if opts.background {
            Some(StreamExecutor::spawn(
                &plan,
                opts.link_groups,
                opts.encoding,
                opts.topk_frac,
                subscribers.clone(),
                metrics.clone(),
            ))
        } else {
            None
        };
        Ok(WeightsBus {
            plan,
            encoding: opts.encoding,
            topk_frac: opts.topk_frac,
            slot: RwLock::new(Arc::new(VersionedParams::new(opts.initial_version, init))),
            subscribers,
            version: AtomicU64::new(opts.initial_version),
            metrics,
            publishers: Mutex::new(vec![0]),
            executor,
            publish_lock: Mutex::new(()),
            notify: (Mutex::new(opts.initial_version), Condvar::new()),
            mint_hook: OnceLock::new(),
        })
    }

    /// Install the run-journal mint hook (once; later calls are ignored).
    pub fn set_mint_hook(&self, hook: Box<dyn Fn(u64, usize) + Send + Sync>) {
        let _ = self.mint_hook.set(hook);
    }

    /// Register an additional trainer-side publisher sharing this bus's
    /// precomputed plan; returns its publisher id for
    /// [`WeightsBus::publish_from`]. Publisher 0 is pre-registered.
    /// Versions stay globally ordered: every publish, whichever publisher
    /// issues it, mints under the same lock.
    pub fn register_publisher(&self) -> usize {
        let _serial = self.publish_lock.lock().unwrap();
        let mut counts = self.publishers.lock().unwrap();
        counts.push(0);
        counts.len() - 1
    }

    /// Register a generator's double-buffered receive slot. Its front starts
    /// at the current master version; every later publish streams into its
    /// staging buffer, and the generator promotes it with
    /// [`GeneratorSlot::swap_at_boundary`] at its own sequence boundary.
    pub fn register_generator(&self) -> Arc<GeneratorSlot> {
        // Serialize against publishes: without this, a slot created while
        // an inline publish streams could seed its front from the
        // not-yet-swapped master AND miss the streaming version's packets,
        // leaving it one version stale until the next publish. (Background
        // workers racing this registration are safe on their own: the slot
        // seeds from the already-swapped master, and GeneratorSlot::begin
        // refuses versions at or below that front.)
        let _serial = self.publish_lock.lock().unwrap();
        let slot = GeneratorSlot::new(self.latest());
        self.subscribers.lock().unwrap().push(slot.clone());
        slot
    }

    /// Publish a new weight snapshot as publisher 0; returns its version.
    pub fn publish(&self, data: Vec<f32>) -> u64 {
        self.publish_from(0, data)
    }

    /// Publish a new weight snapshot from a registered publisher; returns
    /// its (globally ordered) version.
    ///
    /// Ordering contract (regression test
    /// `version_never_ahead_of_latest_snapshot`): the version counter is
    /// minted under the publish lock and stored only *after* the master
    /// slot swap, so an observer that reads `version() == N` is guaranteed
    /// `latest().version >= N`. Readers never observe a partial update
    /// (test: `prop_coordinator::weights_bus_snapshots_are_consistent`).
    ///
    /// With the background executor this is **enqueue-and-return**: the
    /// publisher blocks only for the mint + master swap + queue handoff;
    /// the per-slot fan-out happens on the link-group workers (latest-wins
    /// — a version still queued when a newer one lands is superseded).
    /// Inline, the whole fan-out runs here. Either way the time spent in
    /// this call is what [`WeightsBus::publish_blocked_secs`] accounts.
    pub fn publish_from(&self, publisher: usize, data: Vec<f32>) -> u64 {
        let t0 = Instant::now();
        // Validate the publisher id BEFORE taking any bus lock or minting:
        // a bad id must not leave a phantom publish behind, and panicking
        // while holding the publish/publishers locks would poison the whole
        // bus. Ids are never removed, so this check cannot go stale.
        assert!(
            publisher < self.publishers.lock().unwrap().len(),
            "publisher {publisher} not registered"
        );
        // The publish lock serializes publishers across the whole
        // mint/stream/swap sequence; the notify mutex is touched only at
        // the very end, so `wait_for` callers are never stuck behind the
        // encode/fan-out work.
        let _serial = self.publish_lock.lock().unwrap();
        let version = self.version.load(Ordering::SeqCst) + 1;
        trace::instant(trace::VERSION_MINT, version as f64);
        // publish_block: how long THIS thread is stuck inside publish —
        // enqueue-only with the executor, the whole fan-out inline
        let _block_span = trace::span_with(trace::PUBLISH_BLOCK, version as f64);
        // the previous master snapshot is the delta base
        let base = self.latest();
        let snap = Arc::new(VersionedParams::new(version, data));

        match &self.executor {
            Some(exec) => {
                // Master slot swap strictly before the version-counter
                // bump, then hand the fan-out to the link-group workers.
                *self.slot.write().unwrap() = snap.clone();
                self.version.store(version, Ordering::SeqCst);
                exec.enqueue(PublishJob {
                    params: snap,
                    base: if self.encoding.is_delta() {
                        Some(base)
                    } else {
                        None
                    },
                    publisher,
                });
            }
            None => {
                // Inline fan-out: stream the resharding plan into every
                // generator slot while their decode loops keep reading the
                // front buffer.
                let subs = self.subscribers.lock().unwrap().clone();
                if !subs.is_empty() {
                    let _sync_span = trace::span_with(trace::WEIGHT_SYNC, version as f64);
                    begin_on(&subs, version, self.plan.ops.len(), self.encoding.is_delta());
                    let delta_base = if self.encoding.is_delta() {
                        Some(base.as_ref())
                    } else {
                        None
                    };
                    let mut max_op = 0f64;
                    let mut bytes = 0usize;
                    for &op in &self.plan.ops {
                        let t_op = Instant::now();
                        bytes += fan_out_op(
                            &snap.data,
                            delta_base,
                            version,
                            op,
                            self.encoding,
                            self.topk_frac,
                            &subs,
                            &self.metrics,
                        );
                        max_op = max_op.max(t_op.elapsed().as_secs_f64());
                    }
                    self.metrics
                        .shard_max_nanos
                        .fetch_add((max_op * 1e9) as u64, Ordering::Relaxed);
                    self.metrics.shard_max_samples.fetch_add(1, Ordering::Relaxed);
                    self.metrics
                        .bytes_streamed
                        .fetch_add(bytes as u64, Ordering::Relaxed);
                }
                // Master slot swap strictly before the version-counter bump.
                *self.slot.write().unwrap() = snap;
                self.version.store(version, Ordering::SeqCst);
            }
        }

        if let Some(hook) = self.mint_hook.get() {
            hook(version, publisher);
        }
        self.publishers.lock().unwrap()[publisher] += 1;
        self.metrics.publishes.fetch_add(1, Ordering::Relaxed);
        self.metrics
            .publish_blocked_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let (lock, cvar) = &self.notify;
        *lock.lock().unwrap() = version;
        cvar.notify_all();
        version
    }

    /// Block until every enqueued background publish has streamed into the
    /// registered slots (no-op for an inline bus). Benches and shutdown
    /// paths use this; generators just keep decoding.
    pub fn flush(&self) {
        if let Some(exec) = &self.executor {
            exec.flush();
        }
    }

    /// Whether the background streaming executor is running.
    pub fn is_background(&self) -> bool {
        self.executor.is_some()
    }

    /// Zero-copy attach to the latest master snapshot.
    pub fn latest(&self) -> Arc<VersionedParams> {
        self.slot.read().unwrap().clone()
    }

    pub fn version(&self) -> u64 {
        self.version.load(Ordering::SeqCst)
    }

    /// Block until `version() >= min_version` (used by the evaluator).
    pub fn wait_for(&self, min_version: u64) -> Arc<VersionedParams> {
        let (lock, cvar) = &self.notify;
        let mut v = lock.lock().unwrap();
        while *v < min_version {
            v = cvar.wait(v).unwrap();
        }
        drop(v);
        self.latest()
    }

    pub fn publish_count(&self) -> u64 {
        self.metrics.publishes.load(Ordering::Relaxed)
    }

    /// Publishes issued by one registered publisher.
    pub fn publisher_publishes(&self, publisher: usize) -> u64 {
        self.publishers
            .lock()
            .unwrap()
            .get(publisher)
            .copied()
            .unwrap_or(0)
    }

    /// Registered publishers (>= 1; publisher 0 is built in).
    pub fn publisher_count(&self) -> usize {
        self.publishers.lock().unwrap().len()
    }

    /// Mean seconds a publisher spends blocked inside `publish` — the
    /// trainer-side DDMA handoff cost. Background mode: mint + enqueue;
    /// inline: the whole encode + fan-out.
    pub fn mean_publish_secs(&self) -> f64 {
        self.metrics.mean_publish_blocked_secs()
    }

    /// Total publisher-blocked seconds across all publishes (the quantity
    /// the background executor exists to minimize; reported as
    /// `publish_blocked_secs` in `BENCH_weightsync.json`).
    pub fn publish_blocked_secs(&self) -> f64 {
        self.metrics.publish_blocked_secs()
    }

    /// Mean slowest-shard time per sampled stream job — what a publish
    /// costs when shards move in parallel (cluster DDMA time). Inline: one
    /// sample per publish with subscribers; background: one per link-group
    /// job.
    pub fn mean_shard_max_secs(&self) -> f64 {
        self.metrics.mean_shard_max_secs()
    }

    /// Payload bytes streamed to generator slots so far (int8 shows up as
    /// a ~4x reduction, sparse deltas as orders of magnitude under low
    /// update density).
    pub fn bytes_streamed(&self) -> u64 {
        self.metrics.bytes_streamed.load(Ordering::Relaxed)
    }

    /// Background publishes superseded in a link-group queue before they
    /// streamed (latest-wins coalescing).
    pub fn coalesced_publishes(&self) -> u64 {
        self.metrics.coalesced_jobs.load(Ordering::Relaxed)
    }

    /// Delta packets the base-version fence rejected and the plane re-sent
    /// as full f32.
    pub fn delta_full_resends(&self) -> u64 {
        self.metrics.delta_full_resends.load(Ordering::Relaxed)
    }

    /// Mean measured update density across adaptive-encoding ops
    /// (`sync_encoding=auto`; 0.0 when the plane never measured one). The
    /// full-vs-delta pick counts live in [`SyncMetrics::auto_full_ops`] /
    /// [`SyncMetrics::auto_delta_ops`] via [`WeightsBus::metrics`].
    pub fn mean_update_density(&self) -> f64 {
        self.metrics.mean_update_density()
    }

    /// The shared counter block (bus + executor sides).
    pub fn metrics(&self) -> &SyncMetrics {
        &self.metrics
    }

    /// The resharding schedule every publish executes.
    pub fn plan(&self) -> &ReshardPlan {
        &self.plan
    }

    pub fn subscriber_count(&self) -> usize {
        self.subscribers.lock().unwrap().len()
    }

    /// Front version of every registered generator slot — the fence
    /// positions the run-journal folds into its snapshot records.
    pub fn subscriber_fronts(&self) -> Vec<u64> {
        self.subscribers
            .lock()
            .unwrap()
            .iter()
            .map(|s| s.front_version())
            .collect()
    }
}

/// The sharded snapshot copy: every "rank" copies only its own contiguous
/// shard (paper: each GPU stores/updates its assigned shards). Returns the
/// copy, per-shard timings, and the chunk size used.
pub struct ShardedCopy {
    pub data: Vec<f32>,
    pub shard_secs: Vec<f64>,
    /// elements per shard (last shard may be smaller)
    pub chunk: usize,
}

pub fn sharded_copy(src: &[f32], n_shards: usize) -> ShardedCopy {
    assert!(n_shards > 0);
    let mut data = vec![0f32; src.len()];
    let mut shard_secs = Vec::with_capacity(n_shards);
    let chunk = src.len().div_ceil(n_shards).max(1);
    // NOTE: shards copy sequentially here (one core); the *per-shard* time is
    // what scales to the cluster model, where shards move in parallel and
    // DDMA time = max(shard time) — see topology::ddma_sync_time.
    for (dst_chunk, src_chunk) in data.chunks_mut(chunk).zip(src.chunks(chunk)) {
        let t0 = Instant::now();
        dst_chunk.copy_from_slice(src_chunk);
        shard_secs.push(t0.elapsed().as_secs_f64());
    }
    ShardedCopy {
        data,
        shard_secs,
        chunk,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn versions_monotonic() {
        let bus = WeightsBus::new(vec![0.0; 8]);
        assert_eq!(bus.version(), 0);
        let v1 = bus.publish(vec![1.0; 8]);
        let v2 = bus.publish(vec![2.0; 8]);
        assert_eq!((v1, v2), (1, 2));
        assert_eq!(bus.latest().version, 2);
        assert_eq!(bus.latest().data[0], 2.0);
    }

    #[test]
    fn wait_for_unblocks() {
        let bus = Arc::new(WeightsBus::new(vec![0.0; 4]));
        let b2 = bus.clone();
        let t = std::thread::spawn(move || b2.wait_for(1).version);
        std::thread::sleep(std::time::Duration::from_millis(20));
        bus.publish(vec![1.0; 4]);
        assert_eq!(t.join().unwrap(), 1);
    }

    #[test]
    fn version_never_ahead_of_latest_snapshot() {
        // Regression (publish version/notify race): minting the version
        // before the slot swap let a reader observe version() == N while
        // latest() still returned N-1. The fixed ordering stores the
        // counter only after the swap, so this invariant holds under a
        // racing publisher.
        let bus = Arc::new(WeightsBus::new(vec![0.0; 256]));
        let writer = {
            let bus = bus.clone();
            std::thread::spawn(move || {
                for v in 1..=300u64 {
                    bus.publish(vec![v as f32; 256]);
                }
            })
        };
        loop {
            let observed = bus.version();
            let snap = bus.latest();
            assert!(
                snap.version >= observed,
                "latest() at {} behind observed version() {}",
                snap.version,
                observed
            );
            if observed >= 300 {
                break;
            }
        }
        writer.join().unwrap();
    }

    #[test]
    fn registered_slot_receives_fenced_versions() {
        let n = 64;
        let bus = WeightsBus::with_layouts(
            vec![0.0; n],
            Layout::fsdp(n, 4),
            Layout::tp_flat(n, 2),
            ShardEncoding::F32,
        )
        .unwrap();
        let slot = bus.register_generator();
        assert_eq!(slot.front_version(), 0);
        assert!(slot.swap_at_boundary().is_none(), "nothing staged yet");

        bus.publish(vec![1.5; n]);
        // decode still on version 0 until the generator swaps
        assert_eq!(slot.front_version(), 0);
        let snap = slot.swap_at_boundary().expect("complete staging");
        assert_eq!(snap.version, 1);
        assert!(snap.data.iter().all(|x| *x == 1.5));
        assert!(bus.bytes_streamed() > 0);
        assert!(bus.mean_shard_max_secs() >= 0.0);
    }

    #[test]
    fn quantized_bus_streams_fewer_bytes_within_bound() {
        let n = 1000;
        let init: Vec<f32> = (0..n).map(|i| (i as f32 * 0.01).sin()).collect();
        let next: Vec<f32> = (0..n).map(|i| (i as f32 * 0.013).cos()).collect();
        let mk = |enc| {
            WeightsBus::with_layouts(
                init.clone(),
                Layout::fsdp(n, 4),
                Layout::tp_flat(n, 4),
                enc,
            )
            .unwrap()
        };
        let f32_bus = mk(ShardEncoding::F32);
        let q_bus = mk(ShardEncoding::Int8);
        let f32_slot = f32_bus.register_generator();
        let q_slot = q_bus.register_generator();
        f32_bus.publish(next.clone());
        q_bus.publish(next.clone());
        let exact = f32_slot.swap_at_boundary().unwrap();
        let quant = q_slot.swap_at_boundary().unwrap();
        assert_eq!(*exact.data, next);
        assert!(q_bus.bytes_streamed() * 3 < f32_bus.bytes_streamed());
        let maxabs = next.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        let bound = crate::model::int8_error_bound(maxabs);
        for (a, b) in next.iter().zip(quant.data.iter()) {
            assert!((a - b).abs() <= bound);
        }
        // the master slot stays exact even on a quantized bus
        assert_eq!(*q_bus.latest().data, next);
    }

    fn background_opts(n: usize, encoding: ShardEncoding) -> BusOptions {
        let mut opts = BusOptions::new(Layout::fsdp(n, 4), Layout::tp_flat(n, 2));
        opts.encoding = encoding;
        opts.background = true;
        opts
    }

    #[test]
    fn background_publish_converges_after_flush() {
        let n = 512;
        let bus =
            WeightsBus::with_options(vec![0.0; n], background_opts(n, ShardEncoding::F32))
                .unwrap();
        assert!(bus.is_background());
        let slot = bus.register_generator();
        for v in 1..=25u64 {
            let got = bus.publish(vec![v as f32; n]);
            assert_eq!(got, v);
            // master snapshot is current immediately, before any stream
            assert_eq!(bus.latest().version, v);
        }
        bus.flush();
        let snap = slot.swap_at_boundary().expect("latest version staged");
        assert_eq!(snap.version, 25, "slot must converge to the max version");
        assert!(snap.data.iter().all(|x| *x == 25.0));
        assert_eq!(bus.publish_count(), 25);
        assert!(bus.publish_blocked_secs() >= 0.0);
    }

    #[test]
    fn background_delta_bus_reconstructs_bit_exactly() {
        let n = 600;
        let init: Vec<f32> = (0..n).map(|i| (i as f32 * 0.017).sin()).collect();
        let bus = WeightsBus::with_options(init.clone(), background_opts(n, ShardEncoding::Delta))
            .unwrap();
        let slot = bus.register_generator();
        let mut cur = init;
        for v in 1..=10u64 {
            cur[(v as usize * 53) % n] += 0.5; // sparse update
            bus.publish(cur.clone());
            bus.flush();
            if v % 2 == 0 {
                slot.swap_at_boundary();
            }
        }
        bus.flush();
        while slot.swap_at_boundary().is_some() {}
        let front = slot.attach();
        assert_eq!(front.version, 10);
        assert!(
            front.data.iter().zip(&cur).all(|(a, b)| a.to_bits() == b.to_bits()),
            "delta bus must reconstruct the published snapshot bit-exactly"
        );
        // sparse updates must undercut the 10-publish full-f32 wire cost
        assert!(bus.bytes_streamed() < 10 * (n as u64) * 4);
        // master stays exact too
        assert!(bus.latest().data.iter().zip(&cur).all(|(a, b)| a == b));
    }

    #[test]
    fn multi_publisher_versions_are_totally_ordered() {
        let n = 128;
        let bus = Arc::new(
            WeightsBus::with_options(vec![0.0; n], background_opts(n, ShardEncoding::F32))
                .unwrap(),
        );
        let p1 = bus.register_publisher();
        let p2 = bus.register_publisher();
        assert_eq!((p1, p2), (1, 2));
        let rounds = 40u64;
        let mut handles = Vec::new();
        for pid in [0, p1, p2] {
            let bus = bus.clone();
            handles.push(std::thread::spawn(move || {
                let mut versions = Vec::new();
                for _ in 0..rounds {
                    versions.push(bus.publish_from(pid, vec![pid as f32; n]));
                }
                versions
            }));
        }
        let mut all: Vec<u64> = Vec::new();
        for h in handles {
            let vs = h.join().unwrap();
            assert!(vs.windows(2).all(|w| w[0] < w[1]), "per-publisher order");
            all.extend(vs);
        }
        // one global mint: every version distinct, none skipped
        all.sort_unstable();
        assert_eq!(all, (1..=3 * rounds).collect::<Vec<u64>>());
        assert_eq!(bus.publisher_count(), 3);
        assert_eq!(bus.publisher_publishes(0), rounds);
        assert_eq!(bus.publisher_publishes(p1), rounds);
        assert_eq!(bus.publisher_publishes(p2), rounds);
        // wait_for observers see the same total order
        assert_eq!(bus.wait_for(3 * rounds).version, 3 * rounds);
    }

    #[test]
    fn sharded_copy_is_exact() {
        let src: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        for shards in [1, 3, 7, 16] {
            let c = sharded_copy(&src, shards);
            assert_eq!(c.data, src);
            assert_eq!(c.shard_secs.len(), src.len().div_ceil(c.chunk));
        }
    }
}
