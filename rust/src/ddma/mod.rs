//! Distributed Direct Memory Access (DDMA) weight synchronization
//! (paper §5.2).
//!
//! The paper's DDMA replaces the parameter-server pattern with fully
//! distributed zero-copy GPU-to-GPU shard transfers over NVLink/IB, updating
//! terabyte-scale weights in ~2 s (Table 4). In this single-host testbed the
//! *protocol* is real and the *links* are modelled:
//!
//! * [`WeightsBus`] — the in-process DDMA path: the trainer publishes a
//!   sharded snapshot, generator workers attach to the latest version with a
//!   zero-copy `Arc` clone. Versions are monotonic; every trajectory records
//!   the version it sampled under, so off-policy lag is always measurable.
//! * [`ShardedCopy`] — the sharded memcpy the trainer performs to produce a
//!   publishable snapshot (the analogue of each GPU pushing only its own
//!   shard; real measured bandwidth feeds Table 4's "measured" column).
//! * [`topology`] — NVLink/IB link model producing cluster-scale DDMA
//!   timings for the paper's 8B/70B/405B rows.
//! * [`ps_baseline`] — the parameter-server + weight-reload cost model
//!   calibrated to OpenRLHF's published numbers (Table 4 comparison).

pub mod ps_baseline;
pub mod topology;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::Instant;

use crate::model::VersionedParams;

/// The in-process DDMA weights path between trainer and generators.
pub struct WeightsBus {
    slot: RwLock<Arc<VersionedParams>>,
    version: AtomicU64,
    publishes: AtomicU64,
    publish_nanos: AtomicU64,
    notify: (Mutex<u64>, Condvar),
}

impl WeightsBus {
    /// Create the bus with version-0 initial weights.
    pub fn new(init: Vec<f32>) -> WeightsBus {
        WeightsBus {
            slot: RwLock::new(Arc::new(VersionedParams::new(0, init))),
            version: AtomicU64::new(0),
            publishes: AtomicU64::new(0),
            publish_nanos: AtomicU64::new(0),
            notify: (Mutex::new(0), Condvar::new()),
        }
    }

    /// Publish a new weight snapshot; returns its version. The write lock is
    /// held only for the Arc swap — readers never observe a partial update
    /// (test: `prop_coordinator::weights_bus_snapshots_are_consistent`).
    pub fn publish(&self, data: Vec<f32>) -> u64 {
        let t0 = Instant::now();
        let version = self.version.fetch_add(1, Ordering::SeqCst) + 1;
        let vp = Arc::new(VersionedParams::new(version, data));
        *self.slot.write().unwrap() = vp;
        self.publish_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.publishes.fetch_add(1, Ordering::Relaxed);
        let (lock, cvar) = &self.notify;
        *lock.lock().unwrap() = version;
        cvar.notify_all();
        version
    }

    /// Zero-copy attach to the latest snapshot.
    pub fn latest(&self) -> Arc<VersionedParams> {
        self.slot.read().unwrap().clone()
    }

    pub fn version(&self) -> u64 {
        self.version.load(Ordering::SeqCst)
    }

    /// Block until `version() >= min_version` (used by the evaluator).
    pub fn wait_for(&self, min_version: u64) -> Arc<VersionedParams> {
        let (lock, cvar) = &self.notify;
        let mut v = lock.lock().unwrap();
        while *v < min_version {
            v = cvar.wait(v).unwrap();
        }
        drop(v);
        self.latest()
    }

    pub fn publish_count(&self) -> u64 {
        self.publishes.load(Ordering::Relaxed)
    }

    /// Mean seconds per publish (the real measured DDMA handoff time).
    pub fn mean_publish_secs(&self) -> f64 {
        let n = self.publishes.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.publish_nanos.load(Ordering::Relaxed) as f64 / 1e9 / n as f64
    }
}

/// The sharded snapshot copy: every "rank" copies only its own contiguous
/// shard (paper: each GPU stores/updates its assigned shards). Returns the
/// copy and per-shard timings.
pub struct ShardedCopy {
    pub data: Vec<f32>,
    pub shard_secs: Vec<f64>,
}

pub fn sharded_copy(src: &[f32], n_shards: usize) -> ShardedCopy {
    assert!(n_shards > 0);
    let mut data = vec![0f32; src.len()];
    let mut shard_secs = Vec::with_capacity(n_shards);
    let chunk = src.len().div_ceil(n_shards);
    // NOTE: shards copy sequentially here (one core); the *per-shard* time is
    // what scales to the cluster model, where shards move in parallel and
    // DDMA time = max(shard time) — see topology::ddma_sync_time.
    for (dst_chunk, src_chunk) in data.chunks_mut(chunk).zip(src.chunks(chunk)) {
        let t0 = Instant::now();
        dst_chunk.copy_from_slice(src_chunk);
        shard_secs.push(t0.elapsed().as_secs_f64());
    }
    ShardedCopy { data, shard_secs }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn versions_monotonic() {
        let bus = WeightsBus::new(vec![0.0; 8]);
        assert_eq!(bus.version(), 0);
        let v1 = bus.publish(vec![1.0; 8]);
        let v2 = bus.publish(vec![2.0; 8]);
        assert_eq!((v1, v2), (1, 2));
        assert_eq!(bus.latest().version, 2);
        assert_eq!(bus.latest().data[0], 2.0);
    }

    #[test]
    fn wait_for_unblocks() {
        let bus = Arc::new(WeightsBus::new(vec![0.0; 4]));
        let b2 = bus.clone();
        let t = std::thread::spawn(move || b2.wait_for(1).version);
        std::thread::sleep(std::time::Duration::from_millis(20));
        bus.publish(vec![1.0; 4]);
        assert_eq!(t.join().unwrap(), 1);
    }

    #[test]
    fn sharded_copy_is_exact() {
        let src: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        for shards in [1, 3, 7, 16] {
            let c = sharded_copy(&src, shards);
            assert_eq!(c.data, src);
            assert_eq!(c.shard_secs.len(), src.len().div_ceil(src.len().div_ceil(shards)));
        }
    }
}
