//! Distributed Direct Memory Access (DDMA) weight synchronization
//! (paper §5.2).
//!
//! The paper's DDMA replaces the parameter-server pattern with fully
//! distributed zero-copy GPU-to-GPU shard transfers over NVLink/IB, updating
//! terabyte-scale weights in ~2 s (Table 4). In this single-host testbed the
//! *protocol* is real and the *links* are modelled. Since the weight-sync
//! plane landed, this module is a **facade over [`crate::weightsync`]**:
//!
//! * [`WeightsBus`] — the in-process DDMA path. Internally a publish runs
//!   the resharding plan between the trainer-side FSDP layout and the
//!   generator-side TP layout ([`crate::weightsync::plan_reshard`]):
//!   per-shard [`crate::weightsync::ShardPacket`]s (f32 or int8) stream
//!   into every registered generator's double-buffered
//!   [`crate::weightsync::GeneratorSlot`], where decode keeps running on
//!   version N until the fenced swap at a sequence boundary. The bus also
//!   keeps a master snapshot slot so `latest()` / `wait_for()` serve
//!   non-streaming readers (trainer init, evaluator, sync mode) exactly as
//!   before. Versions are monotonic; every trajectory records the version
//!   it sampled under, so off-policy lag is always measurable.
//! * [`ShardedCopy`] — the sharded memcpy the trainer performs to produce a
//!   publishable snapshot (the analogue of each GPU pushing only its own
//!   shard; real measured bandwidth feeds Table 4's "measured" column).
//! * [`topology`] — NVLink/IB link model producing cluster-scale DDMA
//!   timings for the paper's 8B/70B/405B rows, including the cost of a
//!   planner schedule ([`topology::DdmaModel::plan_secs`]).
//! * [`ps_baseline`] — the parameter-server + weight-reload cost model
//!   calibrated to OpenRLHF's published numbers (Table 4 comparison).

pub mod ps_baseline;
pub mod topology;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::Instant;

use crate::model::VersionedParams;
use crate::util::error::Result;
use crate::weightsync::{
    encode_shard, plan_reshard, GeneratorSlot, Layout, ReshardPlan, ShardEncoding,
};

/// The in-process DDMA weights path between trainer and generators: a facade
/// over the sharded weight-sync plane.
pub struct WeightsBus {
    plan: ReshardPlan,
    encoding: ShardEncoding,
    /// master snapshot (always exact f32) for non-streaming readers
    slot: RwLock<Arc<VersionedParams>>,
    /// per-generator double-buffered receive slots
    subscribers: Mutex<Vec<Arc<GeneratorSlot>>>,
    version: AtomicU64,
    publishes: AtomicU64,
    publish_nanos: AtomicU64,
    /// sum over publishes of the slowest shard's encode+fan-out time — the
    /// modelled parallel DDMA time (shards move concurrently on a cluster)
    shard_max_nanos: AtomicU64,
    /// payload bytes streamed to generator slots
    bytes_streamed: AtomicU64,
    /// serializes publishers (and slot registration) across the whole
    /// mint/stream/swap sequence, so the notify lock below is only ever
    /// held for the microsecond counter-update + wakeup
    publish_lock: Mutex<()>,
    notify: (Mutex<u64>, Condvar),
}

impl WeightsBus {
    /// Create the bus with version-0 initial weights and the trivial
    /// single-shard plan (monolithic behaviour).
    pub fn new(init: Vec<f32>) -> WeightsBus {
        let n = init.len();
        WeightsBus::with_layouts(
            init,
            Layout::fsdp(n, 1),
            Layout::tp_flat(n, 1),
            ShardEncoding::F32,
        )
        .expect("single-shard layouts are always valid")
    }

    /// Create the bus over an explicit trainer-side source layout,
    /// generator-side destination layout, and shard encoding. The resharding
    /// plan is computed once here and reused by every publish.
    pub fn with_layouts(
        init: Vec<f32>,
        src: Layout,
        dst: Layout,
        encoding: ShardEncoding,
    ) -> Result<WeightsBus> {
        let plan = plan_reshard(&src, &dst)?;
        Ok(WeightsBus {
            plan,
            encoding,
            slot: RwLock::new(Arc::new(VersionedParams::new(0, init))),
            subscribers: Mutex::new(Vec::new()),
            version: AtomicU64::new(0),
            publishes: AtomicU64::new(0),
            publish_nanos: AtomicU64::new(0),
            shard_max_nanos: AtomicU64::new(0),
            bytes_streamed: AtomicU64::new(0),
            publish_lock: Mutex::new(()),
            notify: (Mutex::new(0), Condvar::new()),
        })
    }

    /// Register a generator's double-buffered receive slot. Its front starts
    /// at the current master version; every later publish streams into its
    /// staging buffer, and the generator promotes it with
    /// [`GeneratorSlot::swap_at_boundary`] at its own sequence boundary.
    pub fn register_generator(&self) -> Arc<GeneratorSlot> {
        // Serialize against in-flight publishes: without this, a slot
        // created while a publish streams could seed its front from the
        // not-yet-swapped master AND miss the streaming version's packets,
        // leaving it one version stale until the next publish.
        let _serial = self.publish_lock.lock().unwrap();
        let slot = GeneratorSlot::new(self.latest());
        self.subscribers.lock().unwrap().push(slot.clone());
        slot
    }

    /// Publish a new weight snapshot; returns its version.
    ///
    /// Ordering contract (regression test
    /// `version_never_ahead_of_latest_snapshot`): the version counter is
    /// minted under the publish lock and stored only *after* the master
    /// slot swap, so an observer that reads `version() == N` is guaranteed
    /// `latest().version >= N`. Readers never observe a partial update
    /// (test: `prop_coordinator::weights_bus_snapshots_are_consistent`).
    pub fn publish(&self, data: Vec<f32>) -> u64 {
        let t0 = Instant::now();
        // The publish lock serializes publishers across the whole
        // mint/stream/swap sequence; the notify mutex is touched only at
        // the very end, so `wait_for` callers are never stuck behind the
        // encode/fan-out work.
        let _serial = self.publish_lock.lock().unwrap();
        let version = self.version.load(Ordering::SeqCst) + 1;

        // Stream the resharding plan into every generator slot while their
        // decode loops keep reading the front buffer.
        let subs = self.subscribers.lock().unwrap().clone();
        if !subs.is_empty() {
            for slot in &subs {
                slot.begin(version, self.plan.ops.len());
            }
            let mut max_op = 0f64;
            let mut bytes = 0usize;
            for &op in &self.plan.ops {
                let t_op = Instant::now();
                let pkt = encode_shard(&data, version, op, self.encoding);
                bytes += pkt.payload_bytes();
                for slot in &subs {
                    slot.recv(&pkt);
                }
                max_op = max_op.max(t_op.elapsed().as_secs_f64());
            }
            self.shard_max_nanos
                .fetch_add((max_op * 1e9) as u64, Ordering::Relaxed);
            self.bytes_streamed
                .fetch_add(bytes as u64, Ordering::Relaxed);
        }

        // Master slot swap strictly before the version-counter bump.
        *self.slot.write().unwrap() = Arc::new(VersionedParams::new(version, data));
        self.version.store(version, Ordering::SeqCst);
        self.publish_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.publishes.fetch_add(1, Ordering::Relaxed);
        let (lock, cvar) = &self.notify;
        *lock.lock().unwrap() = version;
        cvar.notify_all();
        version
    }

    /// Zero-copy attach to the latest master snapshot.
    pub fn latest(&self) -> Arc<VersionedParams> {
        self.slot.read().unwrap().clone()
    }

    pub fn version(&self) -> u64 {
        self.version.load(Ordering::SeqCst)
    }

    /// Block until `version() >= min_version` (used by the evaluator).
    pub fn wait_for(&self, min_version: u64) -> Arc<VersionedParams> {
        let (lock, cvar) = &self.notify;
        let mut v = lock.lock().unwrap();
        while *v < min_version {
            v = cvar.wait(v).unwrap();
        }
        drop(v);
        self.latest()
    }

    pub fn publish_count(&self) -> u64 {
        self.publishes.load(Ordering::Relaxed)
    }

    /// Mean seconds per publish (the real measured DDMA handoff time).
    pub fn mean_publish_secs(&self) -> f64 {
        let n = self.publishes.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.publish_nanos.load(Ordering::Relaxed) as f64 / 1e9 / n as f64
    }

    /// Mean per-publish time of the slowest shard — what a publish costs
    /// when shards move in parallel (cluster DDMA time).
    pub fn mean_shard_max_secs(&self) -> f64 {
        let n = self.publishes.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.shard_max_nanos.load(Ordering::Relaxed) as f64 / 1e9 / n as f64
    }

    /// Payload bytes streamed to generator slots so far (int8 encoding
    /// shows up here as a ~4x reduction).
    pub fn bytes_streamed(&self) -> u64 {
        self.bytes_streamed.load(Ordering::Relaxed)
    }

    /// The resharding schedule every publish executes.
    pub fn plan(&self) -> &ReshardPlan {
        &self.plan
    }

    pub fn subscriber_count(&self) -> usize {
        self.subscribers.lock().unwrap().len()
    }
}

/// The sharded snapshot copy: every "rank" copies only its own contiguous
/// shard (paper: each GPU stores/updates its assigned shards). Returns the
/// copy, per-shard timings, and the chunk size used.
pub struct ShardedCopy {
    pub data: Vec<f32>,
    pub shard_secs: Vec<f64>,
    /// elements per shard (last shard may be smaller)
    pub chunk: usize,
}

pub fn sharded_copy(src: &[f32], n_shards: usize) -> ShardedCopy {
    assert!(n_shards > 0);
    let mut data = vec![0f32; src.len()];
    let mut shard_secs = Vec::with_capacity(n_shards);
    let chunk = src.len().div_ceil(n_shards).max(1);
    // NOTE: shards copy sequentially here (one core); the *per-shard* time is
    // what scales to the cluster model, where shards move in parallel and
    // DDMA time = max(shard time) — see topology::ddma_sync_time.
    for (dst_chunk, src_chunk) in data.chunks_mut(chunk).zip(src.chunks(chunk)) {
        let t0 = Instant::now();
        dst_chunk.copy_from_slice(src_chunk);
        shard_secs.push(t0.elapsed().as_secs_f64());
    }
    ShardedCopy {
        data,
        shard_secs,
        chunk,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn versions_monotonic() {
        let bus = WeightsBus::new(vec![0.0; 8]);
        assert_eq!(bus.version(), 0);
        let v1 = bus.publish(vec![1.0; 8]);
        let v2 = bus.publish(vec![2.0; 8]);
        assert_eq!((v1, v2), (1, 2));
        assert_eq!(bus.latest().version, 2);
        assert_eq!(bus.latest().data[0], 2.0);
    }

    #[test]
    fn wait_for_unblocks() {
        let bus = Arc::new(WeightsBus::new(vec![0.0; 4]));
        let b2 = bus.clone();
        let t = std::thread::spawn(move || b2.wait_for(1).version);
        std::thread::sleep(std::time::Duration::from_millis(20));
        bus.publish(vec![1.0; 4]);
        assert_eq!(t.join().unwrap(), 1);
    }

    #[test]
    fn version_never_ahead_of_latest_snapshot() {
        // Regression (publish version/notify race): minting the version
        // before the slot swap let a reader observe version() == N while
        // latest() still returned N-1. The fixed ordering stores the
        // counter only after the swap, so this invariant holds under a
        // racing publisher.
        let bus = Arc::new(WeightsBus::new(vec![0.0; 256]));
        let writer = {
            let bus = bus.clone();
            std::thread::spawn(move || {
                for v in 1..=300u64 {
                    bus.publish(vec![v as f32; 256]);
                }
            })
        };
        loop {
            let observed = bus.version();
            let snap = bus.latest();
            assert!(
                snap.version >= observed,
                "latest() at {} behind observed version() {}",
                snap.version,
                observed
            );
            if observed >= 300 {
                break;
            }
        }
        writer.join().unwrap();
    }

    #[test]
    fn registered_slot_receives_fenced_versions() {
        let n = 64;
        let bus = WeightsBus::with_layouts(
            vec![0.0; n],
            Layout::fsdp(n, 4),
            Layout::tp_flat(n, 2),
            ShardEncoding::F32,
        )
        .unwrap();
        let slot = bus.register_generator();
        assert_eq!(slot.front_version(), 0);
        assert!(slot.swap_at_boundary().is_none(), "nothing staged yet");

        bus.publish(vec![1.5; n]);
        // decode still on version 0 until the generator swaps
        assert_eq!(slot.front_version(), 0);
        let snap = slot.swap_at_boundary().expect("complete staging");
        assert_eq!(snap.version, 1);
        assert!(snap.data.iter().all(|x| *x == 1.5));
        assert!(bus.bytes_streamed() > 0);
        assert!(bus.mean_shard_max_secs() >= 0.0);
    }

    #[test]
    fn quantized_bus_streams_fewer_bytes_within_bound() {
        let n = 1000;
        let init: Vec<f32> = (0..n).map(|i| (i as f32 * 0.01).sin()).collect();
        let next: Vec<f32> = (0..n).map(|i| (i as f32 * 0.013).cos()).collect();
        let mk = |enc| {
            WeightsBus::with_layouts(
                init.clone(),
                Layout::fsdp(n, 4),
                Layout::tp_flat(n, 4),
                enc,
            )
            .unwrap()
        };
        let f32_bus = mk(ShardEncoding::F32);
        let q_bus = mk(ShardEncoding::Int8);
        let f32_slot = f32_bus.register_generator();
        let q_slot = q_bus.register_generator();
        f32_bus.publish(next.clone());
        q_bus.publish(next.clone());
        let exact = f32_slot.swap_at_boundary().unwrap();
        let quant = q_slot.swap_at_boundary().unwrap();
        assert_eq!(*exact.data, next);
        assert!(q_bus.bytes_streamed() * 3 < f32_bus.bytes_streamed());
        let maxabs = next.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        let bound = crate::model::int8_error_bound(maxabs);
        for (a, b) in next.iter().zip(quant.data.iter()) {
            assert!((a - b).abs() <= bound);
        }
        // the master slot stays exact even on a quantized bus
        assert_eq!(*q_bus.latest().data, next);
    }

    #[test]
    fn sharded_copy_is_exact() {
        let src: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        for shards in [1, 3, 7, 16] {
            let c = sharded_copy(&src, shards);
            assert_eq!(c.data, src);
            assert_eq!(c.shard_secs.len(), src.len().div_ceil(c.chunk));
        }
    }
}
