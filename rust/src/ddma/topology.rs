//! Cluster-scale DDMA timing model (Table 4, Figure 4).
//!
//! The paper reports DDMA weight-sync times of 0.04 s (7B), 1.15 s (70B) and
//! 2.31 s (405B) on H100 clusters. Two components are modelled:
//!
//! 1. a theoretical floor: each trainer GPU pushes only its own contiguous
//!    shard over its own link, all shards in parallel, so
//!    `t_floor = shard_bytes / link_bw` — *independent of total model size
//!    at fixed shard size*, which is the linear-scalability property the
//!    paper claims (and which `prop_simulator` verifies);
//! 2. an empirical software-stack factor calibrated (log-log least squares)
//!    to the paper's three published measurements, absorbing per-tensor
//!    launch overheads and stream synchronization the floor ignores.

use crate::util::stats::linfit;
use crate::weightsync::ReshardPlan;

/// Per-op software overhead (stream launch + synchronization) paid when
/// costing a planner schedule explicitly; the calibrated power-law fit
/// absorbs the same effect for the aggregate model.
pub const OP_LAUNCH_SECS: f64 = 20e-6;

/// Interconnect bandwidths, bytes/sec.
#[derive(Debug, Clone, Copy)]
pub struct LinkSpec {
    /// intra-node NVLink per GPU
    pub nvlink_bps: f64,
    /// inter-node InfiniBand per GPU
    pub ib_bps: f64,
    /// host <-> device link per GPU (PCIe; what colocated offloading pays)
    pub pcie_bps: f64,
}

impl Default for LinkSpec {
    fn default() -> Self {
        LinkSpec {
            nvlink_bps: 900e9, // NVLink4 ~900 GB/s
            ib_bps: 50e9,      // 400 Gb/s HDR IB per GPU
            pcie_bps: 64e9,    // PCIe gen5 x16 ~64 GB/s per direction
        }
    }
}

/// bf16 bytes for a model of `params` parameters.
pub fn bf16_bytes(params: f64) -> f64 {
    2.0 * params
}

/// The paper's published DDMA measurements: (params, trainer GPUs, seconds).
pub const PAPER_DDMA_POINTS: [(f64, f64, f64); 3] = [
    (7e9, 128.0, 0.04),
    (70e9, 128.0, 1.15),
    (405e9, 512.0, 2.31),
];

/// Calibrated DDMA model. `shard_bytes -> seconds` as a power law fitted to
/// the paper's points, floored by the raw link time.
#[derive(Debug, Clone, Copy)]
pub struct DdmaModel {
    pub link: LinkSpec,
    /// log-log fit: ln t = a + p * ln(shard_GB)
    pub a: f64,
    pub p: f64,
}

impl DdmaModel {
    pub fn calibrated() -> DdmaModel {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for (params, gpus, secs) in PAPER_DDMA_POINTS {
            let shard_gb = bf16_bytes(params) / gpus / 1e9;
            xs.push(shard_gb.ln());
            ys.push(secs.ln());
        }
        let (a, p, _r2) = linfit(&xs, &ys);
        DdmaModel {
            link: LinkSpec::default(),
            a,
            p,
        }
    }

    /// DDMA weight-sync seconds for a model of `params` parameters sharded
    /// over `n_trainer_gpus`, pushed to the generator group.
    pub fn sync_secs(&self, params: f64, n_trainer_gpus: usize) -> f64 {
        let shard_bytes = bf16_bytes(params) / n_trainer_gpus as f64;
        let floor = shard_bytes / self.link.ib_bps;
        let shard_gb = shard_bytes / 1e9;
        let fitted = (self.a + self.p * shard_gb.ln()).exp();
        fitted.max(floor)
    }

    /// The theoretical floor alone (pure link time, zero software overhead).
    pub fn floor_secs(&self, params: f64, n_trainer_gpus: usize) -> f64 {
        bf16_bytes(params) / n_trainer_gpus as f64 / self.link.ib_bps
    }

    /// Host <-> device transfer time for a colocated offload/prefetch of
    /// `bytes`, issued as `chunk_bytes`-sized copies over the PCIe link
    /// (each chunk pays the same per-op launch overhead the planner
    /// schedule model uses). Feeds the memplane's DES timeline segments.
    pub fn offload_secs(&self, bytes: f64, chunk_bytes: f64) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        let chunks = (bytes / chunk_bytes.max(1.0)).ceil().max(1.0);
        bytes / self.link.pcie_bps + chunks * OP_LAUNCH_SECS
    }

    /// Cost of executing a resharding planner schedule on the cluster:
    /// every active (src, dst) link moves its bytes in parallel over IB,
    /// paying [`OP_LAUNCH_SECS`] per op it issues, so schedule time is the
    /// *max* over links — shard size, not model size, is what matters
    /// (the paper's linear-scalability property at plan granularity).
    /// `bytes_per_elem` selects the wire encoding (2.0 bf16, 4.0 f32,
    /// 1.0 int8).
    pub fn plan_secs(&self, plan: &ReshardPlan, bytes_per_elem: f64) -> f64 {
        let ops = plan.link_ops();
        plan.link_elems()
            .iter()
            .map(|(link, n)| {
                *n as f64 * bytes_per_elem / self.link.ib_bps
                    + ops.get(link).copied().unwrap_or(0) as f64 * OP_LAUNCH_SECS
            })
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_paper_points() {
        let m = DdmaModel::calibrated();
        for (params, gpus, secs) in PAPER_DDMA_POINTS {
            let got = m.sync_secs(params, gpus as usize);
            // log-log fit through 3 points: within 2.5x everywhere
            assert!(
                got / secs < 2.5 && secs / got < 2.5,
                "params={params} want {secs} got {got}"
            );
        }
    }

    #[test]
    fn linear_scalability() {
        // doubling model size AND gpu count keeps shard size constant ->
        // DDMA time constant (the paper's linear-scalability claim)
        let m = DdmaModel::calibrated();
        let t1 = m.sync_secs(70e9, 128);
        let t2 = m.sync_secs(140e9, 256);
        assert!((t1 - t2).abs() / t1 < 1e-9);
    }

    #[test]
    fn plan_cost_scales_with_shard_not_model() {
        use crate::weightsync::{plan_reshard, Layout};
        let m = DdmaModel::calibrated();
        // doubling size AND both rank counts keeps per-link volume constant
        let small = plan_reshard(&Layout::fsdp(1 << 20, 8), &Layout::tp_flat(1 << 20, 4))
            .unwrap();
        let large = plan_reshard(&Layout::fsdp(1 << 21, 16), &Layout::tp_flat(1 << 21, 8))
            .unwrap();
        let t_small = m.plan_secs(&small, 2.0);
        let t_large = m.plan_secs(&large, 2.0);
        assert!(
            (t_small - t_large).abs() / t_small < 1e-6,
            "{t_small} vs {t_large}"
        );
        // int8 wire encoding moves half the bf16 bytes
        let t_int8 = m.plan_secs(&small, 1.0);
        assert!(t_int8 < t_small);
    }

    #[test]
    fn offload_time_is_pcie_plus_launches() {
        let m = DdmaModel::calibrated();
        assert_eq!(m.offload_secs(0.0, 4e6), 0.0);
        // 64 MB over ~64 GB/s: about a millisecond, plus 16 chunk launches
        let t = m.offload_secs(64e6, 4e6);
        let floor = 64e6 / m.link.pcie_bps;
        assert!(t >= floor && t < floor + 32.0 * OP_LAUNCH_SECS, "{t}");
        // halving the chunk size only adds launch overhead
        assert!(m.offload_secs(64e6, 2e6) > t);
    }

    #[test]
    fn floor_below_fit() {
        let m = DdmaModel::calibrated();
        for (params, gpus, _) in PAPER_DDMA_POINTS {
            assert!(m.floor_secs(params, gpus as usize) <= m.sync_secs(params, gpus as usize));
        }
    }
}
