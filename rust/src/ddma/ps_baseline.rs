//! Parameter-server weight-sync baseline (Table 4's OpenRLHF column).
//!
//! OpenRLHF's measured weight-communication time grows faster than linearly
//! with model size (paper §3: 4.32 s at 7B, 111.65 s at 70B; the bottleneck
//! is the serial weight-reload path, not link bandwidth). We fit the power
//! law through the two published points and use it to reproduce the paper's
//! ">900 s estimated at 405B" extrapolation.

/// OpenRLHF published measurements: (params, seconds).
pub const OPENRLHF_POINTS: [(f64, f64); 2] = [(7e9, 4.32), (70e9, 111.65)];

#[derive(Debug, Clone, Copy)]
pub struct PsModel {
    /// t = c * (params/1e9)^p
    pub c: f64,
    pub p: f64,
}

impl PsModel {
    pub fn calibrated() -> PsModel {
        let (w1, t1) = OPENRLHF_POINTS[0];
        let (w2, t2) = OPENRLHF_POINTS[1];
        let p = (t2 / t1).ln() / (w2 / w1).ln();
        let c = t1 / (w1 / 1e9).powf(p);
        PsModel { c, p }
    }

    pub fn sync_secs(&self, params: f64) -> f64 {
        self.c * (params / 1e9).powf(self.p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_published_points() {
        let m = PsModel::calibrated();
        for (w, t) in OPENRLHF_POINTS {
            assert!((m.sync_secs(w) - t).abs() / t < 1e-9);
        }
    }

    #[test]
    fn superlinear() {
        let m = PsModel::calibrated();
        assert!(m.p > 1.0, "PS reload cost must be superlinear, p={}", m.p);
    }

    #[test]
    fn paper_405b_extrapolation_exceeds_900s() {
        let m = PsModel::calibrated();
        assert!(
            m.sync_secs(405e9) > 900.0,
            "paper: 405B PS sync estimated over 900 s, got {}",
            m.sync_secs(405e9)
        );
    }
}
