//! Generation-overlapped double buffering: per-generator receive slots with
//! version fencing.
//!
//! Each generator owns a [`GeneratorSlot`] with two buffers:
//!
//! * **front** — the complete version the decode loop reads (zero-copy
//!   `Arc` attach, exactly like the old monolithic bus);
//! * **staging** — the next version streaming in shard by shard while the
//!   generator keeps decoding on front.
//!
//! The version fence: staging becomes swappable only when every op of its
//! plan has landed (`received == expected`), and the swap happens only when
//! the *generator* calls [`GeneratorSlot::swap_at_boundary`] — a sequence
//! boundary of its own choosing (chunk edges, in this codebase). Decode
//! therefore never observes a torn or partial version, and the stall a
//! publish imposes on generation shrinks from "copy the whole snapshot" to
//! one pointer exchange. Publishes are latest-wins: if version N+2 starts
//! streaming before N+1 was swapped in, N+1 is abandoned — generators always
//! jump to the freshest complete version (paper §4.1 semantics).
//!
//! The base-version fence (delta encodings): a delta staging is opened with
//! [`GeneratorSlot::begin_delta`], which seeds the staging buffer from the
//! slot's current front and records that front's version as the staging
//! base. A delta packet whose `base_version` disagrees is rejected with
//! [`RecvOutcome::BaseMismatch`] — applied onto the wrong base it would
//! silently corrupt weights — and the sender re-encodes that shard as full
//! f32 (see `weightsync::executor`). The op only counts toward the version
//! fence once a payload actually lands.

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use crate::model::VersionedParams;
use crate::weightsync::transfer::{apply_packet, ShardPacket};

/// What [`GeneratorSlot::recv`] did with a packet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecvOutcome {
    /// payload applied; the op now counts toward the version fence
    Applied,
    /// no staging open, or the packet's version is not the staging version
    Stale,
    /// delta payload against a base the staging buffer does not hold; the
    /// sender must re-send this op as a self-contained (full) payload
    BaseMismatch,
}

/// The in-flight (staging) buffer: version N+1 while decode runs on N.
struct Staging {
    version: u64,
    data: Vec<f32>,
    /// start offsets of ops landed so far — ops of one plan tile the vector
    /// disjointly, so `start` identifies an op and duplicates cannot count
    /// twice; the fence opens at `expected` DISTINCT ops
    received: BTreeSet<usize>,
    expected: usize,
    /// Some(v): the buffer was seeded from front version v and may accept
    /// delta payloads against exactly v; None: full-payload staging
    base_version: Option<u64>,
}

/// One generator's double-buffered weight slot.
pub struct GeneratorSlot {
    num_params: usize,
    front: RwLock<Arc<VersionedParams>>,
    staging: Mutex<Option<Staging>>,
    swaps: AtomicU64,
    stall_nanos: AtomicU64,
    dropped_versions: AtomicU64,
    base_rejects: AtomicU64,
}

impl GeneratorSlot {
    pub fn new(init: Arc<VersionedParams>) -> Arc<GeneratorSlot> {
        let num_params = init.data.len();
        Arc::new(GeneratorSlot {
            num_params,
            front: RwLock::new(init),
            staging: Mutex::new(None),
            swaps: AtomicU64::new(0),
            stall_nanos: AtomicU64::new(0),
            dropped_versions: AtomicU64::new(0),
            base_rejects: AtomicU64::new(0),
        })
    }

    /// Zero-copy attach to the current front version.
    pub fn attach(&self) -> Arc<VersionedParams> {
        self.front.read().unwrap().clone()
    }

    pub fn front_version(&self) -> u64 {
        self.front.read().unwrap().version
    }

    /// Publisher side: open staging for `version`, expecting `expected_ops`
    /// packets. Latest-wins: an unswapped older staging is abandoned.
    /// Versions at or below the current front are refused outright — a
    /// late-registered slot already starts at the bus's latest snapshot, so
    /// promoting an older stream would regress decode.
    ///
    /// Idempotent per version: concurrent link-group workers may all call
    /// this for the same publish, only the first opens the staging.
    pub fn begin(&self, version: u64, expected_ops: usize) {
        self.begin_inner(version, expected_ops, false)
    }

    /// [`GeneratorSlot::begin`] for a delta-encoded publish: seeds the
    /// staging buffer from the current front and records that front's
    /// version as the staging base, arming the base-version fence.
    pub fn begin_delta(&self, version: u64, expected_ops: usize) {
        self.begin_inner(version, expected_ops, true)
    }

    fn begin_inner(&self, version: u64, expected_ops: usize, delta: bool) {
        // Clone the front Arc *before* taking the staging lock:
        // swap_at_boundary acquires staging -> front(write), so holding
        // front(read) here while waiting on staging would deadlock.
        let front = self.front.read().unwrap().clone();
        if version <= front.version {
            return; // decode is already at (or past) this version
        }
        let mut guard = self.staging.lock().unwrap();
        if let Some(old) = guard.as_ref() {
            if old.version >= version {
                return; // never regress the staging version
            }
            self.dropped_versions.fetch_add(1, Ordering::Relaxed);
        }
        // reuse the abandoned staging allocation when shapes match
        let mut data = match guard.take() {
            Some(old) if old.data.len() == self.num_params => old.data,
            _ => vec![0.0f32; self.num_params],
        };
        let base_version = if delta {
            data.copy_from_slice(&front.data);
            Some(front.version)
        } else {
            None
        };
        *guard = Some(Staging {
            version,
            data,
            received: BTreeSet::new(),
            expected: expected_ops.max(1),
            base_version,
        });
    }

    /// Publisher side: land one shard. Packets for any version other than
    /// the currently staging one are dropped (the version fence); delta
    /// payloads against a base the staging was not seeded from are rejected
    /// (the base-version fence) so the sender can re-send full; duplicated
    /// packets overwrite their own interval but never advance the fence.
    pub fn recv(&self, pkt: &ShardPacket) -> RecvOutcome {
        let mut guard = self.staging.lock().unwrap();
        let Some(staging) = guard.as_mut() else {
            return RecvOutcome::Stale;
        };
        if staging.version != pkt.version {
            return RecvOutcome::Stale;
        }
        if let Some(pkt_base) = pkt.base_version() {
            if staging.base_version != Some(pkt_base) {
                self.base_rejects.fetch_add(1, Ordering::Relaxed);
                return RecvOutcome::BaseMismatch;
            }
        }
        apply_packet(&mut staging.data, pkt);
        staging.received.insert(pkt.op.start);
        RecvOutcome::Applied
    }

    /// Generator side, called at a sequence boundary: if a complete staged
    /// version is waiting, promote it to front (one pointer exchange) and
    /// return it. Incomplete staging never swaps — that is the version
    /// fence.
    pub fn swap_at_boundary(&self) -> Option<Arc<VersionedParams>> {
        let t0 = Instant::now();
        let mut guard = self.staging.lock().unwrap();
        let ready = matches!(guard.as_ref(), Some(s) if s.received.len() >= s.expected);
        if !ready {
            return None;
        }
        let staging = guard.take().unwrap();
        let snap = Arc::new(VersionedParams::new(staging.version, staging.data));
        {
            let mut front = self.front.write().unwrap();
            if snap.version <= front.version {
                // belt-and-braces: begin() refuses versions <= front, so a
                // completed staging is always ahead — but never regress
                return None;
            }
            *front = snap.clone();
        }
        drop(guard);
        self.swaps.fetch_add(1, Ordering::Relaxed);
        self.stall_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Some(snap)
    }

    /// Completed swaps so far.
    pub fn swaps(&self) -> u64 {
        self.swaps.load(Ordering::Relaxed)
    }

    /// Staged versions abandoned because a newer publish arrived first.
    pub fn dropped_versions(&self) -> u64 {
        self.dropped_versions.load(Ordering::Relaxed)
    }

    /// Delta packets rejected by the base-version fence (each one was
    /// re-sent as full by the streaming plane).
    pub fn base_rejects(&self) -> u64 {
        self.base_rejects.load(Ordering::Relaxed)
    }

    /// Total generator-side stall spent in `swap_at_boundary` calls that
    /// actually promoted a version — the whole cost a publish imposes on
    /// the decode loop in overlapped mode (no-op boundary polls are not
    /// counted; they cost one uncontended lock acquire).
    pub fn stall_secs(&self) -> f64 {
        self.stall_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Mean stall per completed swap.
    pub fn mean_stall_secs(&self) -> f64 {
        let n = self.swaps();
        if n == 0 {
            0.0
        } else {
            self.stall_secs() / n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::weightsync::plan::TransferOp;
    use crate::weightsync::transfer::{encode_shard, encode_shard_delta, ShardEncoding};

    fn op(start: usize, len: usize) -> TransferOp {
        TransferOp {
            src: 0,
            dst: 0,
            start,
            len,
        }
    }

    #[test]
    fn incomplete_staging_never_swaps() {
        let slot = GeneratorSlot::new(Arc::new(VersionedParams::new(0, vec![0.0; 8])));
        let next = vec![1.0f32; 8];
        slot.begin(1, 2);
        slot.recv(&encode_shard(&next, 1, op(0, 4), ShardEncoding::F32));
        assert!(slot.swap_at_boundary().is_none(), "fence must hold");
        assert_eq!(slot.front_version(), 0);
        slot.recv(&encode_shard(&next, 1, op(4, 4), ShardEncoding::F32));
        let snap = slot.swap_at_boundary().expect("complete staging swaps");
        assert_eq!(snap.version, 1);
        assert_eq!(*snap.data, next);
        assert_eq!(slot.front_version(), 1);
        // nothing left to swap
        assert!(slot.swap_at_boundary().is_none());
        assert_eq!(slot.swaps(), 1);
    }

    #[test]
    fn stale_packets_are_dropped() {
        let slot = GeneratorSlot::new(Arc::new(VersionedParams::new(0, vec![0.0; 4])));
        let v1 = vec![1.0f32; 4];
        let v2 = vec![2.0f32; 4];
        slot.begin(1, 1);
        // version 2 overtakes before v1's packet lands
        slot.begin(2, 1);
        slot.recv(&encode_shard(&v1, 1, op(0, 4), ShardEncoding::F32)); // stale, dropped
        assert!(slot.swap_at_boundary().is_none());
        slot.recv(&encode_shard(&v2, 2, op(0, 4), ShardEncoding::F32));
        let snap = slot.swap_at_boundary().unwrap();
        assert_eq!(snap.version, 2);
        assert_eq!(*snap.data, v2);
        assert_eq!(slot.dropped_versions(), 1);
    }

    #[test]
    fn duplicate_packets_cannot_open_the_fence() {
        // Regression: the fence counts DISTINCT ops (by start offset), so a
        // duplicated packet plus a missing one must not promote a torn
        // buffer.
        let slot = GeneratorSlot::new(Arc::new(VersionedParams::new(0, vec![0.0; 8])));
        let next = vec![1.0f32; 8];
        slot.begin(1, 2);
        let first = encode_shard(&next, 1, op(0, 4), ShardEncoding::F32);
        slot.recv(&first);
        slot.recv(&first); // duplicate of op 0; op 1 still missing
        assert!(slot.swap_at_boundary().is_none(), "fence opened on duplicate");
        slot.recv(&encode_shard(&next, 1, op(4, 4), ShardEncoding::F32));
        assert_eq!(slot.swap_at_boundary().unwrap().version, 1);
    }

    #[test]
    fn begin_never_regresses() {
        let slot = GeneratorSlot::new(Arc::new(VersionedParams::new(0, vec![0.0; 4])));
        slot.begin(3, 1);
        slot.begin(2, 1); // ignored
        let v3 = vec![3.0f32; 4];
        slot.recv(&encode_shard(&v3, 3, op(0, 4), ShardEncoding::F32));
        assert_eq!(slot.swap_at_boundary().unwrap().version, 3);
    }

    #[test]
    fn begin_refuses_versions_at_or_below_front() {
        // A slot registered after publish N already fronts N; re-streaming
        // N (or older) must not stage, or a later swap would regress decode.
        let slot = GeneratorSlot::new(Arc::new(VersionedParams::new(5, vec![5.0; 4])));
        slot.begin(5, 1);
        slot.recv(&encode_shard(&[9.0f32; 4], 5, op(0, 4), ShardEncoding::F32));
        assert!(slot.swap_at_boundary().is_none());
        assert_eq!(slot.front_version(), 5);
        assert!(slot.attach().data.iter().all(|x| *x == 5.0));
    }

    #[test]
    fn delta_staging_applies_matching_base_exactly() {
        let base = vec![1.0f32, 2.0, 3.0, 4.0];
        let slot = GeneratorSlot::new(Arc::new(VersionedParams::new(3, base.clone())));
        let mut new = base.clone();
        new[2] = 30.0;
        slot.begin_delta(4, 1);
        let (pkt, _) = encode_shard_delta(&new, &base, 3, 4, op(0, 4), None);
        assert_eq!(slot.recv(&pkt), RecvOutcome::Applied);
        let snap = slot.swap_at_boundary().expect("delta staging complete");
        assert_eq!(snap.version, 4);
        assert_eq!(*snap.data, new);
        assert_eq!(slot.base_rejects(), 0);
    }

    #[test]
    fn stale_base_delta_is_fenced_and_full_resend_recovers() {
        // Slot fronts version 2; publisher encodes v4 as a delta against v3
        // (its previous publish). The fence must reject the delta — applied
        // onto v2 it would corrupt — and the full re-send must complete the
        // version fence instead.
        let v2 = vec![2.0f32; 4];
        let v3 = vec![2.0f32, 7.0, 2.0, 2.0];
        let v4 = vec![2.0f32, 7.0, 9.0, 2.0];
        let slot = GeneratorSlot::new(Arc::new(VersionedParams::new(2, v2)));
        slot.begin_delta(4, 1); // seeds from front: base_version = Some(2)
        let (delta_pkt, _) = encode_shard_delta(&v4, &v3, 3, 4, op(0, 4), None);
        assert_eq!(slot.recv(&delta_pkt), RecvOutcome::BaseMismatch);
        assert_eq!(slot.base_rejects(), 1);
        assert!(
            slot.swap_at_boundary().is_none(),
            "rejected delta must not advance the version fence"
        );
        // sender notices and re-encodes the op as self-contained f32
        let full = encode_shard(&v4, 4, op(0, 4), ShardEncoding::F32);
        assert_eq!(slot.recv(&full), RecvOutcome::Applied);
        let snap = slot.swap_at_boundary().unwrap();
        assert_eq!(snap.version, 4);
        assert_eq!(*snap.data, v4);
    }
}
