//! Generation-overlapped double buffering: per-generator receive slots with
//! version fencing.
//!
//! Each generator owns a [`GeneratorSlot`] with two buffers:
//!
//! * **front** — the complete version the decode loop reads (zero-copy
//!   `Arc` attach, exactly like the old monolithic bus);
//! * **staging** — the next version streaming in shard by shard while the
//!   generator keeps decoding on front.
//!
//! The fence: staging becomes swappable only when every op of its plan has
//! landed (`received == expected`), and the swap happens only when the
//! *generator* calls [`GeneratorSlot::swap_at_boundary`] — a sequence
//! boundary of its own choosing (chunk edges, in this codebase). Decode
//! therefore never observes a torn or partial version, and the stall a
//! publish imposes on generation shrinks from "copy the whole snapshot" to
//! one pointer exchange. Publishes are latest-wins: if version N+2 starts
//! streaming before N+1 was swapped in, N+1 is abandoned — generators always
//! jump to the freshest complete version (paper §4.1 semantics).

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use crate::model::VersionedParams;
use crate::weightsync::transfer::{apply_packet, ShardPacket};

/// The in-flight (staging) buffer: version N+1 while decode runs on N.
struct Staging {
    version: u64,
    data: Vec<f32>,
    /// start offsets of ops landed so far — ops of one plan tile the vector
    /// disjointly, so `start` identifies an op and duplicates cannot count
    /// twice; the fence opens at `expected` DISTINCT ops
    received: BTreeSet<usize>,
    expected: usize,
}

/// One generator's double-buffered weight slot.
pub struct GeneratorSlot {
    num_params: usize,
    front: RwLock<Arc<VersionedParams>>,
    staging: Mutex<Option<Staging>>,
    swaps: AtomicU64,
    stall_nanos: AtomicU64,
    dropped_versions: AtomicU64,
}

impl GeneratorSlot {
    pub fn new(init: Arc<VersionedParams>) -> Arc<GeneratorSlot> {
        let num_params = init.data.len();
        Arc::new(GeneratorSlot {
            num_params,
            front: RwLock::new(init),
            staging: Mutex::new(None),
            swaps: AtomicU64::new(0),
            stall_nanos: AtomicU64::new(0),
            dropped_versions: AtomicU64::new(0),
        })
    }

    /// Zero-copy attach to the current front version.
    pub fn attach(&self) -> Arc<VersionedParams> {
        self.front.read().unwrap().clone()
    }

    pub fn front_version(&self) -> u64 {
        self.front.read().unwrap().version
    }

    /// Publisher side: open staging for `version`, expecting `expected_ops`
    /// packets. Latest-wins: an unswapped older staging is abandoned.
    pub fn begin(&self, version: u64, expected_ops: usize) {
        let mut guard = self.staging.lock().unwrap();
        if let Some(old) = guard.as_ref() {
            if old.version >= version {
                return; // never regress the staging version
            }
            self.dropped_versions.fetch_add(1, Ordering::Relaxed);
        }
        // reuse the abandoned staging allocation when shapes match
        let data = match guard.take() {
            Some(old) if old.data.len() == self.num_params => old.data,
            _ => vec![0.0f32; self.num_params],
        };
        *guard = Some(Staging {
            version,
            data,
            received: BTreeSet::new(),
            expected: expected_ops.max(1),
        });
    }

    /// Publisher side: land one shard. Packets for any version other than
    /// the currently staging one are dropped (the fence); duplicated
    /// packets overwrite their own interval but never advance the fence.
    pub fn recv(&self, pkt: &ShardPacket) {
        let mut guard = self.staging.lock().unwrap();
        let Some(staging) = guard.as_mut() else { return };
        if staging.version != pkt.version {
            return;
        }
        apply_packet(&mut staging.data, pkt);
        staging.received.insert(pkt.op.start);
    }

    /// Generator side, called at a sequence boundary: if a complete staged
    /// version is waiting, promote it to front (one pointer exchange) and
    /// return it. Incomplete staging never swaps — that is the version
    /// fence.
    pub fn swap_at_boundary(&self) -> Option<Arc<VersionedParams>> {
        let t0 = Instant::now();
        let mut guard = self.staging.lock().unwrap();
        let ready = matches!(guard.as_ref(), Some(s) if s.received.len() >= s.expected);
        if !ready {
            return None;
        }
        let staging = guard.take().unwrap();
        let snap = Arc::new(VersionedParams::new(staging.version, staging.data));
        *self.front.write().unwrap() = snap.clone();
        drop(guard);
        self.swaps.fetch_add(1, Ordering::Relaxed);
        self.stall_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Some(snap)
    }

    /// Completed swaps so far.
    pub fn swaps(&self) -> u64 {
        self.swaps.load(Ordering::Relaxed)
    }

    /// Staged versions abandoned because a newer publish arrived first.
    pub fn dropped_versions(&self) -> u64 {
        self.dropped_versions.load(Ordering::Relaxed)
    }

    /// Total generator-side stall spent in `swap_at_boundary` calls that
    /// actually promoted a version — the whole cost a publish imposes on
    /// the decode loop in overlapped mode (no-op boundary polls are not
    /// counted; they cost one uncontended lock acquire).
    pub fn stall_secs(&self) -> f64 {
        self.stall_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Mean stall per completed swap.
    pub fn mean_stall_secs(&self) -> f64 {
        let n = self.swaps();
        if n == 0 {
            0.0
        } else {
            self.stall_secs() / n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::weightsync::plan::TransferOp;
    use crate::weightsync::transfer::{encode_shard, ShardEncoding};

    fn op(start: usize, len: usize) -> TransferOp {
        TransferOp {
            src: 0,
            dst: 0,
            start,
            len,
        }
    }

    #[test]
    fn incomplete_staging_never_swaps() {
        let slot = GeneratorSlot::new(Arc::new(VersionedParams::new(0, vec![0.0; 8])));
        let next = vec![1.0f32; 8];
        slot.begin(1, 2);
        slot.recv(&encode_shard(&next, 1, op(0, 4), ShardEncoding::F32));
        assert!(slot.swap_at_boundary().is_none(), "fence must hold");
        assert_eq!(slot.front_version(), 0);
        slot.recv(&encode_shard(&next, 1, op(4, 4), ShardEncoding::F32));
        let snap = slot.swap_at_boundary().expect("complete staging swaps");
        assert_eq!(snap.version, 1);
        assert_eq!(*snap.data, next);
        assert_eq!(slot.front_version(), 1);
        // nothing left to swap
        assert!(slot.swap_at_boundary().is_none());
        assert_eq!(slot.swaps(), 1);
    }

    #[test]
    fn stale_packets_are_dropped() {
        let slot = GeneratorSlot::new(Arc::new(VersionedParams::new(0, vec![0.0; 4])));
        let v1 = vec![1.0f32; 4];
        let v2 = vec![2.0f32; 4];
        slot.begin(1, 1);
        // version 2 overtakes before v1's packet lands
        slot.begin(2, 1);
        slot.recv(&encode_shard(&v1, 1, op(0, 4), ShardEncoding::F32)); // stale, dropped
        assert!(slot.swap_at_boundary().is_none());
        slot.recv(&encode_shard(&v2, 2, op(0, 4), ShardEncoding::F32));
        let snap = slot.swap_at_boundary().unwrap();
        assert_eq!(snap.version, 2);
        assert_eq!(*snap.data, v2);
        assert_eq!(slot.dropped_versions(), 1);
    }

    #[test]
    fn duplicate_packets_cannot_open_the_fence() {
        // Regression: the fence counts DISTINCT ops (by start offset), so a
        // duplicated packet plus a missing one must not promote a torn
        // buffer.
        let slot = GeneratorSlot::new(Arc::new(VersionedParams::new(0, vec![0.0; 8])));
        let next = vec![1.0f32; 8];
        slot.begin(1, 2);
        let first = encode_shard(&next, 1, op(0, 4), ShardEncoding::F32);
        slot.recv(&first);
        slot.recv(&first); // duplicate of op 0; op 1 still missing
        assert!(slot.swap_at_boundary().is_none(), "fence opened on duplicate");
        slot.recv(&encode_shard(&next, 1, op(4, 4), ShardEncoding::F32));
        assert_eq!(slot.swap_at_boundary().unwrap().version, 1);
    }

    #[test]
    fn begin_never_regresses() {
        let slot = GeneratorSlot::new(Arc::new(VersionedParams::new(0, vec![0.0; 4])));
        slot.begin(3, 1);
        slot.begin(2, 1); // ignored
        let v3 = vec![3.0f32; 4];
        slot.recv(&encode_shard(&v3, 3, op(0, 4), ShardEncoding::F32));
        assert_eq!(slot.swap_at_boundary().unwrap().version, 3);
    }
}
